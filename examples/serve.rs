//! Serving demo, artifact-first: train through the unified `Model` API,
//! save a self-describing `HCKM` artifact, reload it (as `hck serve
//! --model` would in another process), expose it over the TCP JSON
//! protocol, and drive it with a client — no retraining anywhere on the
//! serving path.
//!
//! Run: `cargo run --release --example serve`

use hck::error::Result;
use hck::coordinator::{serve_tcp, BatchPolicy, PredictionService};
use hck::data::{spec_by_name, synthetic};
use hck::kernels::Gaussian;
use hck::learn::{EngineSpec, TrainConfig};
use hck::model::{fit, load_any, Model, ModelSpec};
use hck::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn main() -> Result<()> {
    let spec = spec_by_name("ijcnn1").unwrap();
    let (train, test) = synthetic::generate(spec, 3000, 200, 5);
    println!("training hierarchical model on {} (n={})...", train.name, train.n());
    let mspec = ModelSpec::krr(
        TrainConfig::new(Gaussian::new(0.4), EngineSpec::Hierarchical { rank: 96 }).with_seed(2),
    );
    let model: Box<dyn Model> = fit(&mspec, &train)?;

    // Persist + reload: the server side only ever sees the artifact.
    let path = std::env::temp_dir().join("serve_demo.hckm");
    let path = path.to_string_lossy().into_owned();
    model.save(&path)?;
    drop(model);
    let loaded = load_any(&path)?;
    std::fs::remove_file(&path).ok();
    println!("serving artifact: {}", loaded.schema().summary());

    let svc = Arc::new(PredictionService::start_model(
        Arc::from(loaded),
        BatchPolicy { max_batch: 32, max_wait: std::time::Duration::from_millis(1) },
    ));
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    println!("serving on {addr}");
    let svc2 = svc.clone();
    let server = std::thread::spawn(move || serve_tcp(listener, svc2));

    // Drive it like an external client: line-delimited JSON over TCP.
    let mut correct = 0usize;
    let n_queries = 100;
    {
        let mut conn = TcpStream::connect(addr)?;
        let mut reader = BufReader::new(conn.try_clone()?);
        for i in 0..n_queries {
            let req = Json::obj(vec![("features", Json::from_f64s(test.x.row(i)))]);
            conn.write_all(format!("{}\n", req.encode()).as_bytes())?;
            let mut line = String::new();
            reader.read_line(&mut line)?;
            let resp = Json::parse(line.trim()).map_err(hck::error::Error::Data)?;
            let pred = resp.get("prediction").unwrap().to_f64s().unwrap()[0];
            let label = if pred >= 0.0 { 1.0 } else { -1.0 };
            if label == test.y[i] {
                correct += 1;
            }
        }
        // Ask for server-side metrics, then stop the server.
        conn.write_all(b"{\"cmd\": \"metrics\"}\n")?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        println!("server metrics: {}", line.trim());
        conn.write_all(b"{\"cmd\": \"shutdown\"}\n")?;
        line.clear();
        reader.read_line(&mut line)?;
    }
    let conns = server.join().unwrap()?;
    println!(
        "client saw {correct}/{n_queries} correct over {conns} connection(s) — accuracy {:.2}",
        correct as f64 / n_queries as f64
    );
    Ok(())
}
