//! Gaussian-process extension (paper §6): O(nr²) log-marginal likelihood
//! through the fast solver's log-determinant, a bandwidth sweep, MLE by
//! golden-section search, and posterior uncertainty.
//!
//! Run: `cargo run --release --example gp_mle`

use hck::error::Result;
use hck::data::{spec_by_name, synthetic};
use hck::gp::{log_marginal_likelihood, mle_sigma, GpRegressor};
use hck::hkernel::{HConfig, HFactors};
use hck::kernels::Gaussian;
use hck::linalg::Mat;
use hck::util::bench::Table;

fn main() -> Result<()> {
    let spec = spec_by_name("cadata").unwrap();
    let (train, test) = synthetic::generate(spec, 2000, 300, 11);
    let r = 64;
    let lambda = 0.05;
    let mut base = HConfig::new(Gaussian::new(1.0), r).with_seed(5);
    base.n0 = r;

    // ---- Likelihood sweep over σ (eq. 25, evaluated at O(nr²)) ----
    println!("log-marginal likelihood sweep (n = {}, r = {r}):\n", train.n());
    let mut table = Table::new(&["sigma", "log-likelihood"]);
    for &sigma in &[0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0] {
        let mut cfg = base.clone();
        cfg.kind = cfg.kind.with_sigma(sigma);
        let f = HFactors::build(&train.x, cfg)?;
        let ll = log_marginal_likelihood(&f, lambda, &train.y)?;
        table.row(&[format!("{sigma:.2}"), format!("{ll:.1}")]);
    }
    table.print();

    // ---- MLE ----
    let (sigma_star, ll_star) = mle_sigma(&train.x, &train.y, &base, lambda, 0.03, 5.0, 0.05)?;
    println!("\nMLE bandwidth σ* = {sigma_star:.3} (log-likelihood {ll_star:.1})");

    // ---- Posterior prediction with uncertainty ----
    let mut cfg = base.clone();
    cfg.kind = cfg.kind.with_sigma(sigma_star);
    let gp = GpRegressor::fit(&train.x, &train.y, cfg, lambda)?;
    let q = test.x.row_range(0, 5);
    let mean = gp.mean(&q);
    let var = gp.variance(&q)?;
    println!("\nposterior at 5 test points (mean ± 2σ vs target):");
    for i in 0..5 {
        println!(
            "  {:>8.3} ± {:>6.3}   target {:>8.3}",
            mean[i],
            2.0 * var[i].sqrt(),
            test.y[i]
        );
    }
    // A point far outside the data should carry near-prior uncertainty.
    let far = Mat::from_vec(1, train.d(), vec![25.0; train.d()]);
    let vfar = gp.variance(&far)?;
    println!("\nvariance far from data: {:.3} (prior = 1.0)", vfar[0]);
    Ok(())
}
