//! Kernel PCA embedding comparison (paper §5.6, Figure 8): how well each
//! approximate kernel's 3-dimensional embedding aligns with the exact
//! kernel's, as r grows.
//!
//! Run: `cargo run --release --example kpca_embed`

use hck::error::Result;
use hck::approx::{FourierFeatures, NystromFeatures};
use hck::data::{spec_by_name, synthetic};
use hck::hkernel::{HConfig, HFactors};
use hck::kernels::{kernel_block, Gaussian};
use hck::learn::kpca::{
    alignment_difference, embed_from_kernel_matrix, kpca_embed_dense, kpca_embed_features,
    kpca_embed_hierarchical,
};
use hck::util::bench::Table;
use hck::util::rng::Rng;

fn main() -> Result<()> {
    let spec = spec_by_name("cadata").unwrap();
    let (train, _) = synthetic::generate(spec, 1200, 100, 3);
    let x = &train.x;
    let kind = Gaussian::new(0.5);
    let dim = 3;

    println!("exact-kernel kPCA embedding (n = {}, dim = {dim})...", x.rows());
    let u_exact = kpca_embed_dense(kind, x, dim)?;

    let mut table = Table::new(&["r", "nystrom", "fourier", "independent", "hierarchical"]);
    for &r in &[16usize, 64, 256] {
        let mut rng = Rng::new(100 + r as u64);
        // Nyström.
        let nys = {
            let feat = NystromFeatures::fit(kind, x, r, &mut rng)?;
            let u = kpca_embed_features(&feat.transform(x), dim)?;
            alignment_difference(&u_exact, &u)?
        };
        // Fourier.
        let fou = {
            let feat = FourierFeatures::sample(kind, x.cols(), r, &mut rng)?;
            let u = kpca_embed_features(&feat.transform(x), dim)?;
            alignment_difference(&u_exact, &u)?
        };
        // Independent: block-diagonal kernel matrix (dense at this scale).
        let ind = {
            let mut cfg = HConfig::new(kind, r).with_seed(7 + r as u64);
            cfg.n0 = r;
            let f = HFactors::build(x, cfg)?;
            let mut k = hck::linalg::Mat::zeros(x.rows(), x.rows());
            // Keep only leaf-diagonal blocks of the exact kernel.
            let kfull = kernel_block(kind, &f.rows_to_tree_order(x));
            for &leaf in &f.tree.leaves() {
                let nd = &f.tree.nodes[leaf];
                for a in nd.lo..nd.hi {
                    for b in nd.lo..nd.hi {
                        k[(a, b)] = kfull[(a, b)];
                    }
                }
            }
            let u_tree = embed_from_kernel_matrix(&k, dim)?;
            let u = f.rows_from_tree_order(&u_tree);
            alignment_difference(&u_exact, &u)?
        };
        // Hierarchical (Lanczos on the O(nr) matvec — no densification).
        let hier = {
            let mut cfg = HConfig::new(kind, r).with_seed(7 + r as u64);
            cfg.n0 = r;
            let f = HFactors::build(x, cfg)?;
            let u = kpca_embed_hierarchical(&f, dim, 60, &mut rng)?;
            alignment_difference(&u_exact, &u)?
        };
        table.row(&[
            r.to_string(),
            format!("{nys:.4}"),
            format!("{fou:.4}"),
            format!("{ind:.4}"),
            format!("{hier:.4}"),
        ]);
    }
    println!("\nalignment difference ‖U − ŨM‖_F / ‖U‖_F (lower = better):\n");
    table.print();
    println!(
        "\n(Paper Figure 8: the hierarchical kernel generally attains the\n \
         smallest alignment difference at a given r.)"
    );
    Ok(())
}
