//! End-to-end driver — proves every layer composes on a real workload:
//!
//!  1. generate a SUSY-like binary task (the paper's largest set, scaled);
//!  2. build the hierarchical factors with kernel blocks evaluated by the
//!     **AOT-compiled XLA artifacts through PJRT** (L1 Pallas kernel
//!     lowered inside the L2 JAX graph, loaded by the L3 Rust runtime) —
//!     falling back to native evaluation if `make artifacts` hasn't run;
//!  3. factor + solve with the O(nr²) solver, evaluate accuracy;
//!  4. train the three baselines for the comparison table;
//!  5. stand up the serving coordinator and fire concurrent batched
//!     requests, reporting throughput and latency percentiles.
//!
//! The output of this run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use hck::error::Result;
use hck::coordinator::{BatchPolicy, PredictionService};
use hck::data::{spec_by_name, synthetic};
use hck::hkernel::{HConfig, HFactors, HPredictor, HSolver};
use hck::kernels::{Gaussian, NativeEvaluator};
use hck::learn::{EngineSpec, KrrModel, TrainConfig};
use hck::linalg::Mat;
use hck::model::Model;
use hck::partition::PartitionTree;
use hck::runtime::{PjrtBlockEvaluator, PjrtEngine};
use hck::util::bench::Table;
use hck::util::rng::Rng;
use hck::util::timer::Timer;
use std::sync::Arc;

const N_TRAIN: usize = 20_000;
const N_TEST: usize = 4_000;
const RANK: usize = 128;
const SIGMA: f64 = 0.5;
const LAMBDA: f64 = 0.01;

fn main() -> Result<()> {
    println!("=== hck end-to-end driver ===\n");

    // ---- 1. Data ----
    let spec = spec_by_name("SUSY").unwrap();
    let (train, test) = synthetic::generate(spec, N_TRAIN, N_TEST, 2026);
    println!(
        "data: SUSY-like, {} train / {} test, d = {} (paper: 4M/1M on POWER8)",
        train.n(),
        test.n(),
        train.d()
    );

    // ---- 2. Hierarchical factors through the PJRT runtime ----
    let engine = PjrtEngine::load_default().ok().map(Arc::new);
    let mut hcfg = HConfig::new(Gaussian::new(SIGMA), RANK).with_seed(1);
    hcfg.n0 = RANK;
    let mut rng = Rng::new(hcfg.seed);
    let t = Timer::start();
    let tree = PartitionTree::build(&train.x, hcfg.n0, hcfg.rule, &mut rng);
    let t_partition = t.secs();
    let t = Timer::start();
    let factors = match &engine {
        Some(eng) => {
            println!(
                "kernel blocks: AOT XLA artifacts via PJRT ({} artifacts, platform {})",
                eng.artifacts().len(),
                eng.platform()
            );
            let eval = PjrtBlockEvaluator::new(eng.clone());
            HFactors::build_on_tree(&train.x, hcfg, tree, &mut rng, &eval)?
        }
        None => {
            println!("kernel blocks: native evaluator (run `make artifacts` for the PJRT path)");
            HFactors::build_on_tree(&train.x, hcfg, tree, &mut rng, &NativeEvaluator)?
        }
    };
    let t_instantiate = t.secs();
    if let Some(eng) = &engine {
        let stats = eng.stats.lock().unwrap().clone();
        println!(
            "PJRT: {} tiles executed, {} executables compiled",
            stats.tiles_executed, stats.compiles
        );
    }

    let t = Timer::start();
    let solver = HSolver::factor(&factors, LAMBDA)?;
    let t_factor = t.secs();
    let y = train.target_matrix();
    let t = Timer::start();
    let w = solver.solve_mat_original(&y);
    let t_solve = t.secs();
    println!(
        "train: partition {t_partition:.2}s + instantiate {t_instantiate:.2}s + factor {t_factor:.2}s + solve {t_solve:.2}s"
    );
    println!(
        "memory: {:.1} MB of factors (≈{:.1} × n·r words; paper model ≈ 4nr)",
        factors.memory_words() as f64 * 8e-6,
        factors.memory_words() as f64 / (train.n() * RANK) as f64
    );
    println!("log det(K + λI) = {:.1} (GP-MLE extension, §6)", solver.logdet());

    let factors = Arc::new(factors);
    let predictor = HPredictor::new(factors.clone(), &w);
    let t = Timer::start();
    let preds = predictor.predict_batch(&test.x);
    let t_test = t.secs();
    let (acc, _) = hck::learn::metrics::score(&test, &preds);
    println!(
        "hierarchical (r={RANK}): accuracy {acc:.4}, {:.1} µs/query\n",
        t_test * 1e6 / test.n() as f64
    );

    // ---- 3/4. Baseline comparison table ----
    println!("--- engine comparison (same σ={SIGMA}, λ={LAMBDA}, r={RANK}) ---");
    let mut table = Table::new(&["engine", "metric(acc)", "train (s)", "memory (MB)"]);
    table.row(&[
        "hierarchical".into(),
        format!("{acc:.4}"),
        format!("{:.2}", t_partition + t_instantiate + t_factor + t_solve),
        format!("{:.1}", factors.memory_words() as f64 * 8e-6),
    ]);
    for engine_spec in [
        EngineSpec::Nystrom { rank: RANK },
        EngineSpec::Fourier { rank: RANK },
        EngineSpec::Independent { n0: RANK },
    ] {
        let cfg = TrainConfig::new(Gaussian::new(SIGMA), engine_spec)
            .with_lambda(LAMBDA)
            .with_seed(1);
        let t = Timer::start();
        let model = KrrModel::fit_dataset(&cfg, &train)?;
        let secs = t.secs();
        let m = model.evaluate(&test);
        table.row(&[
            engine_spec.name().into(),
            format!("{m:.4}"),
            format!("{secs:.2}"),
            format!("{:.1}", model.memory_words as f64 * 8e-6),
        ]);
    }
    table.print();

    // ---- 5. Serving (artifact-first: save → load_any → serve; the
    // serving process never retrains) ----
    println!("\n--- serving coordinator (dynamic batching, HCKM artifact) ---");
    let mspec = hck::model::ModelSpec::krr(
        TrainConfig::new(Gaussian::new(SIGMA), EngineSpec::Hierarchical { rank: RANK })
            .with_lambda(LAMBDA)
            .with_seed(1),
    );
    let model: Box<dyn Model> = hck::model::fit(&mspec, &train)?;
    let artifact = std::env::temp_dir().join("end_to_end.hckm");
    let artifact = artifact.to_string_lossy().into_owned();
    model.save(&artifact)?;
    drop(model);
    let t = Timer::start();
    let loaded = hck::model::load_any(&artifact)?;
    println!(
        "artifact: {} reloaded in {:.3}s ({})",
        artifact,
        t.secs(),
        loaded.schema().summary()
    );
    std::fs::remove_file(&artifact).ok();
    let svc = Arc::new(PredictionService::start_model(
        Arc::from(loaded),
        BatchPolicy { max_batch: 128, max_wait: std::time::Duration::from_millis(2) },
    ));
    let clients = 8;
    let per_client = 500;
    let t = Timer::start();
    let mut handles = Vec::new();
    for c in 0..clients {
        let svc = svc.clone();
        let queries: Vec<Vec<f64>> = (0..per_client)
            .map(|i| test.x.row((c * per_client + i) % test.n()).to_vec())
            .collect();
        handles.push(std::thread::spawn(move || {
            for q in queries {
                let _ = svc.predict(q).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t.secs();
    let snap = svc.metrics.snapshot();
    println!(
        "{} requests from {clients} concurrent clients in {wall:.2}s",
        snap.requests
    );
    println!(
        "throughput {:.0} req/s | batch size mean {:.1} | latency p50 {:.0} µs, p95 {:.0} µs, p99 {:.0} µs",
        snap.requests as f64 / wall,
        snap.mean_batch_size,
        snap.p50_us,
        snap.p95_us,
        snap.p99_us
    );
    println!("\n=== end-to-end complete ===");

    // Sanity for CI-style usage: the run must actually have learned.
    assert!(acc > 0.6, "accuracy {acc} too low — regression in the pipeline");
    let _ = Mat::zeros(1, 1);
    Ok(())
}
