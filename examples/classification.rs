//! Multiclass classification on the covtype analogue — the workload where
//! the paper's full-rank local kernels shine (slow kernel eigendecay).
//!
//! Trains all four approximate kernels at two ranks and prints the
//! accuracy table; expect hierarchical/independent to beat the low-rank
//! kernels at small r, mirroring the paper's Figures 5–6 covtype rows.
//!
//! Run: `cargo run --release --example classification`

use hck::error::Result;
use hck::data::{spec_by_name, synthetic};
use hck::kernels::Gaussian;
use hck::learn::{EngineSpec, KrrModel, TrainConfig};
use hck::util::bench::Table;
use hck::util::timer::Timer;

fn main() -> Result<()> {
    let spec = spec_by_name("covtype").unwrap();
    let (train, test) = synthetic::generate(spec, 4000, 1000, 7);
    println!(
        "data: {} — {} train / {} test, d = {}, {} classes (one-vs-all)\n",
        train.name,
        train.n(),
        test.n(),
        train.d(),
        match train.task {
            hck::data::Task::Multiclass(k) => k,
            _ => unreachable!(),
        }
    );

    let sigma = 0.3;
    let lambda = 0.01;
    let mut table = Table::new(&["engine", "r", "accuracy", "train (s)", "memory (MB)"]);
    for &r in &[32usize, 128] {
        let engines = [
            EngineSpec::Hierarchical { rank: r },
            EngineSpec::Independent { n0: r },
            EngineSpec::Nystrom { rank: r },
            EngineSpec::Fourier { rank: r },
        ];
        for engine in engines {
            let cfg = TrainConfig::new(Gaussian::new(sigma), engine)
                .with_lambda(lambda)
                .with_seed(3);
            let t = Timer::start();
            let model = KrrModel::fit_dataset(&cfg, &train)?;
            let secs = t.secs();
            let acc = model.evaluate(&test);
            table.row(&[
                engine.name().to_string(),
                r.to_string(),
                format!("{acc:.4}"),
                format!("{secs:.2}"),
                format!("{:.1}", model.memory_words as f64 * 8e-6),
            ]);
        }
    }
    table.print();
    println!(
        "\n(The paper's covtype finding: at small r the full-rank local kernels\n \
         — independent, hierarchical — clearly beat the low-rank ones.)"
    );
    Ok(())
}
