//! Quickstart: train the hierarchically compositional kernel on a small
//! synthetic regression problem and compare it with the exact kernel.
//!
//! Run: `cargo run --release --example quickstart`

use hck::error::Result;
use hck::data::{spec_by_name, synthetic};
use hck::kernels::Gaussian;
use hck::learn::{EngineSpec, KrrModel, TrainConfig};

fn main() -> Result<()> {
    // 1. Data: a cadata-like regression set (8 attributes in [0,1]).
    let spec = spec_by_name("cadata").unwrap();
    let (train, test) = synthetic::generate(spec, 2000, 500, 42);
    println!("data: {} — {} train / {} test, d = {}", train.name, train.n(), test.n(), train.d());

    // 2. Train the paper's kernel: rank r = 128 per tree level
    //    (n0 = r by the size rule, eq. 22), Gaussian base kernel.
    let cfg = TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 128 })
        .with_lambda(0.01)
        .with_seed(1);
    let model = KrrModel::fit_dataset(&cfg, &train)?;
    let err = model.evaluate(&test);
    println!(
        "hierarchical (r=128): relative error {err:.4}  [train {}]",
        model.phases.summary()
    );

    // 3. Reference: the exact dense kernel (O(n^3) — fine at n=2000).
    let exact = KrrModel::fit_dataset(
        &TrainConfig::new(Gaussian::new(0.5), EngineSpec::Exact).with_lambda(0.01),
        &train,
    )?;
    println!("exact dense:          relative error {:.4}", exact.evaluate(&test));

    // 4. Out-of-sample prediction for a single new point (Algorithm 3
    //    under the hood — O(r² log(n/r)) per query).
    let pred = model.predict(&test.x.row_range(0, 1));
    println!("first test point: predicted {:.4}, target {:.4}", pred[(0, 0)], test.y[0]);
    Ok(())
}
