//! Quickstart: the unified `Model` API — fit the hierarchically
//! compositional kernel through one `ModelSpec`, compare with the exact
//! kernel, and round-trip the fitted model through a self-describing
//! `HCKM` artifact.
//!
//! Run: `cargo run --release --example quickstart`

use hck::error::Result;
use hck::data::{spec_by_name, synthetic};
use hck::kernels::Gaussian;
use hck::learn::{metrics, EngineSpec, TrainConfig};
use hck::model::{fit, load_any, Model, ModelSpec};

fn main() -> Result<()> {
    // 1. Data: a cadata-like regression set (8 attributes in [0,1]).
    let spec = spec_by_name("cadata").unwrap();
    let (train, test) = synthetic::generate(spec, 2000, 500, 42);
    println!("data: {} — {} train / {} test, d = {}", train.name, train.n(), test.n(), train.d());

    // 2. Train the paper's kernel through the unified surface: one
    //    ModelSpec covers every engine (and GP/KPCA — see `hck train`).
    let mspec = ModelSpec::krr(
        TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 128 })
            .with_lambda(0.01)
            .with_seed(1),
    );
    let model: Box<dyn Model> = fit(&mspec, &train)?;
    let preds = model.predict_batch(&test.x);
    let (err, _) = metrics::score(&test, &preds);
    println!("hierarchical (r=128): relative error {err:.4}  [{}]", model.schema().summary());

    // 3. Reference: the exact dense kernel (O(n^3) — fine at n=2000),
    //    through the same spec type.
    let exact = fit(
        &ModelSpec::krr(TrainConfig::new(Gaussian::new(0.5), EngineSpec::Exact).with_lambda(0.01)),
        &train,
    )?;
    let (exact_err, _) = metrics::score(&test, &exact.predict_batch(&test.x));
    println!("exact dense:          relative error {exact_err:.4}");

    // 4. Save a self-describing artifact and reload it without knowing
    //    the kind — predictions are identical (`hck serve --model` runs
    //    on exactly this path, no retraining).
    let path = std::env::temp_dir().join("quickstart.hckm");
    let path = path.to_string_lossy();
    model.save(&path)?;
    let loaded = load_any(&path)?;
    println!("reloaded artifact: {}", loaded.schema().summary());
    let p0 = loaded.predict_batch(&test.x.row_range(0, 1));
    println!("first test point: predicted {:.4}, target {:.4}", p0[(0, 0)], test.y[0]);
    std::fs::remove_file(path.as_ref()).ok();
    Ok(())
}
