//! Deterministic pseudo-random number generation.
//!
//! The library is fully offline and dependency-light, so we implement our
//! own PRNG stack: SplitMix64 for seeding and Xoshiro256** as the workhorse
//! generator (the same design used by `rand_xoshiro`). All randomized
//! components of the paper — landmark sampling, random-projection
//! partitioning, random Fourier frequencies, synthetic data — draw from
//! this module so experiments are exactly reproducible from a `u64` seed.

/// SplitMix64: used to expand a single `u64` seed into the Xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** pseudo-random generator.
///
/// Passes BigCrush; period 2^256 - 1. Not cryptographic — exactly what we
/// want for reproducible scientific experiments.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child generator (for per-node / per-worker
    /// streams). Deterministic in (self state, tag).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mixed = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(mixed)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (with caching of the spare variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with mean/stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Standard Cauchy variate (used to sample random Fourier frequencies
    /// for the Laplace kernel, whose spectral density is a product of
    /// Cauchy densities).
    #[inline]
    pub fn cauchy(&mut self) -> f64 {
        // Inverse CDF: tan(pi * (u - 1/2)).
        (std::f64::consts::PI * (self.f64() - 0.5)).tan()
    }

    /// Exponential variate with rate 1.
    #[inline]
    pub fn exponential(&mut self) -> f64 {
        -(1.0 - self.f64()).ln()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, data: &mut [T]) {
        let n = data.len();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            data.swap(i, j);
        }
    }

    /// A uniformly random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from 0..n uniformly (k <= n).
    ///
    /// Uses a partial Fisher–Yates over an index vector when k is a large
    /// fraction of n and Floyd's algorithm otherwise.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        if k * 3 >= n {
            let mut p: Vec<usize> = (0..n).collect();
            // Partial shuffle: only the first k positions need to be final.
            for i in 0..k {
                let j = i + self.below(n - i);
                p.swap(i, j);
            }
            p.truncate(k);
            p
        } else {
            // Floyd's algorithm: O(k) expected when k << n.
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below(j + 1);
                if chosen.insert(t) {
                    out.push(t);
                } else {
                    chosen.insert(j);
                    out.push(j);
                }
            }
            out
        }
    }

    /// Fill a slice with standard normal samples.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Fill a slice with U[lo,hi) samples.
    pub fn fill_uniform(&mut self, out: &mut [f64], lo: f64, hi: f64) {
        for v in out.iter_mut() {
            *v = self.uniform(lo, hi);
        }
    }

    /// A random unit vector of dimension `d` (direction for random
    /// projection partitioning, Section 4.1 of the paper).
    pub fn unit_vector(&mut self, d: usize) -> Vec<f64> {
        let mut v = vec![0.0; d];
        loop {
            self.fill_normal(&mut v);
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-12 {
                for x in v.iter_mut() {
                    *x /= norm;
                }
                return v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let n = 10;
        let mut counts = vec![0usize; n];
        let trials = 100_000;
        for _ in 0..trials {
            counts[r.below(n)] += 1;
        }
        let expected = trials as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expected).abs() < 5.0 * expected.sqrt());
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(10, 10), (100, 7), (50, 40), (1, 1), (1000, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(100);
        let mut q = p.clone();
        q.sort_unstable();
        assert_eq!(q, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn unit_vector_has_unit_norm() {
        let mut r = Rng::new(13);
        for d in [1, 2, 5, 100] {
            let v = r.unit_vector(d);
            let n: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn cauchy_median_near_zero() {
        let mut r = Rng::new(17);
        let mut xs: Vec<f64> = (0..10_001).map(|_| r.cauchy()).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(xs[5000].abs() < 0.1);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(21);
        let mut c1 = base.fork(1);
        let mut c2 = base.fork(2);
        let same = (0..16).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
