//! Minimal command-line argument parser (clap is not in the offline crate
//! set). Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with typed accessors and generated usage text.

use std::collections::BTreeMap;

/// Declarative specification of one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments: options plus positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        spec: &[OptSpec],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        // Seed defaults.
        for s in spec {
            if let Some(d) = s.default {
                out.opts.insert(s.name.to_string(), d.to_string());
            }
        }
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let known = spec.iter().find(|s| s.name == name);
                match known {
                    Some(s) if s.is_flag => {
                        if inline_val.is_some() {
                            return Err(format!("--{name} is a flag, takes no value"));
                        }
                        out.flags.push(name);
                    }
                    Some(_) => {
                        let val = match inline_val {
                            Some(v) => v,
                            None => it
                                .next()
                                .ok_or_else(|| format!("--{name} requires a value"))?,
                        };
                        out.opts.insert(name, val);
                    }
                    None => return Err(format!("unknown option --{name}")),
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// String value of an option (default applied at parse time).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Required string value.
    pub fn req(&self, name: &str) -> Result<&str, String> {
        self.get(name).ok_or_else(|| format!("missing required --{name}"))
    }

    /// Typed accessor: usize.
    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.req(name)?
            .parse()
            .map_err(|_| format!("--{name} must be a non-negative integer"))
    }

    /// Typed accessor: u64.
    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.req(name)?
            .parse()
            .map_err(|_| format!("--{name} must be a non-negative integer"))
    }

    /// Typed accessor: f64.
    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.req(name)?
            .parse()
            .map_err(|_| format!("--{name} must be a number"))
    }

    /// Comma-separated list of f64.
    pub fn f64_list(&self, name: &str) -> Result<Vec<f64>, String> {
        self.req(name)?
            .split(',')
            .map(|t| {
                t.trim()
                    .parse()
                    .map_err(|_| format!("--{name}: '{t}' is not a number"))
            })
            .collect()
    }

    /// Comma-separated list of usize.
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, String> {
        self.req(name)?
            .split(',')
            .map(|t| {
                t.trim()
                    .parse()
                    .map_err(|_| format!("--{name}: '{t}' is not an integer"))
            })
            .collect()
    }

    /// Whether a boolean flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, spec: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for o in spec {
        let kind = if o.is_flag { "" } else { " <value>" };
        let def = o
            .default
            .map(|d| format!(" (default: {d})"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{kind}\n      {}{def}\n", o.name, o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "n", help: "count", default: Some("10"), is_flag: false },
            OptSpec { name: "sigma", help: "bandwidth", default: None, is_flag: false },
            OptSpec { name: "verbose", help: "chatty", default: None, is_flag: true },
        ]
    }

    fn parse(toks: &[&str]) -> Result<Args, String> {
        Args::parse(toks.iter().map(|s| s.to_string()), &spec())
    }

    #[test]
    fn defaults_applied() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.usize("n").unwrap(), 10);
        assert!(a.get("sigma").is_none());
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--n", "5", "--sigma=2.5"]).unwrap();
        assert_eq!(a.usize("n").unwrap(), 5);
        assert_eq!(a.f64("sigma").unwrap(), 2.5);
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["--verbose", "input.txt", "out.txt"]).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["input.txt".to_string(), "out.txt".to_string()]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&["--bogus", "1"]).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parse(&["--verbose=yes"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["--sigma"]).is_err());
    }

    #[test]
    fn lists() {
        let a = parse(&["--sigma", "1,2.5, 3"]).unwrap();
        assert_eq!(a.f64_list("sigma").unwrap(), vec![1.0, 2.5, 3.0]);
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("train", "train a model", &spec());
        assert!(u.contains("--sigma"));
        assert!(u.contains("default: 10"));
    }
}
