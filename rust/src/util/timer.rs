//! Lightweight wall-clock timing helpers used throughout training,
//! benchmarking and the coordinator's metrics.

use std::time::Instant;

/// A simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since start.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Elapsed microseconds since start.
    pub fn micros(&self) -> f64 {
        self.secs() * 1e6
    }

    /// Reset the timer and return the elapsed seconds up to the reset.
    pub fn lap(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Time a closure, returning (result, elapsed seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

/// Accumulates named timing breakdowns (e.g. the phases of training:
/// partition / instantiate / factor / predict), for reporting.
#[derive(Debug, Default, Clone)]
pub struct Phases {
    entries: Vec<(String, f64)>,
}

impl Phases {
    /// New empty breakdown.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record (accumulate) `secs` under `name`.
    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == name) {
            e.1 += secs;
        } else {
            self.entries.push((name.to_string(), secs));
        }
    }

    /// Run and time a closure under `name`.
    pub fn scope<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, s) = timed(f);
        self.add(name, s);
        out
    }

    /// Seconds recorded under `name` (0.0 if absent).
    pub fn get(&self, name: &str) -> f64 {
        self.entries.iter().find(|e| e.0 == name).map_or(0.0, |e| e.1)
    }

    /// Total seconds across all phases.
    pub fn total(&self) -> f64 {
        self.entries.iter().map(|e| e.1).sum()
    }

    /// All (name, secs) entries in insertion order.
    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }

    /// Human-readable one-line summary.
    pub fn summary(&self) -> String {
        let mut parts: Vec<String> = self
            .entries
            .iter()
            .map(|(k, v)| format!("{k}={:.3}s", v))
            .collect();
        parts.push(format!("total={:.3}s", self.total()));
        parts.join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn phases_accumulate() {
        let mut p = Phases::new();
        p.add("a", 1.0);
        p.add("a", 0.5);
        p.add("b", 2.0);
        assert!((p.get("a") - 1.5).abs() < 1e-12);
        assert!((p.total() - 3.5).abs() < 1e-12);
        assert_eq!(p.entries().len(), 2);
        assert!(p.summary().contains("a=1.500s"));
    }

    #[test]
    fn scope_records_and_returns() {
        let mut p = Phases::new();
        let v = p.scope("work", || 7);
        assert_eq!(v, 7);
        assert!(p.get("work") >= 0.0);
    }
}
