//! Shared substrates: PRNG, JSON, CLI argument parsing, timing, benchmark
//! harness, and the scoped-thread parallel executor. These are hand-rolled
//! because the build is fully offline and the vendored crate set is minimal.

pub mod args;
pub mod bench;
pub mod json;
pub mod parallel;
pub mod rng;
pub mod timer;
