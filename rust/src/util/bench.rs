//! Micro-benchmark harness (criterion is not in the offline crate set).
//!
//! Provides warmup + repeated timing with robust statistics (median, MAD,
//! mean, min), throughput helpers, and aligned table output used by every
//! `rust/benches/*.rs` target (all declared `harness = false`).

use super::timer::Timer;

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration wall times in seconds, sorted ascending.
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        percentile_sorted(&self.samples, 50.0)
    }
    pub fn min(&self) -> f64 {
        self.samples.first().copied().unwrap_or(f64::NAN)
    }
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }
    /// Median absolute deviation (robust spread).
    pub fn mad(&self) -> f64 {
        let med = self.median();
        let mut devs: Vec<f64> = self.samples.iter().map(|s| (s - med).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_sorted(&devs, 50.0)
    }
    pub fn p95(&self) -> f64 {
        percentile_sorted(&self.samples, 95.0)
    }
}

/// Percentile of a sorted sample (linear interpolation).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Cap on total measurement wall time in seconds; once exceeded,
    /// measurement stops early (at least one sample is always taken).
    pub max_secs: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 2, measure_iters: 7, max_secs: 30.0 }
    }
}

impl Bench {
    /// Quick preset for cheap closures.
    pub fn quick() -> Self {
        Bench { warmup_iters: 3, measure_iters: 15, max_secs: 10.0 }
    }

    /// Preset for expensive end-to-end runs.
    pub fn heavy() -> Self {
        Bench { warmup_iters: 1, measure_iters: 3, max_secs: 120.0 }
    }

    /// Run a closure repeatedly and collect timing samples. The closure's
    /// return value is passed through `std::hint::black_box` so the work
    /// cannot be optimized away.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let budget = Timer::start();
        let mut samples = Vec::with_capacity(self.measure_iters);
        for i in 0..self.measure_iters {
            let t = Timer::start();
            std::hint::black_box(f());
            samples.push(t.secs());
            if i + 1 < self.measure_iters && budget.secs() > self.max_secs {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Measurement { name: name.to_string(), samples }
    }
}

/// GFLOP/s achieved by `flops` floating-point operations in `secs`
/// seconds — the BLAS-3 benchmark currency (`2·m·n·k` for gemm, `m²·k`
/// for the triangle-only syrk).
pub fn gflops(flops: f64, secs: f64) -> f64 {
    if secs > 0.0 && secs.is_finite() {
        flops / secs / 1e9
    } else {
        f64::NAN
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        "n/a".to_string()
    } else if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// A simple fixed-width table printer for benchmark reports.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .take(ncol)
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Machine-readable benchmark emission.
///
/// Collects flat row objects and writes one JSON document
/// `{"bench": ..., "rows": [...]}` — the format every CI perf artifact
/// (`BENCH_*.json`) uses, so successive PRs have a comparable perf
/// trajectory. Encoding goes through [`crate::util::json::Json`], whose
/// BTreeMap-backed objects serialize deterministically.
#[derive(Debug)]
pub struct BenchJson {
    name: String,
    rows: Vec<crate::util::json::Json>,
}

impl BenchJson {
    /// Start a report for the named benchmark.
    pub fn new(name: &str) -> BenchJson {
        BenchJson { name: name.to_string(), rows: Vec::new() }
    }

    /// Append one measurement row from key/value pairs.
    pub fn row(&mut self, pairs: Vec<(&str, crate::util::json::Json)>) {
        self.rows.push(crate::util::json::Json::obj(pairs));
    }

    /// Number of rows collected so far.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether no rows have been collected.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Serialize the report to a compact JSON string.
    pub fn encode(&self) -> String {
        use crate::util::json::Json;
        Json::obj(vec![
            ("bench", Json::Str(self.name.clone())),
            ("rows", Json::Arr(self.rows.clone())),
        ])
        .encode()
    }

    /// Write the report to a file (one JSON document + trailing newline).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        let mut text = self.encode();
        text.push('\n');
        std::fs::write(path, text)
    }
}

/// Mean and (population) standard deviation of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_stats() {
        let m = Measurement { name: "t".into(), samples: vec![1.0, 2.0, 3.0, 4.0, 100.0] };
        assert_eq!(m.median(), 3.0);
        assert_eq!(m.min(), 1.0);
        assert!((m.mean() - 22.0).abs() < 1e-12);
        assert_eq!(m.mad(), 1.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 50.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
        assert!(percentile_sorted(&[], 50.0).is_nan());
    }

    #[test]
    fn bench_runs_and_counts() {
        let b = Bench { warmup_iters: 1, measure_iters: 5, max_secs: 10.0 };
        let mut count = 0usize;
        let m = b.run("inc", || {
            count += 1;
            count
        });
        assert_eq!(m.samples.len(), 5);
        assert_eq!(count, 6); // 1 warmup + 5 measured
        assert!(m.min() >= 0.0);
    }

    #[test]
    fn gflops_basic() {
        assert!((gflops(2e9, 1.0) - 2.0).abs() < 1e-12);
        assert!((gflops(1e9, 0.5) - 2.0).abs() < 1e-12);
        assert!(gflops(1e9, 0.0).is_nan());
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" µs"));
        assert!(fmt_secs(2e-9).ends_with(" ns"));
        assert_eq!(fmt_secs(f64::NAN), "n/a");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".to_string(), "1".to_string()]);
        t.row(&["longer".to_string(), "2".to_string()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].starts_with("longer"));
    }

    #[test]
    fn bench_json_roundtrip() {
        use crate::util::json::Json;
        let mut r = BenchJson::new("hotpath");
        assert!(r.is_empty());
        r.row(vec![
            ("n", Json::Num(50000.0)),
            ("threads", Json::Num(4.0)),
            ("ns_per_op", Json::Num(123.5)),
        ]);
        assert_eq!(r.len(), 1);
        let parsed = Json::parse(&r.encode()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("hotpath"));
        let rows = parsed.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("n").unwrap().as_usize(), Some(50000));
        assert_eq!(rows[0].get("ns_per_op").unwrap().as_f64(), Some(123.5));
    }

    #[test]
    fn bench_json_writes_file() {
        let mut r = BenchJson::new("t");
        r.row(vec![("k", crate::util::json::Json::Num(1.0))]);
        let path = std::env::temp_dir()
            .join(format!("hck_bench_json_{}.json", std::process::id()));
        let path = path.to_string_lossy().into_owned();
        r.write(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(text.ends_with('\n'));
        assert!(crate::util::json::Json::parse(text.trim()).is_ok());
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
    }
}
