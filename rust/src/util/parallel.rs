//! Persistent worker-pool executor for the structured-matrix hot paths.
//!
//! The offline crate set has no `rayon`, so this module provides the
//! minimal primitives the hierarchical kernel needs: run a bag of
//! independent work items across a fixed number of worker threads
//! ([`run_parallel`]) and an order-preserving parallel map
//! ([`parallel_map`]). Both degenerate to a plain sequential loop when
//! `threads <= 1` or the item count is tiny, so the single-threaded path
//! has zero overhead and is trivially deterministic.
//!
//! **Worker pool.** Workers are long-lived threads fed through
//! per-worker channels, spawned lazily on first use and reused for the
//! lifetime of the process (ROADMAP "Persistent worker pool") — a call
//! no longer pays thread spawn/join on every matvec, which matters for
//! the serving path at small batch sizes. `run_parallel` still blocks
//! until every dispatched item has completed (even when an item panics),
//! so borrowed captures behave exactly as they did under scoped threads.
//! Calls made *from inside* a pool worker — or from the calling thread
//! while it executes its own bin of an enclosing `run_parallel` — run
//! sequentially instead of re-entering the pool ([`in_parallel_region`]),
//! which makes nested use safe by construction and lets the parallel
//! BLAS entry points (`crate::linalg::blas::par_gemm` and friends) be
//! routed through mid-chain code without oversubscribing: they engage
//! threads only when they are the top of the chain.
//!
//! **Determinism policy.** Callers in `hkernel` are written so that every
//! work item computes its outputs independently (no shared accumulator)
//! and results are *applied* in a fixed sequential order afterwards.
//! Items are dealt to workers round-robin independent of scheduling, so
//! floating-point results are bitwise identical for every thread count,
//! which is what lets the test suite assert `T threads == 1 thread`
//! exactly (see `rust/tests/integration.rs`).
//!
//! The global default thread count comes from the `HCK_THREADS`
//! environment variable (clamped to >= 1), falling back to
//! `std::thread::available_parallelism()` capped at 16 — the structured
//! algebra is memory-bandwidth bound well before that.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Hard cap on the default worker count; beyond this the O(nr) kernels
/// are bandwidth-bound and extra threads only add dispatch cost.
const MAX_DEFAULT_THREADS: usize = 16;

/// Problem-size floor for the adaptive entry points: below this many
/// training points even pool dispatch costs more than the block
/// arithmetic it parallelizes, so [`auto_threads`] stays serial.
pub const AUTO_MIN_N: usize = 4096;

/// The thread count the hierarchical hot paths actually use for a
/// problem of `n` points: 1 below [`AUTO_MIN_N`], else
/// [`default_threads`]. Exposed so telemetry can record the true count.
pub fn auto_threads(n: usize) -> usize {
    if n < AUTO_MIN_N {
        1
    } else {
        default_threads()
    }
}

/// The process-wide default thread count: `HCK_THREADS` if set (>= 1),
/// otherwise `available_parallelism()` capped at 16. Cached after the
/// first call.
pub fn default_threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        if let Ok(v) = std::env::var("HCK_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_DEFAULT_THREADS)
    })
}

/// A unit of pool work. Jobs are erased to `'static`; [`run_parallel`]
/// guarantees the underlying borrows outlive execution by blocking on a
/// completion channel before returning.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Erase a borrowed job's lifetime so it can travel through the
/// process-wide pool channels.
///
/// # Safety
/// The caller must not return (or otherwise invalidate the job's
/// borrows) until the job has finished executing. [`run_parallel`]
/// enforces this with a completion channel it drains before returning on
/// every path, including unwinding ones.
unsafe fn erase_job<'a>(job: Box<dyn FnOnce() + Send + 'a>) -> Job {
    // SAFETY: the two types differ only in the lifetime bound of the
    // trait object, which has no layout effect — the fat pointer
    // (data + vtable) is identical, so the transmute itself is sound.
    // Soundness of *using* the result rests on the caller upholding the
    // contract above: the borrows behind `job` stay live until the job
    // has run.
    std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Box<dyn FnOnce() + Send + 'static>>(
        job,
    )
}

/// The process-wide pool: one channel per worker, grown on demand.
/// Workers are indexed, and [`run_parallel`] deals bin `k` to worker
/// `k`, so a given (threads, item-count) shape always lands on the same
/// threads — scheduling never reorders the work assignment.
struct Pool {
    senders: Mutex<Vec<Sender<Job>>>,
    /// One counter block per spawned worker, index-aligned with
    /// `senders`; shared with the worker thread, read by [`pool_stats`].
    stats: Mutex<Vec<Arc<WorkerStat>>>,
    /// When the first worker spawned — the denominator for busy-fraction.
    started: OnceLock<Instant>,
}

/// Lifetime counters one pool worker maintains about itself.
#[derive(Default)]
struct WorkerStat {
    tasks: AtomicU64,
    busy_ns: AtomicU64,
}

/// Per-worker counters in a [`PoolStats`] snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerCounters {
    /// Jobs this worker has executed.
    pub tasks: u64,
    /// Total nanoseconds spent executing jobs (the remainder of the
    /// worker's lifetime is idle time blocked on its queue).
    pub busy_ns: u64,
}

/// Point-in-time utilization snapshot of the persistent worker pool.
/// Workers spawn lazily, so `workers` is the high-water mark of
/// `run_parallel` fan-out so far (0 before any parallel call).
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    /// Workers spawned so far (excludes callers' inline bin-0 work).
    pub workers: usize,
    /// Total jobs executed across all workers.
    pub tasks: u64,
    /// Total busy nanoseconds across all workers.
    pub busy_ns: u64,
    /// Nanoseconds since the first worker spawned.
    pub elapsed_ns: u64,
    /// Per-worker breakdown, indexed by worker id (`hck-pool-{i}`).
    pub per_worker: Vec<WorkerCounters>,
}

impl PoolStats {
    /// Mean fraction of worker lifetime spent executing jobs, in
    /// `0..=1`. A low value under load means work is not fanning out
    /// (items too coarse, or `HCK_THREADS` higher than useful).
    pub fn busy_frac(&self) -> f64 {
        if self.workers == 0 || self.elapsed_ns == 0 {
            return 0.0;
        }
        (self.busy_ns as f64 / (self.workers as f64 * self.elapsed_ns as f64)).clamp(0.0, 1.0)
    }
}

/// Snapshot the pool's utilization counters.
pub fn pool_stats() -> PoolStats {
    let p = pool();
    let stats = p.stats.lock().unwrap();
    let per_worker: Vec<WorkerCounters> = stats
        .iter()
        .map(|s| WorkerCounters {
            // ORDERING: Relaxed loads — each counter is an independent
            // monotone statistic; the snapshot needs no cross-counter
            // consistency and tolerates mid-update tearing between them.
            tasks: s.tasks.load(Ordering::Relaxed),
            busy_ns: s.busy_ns.load(Ordering::Relaxed),
        })
        .collect();
    PoolStats {
        workers: per_worker.len(),
        tasks: per_worker.iter().map(|w| w.tasks).sum(),
        busy_ns: per_worker.iter().map(|w| w.busy_ns).sum(),
        elapsed_ns: p
            .started
            .get()
            .map(|t| t.elapsed().as_nanos() as u64)
            .unwrap_or(0),
        per_worker,
    }
}

thread_local! {
    /// Set once inside every pool worker; used to force nested
    /// `run_parallel` calls onto the sequential path (re-entering the
    /// pool from a worker could deadlock on the worker's own queue).
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };

    /// Set on the *calling* thread for the duration of a parallel
    /// `run_parallel` (it executes bin 0 inline). Nested parallel entry
    /// points — `par_gemm` inside a work item, say — would otherwise
    /// queue their jobs behind the very bins the outer call is waiting
    /// on, serializing the caller's bin against the whole level. With
    /// the flag set they take the sequential path instead, which is the
    /// "parallel variants engage only at the top of the chain" rule
    /// enforced at runtime.
    static IN_PARALLEL_REGION: Cell<bool> = const { Cell::new(false) };
}

/// Whether the current thread is already inside a parallel region (a
/// pool worker, or a caller mid-`run_parallel`). Parallel entry points
/// use this to degrade to their sequential paths instead of feeding the
/// pool recursively.
pub fn in_parallel_region() -> bool {
    IS_POOL_WORKER.with(|w| w.get()) || IN_PARALLEL_REGION.with(|r| r.get())
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        senders: Mutex::new(Vec::new()),
        stats: Mutex::new(Vec::new()),
        started: OnceLock::new(),
    })
}

impl Pool {
    /// Hand `job` to worker `idx`, spawning workers up to that index on
    /// first use. Workers never exit: their receiving channel is owned by
    /// the global pool and lives for the process.
    fn submit(&self, idx: usize, job: Job) {
        let mut senders = self.senders.lock().unwrap();
        while senders.len() <= idx {
            let id = senders.len();
            let (tx, rx) = channel::<Job>();
            let stat = Arc::new(WorkerStat::default());
            self.started.get_or_init(Instant::now);
            self.stats.lock().unwrap().push(Arc::clone(&stat));
            std::thread::Builder::new()
                .name(format!("hck-pool-{id}"))
                .spawn(move || {
                    IS_POOL_WORKER.with(|w| w.set(true));
                    while let Ok(job) = rx.recv() {
                        let t = Instant::now();
                        job();
                        // ORDERING: Relaxed — lifetime statistics read only
                        // by pool_stats snapshots; publication of the job's
                        // memory effects happens via the completion channel,
                        // not these counters.
                        stat.busy_ns
                            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        stat.tasks.fetch_add(1, Ordering::Relaxed);
                    }
                })
                .expect("spawn hck pool worker");
            senders.push(tx);
        }
        senders[idx].send(job).expect("hck pool worker died");
    }
}

/// Run `f` over every item on up to `threads` threads of the persistent
/// worker pool.
///
/// Items are dealt round-robin so neighbouring (similar-cost) items
/// spread across workers; bin 0 runs inline on the calling thread, bins
/// 1.. go to pool workers. With `threads <= 1` (or fewer than two items)
/// this is exactly a sequential `for` loop — the deterministic fallback.
///
/// `f` must be safe to call concurrently (`Sync`); each item is consumed
/// exactly once. The call returns only after every item has run, so `f`
/// may freely borrow from the caller's stack. A panic in any item is
/// re-raised here after the remaining items finish.
pub fn run_parallel<T: Send>(threads: usize, items: Vec<T>, f: impl Fn(T) + Sync) {
    let threads = threads.max(1).min(items.len());
    if threads <= 1 || in_parallel_region() {
        for item in items {
            f(item);
        }
        return;
    }
    // Mark this thread as inside a parallel region while it dispatches,
    // runs its own bin, and waits — nested parallel entry points degrade
    // to sequential for the duration (see [`in_parallel_region`]).
    struct RegionGuard(bool);
    impl Drop for RegionGuard {
        fn drop(&mut self) {
            IN_PARALLEL_REGION.with(|r| r.set(self.0));
        }
    }
    let _region = RegionGuard(IN_PARALLEL_REGION.with(|r| r.replace(true)));
    let mut bins: Vec<Vec<T>> = (0..threads).map(|_| Vec::new()).collect();
    for (k, item) in items.into_iter().enumerate() {
        bins[k % threads].push(item);
    }
    let mut bins = bins.into_iter();
    let own = bins.next().unwrap_or_default();

    let fref = &f;
    // First pool-worker panic payload, re-raised after the drain so the
    // caller sees the original assertion/message, as under scoped
    // threads.
    let worker_panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>> =
        Mutex::new(None);
    let (done_tx, done_rx) = channel::<()>();
    // Dispatch under catch_unwind so the completion wait below runs on
    // every path: an undispatched job never runs (it is dropped inside
    // the failed submit before being counted), so the count of
    // successfully dispatched jobs is exactly the number of completion
    // signals to wait for.
    let mut dispatched = 0usize;
    let dispatch_result = {
        let dispatched = &mut dispatched;
        let worker_panic = &worker_panic;
        let done_tx = &done_tx;
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            for (k, bin) in bins.enumerate() {
                let tx = done_tx.clone();
                // SAFETY: the job borrows `f` and `worker_panic` from
                // this stack frame. run_parallel blocks on `done_rx`
                // below until every dispatched job has signalled
                // completion — on panicking paths too (unwinds are
                // caught and re-raised only after the wait) — so the
                // borrows strictly outlive every execution.
                let job = unsafe {
                    erase_job(Box::new(move || {
                        let res =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                for item in bin {
                                    fref(item);
                                }
                            }));
                        if let Err(payload) = res {
                            let mut slot = worker_panic.lock().unwrap();
                            slot.get_or_insert(payload);
                        }
                        // Always signal, panic or not — the caller's
                        // completion wait keeps the captures alive.
                        let _ = tx.send(());
                    }))
                };
                pool().submit(k, job);
                *dispatched += 1;
            }
        }))
    };

    // Run bin 0 inline; contain a panic until the pool workers drain.
    let own_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        for item in own {
            fref(item);
        }
    }));
    for _ in 0..dispatched {
        let _ = done_rx.recv();
    }
    if let Err(p) = dispatch_result {
        std::panic::resume_unwind(p);
    }
    if let Err(p) = own_result {
        std::panic::resume_unwind(p);
    }
    if let Some(p) = worker_panic.lock().unwrap().take() {
        std::panic::resume_unwind(p);
    }
}

/// Order-preserving parallel map: `out[i] = f(&inputs[i])`.
///
/// The output order matches the input order regardless of scheduling, so
/// downstream sequential reductions stay deterministic.
pub fn parallel_map<I: Sync, O: Send>(
    threads: usize,
    inputs: &[I],
    f: impl Fn(&I) -> O + Sync,
) -> Vec<O> {
    let mut out: Vec<Option<O>> = (0..inputs.len()).map(|_| None).collect();
    {
        let items: Vec<(usize, &mut Option<O>)> = out.iter_mut().enumerate().collect();
        run_parallel(threads, items, |(i, slot)| {
            *slot = Some(f(&inputs[i]));
        });
    }
    out.into_iter().map(|o| o.expect("parallel_map slot unfilled")).collect()
}

/// Split `buf` into mutable sub-slices covering the half-open ranges
/// `ranges` (which must be sorted, disjoint and within bounds). Used to
/// hand each partition-tree leaf its own disjoint window of a shared
/// output vector.
pub fn disjoint_slices<'a, T>(
    mut buf: &'a mut [T],
    ranges: &[(usize, usize)],
) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut offset = 0usize;
    for &(lo, hi) in ranges {
        assert!(lo >= offset && hi >= lo, "ranges must be sorted and disjoint");
        let (_skip, rest) = buf.split_at_mut(lo - offset);
        let (mine, rest) = rest.split_at_mut(hi - lo);
        out.push(mine);
        buf = rest;
        offset = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn run_parallel_visits_every_item_once() {
        for threads in [1usize, 2, 3, 8] {
            let counter = AtomicUsize::new(0);
            let items: Vec<usize> = (0..100).collect();
            run_parallel(threads, items, |i| {
                counter.fetch_add(i + 1, Ordering::SeqCst);
            });
            // sum of 1..=100
            assert_eq!(counter.load(Ordering::SeqCst), 5050, "threads={threads}");
        }
    }

    #[test]
    fn run_parallel_empty_and_single() {
        run_parallel(4, Vec::<usize>::new(), |_| panic!("no items"));
        let hits = AtomicUsize::new(0);
        run_parallel(4, vec![7usize], |v| {
            assert_eq!(v, 7);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let inputs: Vec<usize> = (0..257).collect();
        for threads in [1usize, 2, 5] {
            let out = parallel_map(threads, &inputs, |&i| i * 3);
            assert_eq!(out.len(), 257);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * 3);
            }
        }
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        // Same closure, same inputs: thread count must not change values.
        let inputs: Vec<f64> = (0..64).map(|i| (i as f64) * 0.37 + 0.1).collect();
        let f = |x: &f64| (x.sin() * 1e3).exp().sqrt() + x.ln();
        let seq = parallel_map(1, &inputs, f);
        for threads in [2usize, 4, 16] {
            let par = parallel_map(threads, &inputs, f);
            assert_eq!(seq, par, "threads={threads}");
        }
    }

    /// The pool is persistent: a second identical call runs on exactly
    /// the same worker threads (bin k always lands on worker k), with no
    /// fresh spawns in between.
    #[test]
    fn pool_threads_are_reused_across_calls() {
        use std::collections::BTreeSet;
        use std::sync::Mutex;
        let observe = || {
            let ids: Mutex<BTreeSet<String>> = Mutex::new(BTreeSet::new());
            let items: Vec<usize> = (0..12).collect();
            run_parallel(3, items, |_| {
                let name = std::thread::current()
                    .name()
                    .unwrap_or("unnamed")
                    .to_string();
                ids.lock().unwrap().insert(name);
                // Give every bin a chance to land on its own thread.
                std::thread::sleep(std::time::Duration::from_millis(1));
            });
            ids.into_inner().unwrap()
        };
        let first = observe();
        let second = observe();
        assert_eq!(first, second, "same bins must reuse the same pool workers");
        assert!(
            first.iter().any(|n| n.starts_with("hck-pool-")),
            "expected pool workers to participate: {first:?}"
        );
    }

    /// Every work item — pool-worker bins and the caller's inline bin
    /// alike — observes [`in_parallel_region`], and the flag is restored
    /// once the call returns. This is what keeps nested `par_gemm`
    /// sequential inside level-parallel passes.
    #[test]
    fn region_flag_covers_inline_bin_and_workers() {
        let in_region = AtomicUsize::new(0);
        assert!(!in_parallel_region());
        let items: Vec<usize> = (0..8).collect();
        run_parallel(4, items, |_| {
            if in_parallel_region() {
                in_region.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(in_region.load(Ordering::SeqCst), 8);
        assert!(!in_parallel_region());
    }

    /// Nested calls from inside a pool worker degrade to sequential
    /// instead of deadlocking on the worker's own queue.
    #[test]
    fn nested_run_parallel_completes() {
        let counter = AtomicUsize::new(0);
        let outer: Vec<usize> = (0..8).collect();
        run_parallel(4, outer, |_| {
            let inner: Vec<usize> = (0..8).collect();
            run_parallel(4, inner, |_| {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    /// The ORIGINAL payload must surface (not a generic wrapper), so
    /// assertion messages from parallel work items stay diagnosable.
    #[test]
    #[should_panic(expected = "boom at item 5")]
    fn worker_panic_propagates_with_payload() {
        let items: Vec<usize> = (0..16).collect();
        run_parallel(4, items, |i| {
            // Panic only off the inline bin (bin 0 holds 0, 4, 8, 12) so
            // the propagation path under test is the pool's, not the
            // caller's own resume_unwind; item 5 sits in pool bin 1.
            if i == 5 {
                panic!("boom at item {i}");
            }
        });
    }

    /// Pool utilization counters advance when work runs through the
    /// pool, and the busy fraction stays a valid ratio.
    #[test]
    fn pool_stats_counts_work() {
        let before = pool_stats();
        let items: Vec<usize> = (0..64).collect();
        run_parallel(4, items, |_| {
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        let after = pool_stats();
        assert!(after.workers >= 3, "expected pool workers, got {}", after.workers);
        assert!(after.tasks > before.tasks, "{} !> {}", after.tasks, before.tasks);
        assert!(after.busy_ns > before.busy_ns);
        assert_eq!(after.per_worker.len(), after.workers);
        assert_eq!(after.per_worker.iter().map(|w| w.tasks).sum::<u64>(), after.tasks);
        let frac = after.busy_frac();
        assert!((0.0..=1.0).contains(&frac), "busy_frac out of range: {frac}");
    }

    #[test]
    fn disjoint_slices_windows() {
        let mut buf: Vec<i32> = (0..10).collect();
        let ranges = [(0usize, 3usize), (3, 5), (7, 10)];
        let slices = disjoint_slices(&mut buf, &ranges);
        assert_eq!(slices.len(), 3);
        assert_eq!(slices[0], &[0, 1, 2]);
        assert_eq!(slices[1], &[3, 4]);
        assert_eq!(slices[2], &[7, 8, 9]);
    }

    #[test]
    fn disjoint_slices_parallel_write() {
        let n = 1000;
        let mut buf = vec![0usize; n];
        let ranges: Vec<(usize, usize)> = (0..10).map(|k| (k * 100, (k + 1) * 100)).collect();
        {
            let slices = disjoint_slices(&mut buf, &ranges);
            let items: Vec<(usize, &mut [usize])> =
                slices.into_iter().enumerate().collect();
            run_parallel(4, items, |(k, s)| {
                for (j, v) in s.iter_mut().enumerate() {
                    *v = k * 100 + j;
                }
            });
        }
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }
}
