//! Scoped-thread parallel executor for the structured-matrix hot paths.
//!
//! The offline crate set has no `rayon`, so this module provides the
//! minimal primitives the hierarchical kernel needs: run a bag of
//! independent work items across a fixed number of scoped threads
//! ([`run_parallel`]) and an order-preserving parallel map
//! ([`parallel_map`]). Both degenerate to a plain sequential loop when
//! `threads <= 1` or the item count is tiny, so the single-threaded path
//! has zero overhead and is trivially deterministic.
//!
//! **Determinism policy.** Callers in `hkernel` are written so that every
//! work item computes its outputs independently (no shared accumulator)
//! and results are *applied* in a fixed sequential order afterwards.
//! Floating-point results are therefore bitwise identical for every
//! thread count, which is what lets the test suite assert
//! `T threads == 1 thread` exactly (see `rust/tests/integration.rs`).
//!
//! The global default thread count comes from the `HCK_THREADS`
//! environment variable (clamped to >= 1), falling back to
//! `std::thread::available_parallelism()` capped at 16 — the structured
//! algebra is memory-bandwidth bound well before that.

use std::sync::OnceLock;

/// Hard cap on the default worker count; beyond this the O(nr) kernels
/// are bandwidth-bound and extra threads only add spawn cost.
const MAX_DEFAULT_THREADS: usize = 16;

/// Problem-size floor for the adaptive entry points: below this many
/// training points the scoped-thread spawns cost more than the block
/// arithmetic they parallelize, so [`auto_threads`] stays serial.
pub const AUTO_MIN_N: usize = 4096;

/// The thread count the hierarchical hot paths actually use for a
/// problem of `n` points: 1 below [`AUTO_MIN_N`], else
/// [`default_threads`]. Exposed so telemetry can record the true count.
pub fn auto_threads(n: usize) -> usize {
    if n < AUTO_MIN_N {
        1
    } else {
        default_threads()
    }
}

/// The process-wide default thread count: `HCK_THREADS` if set (>= 1),
/// otherwise `available_parallelism()` capped at 16. Cached after the
/// first call.
pub fn default_threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        if let Ok(v) = std::env::var("HCK_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_DEFAULT_THREADS)
    })
}

/// Run `f` over every item on up to `threads` scoped threads.
///
/// Items are dealt round-robin so neighbouring (similar-cost) items
/// spread across workers. With `threads <= 1` (or fewer than two items)
/// this is exactly a sequential `for` loop — the deterministic fallback.
///
/// `f` must be safe to call concurrently (`Sync`); each item is consumed
/// exactly once.
pub fn run_parallel<T: Send>(threads: usize, items: Vec<T>, f: impl Fn(T) + Sync) {
    let threads = threads.max(1).min(items.len());
    if threads <= 1 {
        for item in items {
            f(item);
        }
        return;
    }
    let mut bins: Vec<Vec<T>> = (0..threads).map(|_| Vec::new()).collect();
    for (k, item) in items.into_iter().enumerate() {
        bins[k % threads].push(item);
    }
    let fref = &f;
    std::thread::scope(|s| {
        // Run the first bin on the current thread; spawn the rest.
        let mut bins = bins.into_iter();
        let own = bins.next().unwrap_or_default();
        for bin in bins {
            s.spawn(move || {
                for item in bin {
                    fref(item);
                }
            });
        }
        for item in own {
            fref(item);
        }
    });
}

/// Order-preserving parallel map: `out[i] = f(&inputs[i])`.
///
/// The output order matches the input order regardless of scheduling, so
/// downstream sequential reductions stay deterministic.
pub fn parallel_map<I: Sync, O: Send>(
    threads: usize,
    inputs: &[I],
    f: impl Fn(&I) -> O + Sync,
) -> Vec<O> {
    let mut out: Vec<Option<O>> = (0..inputs.len()).map(|_| None).collect();
    {
        let items: Vec<(usize, &mut Option<O>)> = out.iter_mut().enumerate().collect();
        run_parallel(threads, items, |(i, slot)| {
            *slot = Some(f(&inputs[i]));
        });
    }
    out.into_iter().map(|o| o.expect("parallel_map slot unfilled")).collect()
}

/// Split `buf` into mutable sub-slices covering the half-open ranges
/// `ranges` (which must be sorted, disjoint and within bounds). Used to
/// hand each partition-tree leaf its own disjoint window of a shared
/// output vector.
pub fn disjoint_slices<'a, T>(
    mut buf: &'a mut [T],
    ranges: &[(usize, usize)],
) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut offset = 0usize;
    for &(lo, hi) in ranges {
        assert!(lo >= offset && hi >= lo, "ranges must be sorted and disjoint");
        let (_skip, rest) = buf.split_at_mut(lo - offset);
        let (mine, rest) = rest.split_at_mut(hi - lo);
        out.push(mine);
        buf = rest;
        offset = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn run_parallel_visits_every_item_once() {
        for threads in [1usize, 2, 3, 8] {
            let counter = AtomicUsize::new(0);
            let items: Vec<usize> = (0..100).collect();
            run_parallel(threads, items, |i| {
                counter.fetch_add(i + 1, Ordering::SeqCst);
            });
            // sum of 1..=100
            assert_eq!(counter.load(Ordering::SeqCst), 5050, "threads={threads}");
        }
    }

    #[test]
    fn run_parallel_empty_and_single() {
        run_parallel(4, Vec::<usize>::new(), |_| panic!("no items"));
        let hits = AtomicUsize::new(0);
        run_parallel(4, vec![7usize], |v| {
            assert_eq!(v, 7);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let inputs: Vec<usize> = (0..257).collect();
        for threads in [1usize, 2, 5] {
            let out = parallel_map(threads, &inputs, |&i| i * 3);
            assert_eq!(out.len(), 257);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * 3);
            }
        }
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        // Same closure, same inputs: thread count must not change values.
        let inputs: Vec<f64> = (0..64).map(|i| (i as f64) * 0.37 + 0.1).collect();
        let f = |x: &f64| (x.sin() * 1e3).exp().sqrt() + x.ln();
        let seq = parallel_map(1, &inputs, f);
        for threads in [2usize, 4, 16] {
            let par = parallel_map(threads, &inputs, f);
            assert_eq!(seq, par, "threads={threads}");
        }
    }

    #[test]
    fn disjoint_slices_windows() {
        let mut buf: Vec<i32> = (0..10).collect();
        let ranges = [(0usize, 3usize), (3, 5), (7, 10)];
        let slices = disjoint_slices(&mut buf, &ranges);
        assert_eq!(slices.len(), 3);
        assert_eq!(slices[0], &[0, 1, 2]);
        assert_eq!(slices[1], &[3, 4]);
        assert_eq!(slices[2], &[7, 8, 9]);
    }

    #[test]
    fn disjoint_slices_parallel_write() {
        let n = 1000;
        let mut buf = vec![0usize; n];
        let ranges: Vec<(usize, usize)> = (0..10).map(|k| (k * 100, (k + 1) * 100)).collect();
        {
            let slices = disjoint_slices(&mut buf, &ranges);
            let items: Vec<(usize, &mut [usize])> =
                slices.into_iter().enumerate().collect();
            run_parallel(4, items, |(k, s)| {
                for (j, v) in s.iter_mut().enumerate() {
                    *v = k * 100 + j;
                }
            });
        }
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }
}
