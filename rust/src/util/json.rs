//! Minimal JSON encoder/decoder.
//!
//! Used for (a) the AOT artifact manifest written by `python/compile/aot.py`,
//! (b) the coordinator's TCP wire protocol, and (c) structured bench output.
//! Hand-rolled because `serde`/`serde_json` are not in the offline crate set.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept in a BTreeMap for deterministic
/// serialization (stable across runs, diff-friendly logs).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Construct an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Access an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As usize if numeric and integral.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// As &str if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convert a `&[f64]` to a JSON array.
    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Extract a `Vec<f64>` from a JSON array of numbers.
    pub fn to_f64s(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Serialize to a compact string.
    pub fn encode(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    // JSON has no Inf/NaN; encode as null like most tools.
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns the value and errors on trailing junk.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected character at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        loop {
            match self.peek() {
                Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') => {
                    self.i += 1;
                }
                _ => break,
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("invalid number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let enc = v.encode();
            assert_eq!(Json::parse(&enc).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let enc = v.encode();
        assert_eq!(Json::parse(&enc).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_trailing_junk() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn numbers_roundtrip() {
        let v = Json::parse("[1e-3, 2.5E2, -0.125, 123456789]").unwrap();
        let xs = v.to_f64s().unwrap();
        assert_eq!(xs, vec![1e-3, 250.0, -0.125, 123456789.0]);
    }

    #[test]
    fn integral_encoding_has_no_fraction() {
        assert_eq!(Json::Num(5.0).encode(), "5");
        assert_eq!(Json::Num(5.5).encode(), "5.5");
    }

    #[test]
    fn obj_builder_and_accessors() {
        let v = Json::obj(vec![
            ("n", Json::Num(3.0)),
            ("name", Json::Str("hck".into())),
            ("flag", Json::Bool(true)),
        ]);
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("name").unwrap().as_str(), Some("hck"));
        assert_eq!(v.get("flag").unwrap().as_bool(), Some(true));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn f64s_helpers() {
        let xs = [1.0, -2.0, 0.5];
        let v = Json::from_f64s(&xs);
        assert_eq!(v.to_f64s().unwrap(), xs.to_vec());
    }
}
