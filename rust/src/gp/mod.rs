//! Gaussian-process view of the hierarchical kernel (paper §1.1 and the
//! §6 future-work extension): posterior prediction, the Gaussian
//! log-marginal likelihood eq. (25) at O(nr²) via the fast solver's
//! log-determinant, and maximum-likelihood bandwidth estimation.

use crate::error::Result;
use crate::hkernel::{HConfig, HFactors, HPredictor, HSolver, HVariance};
use crate::linalg::Mat;

/// Gaussian log-marginal likelihood (eq. 25):
/// L = −½ yᵀ(K+λI)^{-1}y − ½ log det(K+λI) − (n/2) log 2π,
/// where K is the hierarchical kernel matrix described by `f` and λ is the
/// noise variance. O(nr²) — the paper's §6 notes this as the scalable
/// alternative to the O(n³) dense evaluation.
pub fn log_marginal_likelihood(f: &HFactors, lambda: f64, y: &[f64]) -> Result<f64> {
    let n = f.n() as f64;
    let solver = HSolver::factor(f, lambda)?;
    let yt = f.to_tree_order(y);
    let alpha = solver.solve(&yt);
    let quad: f64 = yt.iter().zip(alpha.iter()).map(|(a, b)| a * b).sum();
    Ok(-0.5 * quad - 0.5 * solver.logdet() - 0.5 * n * (2.0 * std::f64::consts::PI).ln())
}

/// Fitted GP regressor on the hierarchical kernel.
pub struct GpRegressor {
    factors: std::sync::Arc<HFactors>,
    lambda: f64,
    /// α = (K + λI)^{-1} y in tree order.
    alpha_tree: Vec<f64>,
    /// Log marginal likelihood of the training data.
    pub log_likelihood: f64,
}

impl GpRegressor {
    /// Fit the GP: factor once, solve for α, record the likelihood.
    pub fn fit(x: &Mat, y: &[f64], config: HConfig, lambda: f64) -> Result<GpRegressor> {
        let factors = std::sync::Arc::new(HFactors::build(x, config)?);
        let solver = HSolver::factor(&factors, lambda)?;
        let yt = factors.to_tree_order(y);
        let alpha_tree = solver.solve(&yt);
        let quad: f64 = yt.iter().zip(alpha_tree.iter()).map(|(a, b)| a * b).sum();
        let n = factors.n() as f64;
        let log_likelihood =
            -0.5 * quad - 0.5 * solver.logdet() - 0.5 * n * (2.0 * std::f64::consts::PI).ln();
        Ok(GpRegressor { factors, lambda, alpha_tree, log_likelihood })
    }

    /// Posterior mean at query points (eq. 3 with the hierarchical kernel).
    pub fn mean(&self, q: &Mat) -> Vec<f64> {
        let alpha_orig = self.factors.from_tree_order(&self.alpha_tree);
        let w = Mat::from_vec(self.factors.n(), 1, alpha_orig);
        let pred = HPredictor::new(self.factors.clone(), &w);
        (0..q.rows()).map(|i| pred.predict(q.row(i))[0]).collect()
    }

    /// Posterior variance at query points (eq. 4):
    /// k(x,x) − k(X,x)ᵀ (K+λI)^{-1} k(X,x). O(n·r) per query (one column
    /// materialization + one solve application) after an O(nr²) factor.
    ///
    /// One-shot convenience over [`GpRegressor::variance_state`]: it
    /// refactors the solver every call. Serving paths should build the
    /// [`HVariance`] state once and call
    /// [`HVariance::variance_batch`] per request (the
    /// [`crate::model::FittedGp`] wrapper caches it).
    pub fn variance(&self, q: &Mat) -> Result<Vec<f64>> {
        Ok(self.variance_state()?.variance_batch(q))
    }

    /// Build the long-lived batched variance state (factored solver +
    /// aggregate column bases) for this posterior.
    pub fn variance_state(&self) -> Result<HVariance> {
        HVariance::new(self.factors.clone(), self.lambda)
    }

    /// The underlying factors.
    pub fn factors(&self) -> &HFactors {
        &self.factors
    }

    /// The noise variance λ the posterior was fitted with.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// α = (K + λI)^{-1} y in **original order** (the weight column the
    /// posterior-mean predictor evaluates against).
    pub fn alpha_original(&self) -> Vec<f64> {
        self.factors.from_tree_order(&self.alpha_tree)
    }

    /// Internal view for [`crate::model`] persistence:
    /// (factors, λ, α in tree order, log-likelihood).
    pub(crate) fn parts(&self) -> (&std::sync::Arc<HFactors>, f64, &[f64], f64) {
        (&self.factors, self.lambda, &self.alpha_tree, self.log_likelihood)
    }

    /// Reassemble from persisted parts without re-solving.
    pub(crate) fn from_parts(
        factors: std::sync::Arc<HFactors>,
        lambda: f64,
        alpha_tree: Vec<f64>,
        log_likelihood: f64,
    ) -> Result<GpRegressor> {
        if alpha_tree.len() != factors.n() {
            return Err(crate::error::Error::data(
                "gp artifact: coefficient length does not match training size",
            ));
        }
        Ok(GpRegressor { factors, lambda, alpha_tree, log_likelihood })
    }
}

/// Sample realizations of the zero-mean Gaussian process prior with
/// covariance `K_hierarchical + λI` at the training sites — the
/// "simulation of random processes" application of §6 (the paper points
/// to Chen 2014a's square-root factorization; here we use the Krylov
/// square root: z = K^{1/2} u ≈ ‖u‖ Q T^{1/2} e₁ from `steps` Lanczos
/// iterations on the O(nr) matvec, exact as steps → n and accurate to
/// ~1e-6 after a few dozen steps for kernel spectra).
///
/// Returns an (n x n_samples) matrix in **original order**.
pub fn sample_prior(
    f: &HFactors,
    lambda: f64,
    n_samples: usize,
    steps: usize,
    rng: &mut crate::util::rng::Rng,
) -> Result<Mat> {
    use crate::linalg::{matmul, sym_eig, Trans};
    let n = f.n();
    let mut out = Mat::zeros(n, n_samples);
    for s in 0..n_samples {
        // Start vector u ~ N(0, I).
        let mut u = vec![0.0; n];
        rng.fill_normal(&mut u);
        let unorm = u.iter().map(|x| x * x).sum::<f64>().sqrt();
        // Lanczos on A = K + λI with start u/‖u‖.
        let m = steps.min(n).max(2);
        let mut qs: Vec<Vec<f64>> = Vec::with_capacity(m);
        let mut alphas = Vec::with_capacity(m);
        let mut betas = Vec::with_capacity(m);
        let mut q: Vec<f64> = u.iter().map(|x| x / unorm).collect();
        qs.push(q.clone());
        for j in 0..m {
            let mut w = crate::hkernel::hmatvec(f, &qs[j]);
            for (wi, qi) in w.iter_mut().zip(qs[j].iter()) {
                *wi += lambda * qi;
            }
            let alpha: f64 = w.iter().zip(qs[j].iter()).map(|(a, b)| a * b).sum();
            alphas.push(alpha);
            for (wi, qi) in w.iter_mut().zip(qs[j].iter()) {
                *wi -= alpha * qi;
            }
            if j > 0 {
                let beta_prev: f64 = betas[j - 1];
                for (wi, qi) in w.iter_mut().zip(qs[j - 1].iter()) {
                    *wi -= beta_prev * qi;
                }
            }
            // Full reorthogonalization (keeps T faithful at small m).
            for qv in &qs {
                let c: f64 = w.iter().zip(qv.iter()).map(|(a, b)| a * b).sum();
                for (wi, qi) in w.iter_mut().zip(qv.iter()) {
                    *wi -= c * qi;
                }
            }
            let beta = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            betas.push(beta);
            if j + 1 == m || beta < 1e-12 {
                break;
            }
            for x in w.iter_mut() {
                *x /= beta;
            }
            qs.push(w.clone());
            q = w;
        }
        let _ = q;
        // T^{1/2} e1 via dense eig of the small tridiagonal.
        let k = qs.len();
        let mut t = Mat::zeros(k, k);
        for i in 0..k {
            t[(i, i)] = alphas[i];
            if i + 1 < k {
                t[(i, i + 1)] = betas[i];
                t[(i + 1, i)] = betas[i];
            }
        }
        let (w_eig, v_eig) = sym_eig(&t)?;
        // sqrt(T) e1 = V sqrt(Λ) Vᵀ e1.
        let vte1: Vec<f64> = (0..k).map(|i| v_eig[(0, i)]).collect();
        let scaled: Vec<f64> =
            vte1.iter().zip(w_eig.iter()).map(|(v, l)| v * l.max(0.0).sqrt()).collect();
        let mut coeff = Mat::zeros(k, 1);
        for i in 0..k {
            coeff[(i, 0)] = scaled[i];
        }
        let coeff = matmul(&v_eig, Trans::No, &coeff, Trans::No);
        // z = ‖u‖ Q (coeff)
        let mut z = vec![0.0; n];
        for (j, qv) in qs.iter().enumerate() {
            let c = unorm * coeff[(j, 0)];
            for (zi, qi) in z.iter_mut().zip(qv.iter()) {
                *zi += c * qi;
            }
        }
        out.set_col(s, &f.from_tree_order(&z));
    }
    Ok(out)
}

/// Maximum-likelihood bandwidth estimation: golden-section search of
/// eq. (25) over σ ∈ [lo, hi] (log-scale), rebuilding the factors at each
/// evaluation. Returns (σ*, L(σ*)). This is the §6 "more principled
/// approach" to parameter selection.
pub fn mle_sigma(
    x: &Mat,
    y: &[f64],
    base: &HConfig,
    lambda: f64,
    lo: f64,
    hi: f64,
    tol: f64,
) -> Result<(f64, f64)> {
    assert!(lo > 0.0 && hi > lo);
    let ll = |sigma: f64| -> Result<f64> {
        let mut cfg = base.clone();
        cfg.kind = cfg.kind.with_sigma(sigma);
        let f = HFactors::build(x, cfg)?;
        log_marginal_likelihood(&f, lambda, y)
    };
    // Golden-section on log σ.
    let phi = (5f64.sqrt() - 1.0) / 2.0;
    let (mut a, mut b) = (lo.ln(), hi.ln());
    let mut c = b - phi * (b - a);
    let mut d = a + phi * (b - a);
    let mut fc = ll(c.exp())?;
    let mut fd = ll(d.exp())?;
    while (b - a).abs() > tol {
        if fc > fd {
            b = d;
            d = c;
            fd = fc;
            c = b - phi * (b - a);
            fc = ll(c.exp())?;
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + phi * (b - a);
            fd = ll(d.exp())?;
        }
    }
    let s = (0.5 * (a + b)).exp();
    let l = ll(s)?;
    Ok((s, l))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hkernel::densify::densify;
    use crate::kernels::Gaussian;
    use crate::linalg::Cholesky;
    use crate::util::rng::Rng;

    fn toy(n: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform(0.0, 1.0));
        let y: Vec<f64> = (0..n)
            .map(|i| (5.0 * x[(i, 0)]).sin() + 0.05 * rng.normal())
            .collect();
        (x, y)
    }

    fn hcfg(r: usize, seed: u64) -> HConfig {
        let mut cfg = HConfig::new(Gaussian::new(0.4), r).with_seed(seed);
        cfg.n0 = r;
        cfg.lambda_prime = 0.0;
        cfg
    }

    #[test]
    fn likelihood_matches_dense() {
        let (x, y) = toy(50, 1);
        let f = HFactors::build(&x, hcfg(8, 2)).unwrap();
        let lambda = 0.1;
        let got = log_marginal_likelihood(&f, lambda, &y).unwrap();
        // Dense reference.
        let mut k = densify(&f);
        k.add_diag(lambda);
        let chol = Cholesky::new_jittered(&k, 5).unwrap();
        let yt = f.to_tree_order(&y);
        let alpha = chol.solve(&yt);
        let quad: f64 = yt.iter().zip(alpha.iter()).map(|(a, b)| a * b).sum();
        let want = -0.5 * quad
            - 0.5 * chol.logdet()
            - 0.5 * 50.0 * (2.0 * std::f64::consts::PI).ln();
        assert!((got - want).abs() < 1e-7 * (1.0 + want.abs()), "{got} vs {want}");
    }

    #[test]
    fn posterior_mean_matches_krr() {
        let (x, y) = toy(60, 3);
        let gp = GpRegressor::fit(&x, &y, hcfg(10, 4), 0.05).unwrap();
        // Posterior mean at training points should fit the data decently.
        let mean = gp.mean(&x);
        let rel = crate::learn::metrics::relative_error(&mean, &y);
        assert!(rel < 0.3, "train rel err {rel}");
    }

    #[test]
    fn variance_nonnegative_and_shrinks_at_training_points() {
        let (x, y) = toy(40, 5);
        let gp = GpRegressor::fit(&x, &y, hcfg(8, 6), 0.01).unwrap();
        let var_train = gp.variance(&x.row_range(0, 5)).unwrap();
        let mut rng = Rng::new(9);
        let far = Mat::from_fn(5, 2, |_, _| 3.0 + rng.uniform(0.0, 1.0));
        let var_far = gp.variance(&far).unwrap();
        for v in &var_train {
            assert!(*v >= 0.0 && *v < 0.2, "train var {v}");
        }
        // Far from data the prior variance (≈1) should remain.
        for v in &var_far {
            assert!(*v > 0.5, "far var {v}");
        }
    }

    #[test]
    fn mle_recovers_reasonable_bandwidth() {
        let (x, y) = toy(80, 7);
        let base = hcfg(12, 8);
        let (sigma, ll) = mle_sigma(&x, &y, &base, 0.05, 0.02, 5.0, 0.15).unwrap();
        assert!(sigma > 0.02 && sigma < 5.0);
        assert!(ll.is_finite());
        // The optimum should beat the endpoints.
        let ll_lo = {
            let mut cfg = base.clone();
            cfg.kind = cfg.kind.with_sigma(0.02);
            log_marginal_likelihood(&HFactors::build(&x, cfg).unwrap(), 0.05, &y).unwrap()
        };
        let ll_hi = {
            let mut cfg = base.clone();
            cfg.kind = cfg.kind.with_sigma(5.0);
            log_marginal_likelihood(&HFactors::build(&x, cfg).unwrap(), 0.05, &y).unwrap()
        };
        assert!(ll >= ll_lo - 1e-6 && ll >= ll_hi - 1e-6, "{ll} vs [{ll_lo}, {ll_hi}]");
    }

    #[test]
    fn prior_samples_have_the_right_covariance() {
        // With steps = n the Krylov square root is exact: the empirical
        // second moment over many samples must converge to K + λI.
        let (x, _) = toy(30, 20);
        let f = HFactors::build(&x, hcfg(6, 21)).unwrap();
        let lambda = 0.3;
        let mut rng = Rng::new(4);
        let n_samples = 4000;
        let z = sample_prior(&f, lambda, n_samples, 30, &mut rng).unwrap();
        // Empirical covariance (original order).
        let mut emp = crate::linalg::Mat::zeros(30, 30);
        crate::linalg::gemm(
            1.0 / n_samples as f64,
            &z,
            crate::linalg::Trans::No,
            &z,
            crate::linalg::Trans::Yes,
            0.0,
            &mut emp,
        );
        let mut want = crate::hkernel::densify::densify_original_order(&f);
        want.add_diag(lambda);
        let mut diff = emp.clone();
        diff.axpy(-1.0, &want);
        // Monte-Carlo error ~ 1/sqrt(4000) ≈ 0.016 per entry.
        let rel = diff.fro_norm() / want.fro_norm();
        assert!(rel < 0.1, "empirical covariance off by {rel}");
        // And samples are not degenerate.
        let var0: f64 = (0..n_samples).map(|s| z[(0, s)] * z[(0, s)]).sum::<f64>()
            / n_samples as f64;
        assert!((var0 - want[(0, 0)]).abs() < 0.15, "var {var0} vs {}", want[(0, 0)]);
    }

    #[test]
    fn column_dot_w_matches_predictor() {
        let (x, _) = toy(36, 10);
        let f = std::sync::Arc::new(HFactors::build(&x, hcfg(6, 11)).unwrap());
        let mut rng = Rng::new(12);
        let w = Mat::from_fn(36, 1, |_, _| rng.normal());
        let pred = HPredictor::new(f.clone(), &w);
        let wt = f.rows_to_tree_order(&w);
        for _ in 0..5 {
            let q: Vec<f64> = (0..2).map(|_| rng.uniform(0.0, 1.0)).collect();
            let col = HPredictor::column(&f, &q);
            let dot: f64 = col.iter().enumerate().map(|(i, v)| v * wt[(i, 0)]).sum();
            let z = pred.predict(&q)[0];
            assert!((dot - z).abs() < 1e-9, "{dot} vs {z}");
        }
    }
}
