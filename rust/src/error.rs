//! Unified error type for the `hck` library.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror` in the offline
//! crate set — see util/mod.rs on the zero-dependency policy).

/// Library-wide error enum.
#[derive(Debug)]
pub enum Error {
    /// A matrix operation received incompatible or invalid dimensions.
    Dim(String),

    /// A factorization (Cholesky/LU/eigen) failed, typically because the
    /// matrix is numerically singular or indefinite.
    Linalg(String),

    /// Invalid configuration or hyper-parameter.
    Config(String),

    /// Data loading / parsing problem.
    Data(String),

    /// PJRT runtime problem (artifact missing, compile/execute failure).
    Runtime(String),

    /// Coordinator / serving problem.
    Serve(String),

    /// I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Dim(m) => write!(f, "dimension mismatch: {m}"),
            Error::Linalg(m) => write!(f, "linear algebra failure: {m}"),
            Error::Config(m) => write!(f, "invalid configuration: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Serve(m) => write!(f, "serving error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper to construct a dimension error.
    pub fn dim(msg: impl Into<String>) -> Self {
        Error::Dim(msg.into())
    }
    /// Helper to construct a linear-algebra error.
    pub fn linalg(msg: impl Into<String>) -> Self {
        Error::Linalg(msg.into())
    }
    /// Helper to construct a configuration error.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Helper to construct a data error.
    pub fn data(msg: impl Into<String>) -> Self {
        Error::Data(msg.into())
    }
    /// Helper to construct a runtime error.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    /// Helper to construct a serving error.
    pub fn serve(msg: impl Into<String>) -> Self {
        Error::Serve(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_and_message() {
        assert_eq!(Error::dim("2x3 vs 4x5").to_string(), "dimension mismatch: 2x3 vs 4x5");
        assert_eq!(Error::linalg("pivot").to_string(), "linear algebra failure: pivot");
        assert_eq!(Error::serve("down").to_string(), "serving error: down");
    }

    #[test]
    fn io_conversion_preserves_source() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "missing");
        let e: Error = io.into();
        assert!(e.to_string().contains("missing"));
        use std::error::Error as _;
        assert!(e.source().is_some());
    }
}
