//! Unified error type for the `hck` library.

use thiserror::Error;

/// Library-wide error enum.
#[derive(Error, Debug)]
pub enum Error {
    /// A matrix operation received incompatible or invalid dimensions.
    #[error("dimension mismatch: {0}")]
    Dim(String),

    /// A factorization (Cholesky/LU/eigen) failed, typically because the
    /// matrix is numerically singular or indefinite.
    #[error("linear algebra failure: {0}")]
    Linalg(String),

    /// Invalid configuration or hyper-parameter.
    #[error("invalid configuration: {0}")]
    Config(String),

    /// Data loading / parsing problem.
    #[error("data error: {0}")]
    Data(String),

    /// PJRT runtime problem (artifact missing, compile/execute failure).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator / serving problem.
    #[error("serving error: {0}")]
    Serve(String),

    /// I/O error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper to construct a dimension error.
    pub fn dim(msg: impl Into<String>) -> Self {
        Error::Dim(msg.into())
    }
    /// Helper to construct a linear-algebra error.
    pub fn linalg(msg: impl Into<String>) -> Self {
        Error::Linalg(msg.into())
    }
    /// Helper to construct a configuration error.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Helper to construct a data error.
    pub fn data(msg: impl Into<String>) -> Self {
        Error::Data(msg.into())
    }
    /// Helper to construct a runtime error.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    /// Helper to construct a serving error.
    pub fn serve(msg: impl Into<String>) -> Self {
        Error::Serve(msg.into())
    }
}
