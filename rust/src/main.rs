//! `hck` — command-line entry point for the hierarchically compositional
//! kernel library.
//!
//! The CLI is **artifact-first**: training produces a self-describing
//! `HCKM` model file, and every downstream command consumes artifacts —
//! nothing retrains in-process.
//!
//! Subcommands:
//!   info       artifact + data set inventory (--model shows an HCKM header)
//!   data-gen   emit a synthetic Table-1 analogue as LIBSVM text
//!   train      fit any model (krr/gp/kpca), report metric, --save artifact
//!   predict    load an HCKM artifact and predict a LIBSVM file
//!   shard      cut an HCKM artifact into a self-contained shard directory
//!   shard-worker serve shards from a directory over the HCKW wire (one
//!              process per host; `hck serve --workers` fans out to them)
//!   serve      serve an HCKM artifact or a shard directory over TCP
//!   likelihood GP log-marginal likelihood / MLE bandwidth search
//!
//! Observability: every subcommand honors `HCK_TRACE=out.json` (and
//! `train`/`serve` take `--trace out.json`) to record a Chrome-trace of
//! the run — open it in Perfetto or chrome://tracing. See
//! [`hck::obs`].
//!
//! Typical pipeline:
//!   hck train --dataset cadata --r 128 --save m.hckm
//!   hck shard --model m.hckm --out shards/ --shards 8
//!   hck serve --shard-dir shards/ --port 7878

use hck::error::{Error, Result};
use hck::coordinator::{serve_tcp, BatchPolicy, PredictionService};
use hck::data::{self, Dataset};
use hck::infer::{PredictRequest, Want};
use hck::kernels::KernelKind;
use hck::learn::{EngineSpec, TrainConfig};
use hck::model::{self, Model, ModelKind, ModelSpec};
use hck::partition::SplitRule;
use hck::util::args::{usage, Args, OptSpec};
use hck::util::json::Json;
use hck::util::timer::{Phases, Timer};
use std::sync::Arc;

/// `anyhow!`-style constructor for CLI errors (the offline crate set has
/// no `anyhow`; hck's own error type carries the message instead).
macro_rules! anyhow {
    ($($arg:tt)*) => {
        Error::config(format!($($arg)*))
    };
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(argv: Vec<String>) -> Result<()> {
    hck::obs::init_from_env();
    let Some(cmd) = argv.first().cloned() else {
        print_help();
        return Ok(());
    };
    let rest = argv[1..].to_vec();
    let result = match cmd.as_str() {
        "info" => cmd_info(rest),
        "data-gen" => cmd_data_gen(rest),
        "train" => cmd_train(rest),
        "predict" => cmd_predict(rest),
        "shard" => cmd_shard(rest),
        "shard-worker" => cmd_shard_worker(rest),
        "serve" => cmd_serve(rest),
        "likelihood" => cmd_likelihood(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand '{other}' (try 'hck help')")),
    };
    flush_trace();
    result
}

/// Write the Chrome-trace file when tracing was enabled (`HCK_TRACE` or
/// `--trace`); a failed write warns instead of masking the command's
/// own result.
fn flush_trace() {
    match hck::obs::flush() {
        Ok(Some(path)) => {
            eprintln!("trace written to {path} (open in Perfetto or chrome://tracing)")
        }
        Ok(None) => {}
        Err(e) => eprintln!("warning: failed to write trace: {e}"),
    }
}

fn print_help() {
    println!(
        "hck — Hierarchically Compositional Kernels (Chen, Avron, Sindhwani 2016)\n\
         \n\
         usage: hck <subcommand> [options]\n\
         \n\
         subcommands:\n\
           info        show artifact inventory and Table-1 data set specs\n\
           data-gen    generate a synthetic data set (LIBSVM format)\n\
           train       fit a model (krr/gp/kpca) and save an HCKM artifact\n\
           predict     load an HCKM artifact and predict a LIBSVM file\n\
           shard       cut an HCKM artifact into a serving shard directory\n\
           shard-worker serve shards from a directory over the HCKW wire\n\
           serve       serve an artifact or shard directory over TCP\n\
           likelihood  GP log-likelihood / MLE bandwidth search\n\
         \n\
         artifact pipeline:\n\
           hck train --dataset cadata --r 128 --save m.hckm\n\
           hck shard --model m.hckm --out shards/ --shards 8\n\
           hck serve --shard-dir shards/ --port 7878\n\
         \n\
         distributed pipeline (replicated workers + balancing router):\n\
           hck shard-worker --shard-dir shards/ --bind 127.0.0.1:7901\n\
           hck shard-worker --shard-dir shards/ --bind 127.0.0.1:7902\n\
           hck serve --shard-dir shards/ --workers 127.0.0.1:7901,127.0.0.1:7902\n\
         \n\
         run 'hck <subcommand> --help' for options"
    );
}

/// Shorthand for a value-taking option (keeps tables one line per option).
fn opt(name: &'static str, help: &'static str, default: Option<&'static str>) -> OptSpec {
    OptSpec { name, help, default, is_flag: false }
}

/// Shorthand for a boolean flag.
fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, help, default: None, is_flag: true }
}

fn common_data_opts() -> Vec<OptSpec> {
    vec![
        opt("dataset", "Table-1 analogue name (e.g. cadata, SUSY, covtype)", Some("cadata")),
        opt("data", "path to a LIBSVM file (overrides --dataset)", None),
        opt("n-train", "training size (synthetic only; 0 = spec default)", Some("0")),
        opt("n-test", "testing size (synthetic only; 0 = spec default)", Some("0")),
        opt("seed", "random seed", Some("0")),
    ]
}

/// Resolve (train, test, normalization) from --data or --dataset options.
/// The normalization ranges (present for LIBSVM files, which get the
/// paper's [0, 1] attribute scaling) ride into the artifact so serving
/// can preprocess raw queries identically.
#[allow(clippy::type_complexity)]
fn load_data(a: &Args) -> Result<(Dataset, Dataset, Option<Vec<(f64, f64)>>)> {
    let seed = a.u64("seed").map_err(Error::Config)?;
    if let Some(path) = a.get("data") {
        let mut ds = data::libsvm::load(path, path)?;
        let ranges = data::preprocess::normalize_unit(&mut ds);
        let removed = data::preprocess::dedup_conflicts(&mut ds);
        if removed > 0 {
            eprintln!("removed {removed} duplicate/conflicting records");
        }
        let mut rng = hck::util::rng::Rng::new(seed);
        let (train, test) = data::preprocess::train_test_split(&ds, 0.2, &mut rng);
        Ok((train, test, Some(ranges)))
    } else {
        let name = a.req("dataset").map_err(Error::Config)?;
        let spec = data::spec_by_name(name)
            .ok_or_else(|| anyhow!("unknown dataset '{name}' (see 'hck info')"))?;
        let n_train = a.usize("n-train").map_err(Error::Config)?;
        let n_test = a.usize("n-test").map_err(Error::Config)?;
        let nt = if n_train == 0 { spec.default_n_train } else { n_train };
        let ns = if n_test == 0 { spec.default_n_test } else { n_test };
        let (train, test) = data::synthetic::generate(spec, nt, ns, seed);
        Ok((train, test, None))
    }
}

fn model_opts() -> Vec<OptSpec> {
    let mut o = common_data_opts();
    o.extend([
        opt(
            "engine",
            "hierarchical | nystrom | fourier | independent | exact",
            Some("hierarchical"),
        ),
        opt("r", "rank / leaf size", Some("128")),
        opt("kernel", "family:sigma, e.g. gaussian:0.5", Some("gaussian:0.5")),
        opt("lambda", "ridge regularization / GP noise", Some("0.01")),
        opt("rule", "rp | pca | kd | kmeans", Some("rp")),
    ]);
    o
}

fn parse_rule(text: &str) -> Result<SplitRule> {
    Ok(match text {
        "rp" => SplitRule::RandomProjection,
        "pca" => SplitRule::Pca { iters: 10 },
        "kd" => SplitRule::KdTree,
        "kmeans" => SplitRule::KMeans { k: 2, iters: 15 },
        other => return Err(anyhow!("unknown split rule '{other}'")),
    })
}

fn build_config(a: &Args) -> Result<TrainConfig> {
    let kind = KernelKind::parse(a.req("kernel").map_err(Error::Config)?)
        .map_err(Error::Config)?;
    let r = a.usize("r").map_err(Error::Config)?;
    let engine = match a.req("engine").map_err(Error::Config)? {
        "hierarchical" => EngineSpec::Hierarchical { rank: r },
        "nystrom" => EngineSpec::Nystrom { rank: r },
        "fourier" => EngineSpec::Fourier { rank: r },
        "independent" => EngineSpec::Independent { n0: r },
        "exact" => EngineSpec::Exact,
        other => return Err(anyhow!("unknown engine '{other}'")),
    };
    Ok(TrainConfig::new(kind, engine)
        .with_lambda(a.f64("lambda").map_err(Error::Config)?)
        .with_seed(a.u64("seed").map_err(Error::Config)?)
        .with_rule(parse_rule(a.req("rule").map_err(Error::Config)?)?))
}

/// The hierarchical factor config implied by the shared options (GP and
/// KPCA always run on the hierarchical kernel).
fn build_hconfig(a: &Args) -> Result<hck::hkernel::HConfig> {
    let cfg = build_config(a)?;
    let r = a.usize("r").map_err(Error::Config)?;
    let mut hcfg = hck::hkernel::HConfig::new(cfg.kind, r)
        .with_seed(cfg.seed)
        .with_rule(cfg.rule);
    hcfg.n0 = r.max(1);
    Ok(hcfg)
}

/// Assemble the unified [`ModelSpec`] from CLI options.
fn build_model_spec(a: &Args, norm: Option<Vec<(f64, f64)>>) -> Result<ModelSpec> {
    let spec = match a.req("algo").map_err(Error::Config)? {
        "krr" => ModelSpec::krr(build_config(a)?),
        "gp" => {
            let lambda = a.f64("lambda").map_err(Error::Config)?;
            ModelSpec::gp(build_hconfig(a)?, lambda)
        }
        "kpca" => {
            let dim = a.usize("embed-dim").map_err(Error::Config)?;
            ModelSpec::kpca(build_hconfig(a)?, dim.max(1))
        }
        other => return Err(anyhow!("unknown algo '{other}' (krr | gp | kpca)")),
    };
    Ok(match norm {
        Some(ranges) => spec.with_normalization(ranges),
        None => spec,
    })
}

/// Detected-backend startup banner: which SIMD microkernel the packed
/// BLAS-3 core dispatched to, and the worker-pool width it multiplies
/// with. Resolving the backend here also makes a forced-but-unavailable
/// `HCK_SIMD` fail loudly at startup instead of mid-request.
fn print_simd_banner() {
    let mode = if std::env::var("HCK_SIMD").is_ok() {
        "forced via HCK_SIMD"
    } else {
        "runtime-detected"
    };
    println!(
        "simd backend: {} ({mode}) | threads: {} (HCK_THREADS)",
        hck::linalg::simd::backend_name(),
        hck::util::parallel::default_threads(),
    );
}

fn cmd_info(argv: Vec<String>) -> Result<()> {
    let spec = vec![
        opt("model", "show the header of an HCKM artifact (schema + metadata)", None),
        flag("help", "show help"),
    ];
    let a = Args::parse(argv, &spec).map_err(Error::Config)?;
    if a.flag("help") {
        println!("{}", usage("hck info", "data set inventory / artifact header", &spec));
        return Ok(());
    }
    // --model: header-only artifact inspection (no payload deserialize).
    if let Some(path) = a.get("model") {
        let header = model::read_header(path)?;
        println!("{path}: HCKM v{}", header.version);
        println!("  schema: {}", header.schema.summary());
        if header.metadata.is_empty() {
            println!("  metadata: (none)");
        } else {
            println!("  metadata:");
            for (k, v) in &header.metadata {
                println!("    {k} = {v}");
            }
        }
        return Ok(());
    }
    println!("Table 1 data set analogues (synthetic generators):");
    println!(
        "{:<20} {:>5} {:<22} {:>10} {:>9} {:>9}",
        "name", "d", "task", "paper n", "bench n", "test n"
    );
    for s in &data::TABLE1_SPECS {
        println!(
            "{:<20} {:>5} {:<22} {:>10} {:>9} {:>9}",
            s.name,
            s.d,
            format!("{:?}", s.task),
            s.paper_n_train,
            s.default_n_train,
            s.default_n_test
        );
    }
    println!();
    print_simd_banner();
    println!();
    match hck::runtime::PjrtEngine::load_default() {
        Ok(engine) => {
            println!(
                "PJRT artifacts: {} loaded (platform: {})",
                engine.artifacts().len(),
                engine.platform()
            );
            for a in engine.artifacts() {
                println!("  {:<28} op={} d={}", a.name, a.op, a.d);
            }
        }
        Err(e) => println!("PJRT artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}

fn cmd_data_gen(argv: Vec<String>) -> Result<()> {
    let mut spec = common_data_opts();
    spec.push(opt(
        "out",
        "output LIBSVM path (train set; .test appended for test)",
        Some("dataset.libsvm"),
    ));
    spec.push(flag("help", "show help"));
    let a = Args::parse(argv, &spec).map_err(Error::Config)?;
    if a.flag("help") {
        println!("{}", usage("hck data-gen", "generate a synthetic data set", &spec));
        return Ok(());
    }
    let (train, test, _) = load_data(&a)?;
    let out = a.req("out").map_err(Error::Config)?;
    data::libsvm::write(&train, out)?;
    data::libsvm::write(&test, &format!("{out}.test"))?;
    println!(
        "wrote {} ({} x {}) and {}.test ({} x {})",
        out,
        train.n(),
        train.d(),
        out,
        test.n(),
        test.d()
    );
    Ok(())
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let mut spec = model_opts();
    spec.extend([
        opt("algo", "krr | gp | kpca (one fit surface for all of them)", Some("krr")),
        opt("embed-dim", "KPCA embedding dimension", Some("8")),
        opt("save", "save the fitted model as a self-describing HCKM artifact", None),
        opt("trace", "write a Chrome-trace JSON of the run to this path", None),
    ]);
    spec.push(flag("json", "machine-readable output (schema, metric, phase breakdown)"));
    spec.push(flag("help", "show help"));
    let a = Args::parse(argv, &spec).map_err(Error::Config)?;
    if a.flag("help") {
        println!("{}", usage("hck train", "fit a model, optionally save an artifact", &spec));
        return Ok(());
    }
    if let Some(path) = a.get("trace") {
        hck::obs::enable(path);
    }
    let json_out = a.flag("json");
    let mut phases = Phases::new();
    let mut t = Timer::start();
    let (train, test, norm) = load_data(&a)?;
    phases.add("load_data", t.lap());
    let mspec = build_model_spec(&a, norm)?;
    if !json_out {
        print_simd_banner();
        println!(
            "training on {} (n={} d={} task={:?})",
            train.name,
            train.n(),
            train.d(),
            train.task
        );
    }
    let model: Box<dyn Model> = model::fit(&mspec, &train)?;
    let fit_secs = t.lap();
    phases.add("fit", fit_secs);
    // Hierarchical-factor models time their build internally — surface
    // the sub-stages alongside the CLI-level phases.
    if let Some(pred) = model.hierarchical_predictor() {
        for (name, secs) in pred.factors().build_phases.entries() {
            phases.add(&format!("fit.{name}"), *secs);
        }
    }
    if !json_out {
        println!("fitted {} in {fit_secs:.3}s", model.schema().summary());
    }
    let mut metric_out: Option<(f64, bool)> = None;
    if model.schema().kind == ModelKind::Kpca {
        if !json_out {
            println!("embedding dimension {}", model.outputs());
            if test.n() > 0 {
                let emb = model.predict_batch(&test.x.row_range(0, 1));
                println!("first test point embeds to {:?}", emb.row(0));
            }
        }
        phases.add("evaluate", t.lap());
    } else {
        let preds = model.predict_batch(&test.x);
        let test_secs = t.lap();
        phases.add("evaluate", test_secs);
        let (metric, higher_better) = hck::learn::metrics::score(&test, &preds);
        metric_out = Some((metric, higher_better));
        if !json_out {
            println!(
                "{}: {metric:.4}",
                if higher_better { "accuracy" } else { "relative error" }
            );
            println!(
                "test:  {test_secs:.3}s ({:.1} µs/query)",
                test_secs * 1e6 / test.n().max(1) as f64
            );
        }
    }
    if let Some(path) = a.get("save") {
        // Persist the phase breakdown into the artifact header so
        // `hck info --model` can show how the model was built.
        let meta: Vec<(String, String)> = phases
            .entries()
            .iter()
            .map(|(name, secs)| (format!("phase.{name}_secs"), format!("{secs:.6}")))
            .collect();
        model.save_meta(path, &meta)?;
        phases.add("save", t.lap());
        if !json_out {
            println!("saved HCKM artifact to {path}");
        }
    }
    if json_out {
        let mut pairs = vec![
            ("schema", model.schema().to_json()),
            (
                "phases",
                Json::obj(
                    phases
                        .entries()
                        .iter()
                        .map(|(name, secs)| (name.as_str(), Json::Num(*secs)))
                        .collect(),
                ),
            ),
            ("total_secs", Json::Num(phases.total())),
        ];
        if let Some((metric, higher_better)) = metric_out {
            pairs.push((
                if higher_better { "accuracy" } else { "relative_error" },
                Json::Num(metric),
            ));
        }
        if let Some(path) = a.get("save") {
            pairs.push(("saved", Json::Str(path.to_string())));
        }
        println!("{}", Json::obj(pairs).encode());
    }
    Ok(())
}

fn cmd_predict(argv: Vec<String>) -> Result<()> {
    let spec = vec![
        opt("model", "HCKM artifact from `hck train --save`", None),
        opt("data", "LIBSVM file of query points", None),
        flag("variance", "request the posterior variance column (GP artifacts)"),
        flag("routes", "request the routed partition-tree leaf per query"),
        flag("json", "machine-readable output (schema, capabilities, per-row results)"),
        flag("quiet", "only print the summary metric"),
        flag("help", "show help"),
    ];
    let a = Args::parse(argv, &spec).map_err(Error::Config)?;
    if a.flag("help") {
        println!("{}", usage("hck predict", "predict with a saved artifact", &spec));
        return Ok(());
    }
    let model_path = a.req("model").map_err(Error::Config)?;
    let data_path = a.req("data").map_err(Error::Config)?;
    let model: Box<dyn Model> = model::load_any(model_path)?;
    eprintln!("loaded {}: {}", model_path, model.schema().summary());
    let queries = data::libsvm::load(data_path, data_path)?;
    let d = model.dim();
    if queries.d() > d {
        return Err(anyhow!(
            "query dimension {} exceeds model dimension {d}",
            queries.d()
        ));
    }
    // Pad query features to the model dimension if the sparse file
    // happened to omit trailing attributes. The typed predict call
    // applies the artifact's recorded normalization internally.
    let q = hck::linalg::Mat::from_fn(queries.n(), d, |i, j| {
        if j < queries.d() {
            queries.x[(i, j)]
        } else {
            0.0
        }
    });
    let mut want = Want::mean_only();
    if a.flag("variance") {
        want = want.with_variance();
    }
    if a.flag("routes") {
        want = want.with_leaf_route();
    }
    let resp = model.predict(&PredictRequest::new(q, want))?;
    let out = &resp.mean;
    if a.flag("json") {
        println!("{}", predict_json(model.as_ref(), &resp).encode());
    } else if !a.flag("quiet") {
        for i in 0..out.rows() {
            let mut row: Vec<String> = out.row(i).iter().map(|v| format!("{v:.6}")).collect();
            if let Some(var) = &resp.variance {
                row.push(format!("var={:.6}", var[i]));
            }
            if let Some(routes) = &resp.routes {
                row.push(format!("leaf=[{},{})", routes[i].rows_lo, routes[i].rows_hi));
            }
            println!("{}", row.join(" "));
        }
    }
    if model.schema().kind == ModelKind::Kpca {
        eprintln!("embedded {} queries into {} dimensions", queries.n(), out.cols());
    } else {
        let (metric, hib) = hck::learn::metrics::score(&queries, out);
        eprintln!(
            "{}: {metric:.4} over {} queries ({:.0} ns/query)",
            if hib { "accuracy" } else { "relative error" },
            queries.n(),
            resp.per_query_ns
        );
    }
    Ok(())
}

/// The `hck predict --json` document: the artifact's schema (with its
/// capability set), the served columns per row, and the timing
/// diagnostic. Reuses the shared [`hck::util::json::Json`] encoder.
fn predict_json(model: &dyn Model, resp: &hck::infer::PredictResponse) -> Json {
    let rows: Vec<Json> = (0..resp.mean.rows())
        .map(|i| {
            let mut pairs = vec![("mean", Json::from_f64s(resp.mean.row(i)))];
            if let Some(var) = &resp.variance {
                pairs.push(("variance", Json::Num(var[i])));
            }
            if let Some(routes) = &resp.routes {
                pairs.push(("route", routes[i].to_json()));
            }
            Json::obj(pairs)
        })
        .collect();
    Json::obj(vec![
        ("schema", model.schema().to_json()),
        ("predictions", Json::Arr(rows)),
        ("per_query_ns", Json::Num(resp.per_query_ns)),
    ])
}

fn cmd_shard(argv: Vec<String>) -> Result<()> {
    let spec = vec![
        opt("model", "HCKM artifact (hierarchical-factor models)", None),
        opt("out", "output shard directory", Some("shards")),
        opt("shards", "minimum shard count (picks the cut depth)", Some("4")),
        opt("depth", "explicit tree cut depth (overrides --shards)", None),
        flag("help", "show help"),
    ];
    let a = Args::parse(argv, &spec).map_err(Error::Config)?;
    if a.flag("help") {
        println!(
            "{}",
            usage("hck shard", "cut an artifact into a serving shard directory", &spec)
        );
        return Ok(());
    }
    let model_path = a.req("model").map_err(Error::Config)?;
    let model: Box<dyn Model> = model::load_any(model_path)?;
    let pred = model.hierarchical_predictor().ok_or_else(|| {
        anyhow!(
            "sharding requires a hierarchical-factor model; '{}' has none",
            model.schema().kind.name()
        )
    })?;
    let tree = &pred.factors().tree;
    let depth = match a.get("depth") {
        Some(v) => v.parse::<usize>().map_err(|_| anyhow!("bad --depth '{v}'"))?,
        None => {
            let want = a.usize("shards").map_err(Error::Config)?;
            hck::shard::depth_for_shards(tree, want.max(1))
        }
    };
    let out = a.req("out").map_err(Error::Config)?;
    let norm = model.schema().normalization.as_deref();
    let n = hck::shard::save_shard_dir(pred, depth, out, norm)?;
    println!(
        "wrote {n} shards at tree depth {depth} (tree depth {}) to {out}/ — \
         serve with `hck serve --shard-dir {out}`",
        tree.depth()
    );
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let spec = vec![
        opt("model", "HCKM artifact from `hck train --save`", None),
        opt("shard-dir", "shard directory from `hck shard --out`", None),
        opt("port", "TCP port", Some("7878")),
        opt("bind", "listen address (use 0.0.0.0 for non-loopback clients)", Some("127.0.0.1")),
        opt(
            "workers",
            "comma-separated shard-worker host:port list (remote fan-out; needs --shard-dir)",
            None,
        ),
        opt("worker-timeout-ms", "per-worker request timeout (ms)", Some("2000")),
        opt(
            "stats-timeout-ms",
            "per-worker stats-poll timeout (ms; shorter than the request timeout so a \
             hung worker cannot stall load refresh)",
            Some("250"),
        ),
        opt(
            "hedge-ms",
            "re-issue a straggling sub-batch to a sibling replica after this many ms \
             (0 = off; default: auto, 2x recent p95)",
            None,
        ),
        opt(
            "breaker-failures",
            "consecutive predict failures that open a worker's circuit breaker",
            Some("5"),
        ),
        opt(
            "breaker-cooldown-ms",
            "how long an open breaker fast-fails before a half-open probe (ms)",
            Some("1000"),
        ),
        opt(
            "standby",
            "comma-separated standby shard-worker host:port list the supervisor may \
             attach under load",
            None,
        ),
        opt(
            "attach-busy",
            "attach the next standby when peak worker busy fraction exceeds this",
            Some("0.75"),
        ),
        opt(
            "retire-busy",
            "drain a redundant replica when peak busy fraction falls below this (0 = never)",
            Some("0"),
        ),
        opt("max-batch", "dynamic batch size cap", Some("64")),
        opt("max-wait-ms", "batching window (ms)", Some("2")),
        opt("shards", "cut an in-process shard layer from --model (0 = off)", Some("0")),
        opt("shard-depth", "tree depth of the in-process cut (default: fits --shards)", None),
        opt("trace", "write a Chrome-trace JSON of the serving run to this path", None),
        flag("variance", "require the posterior-variance capability at startup"),
        flag("routes", "require the leaf-route capability at startup"),
        flag("metrics", "print the Prometheus exposition at shutdown"),
        flag("help", "show help"),
    ];
    let a = Args::parse(argv, &spec).map_err(Error::Config)?;
    if a.flag("help") {
        println!(
            "{}",
            usage("hck serve", "serve a saved artifact or shard directory over TCP", &spec)
        );
        return Ok(());
    }
    if let Some(path) = a.get("trace") {
        hck::obs::enable(path);
    }
    print_simd_banner();
    let policy = BatchPolicy {
        max_batch: a.usize("max-batch").map_err(Error::Config)?,
        max_wait: std::time::Duration::from_millis(
            a.u64("max-wait-ms").map_err(Error::Config)?,
        ),
    };
    let n_shards = a.usize("shards").map_err(Error::Config)?;
    let shard_depth = a
        .get("shard-depth")
        .map(|v| v.parse::<usize>().map_err(|_| anyhow!("bad --shard-depth '{v}'")))
        .transpose()?;

    // Remote fan-out needs the shard directory for its router +
    // normalization; the shards themselves live in the workers.
    let workers: Option<Vec<String>> = a.get("workers").map(|w| {
        w.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
    });
    if workers.is_some() && a.get("shard-dir").is_none() {
        return Err(anyhow!(
            "--workers needs --shard-dir (the router and normalization are read from it; \
             the shards are served by the `hck shard-worker` processes)"
        ));
    }

    let svc = match (a.get("model"), a.get("shard-dir")) {
        (Some(_), Some(_)) => {
            return Err(anyhow!("pass either --model or --shard-dir, not both"))
        }
        (None, None) => {
            return Err(anyhow!(
                "serve consumes artifacts: pass --model m.hckm (from `hck train --save`) \
                 or --shard-dir dir/ (from `hck shard`)"
            ))
        }
        (None, Some(dir)) if workers.is_some() => {
            // Remote fan-out: route locally, predict on the workers,
            // balance across replicas, fail over when one dies, hedge
            // stragglers, and supervise the replica lifecycle.
            let addrs = workers.unwrap_or_default();
            let timeout = std::time::Duration::from_millis(
                a.u64("worker-timeout-ms").map_err(Error::Config)?,
            );
            let cfg = hck::shard::ResilienceConfig {
                breaker_failures: a
                    .usize("breaker-failures")
                    .map_err(Error::Config)? as u32,
                breaker_cooldown: std::time::Duration::from_millis(
                    a.u64("breaker-cooldown-ms").map_err(Error::Config)?,
                ),
                hedge_after_ms: a
                    .get("hedge-ms")
                    .map(|v| v.parse::<u64>().map_err(|_| anyhow!("bad --hedge-ms '{v}'")))
                    .transpose()?,
                stats_timeout: std::time::Duration::from_millis(
                    a.u64("stats-timeout-ms").map_err(Error::Config)?,
                ),
                ..Default::default()
            };
            let standby: Vec<String> = a
                .get("standby")
                .map(|w| {
                    w.split(',')
                        .map(|s| s.trim().to_string())
                        .filter(|s| !s.is_empty())
                        .collect()
                })
                .unwrap_or_default();
            let scale = if standby.is_empty() {
                None
            } else {
                Some(hck::shard::ScalePolicy {
                    standby,
                    attach_busy: a.f64("attach-busy").map_err(Error::Config)?,
                    retire_busy: a.f64("retire-busy").map_err(Error::Config)?,
                })
            };
            let remote = hck::shard::RemoteShardedPredictor::connect_dir_with(
                dir, &addrs, timeout, cfg, scale,
            )?;
            eprintln!(
                "remote serving: {} shards across {} worker(s), replicas per shard {:?}",
                remote.shards(),
                addrs.len(),
                remote.replica_counts()
            );
            Arc::new(PredictionService::start(Arc::new(remote), policy))
        }
        (None, Some(dir)) => {
            // Shards straight from disk: each worker owns only its slice.
            let sharded = hck::shard::load_shard_dir(dir)?;
            eprintln!(
                "serving {} shards from {dir} (loaded from disk, no retraining)",
                sharded.shards()
            );
            Arc::new(PredictionService::start(Arc::new(sharded), policy))
        }
        (Some(path), None) => {
            let model: Box<dyn Model> = model::load_any(path)?;
            eprintln!("loaded {path}: {}", model.schema().summary());
            if n_shards > 0 || shard_depth.is_some() {
                let sharded = {
                    let pred = model.hierarchical_predictor().ok_or_else(|| {
                        anyhow!("--shards/--shard-depth require a hierarchical-factor model")
                    })?;
                    let tree = &pred.factors().tree;
                    let depth = shard_depth
                        .unwrap_or_else(|| hck::shard::depth_for_shards(tree, n_shards.max(1)));
                    eprintln!(
                        "sharded serving: cut at tree depth {depth} (tree depth {})",
                        tree.depth()
                    );
                    // from_model carries the artifact's normalization
                    // stats onto the sharded path.
                    hck::shard::ShardedPredictor::from_model(model.as_ref(), depth)?
                };
                // The shards own their slices (plus the small top-path
                // replica); drop the unsharded model so serving holds one
                // copy, not two.
                drop(model);
                Arc::new(PredictionService::start(Arc::new(sharded), policy))
            } else {
                Arc::new(PredictionService::start_model(Arc::from(model), policy))
            }
        }
    };

    // Capability preflight: fail fast at startup instead of serving
    // typed `unsupported` errors to every client.
    let caps = svc.capabilities();
    let mut required = Want::mean_only();
    if a.flag("variance") {
        required = required.with_variance();
    }
    if a.flag("routes") {
        required = required.with_leaf_route();
    }
    caps.check(required)?;

    let port = a.usize("port").map_err(Error::Config)?;
    let bind = a.get("bind").unwrap_or("127.0.0.1");
    let listener = std::net::TcpListener::bind((bind, port as u16))
        .map_err(|e| anyhow!("cannot bind {bind}:{port}: {e}"))?;
    eprintln!(
        "serving on {bind}:{port} (capabilities: {caps}) — send \
         {{\"features\": [...]}} (v1) or {{\"v\":2, \"queries\": [[...]], \
         \"want\": {{...}}}} lines; {{\"cmd\":\"metrics_text\"}} for a \
         Prometheus scrape; {{\"cmd\":\"shutdown\"}} to stop"
    );
    let conns = serve_tcp(listener, svc.clone())?;
    let snap = svc.snapshot();
    eprintln!(
        "served {} requests over {} connections; {:.0} rps, p50 {:.0} µs, p99 {:.0} µs",
        snap.requests, conns, snap.throughput_rps, snap.p50_us, snap.p99_us
    );
    for s in &snap.shards {
        eprintln!(
            "  shard {} rows [{}, {}): {} queries in {} batches \
             (mean {:.1}/batch), {:.0} ns/query, queue {}, \
             wait {:.0} ns/batch, busy {:.0}%",
            s.shard,
            s.rows_lo,
            s.rows_hi,
            s.requests,
            s.batches,
            s.mean_batch_size,
            s.ns_per_query,
            s.queue_depth,
            s.queue_wait_ns,
            s.busy_frac * 100.0
        );
    }
    for w in &snap.workers {
        let served: u64 = w.shards.iter().map(|s| s.requests).sum();
        eprintln!(
            "  worker {} ({}): shards {:?}, {} queries, {} reconnect(s)",
            w.worker,
            if w.reachable { "up" } else { "unreachable" },
            w.shards.iter().map(|s| s.shard).collect::<Vec<_>>(),
            served,
            w.reconnects
        );
    }
    if a.flag("metrics") {
        let pool = hck::util::parallel::pool_stats();
        print!("{}", hck::coordinator::metrics::render_prometheus(&snap, &pool));
    }
    Ok(())
}

fn cmd_shard_worker(argv: Vec<String>) -> Result<()> {
    let spec = vec![
        opt("shard-dir", "shard directory from `hck shard --out`", None),
        opt("index", "comma-separated shard indices to serve (default: all — a full replica)", None),
        opt("bind", "listen address as host:port (port 0 picks an ephemeral port)", Some("127.0.0.1:7900")),
        opt("trace", "write a Chrome-trace JSON of the worker run to this path", None),
        flag("help", "show help"),
    ];
    let a = Args::parse(argv, &spec).map_err(Error::Config)?;
    if a.flag("help") {
        println!(
            "{}",
            usage(
                "hck shard-worker",
                "serve shards from a directory over the HCKW wire \
                 (predict/stats/hello/shutdown); front with `hck serve --workers`",
                &spec
            )
        );
        return Ok(());
    }
    if let Some(path) = a.get("trace") {
        hck::obs::enable(path);
    }
    print_simd_banner();
    let dir = a.req("shard-dir").map_err(Error::Config)?;
    let indices: Option<Vec<usize>> = match a.get("index") {
        Some(s) => Some(
            s.split(',')
                .filter(|t| !t.trim().is_empty())
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow!("bad --index entry '{t}'"))
                })
                .collect::<Result<Vec<usize>>>()?,
        ),
        None => None,
    };
    let bind = a.req("bind").map_err(Error::Config)?;
    hck::shard::remote::run_worker(dir, indices.as_deref(), bind)
}

fn cmd_likelihood(argv: Vec<String>) -> Result<()> {
    let mut spec = model_opts();
    spec.push(flag("mle", "run golden-section MLE over sigma"));
    spec.push(flag("help", "show help"));
    let a = Args::parse(argv, &spec).map_err(Error::Config)?;
    if a.flag("help") {
        println!("{}", usage("hck likelihood", "GP log-likelihood / MLE", &spec));
        return Ok(());
    }
    let (train, _, _) = load_data(&a)?;
    let cfg = build_config(&a)?;
    let r = a.usize("r").map_err(Error::Config)?;
    let mut hcfg = hck::hkernel::HConfig::new(cfg.kind, r).with_seed(cfg.seed);
    hcfg.n0 = r;
    if a.flag("mle") {
        let (sig, ll) =
            hck::gp::mle_sigma(&train.x, &train.y, &hcfg, cfg.lambda, 0.01, 20.0, 0.05)?;
        println!("MLE bandwidth σ* = {sig:.4}, log-likelihood = {ll:.2}");
    } else {
        let f = hck::hkernel::HFactors::build(&train.x, hcfg)?;
        let ll = hck::gp::log_marginal_likelihood(&f, cfg.lambda, &train.y)?;
        println!("log-likelihood at σ={}: {ll:.2}", cfg.kind.sigma());
    }
    Ok(())
}
