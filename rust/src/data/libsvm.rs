//! LIBSVM sparse text format parser.
//!
//! The paper's eight benchmark data sets are distributed in this format
//! (`label idx:val idx:val ...`, 1-based indices). The offline environment
//! cannot download them, so the experiments default to the synthetic
//! analogues in [`super::synthetic`]; this parser makes the pipeline
//! drop-in ready for the real files (`hck train --data path.libsvm`).

use super::dataset::{Dataset, Task};
use crate::error::{Error, Result};
use crate::linalg::Mat;
use std::io::BufRead;

/// Parse LIBSVM text content. `d_hint` can force a dimension (use 0 to
/// infer from the max index seen). Labels are returned raw; task inference
/// happens in [`infer_task`].
pub fn parse_text(text: &str, d_hint: usize) -> Result<(Vec<Vec<(usize, f64)>>, Vec<f64>, usize)> {
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut labels: Vec<f64> = Vec::new();
    let mut dmax = d_hint;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut toks = line.split_whitespace();
        let label: f64 = toks
            .next()
            .ok_or_else(|| Error::data(format!("line {}: empty", lineno + 1)))?
            .parse()
            .map_err(|_| Error::data(format!("line {}: bad label", lineno + 1)))?;
        let mut feats = Vec::new();
        for t in toks {
            let (is, vs) = t
                .split_once(':')
                .ok_or_else(|| Error::data(format!("line {}: token '{t}'", lineno + 1)))?;
            let idx: usize = is
                .parse()
                .map_err(|_| Error::data(format!("line {}: index '{is}'", lineno + 1)))?;
            if idx == 0 {
                return Err(Error::data(format!("line {}: 1-based indices expected", lineno + 1)));
            }
            let val: f64 = vs
                .parse()
                .map_err(|_| Error::data(format!("line {}: value '{vs}'", lineno + 1)))?;
            dmax = dmax.max(idx);
            feats.push((idx - 1, val));
        }
        rows.push(feats);
        labels.push(label);
    }
    Ok((rows, labels, dmax))
}

/// Infer the task from raw labels: {-1,+1} or {0,1} → binary; a small set
/// of non-negative integers → multiclass; anything else → regression.
pub fn infer_task(labels: &mut [f64]) -> Task {
    let mut distinct: Vec<f64> = labels.to_vec();
    distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
    distinct.dedup();
    let all_int = distinct.iter().all(|v| v.fract() == 0.0);
    if distinct.len() == 2 && all_int {
        // Map the two values to ±1.
        let (lo, hi) = (distinct[0], distinct[1]);
        for v in labels.iter_mut() {
            *v = if *v == hi { 1.0 } else { -1.0 };
        }
        let _ = lo;
        return Task::Binary;
    }
    if all_int && distinct.len() <= 64 && distinct.len() > 2 {
        // Re-index to 0..k-1.
        for v in labels.iter_mut() {
            let pos = distinct.iter().position(|d| d == v).unwrap();
            *v = pos as f64;
        }
        return Task::Multiclass(distinct.len());
    }
    Task::Regression
}

/// Write a dataset to LIBSVM text format (1-based indices, zeros
/// omitted). Enables `hck data-gen` to emit files interchangeable with
/// the real benchmark downloads.
pub fn write(ds: &Dataset, path: &str) -> Result<()> {
    use std::io::Write as _;
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    for i in 0..ds.n() {
        write!(out, "{}", ds.y[i])?;
        for (j, &v) in ds.x.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(out, " {}:{}", j + 1, v)?;
            }
        }
        writeln!(out)?;
    }
    Ok(())
}

/// Load a LIBSVM file into a dense [`Dataset`].
pub fn load(path: &str, name: &str) -> Result<Dataset> {
    let file = std::fs::File::open(path)?;
    let mut text = String::new();
    let mut reader = std::io::BufReader::new(file);
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        text.push_str(&line);
    }
    from_text(&text, name)
}

/// Build a dense [`Dataset`] from LIBSVM text.
pub fn from_text(text: &str, name: &str) -> Result<Dataset> {
    let (rows, mut labels, d) = parse_text(text, 0)?;
    if rows.is_empty() {
        return Err(Error::data("empty libsvm file"));
    }
    let task = infer_task(&mut labels);
    let mut x = Mat::zeros(rows.len(), d);
    for (i, feats) in rows.iter().enumerate() {
        for &(j, v) in feats {
            x[(i, j)] = v;
        }
    }
    Dataset::new(name, x, labels, task)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let text = "1 1:0.5 3:2.0\n-1 2:1.0\n";
        let ds = from_text(text, "t").unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.task, Task::Binary);
        assert_eq!(ds.x[(0, 0)], 0.5);
        assert_eq!(ds.x[(0, 2)], 2.0);
        assert_eq!(ds.x[(1, 1)], 1.0);
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn binary_zero_one_maps_to_pm1() {
        let text = "0 1:1\n1 1:2\n";
        let ds = from_text(text, "t").unwrap();
        assert_eq!(ds.task, Task::Binary);
        assert_eq!(ds.y, vec![-1.0, 1.0]);
    }

    #[test]
    fn multiclass_reindexed() {
        let text = "3 1:1\n5 1:2\n7 1:3\n3 1:4\n";
        let ds = from_text(text, "t").unwrap();
        assert_eq!(ds.task, Task::Multiclass(3));
        assert_eq!(ds.y, vec![0.0, 1.0, 2.0, 0.0]);
    }

    #[test]
    fn regression_detected() {
        let text = "1.5 1:1\n-0.25 1:2\n3.0 1:3\n";
        let ds = from_text(text, "t").unwrap();
        assert_eq!(ds.task, Task::Regression);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let text = "# header\n\n1.5 1:1\n2.5 2:1\n";
        let ds = from_text(text, "t").unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 2);
    }

    #[test]
    fn rejects_bad_tokens() {
        assert!(from_text("1 0:5\n", "t").is_err()); // 0-based index
        assert!(from_text("1 a:b\n", "t").is_err());
        assert!(from_text("x 1:1\n", "t").is_err());
        assert!(from_text("", "t").is_err());
    }
}
