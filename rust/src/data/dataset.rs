//! In-memory data set representation.

use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Learning task type, mirroring Table 1 of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Real-valued targets; performance metric = relative error.
    Regression,
    /// Labels in {-1, +1}; metric = accuracy.
    Binary,
    /// Labels in {0, .., k-1}; metric = accuracy (one-vs-all training).
    Multiclass(usize),
}

impl Task {
    /// Number of regression outputs needed to train this task
    /// (one-vs-all for multiclass).
    pub fn n_outputs(&self) -> usize {
        match self {
            Task::Regression | Task::Binary => 1,
            Task::Multiclass(k) => *k,
        }
    }
}

/// A supervised data set: row-major feature matrix plus targets.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// n x d feature matrix.
    pub x: Mat,
    /// n targets (class index for classification).
    pub y: Vec<f64>,
    /// Task type.
    pub task: Task,
    /// Human-readable name (for reports).
    pub name: String,
}

impl Dataset {
    /// Construct, validating shapes and labels.
    pub fn new(name: impl Into<String>, x: Mat, y: Vec<f64>, task: Task) -> Result<Dataset> {
        if x.rows() != y.len() {
            return Err(Error::data(format!(
                "x has {} rows but y has {} entries",
                x.rows(),
                y.len()
            )));
        }
        match task {
            Task::Binary => {
                if y.iter().any(|&v| v != -1.0 && v != 1.0) {
                    return Err(Error::data("binary labels must be ±1"));
                }
            }
            Task::Multiclass(k) => {
                if y.iter().any(|&v| v < 0.0 || v >= k as f64 || v.fract() != 0.0) {
                    return Err(Error::data(format!("multiclass labels must be 0..{k}")));
                }
            }
            Task::Regression => {}
        }
        Ok(Dataset { x, y, task, name: name.into() })
    }

    /// Number of examples.
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.x.cols()
    }

    /// Restrict to a subset of rows (in the given order).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.select_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
            task: self.task,
            name: self.name.clone(),
        }
    }

    /// Targets encoded for training: regression targets as-is; binary ±1;
    /// multiclass one-vs-all columns (+1 for class c, -1 otherwise).
    pub fn target_matrix(&self) -> Mat {
        match self.task {
            Task::Regression | Task::Binary => {
                Mat::from_vec(self.n(), 1, self.y.clone())
            }
            Task::Multiclass(k) => Mat::from_fn(self.n(), k, |i, c| {
                if self.y[i] as usize == c {
                    1.0
                } else {
                    -1.0
                }
            }),
        }
    }

    /// Decode a prediction matrix (n x n_outputs) back to task targets.
    pub fn decode_predictions(&self, preds: &Mat) -> Vec<f64> {
        match self.task {
            Task::Regression => preds.col(0),
            Task::Binary => {
                preds.col(0).iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect()
            }
            Task::Multiclass(k) => (0..preds.rows())
                .map(|i| {
                    let row = preds.row(i);
                    let mut best = 0usize;
                    for c in 1..k {
                        if row[c] > row[best] {
                            best = c;
                        }
                    }
                    best as f64
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xy(n: usize, d: usize) -> (Mat, Vec<f64>) {
        (Mat::from_fn(n, d, |i, j| (i + j) as f64), (0..n).map(|i| i as f64).collect())
    }

    #[test]
    fn construction_validates() {
        let (x, y) = xy(4, 2);
        let ds = Dataset::new("t", x.clone(), y, Task::Regression).unwrap();
        assert_eq!(ds.n(), 4);
        assert_eq!(ds.d(), 2);
        assert!(Dataset::new("t", x.clone(), vec![0.0; 3], Task::Regression).is_err());
        assert!(Dataset::new("t", x.clone(), vec![0.0; 4], Task::Binary).is_err());
        assert!(Dataset::new("t", x, vec![5.0; 4], Task::Multiclass(3)).is_err());
    }

    #[test]
    fn subset_selects() {
        let (x, y) = xy(5, 2);
        let ds = Dataset::new("t", x, y, Task::Regression).unwrap();
        let s = ds.subset(&[4, 0]);
        assert_eq!(s.n(), 2);
        assert_eq!(s.y, vec![4.0, 0.0]);
        assert_eq!(s.x.row(0), ds.x.row(4));
    }

    #[test]
    fn target_matrix_multiclass_one_vs_all() {
        let x = Mat::zeros(3, 1);
        let ds = Dataset::new("t", x, vec![0.0, 2.0, 1.0], Task::Multiclass(3)).unwrap();
        let t = ds.target_matrix();
        assert_eq!(t.shape(), (3, 3));
        assert_eq!(t[(0, 0)], 1.0);
        assert_eq!(t[(0, 1)], -1.0);
        assert_eq!(t[(1, 2)], 1.0);
        assert_eq!(t[(2, 1)], 1.0);
    }

    #[test]
    fn decode_binary_and_multiclass() {
        let x = Mat::zeros(2, 1);
        let b = Dataset::new("b", x.clone(), vec![1.0, -1.0], Task::Binary).unwrap();
        let preds = Mat::from_vec(2, 1, vec![0.3, -2.0]);
        assert_eq!(b.decode_predictions(&preds), vec![1.0, -1.0]);

        let m = Dataset::new("m", x, vec![0.0, 1.0], Task::Multiclass(3)).unwrap();
        let preds = Mat::from_vec(2, 3, vec![0.1, 0.9, -1.0, 2.0, 0.0, 1.0]);
        assert_eq!(m.decode_predictions(&preds), vec![1.0, 0.0]);
    }

    #[test]
    fn n_outputs() {
        assert_eq!(Task::Regression.n_outputs(), 1);
        assert_eq!(Task::Binary.n_outputs(), 1);
        assert_eq!(Task::Multiclass(7).n_outputs(), 7);
    }
}
