//! Data sets: representation, parsing, preprocessing, and synthetic
//! generators mirroring the paper's Table 1 benchmarks.

pub mod dataset;
pub mod libsvm;
pub mod preprocess;
pub mod synthetic;

pub use dataset::{Dataset, Task};
pub use preprocess::{dedup_conflicts, normalize_unit, train_test_split};
pub use synthetic::{generate, generate_default, spec_by_name, SyntheticSpec, TABLE1_SPECS};
