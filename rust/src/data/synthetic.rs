//! Synthetic analogues of the paper's Table 1 data sets.
//!
//! The experiments of Section 5 use eight LIBSVM benchmarks (cadata,
//! YearPredictionMSD, ijcnn1, covtype.binary, SUSY, mnist, acoustic,
//! covtype). This environment is offline, so we generate synthetic data
//! sets matched in dimension, task type, and — most importantly — the
//! qualitative *spectral* character that drives the paper's comparisons:
//!
//! - smooth low-dimensional manifolds (cadata-like) → fast eigendecay,
//!   low-rank kernels do well at small r;
//! - many well-separated clusters (covtype-like) → slow eigendecay, the
//!   full-rank local kernels (independent, hierarchical) dominate,
//!   reproducing the paper's covtype gap;
//! - overlapping high-noise classes (susy-like) → intermediate regime.
//!
//! Every generator is deterministic in (spec, n, seed). Sizes default to a
//! scaled-down fraction of the paper's (this testbed is a single core; the
//! paper used a 12-core POWER8 node — see DESIGN.md §Hardware-Adaptation),
//! but the full Table 1 sizes are carried in the spec for reference.

use super::dataset::{Dataset, Task};
use crate::linalg::matrix::sqdist;
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Recipe controlling the geometry of a synthetic data set.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    /// Data set name (matches Table 1).
    pub name: &'static str,
    /// Feature dimension (matches Table 1).
    pub d: usize,
    /// Task (matches Table 1).
    pub task: Task,
    /// Paper's training size (for reference / reporting).
    pub paper_n_train: usize,
    /// Paper's testing size (for reference / reporting).
    pub paper_n_test: usize,
    /// Default scaled training size used by benches.
    pub default_n_train: usize,
    /// Default scaled testing size used by benches.
    pub default_n_test: usize,
    /// Number of Gaussian clusters the inputs are drawn from.
    pub clusters: usize,
    /// Cluster standard deviation (small ⇒ tight clusters ⇒ slow kernel
    /// eigendecay at moderate bandwidths).
    pub spread: f64,
    /// Intrinsic manifold dimension (point = center + tangent coords).
    pub intrinsic_dim: usize,
    /// Observation noise on regression targets / label flip prob.
    pub noise: f64,
}

/// The eight Table 1 analogues.
pub const TABLE1_SPECS: [SyntheticSpec; 8] = [
    SyntheticSpec {
        name: "cadata",
        d: 8,
        task: Task::Regression,
        paper_n_train: 16_512,
        paper_n_test: 4_128,
        default_n_train: 4_000,
        default_n_test: 1_000,
        clusters: 6,
        spread: 0.18,
        intrinsic_dim: 3,
        noise: 0.08,
    },
    SyntheticSpec {
        name: "YearPredictionMSD",
        d: 90,
        task: Task::Regression,
        paper_n_train: 463_518,
        paper_n_test: 51_630,
        default_n_train: 8_000,
        default_n_test: 2_000,
        clusters: 10,
        spread: 0.22,
        intrinsic_dim: 12,
        noise: 0.20,
    },
    SyntheticSpec {
        name: "ijcnn1",
        d: 22,
        task: Task::Binary,
        paper_n_train: 35_000,
        paper_n_test: 91_701,
        default_n_train: 6_000,
        default_n_test: 2_000,
        clusters: 14,
        spread: 0.10,
        intrinsic_dim: 6,
        noise: 0.05,
    },
    SyntheticSpec {
        name: "covtype.binary",
        d: 54,
        task: Task::Binary,
        paper_n_train: 464_809,
        paper_n_test: 116_203,
        default_n_train: 8_000,
        default_n_test: 2_000,
        clusters: 60,
        spread: 0.045,
        intrinsic_dim: 8,
        noise: 0.03,
    },
    SyntheticSpec {
        name: "SUSY",
        d: 18,
        task: Task::Binary,
        paper_n_train: 4_000_000,
        paper_n_test: 1_000_000,
        default_n_train: 10_000,
        default_n_test: 2_500,
        clusters: 8,
        spread: 0.20,
        intrinsic_dim: 9,
        noise: 0.18,
    },
    SyntheticSpec {
        name: "mnist",
        d: 780,
        task: Task::Multiclass(10),
        paper_n_train: 60_000,
        paper_n_test: 10_000,
        default_n_train: 4_000,
        default_n_test: 1_000,
        clusters: 10,
        spread: 0.06,
        intrinsic_dim: 12,
        noise: 0.02,
    },
    SyntheticSpec {
        name: "acoustic",
        d: 50,
        task: Task::Multiclass(3),
        paper_n_train: 78_823,
        paper_n_test: 19_705,
        default_n_train: 6_000,
        default_n_test: 1_500,
        clusters: 9,
        spread: 0.15,
        intrinsic_dim: 8,
        noise: 0.10,
    },
    SyntheticSpec {
        name: "covtype",
        d: 54,
        task: Task::Multiclass(7),
        paper_n_train: 464_809,
        paper_n_test: 116_203,
        default_n_train: 8_000,
        default_n_test: 2_000,
        clusters: 63,
        spread: 0.045,
        intrinsic_dim: 8,
        noise: 0.03,
    },
];

/// Look up a Table 1 spec by name.
pub fn spec_by_name(name: &str) -> Option<&'static SyntheticSpec> {
    TABLE1_SPECS.iter().find(|s| s.name == name)
}

/// Generate (train, test) with the spec's default scaled sizes.
pub fn generate_default(spec: &SyntheticSpec, seed: u64) -> (Dataset, Dataset) {
    generate(spec, spec.default_n_train, spec.default_n_test, seed)
}

/// Generate (train, test) of the requested sizes.
///
/// Points are drawn from a mixture of `clusters` Gaussians whose centers
/// live in [0.15, 0.85]^d; each point is center + tangent-subspace
/// coordinates (intrinsic_dim directions) + small isotropic jitter, then
/// clipped to [0, 1]^d (the paper normalizes attributes to unit intervals).
///
/// Targets:
/// - regression: a smooth mixture of RBF bumps + a linear trend + noise,
///   normalized to unit scale;
/// - binary: sign of a smooth score with cluster-level offsets, labels
///   flipped with prob `noise`;
/// - multiclass: cluster-majority classes with a smooth boundary
///   perturbation and `noise` flips.
pub fn generate(
    spec: &SyntheticSpec,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> (Dataset, Dataset) {
    let n = n_train + n_test;
    let d = spec.d;
    let mut rng = Rng::new(seed ^ hash_name(spec.name));

    // Cluster centers and per-cluster tangent bases.
    let centers = Mat::from_fn(spec.clusters, d, |_, _| rng.uniform(0.15, 0.85));
    let mut bases: Vec<Mat> = Vec::with_capacity(spec.clusters);
    for _ in 0..spec.clusters {
        // intrinsic_dim random orthogonal-ish directions (unit rows).
        let mut b = Mat::zeros(spec.intrinsic_dim.max(1), d);
        for r0 in 0..b.rows() {
            let u = rng.unit_vector(d);
            b.row_mut(r0).copy_from_slice(&u);
        }
        bases.push(b);
    }

    // Bump centers/weights for the smooth part of the target.
    let n_bumps = 12;
    let bumps = Mat::from_fn(n_bumps, d, |_, _| rng.uniform(0.0, 1.0));
    let bump_w: Vec<f64> = (0..n_bumps).map(|_| rng.normal()).collect();
    let trend = rng.unit_vector(d);
    let bump_scale = 0.35 * (d as f64).sqrt();
    // Per-cluster label offsets for classification tasks.
    let k_classes = match spec.task {
        Task::Multiclass(k) => k,
        _ => 2,
    };
    let cluster_class: Vec<usize> =
        (0..spec.clusters).map(|c| c % k_classes).collect();
    let cluster_offset: Vec<f64> = (0..spec.clusters).map(|_| rng.normal()).collect();

    let mut x = Mat::zeros(n, d);
    let mut raw_scores = vec![0.0; n];
    let mut clusters_of = vec![0usize; n];
    for i in 0..n {
        let c = rng.below(spec.clusters);
        clusters_of[i] = c;
        let basis = &bases[c];
        let row = x.row_mut(i);
        row.copy_from_slice(centers.row(c));
        // Tangent coordinates.
        for t in 0..basis.rows() {
            let coef = rng.normal() * spec.spread;
            for (rj, bj) in row.iter_mut().zip(basis.row(t).iter()) {
                *rj += coef * bj;
            }
        }
        // Isotropic jitter + clip to the unit box.
        for rj in row.iter_mut() {
            *rj += rng.normal() * spec.spread * 0.15;
            *rj = rj.clamp(0.0, 1.0);
        }
    }
    for i in 0..n {
        let xi = x.row(i);
        let mut s = crate::linalg::matrix::dot(xi, &trend);
        for b in 0..n_bumps {
            let d2 = sqdist(xi, bumps.row(b));
            s += bump_w[b] * (-d2 / (2.0 * bump_scale * bump_scale)).exp();
        }
        raw_scores[i] = s + 0.6 * cluster_offset[clusters_of[i]];
    }

    // Standardize scores to zero mean / unit variance for stable labeling.
    let mean = raw_scores.iter().sum::<f64>() / n as f64;
    let var = raw_scores.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    let std = var.sqrt().max(1e-12);
    for s in raw_scores.iter_mut() {
        *s = (*s - mean) / std;
    }

    let y: Vec<f64> = match spec.task {
        Task::Regression => raw_scores
            .iter()
            .map(|&s| s + rng.normal() * spec.noise)
            .collect(),
        Task::Binary => (0..n)
            .map(|i| {
                let clean = if raw_scores[i] >= 0.0 { 1.0 } else { -1.0 };
                if rng.bernoulli(spec.noise) {
                    -clean
                } else {
                    clean
                }
            })
            .collect(),
        Task::Multiclass(k) => (0..n)
            .map(|i| {
                // Cluster majority class, perturbed near smooth boundaries.
                let base = cluster_class[clusters_of[i]];
                let shifted = if raw_scores[i] > 1.2 {
                    (base + 1) % k
                } else {
                    base
                };
                let label = if rng.bernoulli(spec.noise) {
                    rng.below(k)
                } else {
                    shifted
                };
                label as f64
            })
            .collect(),
    };

    let full = Dataset::new(spec.name, x, y, spec.task).expect("synthetic construction");
    let (test_idx, train_idx): (Vec<usize>, Vec<usize>) = {
        let perm = rng.permutation(n);
        (perm[..n_test].to_vec(), perm[n_test..].to_vec())
    };
    (full.subset(&train_idx), full.subset(&test_idx))
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a, to decorrelate seeds across data sets.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_table1_dims() {
        assert_eq!(TABLE1_SPECS.len(), 8);
        let s = spec_by_name("mnist").unwrap();
        assert_eq!(s.d, 780);
        assert_eq!(s.task, Task::Multiclass(10));
        assert_eq!(spec_by_name("cadata").unwrap().d, 8);
        assert_eq!(spec_by_name("SUSY").unwrap().paper_n_train, 4_000_000);
        assert!(spec_by_name("nope").is_none());
    }

    #[test]
    fn deterministic_in_seed() {
        let s = spec_by_name("cadata").unwrap();
        let (a, _) = generate(s, 100, 20, 7);
        let (b, _) = generate(s, 100, 20, 7);
        assert_eq!(a.x.as_slice(), b.x.as_slice());
        assert_eq!(a.y, b.y);
        let (c, _) = generate(s, 100, 20, 8);
        assert_ne!(a.x.as_slice(), c.x.as_slice());
    }

    #[test]
    fn shapes_and_ranges() {
        for s in &TABLE1_SPECS {
            let (train, test) = generate(s, 120, 30, 1);
            assert_eq!(train.n(), 120);
            assert_eq!(test.n(), 30);
            assert_eq!(train.d(), s.d);
            assert!(train.x.as_slice().iter().all(|&v| (0.0..=1.0).contains(&v)));
            // Labels valid for the task (Dataset::new validated already).
            assert_eq!(train.task, s.task);
        }
    }

    #[test]
    fn binary_labels_both_present() {
        let s = spec_by_name("SUSY").unwrap();
        let (train, _) = generate(s, 400, 50, 3);
        let pos = train.y.iter().filter(|&&v| v == 1.0).count();
        assert!(pos > 40 && pos < 360, "pos={pos}");
    }

    #[test]
    fn multiclass_all_classes_present() {
        let s = spec_by_name("covtype").unwrap();
        let (train, _) = generate(s, 1000, 100, 4);
        let mut seen = vec![false; 7];
        for &v in &train.y {
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&b| b), "seen={seen:?}");
    }

    #[test]
    fn regression_targets_standardized() {
        let s = spec_by_name("cadata").unwrap();
        let (train, _) = generate(s, 2000, 100, 5);
        let mean = train.y.iter().sum::<f64>() / train.n() as f64;
        let var = train.y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / train.n() as f64;
        assert!(mean.abs() < 0.2, "mean={mean}");
        assert!(var > 0.4 && var < 2.5, "var={var}");
    }

    #[test]
    fn covtype_like_is_clustery() {
        // The covtype analogue should have many tight clusters: nearest-
        // neighbor distances much smaller than random-pair distances.
        let s = spec_by_name("covtype.binary").unwrap();
        let (train, _) = generate(s, 400, 10, 6);
        let mut rng = Rng::new(1);
        let mut nn = 0.0;
        let mut rand_pair = 0.0;
        let m = 60;
        for _ in 0..m {
            let i = rng.below(train.n());
            let mut best = f64::INFINITY;
            for j in 0..train.n() {
                if j != i {
                    best = best.min(sqdist(train.x.row(i), train.x.row(j)));
                }
            }
            nn += best.sqrt();
            let j = rng.below(train.n());
            rand_pair += sqdist(train.x.row(i), train.x.row(j)).sqrt();
        }
        let m = m as f64;
        assert!(nn / m < 0.5 * rand_pair / m, "nn={} rand={}", nn / m, rand_pair / m);
    }
}
