//! Preprocessing mirroring Section 5 of the paper: attribute normalization
//! to [0, 1], removal of duplicate and conflicting training records, and
//! the 4:1 train/test split used for data sets that ship unsplit.

use super::dataset::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Normalize every attribute to [0, 1] (affine per column). Columns with
/// zero range map to 0. Returns the per-column (min, max) used, so the
/// same transform can be applied to a test set via [`apply_normalization`].
pub fn normalize_unit(ds: &mut Dataset) -> Vec<(f64, f64)> {
    let (n, d) = ds.x.shape();
    let mut ranges = vec![(f64::INFINITY, f64::NEG_INFINITY); d];
    for i in 0..n {
        for (j, &v) in ds.x.row(i).iter().enumerate() {
            ranges[j].0 = ranges[j].0.min(v);
            ranges[j].1 = ranges[j].1.max(v);
        }
    }
    apply_normalization(&mut ds.x, &ranges);
    ranges
}

/// Apply a previously computed per-column normalization.
pub fn apply_normalization(x: &mut Mat, ranges: &[(f64, f64)]) {
    let (n, d) = x.shape();
    assert_eq!(d, ranges.len());
    for i in 0..n {
        let row = x.row_mut(i);
        for j in 0..d {
            let (lo, hi) = ranges[j];
            let span = hi - lo;
            row[j] = if span > 0.0 { ((row[j] - lo) / span).clamp(0.0, 1.0) } else { 0.0 };
        }
    }
}

/// Remove duplicate records, and *conflicting* records (same features,
/// inconsistent labels) entirely — as the paper does for training sets.
/// Returns the number of rows removed.
pub fn dedup_conflicts(ds: &mut Dataset) -> usize {
    let n = ds.n();
    // Hash rows by their bit pattern.
    let mut first_of: HashMap<Vec<u64>, usize> = HashMap::with_capacity(n);
    let mut conflicted: Vec<bool> = vec![false; n];
    let mut keep: Vec<bool> = vec![false; n];
    let mut owner: Vec<usize> = vec![usize::MAX; n];
    for i in 0..n {
        let key: Vec<u64> = ds.x.row(i).iter().map(|v| v.to_bits()).collect();
        match first_of.get(&key) {
            None => {
                first_of.insert(key, i);
                keep[i] = true;
                owner[i] = i;
            }
            Some(&j) => {
                owner[i] = j;
                if ds.y[i] != ds.y[j] {
                    conflicted[j] = true;
                }
            }
        }
    }
    let idx: Vec<usize> =
        (0..n).filter(|&i| keep[i] && !conflicted[i]).collect();
    let removed = n - idx.len();
    *ds = ds.subset(&idx);
    removed
}

/// Random 4:1 (or custom-fraction) split into (train, test).
pub fn train_test_split(ds: &Dataset, test_fraction: f64, rng: &mut Rng) -> (Dataset, Dataset) {
    let n = ds.n();
    let perm = rng.permutation(n);
    let n_test = ((n as f64) * test_fraction).round() as usize;
    let (test_idx, train_idx) = perm.split_at(n_test.min(n));
    (ds.subset(train_idx), ds.subset(test_idx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Task;

    fn make(xs: Vec<f64>, n: usize, d: usize, y: Vec<f64>) -> Dataset {
        Dataset::new("t", Mat::from_vec(n, d, xs), y, Task::Regression).unwrap()
    }

    #[test]
    fn normalize_maps_to_unit() {
        let mut ds = make(vec![0.0, 10.0, 5.0, 20.0, 10.0, 30.0], 3, 2, vec![0.0; 3]);
        let ranges = normalize_unit(&mut ds);
        assert_eq!(ranges, vec![(0.0, 10.0), (10.0, 30.0)]);
        assert_eq!(ds.x[(0, 0)], 0.0);
        assert_eq!(ds.x[(2, 0)], 1.0);
        assert_eq!(ds.x[(1, 1)], 0.5);
    }

    #[test]
    fn normalize_constant_column() {
        let mut ds = make(vec![7.0, 7.0, 7.0], 3, 1, vec![0.0; 3]);
        normalize_unit(&mut ds);
        assert!(ds.x.col(0).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn apply_normalization_clamps_test_points() {
        let mut x = Mat::from_vec(1, 1, vec![50.0]);
        apply_normalization(&mut x, &[(0.0, 10.0)]);
        assert_eq!(x[(0, 0)], 1.0);
    }

    #[test]
    fn dedup_removes_duplicates_and_conflicts() {
        // rows: a, a (dup consistent), b, b (conflicting label), c
        let mut ds = make(
            vec![1.0, 1.0, 2.0, 2.0, 3.0],
            5,
            1,
            vec![10.0, 10.0, 20.0, 21.0, 30.0],
        );
        let removed = dedup_conflicts(&mut ds);
        assert_eq!(removed, 3); // one dup + both rows of the conflict pair
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.y, vec![10.0, 30.0]);
    }

    #[test]
    fn split_partitions_everything() {
        let vals: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ds = make(vals.clone(), 20, 1, vals);
        let mut rng = Rng::new(1);
        let (train, test) = train_test_split(&ds, 0.2, &mut rng);
        assert_eq!(train.n(), 16);
        assert_eq!(test.n(), 4);
        let mut all: Vec<f64> = train.y.iter().chain(test.y.iter()).copied().collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(all, (0..20).map(|i| i as f64).collect::<Vec<_>>());
    }
}
