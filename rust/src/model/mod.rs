//! Unified model surface: one `fit → predict → save → serve` API across
//! every learner in the crate, with self-describing artifacts.
//!
//! The paper's promise is one kernel machinery serving regression,
//! classification, and KPCA at O(nr) memory; this module is the single
//! entry point that delivers it. A [`ModelSpec`] says *what* to fit
//! (any of the five KRR engines, the GP posterior, or a KPCA transform);
//! [`fit`] returns a type-erased [`Model`] that predicts in batches,
//! reports its own [`ModelSchema`] (kind, dims, task, preprocessing
//! stats), saves itself to a versioned `HCKM` artifact, and — when the
//! hierarchical engine backs it — exposes the Algorithm-3 predictor for
//! partition-tree sharding. [`load_any`] reads any `HCKM` file back into
//! a `Box<dyn Model>` without the caller knowing the kind.
//!
//! # Walkthrough: train → save → shard → serve
//!
//! ```no_run
//! use hck::data::{spec_by_name, synthetic};
//! use hck::kernels::Gaussian;
//! use hck::learn::{EngineSpec, TrainConfig};
//! use hck::model::{fit, load_any, Model, ModelSpec};
//!
//! // 1. Train any engine through one spec type.
//! let (train, test) = synthetic::generate(spec_by_name("cadata").unwrap(), 2000, 500, 1);
//! let spec = ModelSpec::krr(
//!     TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 128 }),
//! );
//! let model: Box<dyn Model> = fit(&spec, &train)?;
//!
//! // 2. Save a self-describing artifact; reload without knowing the kind.
//! model.save("m.hckm")?;
//! let loaded = load_any("m.hckm")?;
//! assert_eq!(loaded.schema().dim, train.d());
//! let preds = loaded.predict_batch(&test.x);
//!
//! // 3. Cut the artifact into self-contained serving shards on disk
//! //    (the schema's normalization stats ride along) …
//! let pred = loaded.hierarchical_predictor().expect("hierarchical engine");
//! hck::shard::save_shard_dir(pred, 2, "shards/", loaded.schema().normalization.as_deref())?;
//!
//! // 4. … and serve them from another process, no retraining:
//! //    `hck serve --shard-dir shards/` (or in-process:)
//! let sharded = hck::shard::load_shard_dir("shards/")?;
//! let svc = hck::coordinator::PredictionService::start(
//!     std::sync::Arc::new(sharded),
//!     hck::coordinator::BatchPolicy::default(),
//! );
//! # let _ = (preds, svc);
//! # Ok::<(), hck::Error>(())
//! ```
//!
//! The same flow drives the CLI: `hck train --save m.hckm`,
//! `hck predict --model m.hckm`, `hck shard --model m.hckm --out dir/`,
//! `hck serve --model m.hckm | --shard-dir dir/`.

pub mod persist;

pub use persist::{load_any, FORMAT_VERSION};

use crate::data::{Dataset, Task};
use crate::error::Result;
use crate::gp::GpRegressor;
use crate::hkernel::{HConfig, HFactors, HPredictor};
use crate::learn::krr::EngineSpec;
use crate::learn::{KpcaTransformer, KrrModel, TrainConfig};
use crate::linalg::Mat;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Which learner an artifact holds. Doubles as the `HCKM` header tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// KRR on the paper's hierarchically compositional kernel.
    KrrHierarchical,
    /// KRR on the Nyström low-rank kernel.
    KrrNystrom,
    /// KRR on random Fourier features.
    KrrFourier,
    /// KRR on the cross-domain independent kernel.
    KrrIndependent,
    /// KRR on the exact dense kernel.
    KrrExact,
    /// Gaussian-process posterior mean on the hierarchical kernel.
    Gp,
    /// Kernel-PCA transform on the hierarchical kernel.
    Kpca,
}

impl ModelKind {
    /// Stable short name (CLI reports, artifact listings).
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::KrrHierarchical => "krr-hierarchical",
            ModelKind::KrrNystrom => "krr-nystrom",
            ModelKind::KrrFourier => "krr-fourier",
            ModelKind::KrrIndependent => "krr-independent",
            ModelKind::KrrExact => "krr-exact",
            ModelKind::Gp => "gp",
            ModelKind::Kpca => "kpca",
        }
    }

    /// The kind a fitted KRR engine maps to.
    pub fn of_engine(engine: EngineSpec) -> ModelKind {
        match engine {
            EngineSpec::Hierarchical { .. } => ModelKind::KrrHierarchical,
            EngineSpec::Nystrom { .. } => ModelKind::KrrNystrom,
            EngineSpec::Fourier { .. } => ModelKind::KrrFourier,
            EngineSpec::Independent { .. } => ModelKind::KrrIndependent,
            EngineSpec::Exact => ModelKind::KrrExact,
        }
    }
}

/// Self-describing metadata carried by every fitted model and serialized
/// into the `HCKM` header, so a loaded artifact knows how to validate and
/// preprocess requests without side-channel configuration.
#[derive(Debug, Clone)]
pub struct ModelSchema {
    /// Which learner this is.
    pub kind: ModelKind,
    /// Feature dimension d the model was trained on.
    pub dim: usize,
    /// Output columns per prediction (m; embedding dim for KPCA).
    pub outputs: usize,
    /// The training task (decides how raw outputs decode to labels).
    pub task: Task,
    /// Per-column (min, max) ranges of the `[0, 1]` normalization applied
    /// to the training features, when the training pipeline normalized
    /// (see [`crate::data::preprocess::normalize_unit`]). `None` when the
    /// model was trained on raw features.
    pub normalization: Option<Vec<(f64, f64)>>,
}

impl ModelSchema {
    /// One-line human-readable description.
    pub fn summary(&self) -> String {
        format!(
            "{} (d={}, outputs={}, task={:?}{})",
            self.kind.name(),
            self.dim,
            self.outputs,
            self.task,
            if self.normalization.is_some() { ", normalized features" } else { "" }
        )
    }
}

/// A fitted model behind one uniform surface: batch prediction, schema
/// introspection, artifact persistence, and (when hierarchical factors
/// back it) access to the Algorithm-3 predictor for sharding. All
/// implementations are `Send + Sync`, so an `Arc<dyn Model>` drops
/// straight behind [`crate::coordinator::PredictionService`].
pub trait Model: Send + Sync {
    /// Predict raw outputs for a batch of query rows (q.rows() x outputs).
    fn predict_batch(&self, q: &Mat) -> Mat;

    /// The model's self-description (also the artifact header).
    fn schema(&self) -> &ModelSchema;

    /// Write a self-describing `HCKM` artifact; [`load_any`] restores it.
    fn save(&self, path: &str) -> Result<()>;

    /// The long-lived Algorithm-3 predictor, when the model is backed by
    /// hierarchical factors — the input to partition-tree sharding
    /// ([`crate::shard::split_predictor`] / [`crate::shard::save_shard_dir`]).
    fn hierarchical_predictor(&self) -> Option<&HPredictor> {
        None
    }

    /// Feature dimension d (from the schema).
    fn dim(&self) -> usize {
        self.schema().dim
    }

    /// Output columns m (from the schema).
    fn outputs(&self) -> usize {
        self.schema().outputs
    }

    /// Apply the artifact's recorded feature normalization to raw query
    /// rows (identity when the model was trained on raw features). The
    /// queries must already have the model's dimension.
    fn normalize(&self, q: &Mat) -> Mat {
        let mut out = q.clone();
        if let Some(ranges) = &self.schema().normalization {
            crate::data::preprocess::apply_normalization(&mut out, ranges);
        }
        out
    }
}

/// Every `Arc<dyn Model>` is a coordinator predictor: artifact-loaded
/// models drop behind the dynamic batcher (and the TCP front) without
/// engine-specific plumbing. The serving path applies the artifact's
/// recorded feature normalization here, so TCP clients send **raw**
/// features and get the same answers as `hck predict --model` (which
/// normalizes explicitly).
impl crate::coordinator::Predictor for Arc<dyn Model> {
    fn predict_batch(&self, q: &Mat) -> Mat {
        if self.schema().normalization.is_some() {
            Model::predict_batch(self.as_ref(), &self.normalize(q))
        } else {
            Model::predict_batch(self.as_ref(), q)
        }
    }
    fn dim(&self) -> usize {
        self.schema().dim
    }
    fn outputs(&self) -> usize {
        self.schema().outputs
    }
}

/// The algorithm half of a [`ModelSpec`].
#[derive(Debug, Clone)]
pub enum Algo {
    /// Kernel ridge regression / one-vs-all classification, any engine.
    Krr(TrainConfig),
    /// GP posterior mean on the hierarchical kernel with noise λ.
    Gp {
        /// Hierarchical factor configuration.
        config: HConfig,
        /// Noise variance λ.
        lambda: f64,
    },
    /// Kernel-PCA transform on the hierarchical kernel.
    Kpca {
        /// Hierarchical factor configuration.
        config: HConfig,
        /// Embedding dimension.
        dim: usize,
        /// Lanczos iteration budget (0 = auto).
        iters: usize,
    },
}

/// What to fit: an algorithm plus optional preprocessing stats to bake
/// into the artifact. Builder-style construction:
///
/// ```
/// use hck::kernels::Gaussian;
/// use hck::learn::{EngineSpec, TrainConfig};
/// use hck::model::ModelSpec;
/// let spec = ModelSpec::krr(
///     TrainConfig::new(Gaussian::new(0.5), EngineSpec::Nystrom { rank: 64 }),
/// );
/// assert!(spec.normalization.is_none());
/// ```
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Which learner to fit.
    pub algo: Algo,
    /// Per-column (min, max) normalization already applied to the
    /// training features; recorded in the artifact so the serving side
    /// can preprocess raw queries identically.
    pub normalization: Option<Vec<(f64, f64)>>,
}

impl ModelSpec {
    /// KRR (any engine) spec.
    pub fn krr(config: TrainConfig) -> ModelSpec {
        ModelSpec { algo: Algo::Krr(config), normalization: None }
    }

    /// GP regression spec.
    pub fn gp(config: HConfig, lambda: f64) -> ModelSpec {
        ModelSpec { algo: Algo::Gp { config, lambda }, normalization: None }
    }

    /// KPCA spec with the default Lanczos budget.
    pub fn kpca(config: HConfig, dim: usize) -> ModelSpec {
        ModelSpec { algo: Algo::Kpca { config, dim, iters: 0 }, normalization: None }
    }

    /// Record the preprocessing applied to the training features.
    pub fn with_normalization(mut self, ranges: Vec<(f64, f64)>) -> Self {
        self.normalization = Some(ranges);
        self
    }

    /// Fit on a data set (sugar for [`fit`]).
    pub fn fit(&self, ds: &Dataset) -> Result<Box<dyn Model>> {
        fit(self, ds)
    }
}

/// Fit a [`ModelSpec`] on a data set, returning the type-erased model.
pub fn fit(spec: &ModelSpec, ds: &Dataset) -> Result<Box<dyn Model>> {
    match &spec.algo {
        Algo::Krr(cfg) => {
            let model = KrrModel::fit_dataset(cfg, ds)?;
            Ok(Box::new(FittedKrr::new(model, ds.task, spec.normalization.clone())))
        }
        Algo::Gp { config, lambda } => {
            let gp = GpRegressor::fit(&ds.x, &ds.y, config.clone(), *lambda)?;
            Ok(Box::new(FittedGp::new(gp, ds.task, spec.normalization.clone())))
        }
        Algo::Kpca { config, dim, iters } => {
            let factors = Arc::new(HFactors::build(&ds.x, config.clone())?);
            // Fork the embedding randomness off the factor seed so spec →
            // model is a pure function.
            let mut rng = Rng::new(config.seed ^ 0x6b70_6361);
            let t = KpcaTransformer::fit(factors, *dim, *iters, &mut rng)?;
            Ok(Box::new(FittedKpca::new(t, ds.task, spec.normalization.clone())))
        }
    }
}

// ---- concrete Model implementations ----

/// [`Model`] face of a fitted [`KrrModel`] (any engine).
pub struct FittedKrr {
    pub(crate) model: KrrModel,
    schema: ModelSchema,
}

impl FittedKrr {
    pub(crate) fn new(
        model: KrrModel,
        task: Task,
        normalization: Option<Vec<(f64, f64)>>,
    ) -> FittedKrr {
        let schema = ModelSchema {
            kind: ModelKind::of_engine(model.config().engine),
            dim: model.dim(),
            outputs: model.outputs(),
            task,
            normalization,
        };
        FittedKrr { model, schema }
    }

    /// The underlying KRR model (metrics, phase timings, engine access).
    pub fn krr(&self) -> &KrrModel {
        &self.model
    }
}

impl Model for FittedKrr {
    fn predict_batch(&self, q: &Mat) -> Mat {
        self.model.predict(q)
    }
    fn schema(&self) -> &ModelSchema {
        &self.schema
    }
    fn save(&self, path: &str) -> Result<()> {
        persist::save_krr(self, path)
    }
    fn hierarchical_predictor(&self) -> Option<&HPredictor> {
        self.model.hierarchical_predictor()
    }
}

/// [`Model`] face of a fitted [`GpRegressor`]: the posterior mean served
/// through a long-lived Algorithm-3 predictor (built once at fit/load).
pub struct FittedGp {
    pub(crate) gp: GpRegressor,
    predictor: HPredictor,
    schema: ModelSchema,
}

impl FittedGp {
    pub(crate) fn new(
        gp: GpRegressor,
        task: Task,
        normalization: Option<Vec<(f64, f64)>>,
    ) -> FittedGp {
        let (factors, _, _, _) = gp.parts();
        let factors = factors.clone();
        let alpha = gp.alpha_original();
        let w = Mat::from_vec(alpha.len(), 1, alpha);
        let predictor = HPredictor::new(factors.clone(), &w);
        let schema = ModelSchema {
            kind: ModelKind::Gp,
            dim: factors.x.cols(),
            outputs: 1,
            task,
            normalization,
        };
        FittedGp { gp, predictor, schema }
    }

    /// The underlying GP (posterior variance, log-likelihood).
    pub fn gp(&self) -> &GpRegressor {
        &self.gp
    }
}

impl Model for FittedGp {
    fn predict_batch(&self, q: &Mat) -> Mat {
        self.predictor.predict_batch(q)
    }
    fn schema(&self) -> &ModelSchema {
        &self.schema
    }
    fn save(&self, path: &str) -> Result<()> {
        persist::save_gp(self, path)
    }
    fn hierarchical_predictor(&self) -> Option<&HPredictor> {
        Some(&self.predictor)
    }
}

/// [`Model`] face of a fitted [`KpcaTransformer`]: `predict_batch` is the
/// out-of-sample embedding (one row per query, `dim` columns).
pub struct FittedKpca {
    pub(crate) transformer: KpcaTransformer,
    schema: ModelSchema,
}

impl FittedKpca {
    pub(crate) fn new(
        transformer: KpcaTransformer,
        task: Task,
        normalization: Option<Vec<(f64, f64)>>,
    ) -> FittedKpca {
        let schema = ModelSchema {
            kind: ModelKind::Kpca,
            dim: transformer.factors().x.cols(),
            outputs: transformer.dim(),
            task,
            normalization,
        };
        FittedKpca { transformer, schema }
    }

    /// The underlying transform (training embedding, factors).
    pub fn transformer(&self) -> &KpcaTransformer {
        &self.transformer
    }
}

impl Model for FittedKpca {
    fn predict_batch(&self, q: &Mat) -> Mat {
        self.transformer.transform(q)
    }
    fn schema(&self) -> &ModelSchema {
        &self.schema
    }
    fn save(&self, path: &str) -> Result<()> {
        persist::save_kpca(self, path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{spec_by_name, synthetic};
    use crate::kernels::Gaussian;

    fn small() -> (Dataset, Dataset) {
        let spec = spec_by_name("cadata").unwrap();
        synthetic::generate(spec, 300, 60, 31)
    }

    #[test]
    fn fit_dispatches_all_kinds() {
        let (train, test) = small();
        let cases: Vec<(ModelSpec, ModelKind, usize)> = vec![
            (
                ModelSpec::krr(TrainConfig::new(
                    Gaussian::new(0.5),
                    EngineSpec::Hierarchical { rank: 24 },
                )),
                ModelKind::KrrHierarchical,
                1,
            ),
            (
                ModelSpec::krr(TrainConfig::new(
                    Gaussian::new(0.5),
                    EngineSpec::Nystrom { rank: 24 },
                )),
                ModelKind::KrrNystrom,
                1,
            ),
            (
                ModelSpec::gp(HConfig::new(Gaussian::new(0.5), 16).with_seed(2), 0.05),
                ModelKind::Gp,
                1,
            ),
            (
                ModelSpec::kpca(HConfig::new(Gaussian::new(0.5), 16).with_seed(3), 4),
                ModelKind::Kpca,
                4,
            ),
        ];
        for (spec, kind, outputs) in cases {
            let model = fit(&spec, &train).unwrap();
            let schema = model.schema();
            assert_eq!(schema.kind, kind);
            assert_eq!(schema.dim, train.d());
            assert_eq!(schema.outputs, outputs, "{}", kind.name());
            let preds = model.predict_batch(&test.x);
            assert_eq!(preds.shape(), (test.n(), outputs));
            assert!(preds.as_slice().iter().all(|v| v.is_finite()), "{}", kind.name());
        }
    }

    #[test]
    fn gp_and_hierarchical_expose_shardable_predictor() {
        let (train, _) = small();
        let hier = fit(
            &ModelSpec::krr(TrainConfig::new(
                Gaussian::new(0.5),
                EngineSpec::Hierarchical { rank: 24 },
            )),
            &train,
        )
        .unwrap();
        assert!(hier.hierarchical_predictor().is_some());
        let gp = fit(
            &ModelSpec::gp(HConfig::new(Gaussian::new(0.5), 16).with_seed(5), 0.05),
            &train,
        )
        .unwrap();
        assert!(gp.hierarchical_predictor().is_some());
        let nys = fit(
            &ModelSpec::krr(TrainConfig::new(
                Gaussian::new(0.5),
                EngineSpec::Nystrom { rank: 24 },
            )),
            &train,
        )
        .unwrap();
        assert!(nys.hierarchical_predictor().is_none());
    }

    #[test]
    fn normalize_applies_recorded_ranges() {
        let (train, _) = small();
        let d = train.d();
        let ranges: Vec<(f64, f64)> = (0..d).map(|_| (0.0, 2.0)).collect();
        let spec = ModelSpec::krr(TrainConfig::new(
            Gaussian::new(0.5),
            EngineSpec::Nystrom { rank: 16 },
        ))
        .with_normalization(ranges);
        let model = fit(&spec, &train).unwrap();
        let q = Mat::from_fn(2, d, |_, _| 1.0);
        let norm = model.normalize(&q);
        assert!(norm.as_slice().iter().all(|&v| (v - 0.5).abs() < 1e-15));
        // GP predictor through the Predictor impl (Arc<dyn Model>).
        let arc: Arc<dyn Model> = Arc::from(model);
        use crate::coordinator::Predictor as _;
        assert_eq!(arc.dim(), d);
        let out = arc.predict_batch(&q);
        assert_eq!(out.rows(), 2);
    }
}
