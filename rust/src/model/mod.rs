//! Unified model surface: one `fit → predict → save → serve` API across
//! every learner in the crate, with self-describing artifacts.
//!
//! The paper's promise is one kernel machinery serving regression,
//! classification, and KPCA at O(nr) memory; this module is the single
//! entry point that delivers it. A [`ModelSpec`] says *what* to fit
//! (any of the five KRR engines, the GP posterior, or a KPCA transform);
//! [`fit`] returns a type-erased [`Model`] that predicts in batches,
//! reports its own [`ModelSchema`] (kind, dims, task, preprocessing
//! stats), saves itself to a versioned `HCKM` artifact, and — when the
//! hierarchical engine backs it — exposes the Algorithm-3 predictor for
//! partition-tree sharding. [`load_any`] reads any `HCKM` file back into
//! a `Box<dyn Model>` without the caller knowing the kind.
//!
//! # Walkthrough: train → save → shard → serve
//!
//! ```no_run
//! use hck::data::{spec_by_name, synthetic};
//! use hck::kernels::Gaussian;
//! use hck::learn::{EngineSpec, TrainConfig};
//! use hck::model::{fit, load_any, Model, ModelSpec};
//!
//! // 1. Train any engine through one spec type.
//! let (train, test) = synthetic::generate(spec_by_name("cadata").unwrap(), 2000, 500, 1);
//! let spec = ModelSpec::krr(
//!     TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 128 }),
//! );
//! let model: Box<dyn Model> = fit(&spec, &train)?;
//!
//! // 2. Save a self-describing artifact; reload without knowing the kind.
//! model.save("m.hckm")?;
//! let loaded = load_any("m.hckm")?;
//! assert_eq!(loaded.schema().dim, train.d());
//! let preds = loaded.predict_batch(&test.x);
//!
//! // 3. Cut the artifact into self-contained serving shards on disk
//! //    (the schema's normalization stats ride along) …
//! let pred = loaded.hierarchical_predictor().expect("hierarchical engine");
//! hck::shard::save_shard_dir(pred, 2, "shards/", loaded.schema().normalization.as_deref())?;
//!
//! // 4. … and serve them from another process, no retraining:
//! //    `hck serve --shard-dir shards/` (or in-process:)
//! let sharded = hck::shard::load_shard_dir("shards/")?;
//! let svc = hck::coordinator::PredictionService::start(
//!     std::sync::Arc::new(sharded),
//!     hck::coordinator::BatchPolicy::default(),
//! );
//! # let _ = (preds, svc);
//! # Ok::<(), hck::Error>(())
//! ```
//!
//! The same flow drives the CLI: `hck train --save m.hckm`,
//! `hck predict --model m.hckm`, `hck shard --model m.hckm --out dir/`,
//! `hck serve --model m.hckm | --shard-dir dir/`.

pub mod persist;

pub use persist::{load_any, read_header, ArtifactHeader, FORMAT_VERSION};

use crate::data::{Dataset, Task};
use crate::error::Result;
use crate::gp::GpRegressor;
use crate::hkernel::{HConfig, HFactors, HPredictor, HVariance, LazyVariance};
use crate::infer::{
    Capabilities, InferResult, LeafRoute, PredictError, PredictRequest, PredictResponse,
};
use crate::learn::krr::EngineSpec;
use crate::learn::{KpcaTransformer, KrrModel, TrainConfig};
use crate::linalg::Mat;
use crate::partition::PartitionTree;
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Which learner an artifact holds. Doubles as the `HCKM` header tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// KRR on the paper's hierarchically compositional kernel.
    KrrHierarchical,
    /// KRR on the Nyström low-rank kernel.
    KrrNystrom,
    /// KRR on random Fourier features.
    KrrFourier,
    /// KRR on the cross-domain independent kernel.
    KrrIndependent,
    /// KRR on the exact dense kernel.
    KrrExact,
    /// Gaussian-process posterior mean on the hierarchical kernel.
    Gp,
    /// Kernel-PCA transform on the hierarchical kernel.
    Kpca,
}

impl ModelKind {
    /// Stable short name (CLI reports, artifact listings).
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::KrrHierarchical => "krr-hierarchical",
            ModelKind::KrrNystrom => "krr-nystrom",
            ModelKind::KrrFourier => "krr-fourier",
            ModelKind::KrrIndependent => "krr-independent",
            ModelKind::KrrExact => "krr-exact",
            ModelKind::Gp => "gp",
            ModelKind::Kpca => "kpca",
        }
    }

    /// The kind a fitted KRR engine maps to.
    pub fn of_engine(engine: EngineSpec) -> ModelKind {
        match engine {
            EngineSpec::Hierarchical { .. } => ModelKind::KrrHierarchical,
            EngineSpec::Nystrom { .. } => ModelKind::KrrNystrom,
            EngineSpec::Fourier { .. } => ModelKind::KrrFourier,
            EngineSpec::Independent { .. } => ModelKind::KrrIndependent,
            EngineSpec::Exact => ModelKind::KrrExact,
        }
    }
}

/// Self-describing metadata carried by every fitted model and serialized
/// into the `HCKM` header, so a loaded artifact knows how to validate and
/// preprocess requests without side-channel configuration.
#[derive(Debug, Clone)]
pub struct ModelSchema {
    /// Which learner this is.
    pub kind: ModelKind,
    /// Feature dimension d the model was trained on.
    pub dim: usize,
    /// Output columns per prediction (m; embedding dim for KPCA).
    pub outputs: usize,
    /// The training task (decides how raw outputs decode to labels).
    pub task: Task,
    /// Per-column (min, max) ranges of the `[0, 1]` normalization applied
    /// to the training features, when the training pipeline normalized
    /// (see [`crate::data::preprocess::normalize_unit`]). `None` when the
    /// model was trained on raw features.
    pub normalization: Option<Vec<(f64, f64)>>,
}

impl ModelSchema {
    /// One-line human-readable description.
    pub fn summary(&self) -> String {
        format!(
            "{} (d={}, outputs={}, task={:?}{})",
            self.kind.name(),
            self.dim,
            self.outputs,
            self.task,
            if self.normalization.is_some() { ", normalized features" } else { "" }
        )
    }

    /// What this kind of model can put in a
    /// [`crate::infer::PredictResponse`] — the negotiation set callers
    /// (CLI, service, router) consult instead of guessing:
    ///
    /// - every kind serves the mean;
    /// - `gp` additionally serves the posterior variance;
    /// - the hierarchical-factor kinds (`krr-hierarchical`, `gp`, `kpca`)
    ///   serve per-query leaf routes.
    pub fn capabilities(&self) -> Capabilities {
        match self.kind {
            ModelKind::Gp => Capabilities { mean: true, variance: true, leaf_route: true },
            ModelKind::KrrHierarchical | ModelKind::Kpca => {
                Capabilities { mean: true, variance: false, leaf_route: true }
            }
            _ => Capabilities::mean_only(),
        }
    }

    /// Machine-readable description (the `schema` TCP command and
    /// `hck predict --json` header): kind, dims, task, preprocessing
    /// presence, and the capability set.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str(self.kind.name().into())),
            ("dim", Json::Num(self.dim as f64)),
            ("outputs", Json::Num(self.outputs as f64)),
            ("task", Json::Str(format!("{:?}", self.task))),
            ("normalized_features", Json::Bool(self.normalization.is_some())),
            ("capabilities", self.capabilities().to_json()),
        ])
    }
}

/// A fitted model behind one uniform surface: typed batch prediction
/// ([`crate::infer::PredictRequest`] → [`crate::infer::PredictResponse`]),
/// schema/capability introspection, artifact persistence, and (when
/// hierarchical factors back it) access to the Algorithm-3 predictor for
/// sharding. All implementations are `Send + Sync`, so an
/// `Arc<dyn Model>` drops straight behind
/// [`crate::coordinator::PredictionService`].
pub trait Model: Send + Sync {
    /// Serve one typed request — the single inference entry point.
    ///
    /// Validates the batch (dimension, finiteness), rejects wants outside
    /// the model's [`ModelSchema::capabilities`], applies the artifact's
    /// recorded feature normalization (unless
    /// [`crate::infer::PredictOpts::pre_normalized`]), and returns the
    /// requested columns. A mean-only request reproduces the
    /// pre-protocol `predict_batch` outputs bitwise.
    fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse>;

    /// The model's self-description (also the artifact header).
    fn schema(&self) -> &ModelSchema;

    /// Write a self-describing `HCKM` artifact with header metadata
    /// attached (ordered key/value string pairs — e.g. the training
    /// phase breakdown); [`load_any`] restores the model and
    /// [`read_header`] reads the metadata back without touching the
    /// payload.
    fn save_meta(&self, path: &str, meta: &[(String, String)]) -> Result<()>;

    /// Write a self-describing `HCKM` artifact; [`load_any`] restores it.
    fn save(&self, path: &str) -> Result<()> {
        self.save_meta(path, &[])
    }

    /// The long-lived Algorithm-3 predictor, when the model is backed by
    /// hierarchical factors — the input to partition-tree sharding
    /// ([`crate::shard::split_predictor`] / [`crate::shard::save_shard_dir`]).
    fn hierarchical_predictor(&self) -> Option<&HPredictor> {
        None
    }

    /// The shared lazy posterior-variance state, for models with the
    /// `variance` capability (`None` otherwise). The sharded serving
    /// front attaches the `Arc` to every worker, so sharded variance
    /// shares one factorization with the in-process pass and matches it
    /// exactly ([`crate::shard::ShardedPredictor::from_model`]); the
    /// O(nr²) factorization itself runs on the first variance request,
    /// never for mean-only traffic.
    fn variance_state(&self) -> Option<Arc<LazyVariance>> {
        None
    }

    /// What this model can serve (from the schema).
    fn capabilities(&self) -> Capabilities {
        self.schema().capabilities()
    }

    /// Feature dimension d (from the schema).
    fn dim(&self) -> usize {
        self.schema().dim
    }

    /// Output columns m (from the schema).
    fn outputs(&self) -> usize {
        self.schema().outputs
    }

    /// Apply the artifact's recorded feature normalization to raw query
    /// rows (identity when the model was trained on raw features). The
    /// queries must already have the model's dimension.
    fn normalize(&self, q: &Mat) -> Mat {
        let mut out = q.clone();
        if let Some(ranges) = &self.schema().normalization {
            crate::data::preprocess::apply_normalization(&mut out, ranges);
        }
        out
    }

    /// Mean-only convenience on **already-normalized** queries — the
    /// pre-protocol `predict_batch` semantics, kept for in-process
    /// callers and tests. Panics on a rejected request (use
    /// [`Model::predict`] for typed errors).
    fn predict_batch(&self, q: &Mat) -> Mat {
        match self.predict(&PredictRequest::raw_mean(q)) {
            Ok(resp) => resp.mean,
            // hck-lint: allow(serving-no-panic): documented panicking
            // convenience for in-process callers and tests; the serving
            // stack goes through `Model::predict` and its typed errors.
            Err(e) => panic!("predict_batch: {e}"),
        }
    }
}

/// Shared request pipeline for the concrete models: validate the batch,
/// check the want against the capability set, apply the recorded
/// normalization, time the evaluation, and assemble the response. The
/// `variance`/`routes` closures are only invoked when requested (and the
/// capability check already admitted them).
fn serve_request<Fm, Fv, Fr>(
    schema: &ModelSchema,
    req: &PredictRequest,
    mean: Fm,
    variance: Fv,
    routes: Fr,
) -> InferResult<PredictResponse>
where
    Fm: FnOnce(&Mat) -> Mat,
    Fv: FnOnce(&Mat) -> InferResult<Vec<f64>>,
    Fr: FnOnce(&Mat) -> InferResult<Vec<LeafRoute>>,
{
    crate::infer::validate_queries(&req.queries, schema.dim)?;
    schema.capabilities().check(req.want)?;
    let normalized = crate::infer::normalized_queries(req, schema.normalization.as_deref());
    let q: &Mat = normalized.as_ref().unwrap_or(&req.queries);
    let t = std::time::Instant::now();
    let mean = mean(q);
    let variance = if req.want.variance { Some(variance(q)?) } else { None };
    let routes = if req.want.leaf_route { Some(routes(q)?) } else { None };
    let per_query_ns = t.elapsed().as_nanos() as f64 / req.queries.rows() as f64;
    Ok(PredictResponse { mean, variance, routes, per_query_ns })
}

/// Route every query row through a partition tree, reporting each routed
/// leaf's global training-row range (the unsharded side of the
/// [`LeafRoute`] contract; shards report the same ranges plus their id).
/// Shared with the coordinator's `KrrModel` predictor impl.
pub(crate) fn routes_of_tree(tree: &PartitionTree, q: &Mat) -> Vec<LeafRoute> {
    (0..q.rows())
        .map(|i| {
            let leaf = tree.route_leaf(q.row(i));
            let nd = &tree.nodes[leaf];
            LeafRoute { shard: None, rows_lo: nd.lo, rows_hi: nd.hi }
        })
        .collect()
}

/// Every `Arc<dyn Model>` is a coordinator predictor: artifact-loaded
/// models drop behind the dynamic batcher (and the TCP front) without
/// engine-specific plumbing. Requests arrive with **raw** features on the
/// wire; [`Model::predict`] applies the artifact's recorded normalization,
/// so TCP clients get the same answers as `hck predict --model`.
impl crate::coordinator::Predictor for Arc<dyn Model> {
    fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse> {
        Model::predict(self.as_ref(), req)
    }
    fn dim(&self) -> usize {
        self.schema().dim
    }
    fn outputs(&self) -> usize {
        self.schema().outputs
    }
    fn capabilities(&self) -> Capabilities {
        self.schema().capabilities()
    }
    fn schema_json(&self) -> Option<Json> {
        Some(self.schema().to_json())
    }
}

/// The algorithm half of a [`ModelSpec`].
#[derive(Debug, Clone)]
pub enum Algo {
    /// Kernel ridge regression / one-vs-all classification, any engine.
    Krr(TrainConfig),
    /// GP posterior mean on the hierarchical kernel with noise λ.
    Gp {
        /// Hierarchical factor configuration.
        config: HConfig,
        /// Noise variance λ.
        lambda: f64,
    },
    /// Kernel-PCA transform on the hierarchical kernel.
    Kpca {
        /// Hierarchical factor configuration.
        config: HConfig,
        /// Embedding dimension.
        dim: usize,
        /// Lanczos iteration budget (0 = auto).
        iters: usize,
    },
}

/// What to fit: an algorithm plus optional preprocessing stats to bake
/// into the artifact. Builder-style construction:
///
/// ```
/// use hck::kernels::Gaussian;
/// use hck::learn::{EngineSpec, TrainConfig};
/// use hck::model::ModelSpec;
/// let spec = ModelSpec::krr(
///     TrainConfig::new(Gaussian::new(0.5), EngineSpec::Nystrom { rank: 64 }),
/// );
/// assert!(spec.normalization.is_none());
/// ```
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Which learner to fit.
    pub algo: Algo,
    /// Per-column (min, max) normalization already applied to the
    /// training features; recorded in the artifact so the serving side
    /// can preprocess raw queries identically.
    pub normalization: Option<Vec<(f64, f64)>>,
}

impl ModelSpec {
    /// KRR (any engine) spec.
    pub fn krr(config: TrainConfig) -> ModelSpec {
        ModelSpec { algo: Algo::Krr(config), normalization: None }
    }

    /// GP regression spec.
    pub fn gp(config: HConfig, lambda: f64) -> ModelSpec {
        ModelSpec { algo: Algo::Gp { config, lambda }, normalization: None }
    }

    /// KPCA spec with the default Lanczos budget.
    pub fn kpca(config: HConfig, dim: usize) -> ModelSpec {
        ModelSpec { algo: Algo::Kpca { config, dim, iters: 0 }, normalization: None }
    }

    /// Record the preprocessing applied to the training features.
    pub fn with_normalization(mut self, ranges: Vec<(f64, f64)>) -> Self {
        self.normalization = Some(ranges);
        self
    }

    /// Fit on a data set (sugar for [`fit`]).
    pub fn fit(&self, ds: &Dataset) -> Result<Box<dyn Model>> {
        fit(self, ds)
    }
}

/// Fit a [`ModelSpec`] on a data set, returning the type-erased model.
pub fn fit(spec: &ModelSpec, ds: &Dataset) -> Result<Box<dyn Model>> {
    match &spec.algo {
        Algo::Krr(cfg) => {
            let model = KrrModel::fit_dataset(cfg, ds)?;
            Ok(Box::new(FittedKrr::new(model, ds.task, spec.normalization.clone())))
        }
        Algo::Gp { config, lambda } => {
            let gp = GpRegressor::fit(&ds.x, &ds.y, config.clone(), *lambda)?;
            Ok(Box::new(FittedGp::new(gp, ds.task, spec.normalization.clone())))
        }
        Algo::Kpca { config, dim, iters } => {
            let factors = Arc::new(HFactors::build(&ds.x, config.clone())?);
            // Fork the embedding randomness off the factor seed so spec →
            // model is a pure function.
            let mut rng = Rng::new(config.seed ^ 0x6b70_6361);
            let t = KpcaTransformer::fit(factors, *dim, *iters, &mut rng)?;
            Ok(Box::new(FittedKpca::new(t, ds.task, spec.normalization.clone())))
        }
    }
}

// ---- concrete Model implementations ----

/// [`Model`] face of a fitted [`KrrModel`] (any engine).
pub struct FittedKrr {
    pub(crate) model: KrrModel,
    schema: ModelSchema,
}

impl FittedKrr {
    pub(crate) fn new(
        model: KrrModel,
        task: Task,
        normalization: Option<Vec<(f64, f64)>>,
    ) -> FittedKrr {
        let schema = ModelSchema {
            kind: ModelKind::of_engine(model.config().engine),
            dim: model.dim(),
            outputs: model.outputs(),
            task,
            normalization,
        };
        FittedKrr { model, schema }
    }

    /// The underlying KRR model (metrics, phase timings, engine access).
    pub fn krr(&self) -> &KrrModel {
        &self.model
    }
}

impl Model for FittedKrr {
    fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse> {
        serve_request(
            &self.schema,
            req,
            |q| self.model.predict(q),
            |_| Err(PredictError::Unsupported("krr serves no variance".into())),
            |q| {
                // Admitted by the capability check only for the
                // hierarchical engine, which always has a predictor; a
                // mismatch is an internal invariant breach, not a panic.
                let pred = self.model.hierarchical_predictor().ok_or_else(|| {
                    PredictError::Internal(
                        "leaf_route capability admitted without hierarchical factors".into(),
                    )
                })?;
                Ok(routes_of_tree(&pred.factors().tree, q))
            },
        )
    }
    fn schema(&self) -> &ModelSchema {
        &self.schema
    }
    fn save_meta(&self, path: &str, meta: &[(String, String)]) -> Result<()> {
        persist::save_krr(self, path, meta)
    }
    fn hierarchical_predictor(&self) -> Option<&HPredictor> {
        self.model.hierarchical_predictor()
    }
}

/// [`Model`] face of a fitted [`GpRegressor`]: the posterior mean served
/// through a long-lived Algorithm-3 predictor (built once at fit/load),
/// and the posterior **variance** served through a lazily-built, cached
/// [`HVariance`] state — the `variance` capability of the unified API.
pub struct FittedGp {
    pub(crate) gp: GpRegressor,
    predictor: HPredictor,
    /// Shared lazy variance state: the O(nr²) factorization runs on the
    /// first variance request (mean-only deployments never pay it) and
    /// the same `Arc` rides into shard workers, so in-process and
    /// sharded serving share one factorization. A failed factorization
    /// is cached as an error string rather than refactored per request.
    variance: Arc<LazyVariance>,
    schema: ModelSchema,
}

impl FittedGp {
    pub(crate) fn new(
        gp: GpRegressor,
        task: Task,
        normalization: Option<Vec<(f64, f64)>>,
    ) -> FittedGp {
        let (factors, _, _, _) = gp.parts();
        let factors = factors.clone();
        let alpha = gp.alpha_original();
        let w = Mat::from_vec(alpha.len(), 1, alpha);
        let predictor = HPredictor::new(factors.clone(), &w);
        let schema = ModelSchema {
            kind: ModelKind::Gp,
            dim: factors.x.cols(),
            outputs: 1,
            task,
            normalization,
        };
        let variance = Arc::new(LazyVariance::new(factors, gp.lambda()));
        FittedGp { gp, predictor, variance, schema }
    }

    /// The underlying GP (posterior variance, log-likelihood).
    pub fn gp(&self) -> &GpRegressor {
        &self.gp
    }

    /// The cached batched variance state (factored on first use).
    fn variance_cached(&self) -> InferResult<&HVariance> {
        self.variance.get().map_err(PredictError::Internal)
    }
}

impl Model for FittedGp {
    fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse> {
        serve_request(
            &self.schema,
            req,
            |q| self.predictor.predict_batch(q),
            |q| self.variance_cached().map(|hv| hv.variance_batch(q)),
            |q| Ok(routes_of_tree(&self.predictor.factors().tree, q)),
        )
    }
    fn schema(&self) -> &ModelSchema {
        &self.schema
    }
    fn save_meta(&self, path: &str, meta: &[(String, String)]) -> Result<()> {
        persist::save_gp(self, path, meta)
    }
    fn hierarchical_predictor(&self) -> Option<&HPredictor> {
        Some(&self.predictor)
    }
    fn variance_state(&self) -> Option<Arc<LazyVariance>> {
        Some(self.variance.clone())
    }
}

/// [`Model`] face of a fitted [`KpcaTransformer`]: `predict_batch` is the
/// out-of-sample embedding (one row per query, `dim` columns).
pub struct FittedKpca {
    pub(crate) transformer: KpcaTransformer,
    schema: ModelSchema,
}

impl FittedKpca {
    pub(crate) fn new(
        transformer: KpcaTransformer,
        task: Task,
        normalization: Option<Vec<(f64, f64)>>,
    ) -> FittedKpca {
        let schema = ModelSchema {
            kind: ModelKind::Kpca,
            dim: transformer.factors().x.cols(),
            outputs: transformer.dim(),
            task,
            normalization,
        };
        FittedKpca { transformer, schema }
    }

    /// The underlying transform (training embedding, factors).
    pub fn transformer(&self) -> &KpcaTransformer {
        &self.transformer
    }
}

impl Model for FittedKpca {
    fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse> {
        serve_request(
            &self.schema,
            req,
            |q| self.transformer.transform(q),
            |_| Err(PredictError::Unsupported("kpca serves no variance".into())),
            |q| Ok(routes_of_tree(&self.transformer.factors().tree, q)),
        )
    }
    fn schema(&self) -> &ModelSchema {
        &self.schema
    }
    fn save_meta(&self, path: &str, meta: &[(String, String)]) -> Result<()> {
        persist::save_kpca(self, path, meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{spec_by_name, synthetic};
    use crate::kernels::Gaussian;

    fn small() -> (Dataset, Dataset) {
        let spec = spec_by_name("cadata").unwrap();
        synthetic::generate(spec, 300, 60, 31)
    }

    #[test]
    fn fit_dispatches_all_kinds() {
        let (train, test) = small();
        let cases: Vec<(ModelSpec, ModelKind, usize)> = vec![
            (
                ModelSpec::krr(TrainConfig::new(
                    Gaussian::new(0.5),
                    EngineSpec::Hierarchical { rank: 24 },
                )),
                ModelKind::KrrHierarchical,
                1,
            ),
            (
                ModelSpec::krr(TrainConfig::new(
                    Gaussian::new(0.5),
                    EngineSpec::Nystrom { rank: 24 },
                )),
                ModelKind::KrrNystrom,
                1,
            ),
            (
                ModelSpec::gp(HConfig::new(Gaussian::new(0.5), 16).with_seed(2), 0.05),
                ModelKind::Gp,
                1,
            ),
            (
                ModelSpec::kpca(HConfig::new(Gaussian::new(0.5), 16).with_seed(3), 4),
                ModelKind::Kpca,
                4,
            ),
        ];
        for (spec, kind, outputs) in cases {
            let model = fit(&spec, &train).unwrap();
            let schema = model.schema();
            assert_eq!(schema.kind, kind);
            assert_eq!(schema.dim, train.d());
            assert_eq!(schema.outputs, outputs, "{}", kind.name());
            let preds = model.predict_batch(&test.x);
            assert_eq!(preds.shape(), (test.n(), outputs));
            assert!(preds.as_slice().iter().all(|v| v.is_finite()), "{}", kind.name());
        }
    }

    #[test]
    fn gp_and_hierarchical_expose_shardable_predictor() {
        let (train, _) = small();
        let hier = fit(
            &ModelSpec::krr(TrainConfig::new(
                Gaussian::new(0.5),
                EngineSpec::Hierarchical { rank: 24 },
            )),
            &train,
        )
        .unwrap();
        assert!(hier.hierarchical_predictor().is_some());
        let gp = fit(
            &ModelSpec::gp(HConfig::new(Gaussian::new(0.5), 16).with_seed(5), 0.05),
            &train,
        )
        .unwrap();
        assert!(gp.hierarchical_predictor().is_some());
        let nys = fit(
            &ModelSpec::krr(TrainConfig::new(
                Gaussian::new(0.5),
                EngineSpec::Nystrom { rank: 24 },
            )),
            &train,
        )
        .unwrap();
        assert!(nys.hierarchical_predictor().is_none());
    }

    #[test]
    fn normalize_applies_recorded_ranges() {
        let (train, _) = small();
        let d = train.d();
        let ranges: Vec<(f64, f64)> = (0..d).map(|_| (0.0, 2.0)).collect();
        let spec = ModelSpec::krr(TrainConfig::new(
            Gaussian::new(0.5),
            EngineSpec::Nystrom { rank: 16 },
        ))
        .with_normalization(ranges);
        let model = fit(&spec, &train).unwrap();
        let q = Mat::from_fn(2, d, |_, _| 1.0);
        let norm = model.normalize(&q);
        assert!(norm.as_slice().iter().all(|&v| (v - 0.5).abs() < 1e-15));
        // GP predictor through the Predictor impl (Arc<dyn Model>).
        let arc: Arc<dyn Model> = Arc::from(model);
        use crate::coordinator::Predictor as _;
        assert_eq!(arc.dim(), d);
        let out = arc.predict_batch(&q);
        assert_eq!(out.rows(), 2);
    }

    #[test]
    fn typed_requests_negotiate_capabilities() {
        use crate::infer::Want;
        let (train, test) = small();
        let gp = fit(
            &ModelSpec::gp(HConfig::new(Gaussian::new(0.5), 16).with_seed(7), 0.05),
            &train,
        )
        .unwrap();
        assert!(gp.capabilities().variance && gp.capabilities().leaf_route);
        let q = test.x.row_range(0, 5);
        let resp = gp
            .predict(&PredictRequest::new(
                q.clone(),
                Want::mean_only().with_variance().with_leaf_route(),
            ))
            .unwrap();
        assert_eq!(resp.mean.shape(), (5, 1));
        let var = resp.variance.unwrap();
        assert_eq!(var.len(), 5);
        assert!(var.iter().all(|v| v.is_finite() && *v >= 0.0));
        let routes = resp.routes.unwrap();
        assert_eq!(routes.len(), 5);
        assert!(routes.iter().all(|r| r.shard.is_none() && r.rows_lo < r.rows_hi));
        assert!(resp.per_query_ns > 0.0);

        // Mean-only requests reproduce the convenience path bitwise.
        let mean_only = gp.predict(&PredictRequest::raw_mean(&q)).unwrap();
        assert_eq!(mean_only.mean.as_slice(), gp.predict_batch(&q).as_slice());
        assert!(mean_only.variance.is_none() && mean_only.routes.is_none());

        // A mean-only engine rejects variance requests with a typed error.
        let nys = fit(
            &ModelSpec::krr(TrainConfig::new(
                Gaussian::new(0.5),
                EngineSpec::Nystrom { rank: 16 },
            )),
            &train,
        )
        .unwrap();
        let err = nys
            .predict(&PredictRequest::new(q.clone(), Want::mean_only().with_variance()))
            .unwrap_err();
        assert_eq!(err.kind(), "unsupported");

        // Malformed batches are BadRequest, not panics.
        let bad = Mat::zeros(2, train.d() + 1);
        assert_eq!(
            gp.predict(&PredictRequest::mean_of(&bad)).unwrap_err().kind(),
            "bad_request"
        );
        let mut nan = q.clone();
        nan.row_mut(0)[0] = f64::NAN;
        assert_eq!(
            gp.predict(&PredictRequest::mean_of(&nan)).unwrap_err().kind(),
            "bad_request"
        );
    }
}
