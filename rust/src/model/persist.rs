//! The versioned `HCKM` artifact format: self-describing persistence for
//! every [`Model`] kind.
//!
//! Layout (little-endian, tagged stream like the `HCK1`/`HCKS` formats
//! it generalizes):
//!
//! ```text
//! "HCKM" | version u64 | schema (kind, dim, outputs, task, norm stats)
//!        | metadata (v2+: count, then key/value string pairs)
//!        | kind-specific payload
//! ```
//!
//! The header alone tells a loader what the artifact is — model kind,
//! feature dimension, output columns, task type, and the feature
//! normalization applied at training time — so [`load_any`] can dispatch
//! and a server can validate/preprocess requests without side-channel
//! configuration. Version 2 adds a free-form **metadata** section of
//! ordered key/value string pairs between schema and payload — the CLI
//! records the training phase breakdown there (`hck train --save` →
//! `hck info`); version-1 files (no metadata) still load. Use
//! [`read_header`] to inspect an artifact without deserializing its
//! payload. Payloads reuse the factor/tree/matrix primitives of
//! [`crate::hkernel::persist`]; everything derived (Cholesky factors,
//! Algorithm-3 predictor state, KPCA aggregate bases) is recomputed
//! deterministically on load, so a reloaded model predicts
//! bit-identically to the saved one.
//!
//! Wrong magic, wrong version, truncated files, and structurally
//! inconsistent payloads are all rejected with a data error — never a
//! panic in the serving path.

use super::{FittedGp, FittedKpca, FittedKrr, Model, ModelKind, ModelSchema};
use crate::approx::{ExactKrr, FourierKrr, IndependentKrr, NystromKrr};
use crate::data::Task;
use crate::error::{Error, Result};
use crate::gp::GpRegressor;
use crate::hkernel::persist::{
    read_f64s, read_factors, read_kind, read_mat, read_opt_mat, read_rule, read_tree, rf64,
    ru64, wf64, write_f64s, write_factors, write_kind, write_mat, write_opt_mat, write_rule,
    write_tree, wu64,
};
use crate::hkernel::HPredictor;
use crate::learn::krr::{EngineSpec, FittedEngine, KrrModel, TrainConfig};
use crate::learn::KpcaTransformer;
use std::io::{BufReader, BufWriter, Read, Write};
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"HCKM";

/// Current `HCKM` format version. Bumped on breaking layout changes;
/// [`load_any`] reads this version and version 1 (v2 = v1 plus the
/// metadata section) and rejects everything else.
pub const FORMAT_VERSION: u64 = 2;

/// Load any `HCKM` artifact as a type-erased [`Model`] — the caller does
/// not need to know what kind of model the file holds.
pub fn load_any(path: &str) -> Result<Box<dyn Model>> {
    let file = std::fs::File::open(path)?;
    let mut inp = BufReader::new(file);
    let (_, schema, _meta) = read_header_from(&mut inp)?;
    match schema.kind {
        ModelKind::KrrHierarchical
        | ModelKind::KrrNystrom
        | ModelKind::KrrFourier
        | ModelKind::KrrIndependent
        | ModelKind::KrrExact => read_krr(&mut inp, schema),
        ModelKind::Gp => read_gp(&mut inp, schema),
        ModelKind::Kpca => read_kpca(&mut inp, schema),
    }
}

/// Everything before the kind-specific payload of an `HCKM` artifact.
#[derive(Debug, Clone)]
pub struct ArtifactHeader {
    /// The on-disk format version (1 or 2).
    pub version: u64,
    /// The model's self-description.
    pub schema: ModelSchema,
    /// Ordered key/value metadata pairs (always empty for version 1) —
    /// e.g. the training phase breakdown recorded by `hck train --save`.
    pub metadata: Vec<(String, String)>,
}

/// Read just the header of an `HCKM` artifact — version, schema, and
/// metadata — without deserializing the payload. Backs `hck info`.
pub fn read_header(path: &str) -> Result<ArtifactHeader> {
    let file = std::fs::File::open(path)?;
    let mut inp = BufReader::new(file);
    let (version, schema, metadata) = read_header_from(&mut inp)?;
    Ok(ArtifactHeader { version, schema, metadata })
}

/// Shared header parse: magic, version gate (1 or [`FORMAT_VERSION`]),
/// schema, and the v2 metadata section.
fn read_header_from(
    inp: &mut impl Read,
) -> Result<(u64, ModelSchema, Vec<(String, String)>)> {
    let mut magic = [0u8; 4];
    inp.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::data("not an HCKM model artifact (bad magic)"));
    }
    let version = ru64(inp)?;
    if version != 1 && version != FORMAT_VERSION {
        return Err(Error::data(format!(
            "unsupported HCKM version {version} (this build reads versions 1..={FORMAT_VERSION})"
        )));
    }
    let schema = read_schema(inp)?;
    let metadata = if version >= 2 { read_metadata(inp)? } else { Vec::new() };
    Ok((version, schema, metadata))
}

// ---- metadata (v2) ----

/// Per-string and per-section caps: metadata is a header, not a payload.
const META_MAX_ENTRIES: u64 = 4096;
const META_MAX_STR: u64 = 1 << 20;

fn wstr(out: &mut impl Write, s: &str) -> Result<()> {
    wu64(out, s.len() as u64)?;
    out.write_all(s.as_bytes())?;
    Ok(())
}

fn rstr(inp: &mut impl Read) -> Result<String> {
    let len = ru64(inp)?;
    if len > META_MAX_STR {
        return Err(Error::data("corrupt HCKM artifact (metadata string length)"));
    }
    let mut buf = vec![0u8; len as usize];
    inp.read_exact(&mut buf)?;
    String::from_utf8(buf).map_err(|_| Error::data("corrupt HCKM artifact (metadata utf-8)"))
}

fn write_metadata(out: &mut impl Write, meta: &[(String, String)]) -> Result<()> {
    wu64(out, meta.len() as u64)?;
    for (k, v) in meta {
        wstr(out, k)?;
        wstr(out, v)?;
    }
    Ok(())
}

fn read_metadata(inp: &mut impl Read) -> Result<Vec<(String, String)>> {
    let count = ru64(inp)?;
    if count > META_MAX_ENTRIES {
        return Err(Error::data("corrupt HCKM artifact (metadata entry count)"));
    }
    let mut meta = Vec::with_capacity(count as usize);
    for _ in 0..count {
        let k = rstr(inp)?;
        let v = rstr(inp)?;
        meta.push((k, v));
    }
    Ok(meta)
}

// ---- schema ----

fn kind_tag(kind: ModelKind) -> u64 {
    match kind {
        ModelKind::KrrHierarchical => 0,
        ModelKind::KrrNystrom => 1,
        ModelKind::KrrFourier => 2,
        ModelKind::KrrIndependent => 3,
        ModelKind::KrrExact => 4,
        ModelKind::Gp => 5,
        ModelKind::Kpca => 6,
    }
}

fn kind_from_tag(tag: u64) -> Result<ModelKind> {
    Ok(match tag {
        0 => ModelKind::KrrHierarchical,
        1 => ModelKind::KrrNystrom,
        2 => ModelKind::KrrFourier,
        3 => ModelKind::KrrIndependent,
        4 => ModelKind::KrrExact,
        5 => ModelKind::Gp,
        6 => ModelKind::Kpca,
        _ => return Err(Error::data("corrupt HCKM artifact (model kind tag)")),
    })
}

fn write_schema(out: &mut impl Write, s: &ModelSchema) -> Result<()> {
    wu64(out, kind_tag(s.kind))?;
    wu64(out, s.dim as u64)?;
    wu64(out, s.outputs as u64)?;
    match s.task {
        Task::Regression => wu64(out, 0)?,
        Task::Binary => wu64(out, 1)?,
        Task::Multiclass(k) => {
            wu64(out, 2)?;
            wu64(out, k as u64)?;
        }
    }
    match &s.normalization {
        None => wu64(out, 0)?,
        Some(ranges) => {
            wu64(out, 1)?;
            wu64(out, ranges.len() as u64)?;
            for &(lo, hi) in ranges {
                wf64(out, lo)?;
                wf64(out, hi)?;
            }
        }
    }
    Ok(())
}

fn read_schema(inp: &mut impl Read) -> Result<ModelSchema> {
    let kind = kind_from_tag(ru64(inp)?)?;
    let dim = ru64(inp)? as usize;
    let outputs = ru64(inp)? as usize;
    if dim == 0 || dim > (1usize << 32) || outputs == 0 || outputs > (1usize << 32) {
        return Err(Error::data("corrupt HCKM artifact (schema dims)"));
    }
    let task = match ru64(inp)? {
        0 => Task::Regression,
        1 => Task::Binary,
        2 => Task::Multiclass(ru64(inp)? as usize),
        _ => return Err(Error::data("corrupt HCKM artifact (task tag)")),
    };
    let normalization = match ru64(inp)? {
        0 => None,
        1 => {
            let d = ru64(inp)? as usize;
            if d != dim {
                return Err(Error::data(
                    "corrupt HCKM artifact (normalization dimension mismatch)",
                ));
            }
            let mut ranges = Vec::with_capacity(d);
            for _ in 0..d {
                ranges.push((rf64(inp)?, rf64(inp)?));
            }
            Some(ranges)
        }
        _ => return Err(Error::data("corrupt HCKM artifact (normalization tag)")),
    };
    Ok(ModelSchema { kind, dim, outputs, task, normalization })
}

fn open_for_write(
    path: &str,
    schema: &ModelSchema,
    meta: &[(String, String)],
) -> Result<BufWriter<std::fs::File>> {
    let file = std::fs::File::create(path)?;
    let mut out = BufWriter::new(file);
    out.write_all(MAGIC)?;
    wu64(&mut out, FORMAT_VERSION)?;
    write_schema(&mut out, schema)?;
    write_metadata(&mut out, meta)?;
    Ok(out)
}

// ---- train config (KRR payload prefix) ----

fn write_train_config(out: &mut impl Write, cfg: &TrainConfig) -> Result<()> {
    write_kind(out, cfg.kind)?;
    wf64(out, cfg.lambda)?;
    wf64(out, cfg.lambda_prime)?;
    wu64(out, cfg.seed)?;
    write_rule(out, cfg.rule)?;
    match cfg.engine {
        EngineSpec::Hierarchical { rank } => {
            wu64(out, 0)?;
            wu64(out, rank as u64)?;
        }
        EngineSpec::Nystrom { rank } => {
            wu64(out, 1)?;
            wu64(out, rank as u64)?;
        }
        EngineSpec::Fourier { rank } => {
            wu64(out, 2)?;
            wu64(out, rank as u64)?;
        }
        EngineSpec::Independent { n0 } => {
            wu64(out, 3)?;
            wu64(out, n0 as u64)?;
        }
        EngineSpec::Exact => wu64(out, 4)?,
    }
    Ok(())
}

fn read_train_config(inp: &mut impl Read) -> Result<TrainConfig> {
    let kind = read_kind(inp)?;
    let lambda = rf64(inp)?;
    let lambda_prime = rf64(inp)?;
    let seed = ru64(inp)?;
    let rule = read_rule(inp)?;
    let engine = match ru64(inp)? {
        0 => EngineSpec::Hierarchical { rank: ru64(inp)? as usize },
        1 => EngineSpec::Nystrom { rank: ru64(inp)? as usize },
        2 => EngineSpec::Fourier { rank: ru64(inp)? as usize },
        3 => EngineSpec::Independent { n0: ru64(inp)? as usize },
        4 => EngineSpec::Exact,
        _ => return Err(Error::data("corrupt HCKM artifact (engine tag)")),
    };
    Ok(TrainConfig { kind, lambda, engine, rule, seed, lambda_prime })
}

// ---- KRR ----

pub(crate) fn save_krr(m: &FittedKrr, path: &str, meta: &[(String, String)]) -> Result<()> {
    let mut out = open_for_write(path, m.schema(), meta)?;
    let krr = &m.model;
    write_train_config(&mut out, krr.config())?;
    wu64(&mut out, krr.memory_words as u64)?;
    match krr.engine() {
        FittedEngine::Hierarchical { factors, w, .. } => {
            write_factors(&mut out, factors)?;
            write_mat(&mut out, w)?;
        }
        FittedEngine::Nystrom(e) => {
            let (landmarks, w) = e.parts();
            write_mat(&mut out, landmarks)?;
            write_mat(&mut out, w)?;
        }
        FittedEngine::Fourier(e) => {
            let (omega, b, w) = e.parts();
            write_mat(&mut out, omega)?;
            write_f64s(&mut out, b)?;
            write_mat(&mut out, w)?;
        }
        FittedEngine::Independent(e) => {
            let (tree, x, alpha) = e.parts();
            write_tree(&mut out, tree)?;
            write_mat(&mut out, x)?;
            for a in alpha {
                write_opt_mat(&mut out, a)?;
            }
        }
        FittedEngine::Exact(e) => {
            let (x, alpha) = e.parts();
            write_mat(&mut out, x)?;
            write_mat(&mut out, alpha)?;
        }
    }
    out.flush()?;
    Ok(())
}

fn read_krr(inp: &mut impl Read, schema: ModelSchema) -> Result<Box<dyn Model>> {
    let bad = |what: &str| Err(Error::data(format!("corrupt HCKM artifact ({what})")));
    let cfg = read_train_config(inp)?;
    if ModelKind::of_engine(cfg.engine) != schema.kind {
        return bad("engine does not match schema kind");
    }
    let memory_words = ru64(inp)? as usize;
    let engine = match cfg.engine {
        EngineSpec::Hierarchical { .. } => {
            let f = read_factors(inp)?;
            let w = read_mat(inp)?;
            if f.x.cols() != schema.dim || w.rows() != f.n() || w.cols() != schema.outputs {
                return bad("hierarchical payload shapes");
            }
            let factors = Arc::new(f);
            let predictor = HPredictor::new(factors.clone(), &w);
            FittedEngine::Hierarchical { factors, w, predictor }
        }
        EngineSpec::Nystrom { .. } => {
            let landmarks = read_mat(inp)?;
            let w = read_mat(inp)?;
            if landmarks.cols() != schema.dim || w.cols() != schema.outputs {
                return bad("nystrom payload shapes");
            }
            FittedEngine::Nystrom(NystromKrr::from_parts(cfg.kind, landmarks, w)?)
        }
        EngineSpec::Fourier { .. } => {
            let omega = read_mat(inp)?;
            let b = read_f64s(inp)?;
            let w = read_mat(inp)?;
            if omega.cols() != schema.dim || w.cols() != schema.outputs {
                return bad("fourier payload shapes");
            }
            FittedEngine::Fourier(FourierKrr::from_parts(omega, b, w)?)
        }
        EngineSpec::Independent { .. } => {
            let tree = read_tree(inp)?;
            let x = read_mat(inp)?;
            // The prediction path routes through this tree per query —
            // structural corruption must fail here, like the
            // hierarchical payload's validate_factors.
            crate::hkernel::persist::validate_tree(&tree, x.rows(), x.cols())?;
            let mut alpha = Vec::new();
            for _ in 0..tree.nodes.len() {
                alpha.push(read_opt_mat(inp)?);
            }
            if x.cols() != schema.dim
                || alpha.iter().flatten().any(|a| a.cols() != schema.outputs)
            {
                return bad("independent payload shapes");
            }
            FittedEngine::Independent(IndependentKrr::from_parts(cfg.kind, tree, x, alpha)?)
        }
        EngineSpec::Exact => {
            let x = read_mat(inp)?;
            let alpha = read_mat(inp)?;
            if x.cols() != schema.dim || alpha.cols() != schema.outputs {
                return bad("exact payload shapes");
            }
            FittedEngine::Exact(ExactKrr::from_parts(cfg.kind, x, alpha)?)
        }
    };
    let model =
        KrrModel::from_engine(engine, cfg, schema.dim, schema.outputs, memory_words);
    Ok(Box::new(FittedKrr::new(model, schema.task, schema.normalization)))
}

// ---- GP ----

pub(crate) fn save_gp(m: &FittedGp, path: &str, meta: &[(String, String)]) -> Result<()> {
    let mut out = open_for_write(path, m.schema(), meta)?;
    let (factors, lambda, alpha_tree, log_likelihood) = m.gp.parts();
    wf64(&mut out, lambda)?;
    wf64(&mut out, log_likelihood)?;
    write_factors(&mut out, factors)?;
    write_f64s(&mut out, alpha_tree)?;
    out.flush()?;
    Ok(())
}

fn read_gp(inp: &mut impl Read, schema: ModelSchema) -> Result<Box<dyn Model>> {
    let lambda = rf64(inp)?;
    let log_likelihood = rf64(inp)?;
    let f = read_factors(inp)?;
    if f.x.cols() != schema.dim {
        return Err(Error::data("corrupt HCKM artifact (gp payload shapes)"));
    }
    let alpha_tree = read_f64s(inp)?;
    let gp = GpRegressor::from_parts(Arc::new(f), lambda, alpha_tree, log_likelihood)?;
    Ok(Box::new(FittedGp::new(gp, schema.task, schema.normalization)))
}

// ---- KPCA ----

pub(crate) fn save_kpca(m: &FittedKpca, path: &str, meta: &[(String, String)]) -> Result<()> {
    let mut out = open_for_write(path, m.schema(), meta)?;
    let (factors, proj, row_means, grand_mean, train_embedding) = m.transformer.parts();
    wf64(&mut out, grand_mean)?;
    write_factors(&mut out, factors)?;
    write_mat(&mut out, proj)?;
    write_f64s(&mut out, row_means)?;
    write_mat(&mut out, train_embedding)?;
    out.flush()?;
    Ok(())
}

fn read_kpca(inp: &mut impl Read, schema: ModelSchema) -> Result<Box<dyn Model>> {
    let grand_mean = rf64(inp)?;
    let f = read_factors(inp)?;
    if f.x.cols() != schema.dim {
        return Err(Error::data("corrupt HCKM artifact (kpca payload shapes)"));
    }
    let proj = read_mat(inp)?;
    let row_means = read_f64s(inp)?;
    let train_embedding = read_mat(inp)?;
    if proj.cols() != schema.outputs {
        return Err(Error::data("corrupt HCKM artifact (kpca payload shapes)"));
    }
    let t = KpcaTransformer::from_parts(
        Arc::new(f),
        proj,
        row_means,
        grand_mean,
        train_embedding,
    )?;
    Ok(Box::new(FittedKpca::new(t, schema.task, schema.normalization)))
}
