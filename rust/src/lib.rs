//! # hck — Hierarchically Compositional Kernels
//!
//! A production-grade reproduction of *"Hierarchically Compositional Kernels
//! for Scalable Nonparametric Learning"* (Chen, Avron, Sindhwani, 2016).
//!
//! The library implements the paper's hierarchically compositional kernel
//! `k_hierarchical` — a strictly positive-definite kernel built by marrying
//! the Nyström (globally low-rank) approximation with a locally lossless
//! block-diagonal approximation across a hierarchical partitioning of the
//! data domain — together with the full O(nr)/O(nr^2) structured linear
//! algebra it induces (Algorithms 1–3 of the paper), all baselines the paper
//! compares against (Nyström, random Fourier features, cross-domain
//! independent kernel, exact dense), and the downstream learning tasks
//! (kernel ridge regression, classification, kernel PCA, Gaussian-process
//! log-likelihood / MLE).
//!
//! ## Three-layer architecture
//!
//! - **L3 (this crate)**: the coordinator and the structured-matrix engine —
//!   partition trees, hierarchical factor construction, fast matvec/solve,
//!   out-of-sample prediction, training pipeline, a threaded prediction
//!   server with dynamic batching, CLI.
//! - **L2 (python/compile/model.py)**: JAX compute graphs for kernel-block
//!   evaluation and feature maps, AOT-lowered to HLO text once at build time.
//! - **L1 (python/compile/kernels/)**: Pallas kernels for the tiled pairwise
//!   distance + kernel-application hot spot, lowered inside the L2 graphs.
//!
//! Python never runs at inference time: the Rust binary loads the AOT HLO
//! artifacts through PJRT ([`runtime`]) and otherwise uses its own native
//! kernels ([`kernels`]).

pub mod approx;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod gp;
pub mod hkernel;
pub mod infer;
pub mod learn;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod kernels;
pub mod linalg;
pub mod partition;
pub mod shard;
pub mod util;

pub use error::{Error, Result};
