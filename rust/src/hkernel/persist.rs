//! Binary persistence of fitted hierarchical models.
//!
//! `save_model` serializes the factors (tree, landmarks, Σ/W/U/A blocks)
//! plus the trained weight block `W = (A + λI)^{-1} Y`, so a server can
//! load and serve without re-training (`hck train --save` /
//! `hck serve --model`). The Σ Cholesky factors are recomputed on load
//! (O((n/r)·r³) — negligible next to I/O).
//!
//! Format: little-endian, magic `HCK1`, then a tagged stream. Not a
//! public interchange format — versioned and rejected on mismatch.

use super::build::{HConfig, HFactors};
use crate::error::{Error, Result};
use crate::kernels::KernelKind;
use crate::linalg::{Cholesky, Mat};
use crate::partition::{Node, PartitionTree, Split, SplitRule};
use std::io::{BufReader, BufWriter, Read, Write};

const MAGIC: &[u8; 4] = b"HCK1";

/// Save a fitted model (factors + weights) to a file.
pub fn save_model(f: &HFactors, w: &Mat, path: &str) -> Result<()> {
    let file = std::fs::File::create(path)?;
    let mut out = BufWriter::new(file);
    out.write_all(MAGIC)?;
    write_factors(&mut out, f)?;
    write_mat(&mut out, w)?;
    out.flush()?;
    Ok(())
}

/// Load a fitted model saved by [`save_model`].
pub fn load_model(path: &str) -> Result<(HFactors, Mat)> {
    let file = std::fs::File::open(path)?;
    let mut inp = BufReader::new(file);
    let mut magic = [0u8; 4];
    inp.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::data("not an HCK1 model file"));
    }
    let f = read_factors(&mut inp)?;
    let w = read_mat(&mut inp)?;
    if w.rows() != f.x.rows() {
        return Err(Error::data("weight rows do not match training size"));
    }
    Ok((f, w))
}

/// Serialize the full factor state (config, tree, training points,
/// per-node blocks). Shared by the legacy `HCK1` format and the typed
/// `HCKM` artifacts of [`crate::model`].
pub(crate) fn write_factors(out: &mut impl Write, f: &HFactors) -> Result<()> {
    write_config(out, &f.config)?;
    write_tree(out, &f.tree)?;
    write_mat(out, &f.x)?;
    let nn = f.tree.nodes.len();
    for i in 0..nn {
        write_usizes(out, &f.landmark_idx[i])?;
        write_opt_mat(out, &f.landmarks[i])?;
        write_opt_mat(out, &f.sigma[i])?;
        write_opt_mat(out, &f.w[i])?;
        write_opt_mat(out, &f.u[i])?;
        write_opt_mat(out, &f.a_leaf[i])?;
    }
    Ok(())
}

/// A split must be able to address every child index
/// [`crate::partition::follow_split`] can produce — two children for
/// hyperplane/axis cuts, one center per child for k-means cuts — and,
/// when the feature dimension is known, must index/match it. Shared by
/// every loader whose query walk goes through a decoded split.
pub(crate) fn validate_split(split: &Split, n_children: usize, d: Option<usize>) -> Result<()> {
    let bad = |what: &str| Err(Error::data(format!("corrupt model file ({what})")));
    match split {
        Split::Hyperplane { dir, .. } => {
            if n_children != 2 {
                return bad("split arity");
            }
            if let Some(d) = d {
                if dir.len() != d {
                    return bad("split dimension");
                }
            }
        }
        Split::Axis { axis, .. } => {
            if n_children != 2 {
                return bad("split arity");
            }
            if let Some(d) = d {
                if *axis >= d {
                    return bad("split dimension");
                }
            }
        }
        Split::Centers { centers } => {
            if centers.rows() != n_children || n_children == 0 {
                return bad("split arity");
            }
            if let Some(d) = d {
                if centers.cols() != d {
                    return bad("split dimension");
                }
            }
        }
    }
    Ok(())
}

/// Structural invariants of a decoded partition tree over `n` points of
/// dimension `d` — everything the routing walks, `node_points`, and the
/// level-synchronous solver schedule index or unwrap on. Shared by the
/// factor loader and the independent-engine payload of
/// [`crate::model::persist`]: a corrupt tree that decodes cleanly must
/// fail the load, not panic (or cycle forever) inside a serving thread.
pub(crate) fn validate_tree(t: &PartitionTree, n: usize, d: usize) -> Result<()> {
    let bad = |what: &str| Err(Error::data(format!("corrupt model file ({what})")));
    let nn = t.nodes.len();
    if nn == 0 {
        return bad("empty tree");
    }
    // perm must be a permutation of 0..n (node_points slices it and the
    // order maps are built from it).
    if t.perm.len() != n || n == 0 {
        return bad("permutation length");
    }
    let mut seen = vec![false; n];
    for &p in &t.perm {
        if p >= n || seen[p] {
            return bad("permutation");
        }
        seen[p] = true;
    }
    let root = &t.nodes[0];
    if root.parent.is_some() || root.lo != 0 || root.hi != n || root.depth != 0 {
        return bad("root range");
    }
    for (i, nd) in t.nodes.iter().enumerate() {
        if nd.lo > nd.hi || nd.hi > n {
            return bad("node range");
        }
        if nd.children.len() == 1 {
            return bad("single-child node");
        }
        // Children must partition [lo, hi) in order, one level deeper
        // (the level-synchronous solver schedules by depth), with ids
        // strictly after the parent's (the builder's parent-before-child
        // id order; also guarantees every walk terminates).
        let mut pos = nd.lo;
        for &ch in &nd.children {
            if ch >= nn || ch <= i {
                return bad("child link");
            }
            let c = &t.nodes[ch];
            if c.parent != Some(i) || c.lo != pos || c.depth != nd.depth + 1 {
                return bad("child link");
            }
            pos = c.hi;
        }
        if !nd.children.is_empty() && pos != nd.hi {
            return bad("child coverage");
        }
        if let Some(p) = nd.parent {
            if p >= nn || !t.nodes[p].children.contains(&i) {
                return bad("parent link");
            }
        } else if i != 0 {
            return bad("non-root without parent");
        }
        if nd.is_leaf() {
            if nd.split.is_some() {
                return bad("leaf with split");
            }
        } else {
            let Some(split) = &nd.split else {
                return bad("inner node without split");
            };
            validate_split(split, nd.children.len(), Some(d))?;
        }
    }
    Ok(())
}

/// Structural invariants of decoded factors — the tree plus everything
/// `HPredictor` and `HSolver` unwrap on per node. A corrupt file that
/// decodes cleanly must fail the load with a data error here, not panic
/// later inside a serving thread.
fn validate_factors(f: &HFactors) -> Result<()> {
    let bad = |what: &str| Err(Error::data(format!("corrupt model file ({what})")));
    let n = f.x.rows();
    let d = f.x.cols();
    validate_tree(&f.tree, n, d)?;
    for (i, nd) in f.tree.nodes.iter().enumerate() {
        if nd.is_leaf() {
            let ni = nd.hi - nd.lo;
            let Some(a) = &f.a_leaf[i] else {
                return bad("leaf without diagonal block");
            };
            if a.rows() != ni || a.cols() != ni {
                return bad("leaf block shape");
            }
            if let Some(p) = nd.parent {
                let Some(u) = &f.u[i] else {
                    return bad("leaf without basis");
                };
                if u.rows() != ni || u.cols() != f.landmark_idx[p].len() {
                    return bad("leaf basis shape");
                }
            }
        } else {
            let (Some(lm), Some(sig)) = (&f.landmarks[i], &f.sigma[i]) else {
                return bad("inner node without landmark state");
            };
            if f.sigma_chol[i].is_none() {
                return bad("inner node without landmark state");
            }
            let r_i = f.landmark_idx[i].len();
            if lm.rows() != r_i || lm.cols() != d || sig.rows() != r_i || sig.cols() != r_i {
                return bad("landmark state shape");
            }
            if f.landmark_idx[i].iter().any(|&ix| ix >= n) {
                return bad("landmark index");
            }
            if let Some(p) = nd.parent {
                let Some(w) = &f.w[i] else {
                    return bad("inner node without W");
                };
                if w.rows() != r_i || w.cols() != f.landmark_idx[p].len() {
                    return bad("W shape");
                }
            }
        }
    }
    Ok(())
}

/// Read factor state written by [`write_factors`]. The Σ Cholesky
/// factors are recomputed (deterministically, from the stored Σ blocks),
/// so loaded factors predict bit-identically to the saved ones. The
/// decoded structure is validated ([`validate_factors`]) so corrupt
/// files fail the load instead of panicking in a serving thread.
pub(crate) fn read_factors(inp: &mut impl Read) -> Result<HFactors> {
    let config = read_config(inp)?;
    let tree = read_tree(inp)?;
    let x = read_mat(inp)?;
    let nn = tree.nodes.len();
    if nn == 0 {
        return Err(Error::data("corrupt model file (empty tree)"));
    }
    let mut f = HFactors {
        x,
        landmark_idx: Vec::with_capacity(nn),
        landmarks: Vec::with_capacity(nn),
        sigma: Vec::with_capacity(nn),
        sigma_chol: Vec::with_capacity(nn),
        w: Vec::with_capacity(nn),
        u: Vec::with_capacity(nn),
        a_leaf: Vec::with_capacity(nn),
        build_phases: crate::util::timer::Phases::new(),
        tree,
        config,
    };
    for _ in 0..nn {
        f.landmark_idx.push(read_usizes(inp)?);
        f.landmarks.push(read_opt_mat(inp)?);
        let sigma = read_opt_mat(inp)?;
        let chol = match &sigma {
            Some(s) => Some(Cholesky::new_jittered(s, 30)?),
            None => None,
        };
        f.sigma.push(sigma);
        f.sigma_chol.push(chol);
        f.w.push(read_opt_mat(inp)?);
        f.u.push(read_opt_mat(inp)?);
        f.a_leaf.push(read_opt_mat(inp)?);
    }
    validate_factors(&f)?;
    Ok(f)
}

const SHARD_MAGIC: &[u8; 4] = b"HCKS";

/// Save one serving shard to a file, so a worker process can load only
/// its slice of the model (the replicated entry/top path state rides
/// along — a shard file is self-contained).
pub fn save_shard(s: &crate::shard::Shard, path: &str) -> Result<()> {
    let file = std::fs::File::create(path)?;
    let mut out = BufWriter::new(file);
    out.write_all(SHARD_MAGIC)?;
    wu64(&mut out, s.id as u64)?;
    wu64(&mut out, s.root_global as u64)?;
    write_kind(&mut out, s.kind)?;
    wu64(&mut out, s.dim as u64)?;
    wu64(&mut out, s.outputs as u64)?;
    wu64(&mut out, s.nodes.len() as u64)?;
    for nd in &s.nodes {
        write_node(&mut out, nd)?;
    }
    for l in 0..s.nodes.len() {
        write_opt_mat(&mut out, &s.leaf_x[l])?;
        write_opt_mat(&mut out, &s.leaf_w[l])?;
        write_opt_mat(&mut out, &s.c[l])?;
        write_opt_mat(&mut out, &s.landmarks[l])?;
        write_opt_mat(&mut out, &s.sigma[l])?;
        write_opt_mat(&mut out, &s.wfac[l])?;
    }
    match &s.entry {
        None => wu64(&mut out, 0)?,
        Some(e) => {
            wu64(&mut out, 1)?;
            write_mat(&mut out, &e.landmarks)?;
            write_mat(&mut out, &e.sigma)?;
        }
    }
    wu64(&mut out, s.top.len() as u64)?;
    for step in &s.top {
        write_mat(&mut out, &step.w)?;
        write_mat(&mut out, &step.c)?;
    }
    out.flush()?;
    Ok(())
}

/// Load a shard saved by [`save_shard`] (Σ Choleskys are recomputed).
pub fn load_shard(path: &str) -> Result<crate::shard::Shard> {
    let file = std::fs::File::open(path)?;
    let mut inp = BufReader::new(file);
    let mut magic = [0u8; 4];
    inp.read_exact(&mut magic)?;
    if &magic != SHARD_MAGIC {
        return Err(Error::data("not an HCKS shard file"));
    }
    let id = ru64(&mut inp)? as usize;
    let root_global = ru64(&mut inp)? as usize;
    let kind = read_kind(&mut inp)?;
    let dim = ru64(&mut inp)? as usize;
    let outputs = ru64(&mut inp)? as usize;
    let nn = ru64(&mut inp)? as usize;
    if nn == 0 || nn > (1usize << 32) {
        return Err(Error::data("corrupt shard file (node count)"));
    }
    // Counts come from the file: grow the vectors by actual reads (a
    // truncated/corrupt file errors on read_exact) rather than
    // pre-allocating attacker-chosen capacities.
    let mut nodes = Vec::new();
    for _ in 0..nn {
        nodes.push(read_node(&mut inp)?);
    }
    let mut leaf_x = Vec::new();
    let mut leaf_w = Vec::new();
    let mut c = Vec::new();
    let mut landmarks = Vec::new();
    let mut sigma: Vec<Option<Mat>> = Vec::new();
    let mut sigma_chol = Vec::new();
    let mut wfac = Vec::new();
    for _ in 0..nn {
        leaf_x.push(read_opt_mat(&mut inp)?);
        leaf_w.push(read_opt_mat(&mut inp)?);
        c.push(read_opt_mat(&mut inp)?);
        landmarks.push(read_opt_mat(&mut inp)?);
        let sig = read_opt_mat(&mut inp)?;
        let chol = match &sig {
            Some(s) => Some(Cholesky::new_jittered(s, 30)?),
            None => None,
        };
        sigma.push(sig);
        sigma_chol.push(chol);
        wfac.push(read_opt_mat(&mut inp)?);
    }
    let entry = match ru64(&mut inp)? {
        0 => None,
        1 => {
            let landmarks = read_mat(&mut inp)?;
            let sigma = read_mat(&mut inp)?;
            let chol = Cholesky::new_jittered(&sigma, 30)?;
            Some(crate::shard::EntryState { landmarks, sigma, chol })
        }
        _ => return Err(Error::data("corrupt shard file (entry tag)")),
    };
    let nt = ru64(&mut inp)? as usize;
    // The top path is one entry per tree level above the cut; anything
    // beyond a few dozen is corrupt.
    if nt > (1usize << 16) {
        return Err(Error::data("corrupt shard file (top path too long)"));
    }
    let mut top = Vec::new();
    for _ in 0..nt {
        let w = read_mat(&mut inp)?;
        let cm = read_mat(&mut inp)?;
        top.push(crate::shard::TopStep { w, c: cm });
    }
    let shard = crate::shard::Shard {
        id,
        root_global,
        kind,
        dim,
        outputs,
        nodes,
        leaf_x,
        leaf_w,
        c,
        landmarks,
        sigma,
        sigma_chol,
        wfac,
        entry,
        top,
    };
    validate_shard(&shard)?;
    Ok(shard)
}

const ROUTER_MAGIC: &[u8; 4] = b"HCKR";

/// Save a query→shard router (the top-of-tree walk state) to a file, so
/// a serving process can route into a directory of shard files without
/// the full model (`hck shard --out dir/` writes one next to the shards).
pub fn save_router(r: &crate::shard::ShardRouter, path: &str) -> Result<()> {
    let file = std::fs::File::create(path)?;
    let mut out = BufWriter::new(file);
    out.write_all(ROUTER_MAGIC)?;
    let (nodes, shard_of, n_shards) = r.parts();
    wu64(&mut out, n_shards as u64)?;
    wu64(&mut out, nodes.len() as u64)?;
    for nd in nodes {
        write_node(&mut out, nd)?;
    }
    for s in shard_of {
        wu64(&mut out, s.map(|v| v as u64 + 1).unwrap_or(0))?;
    }
    out.flush()?;
    Ok(())
}

/// Load a router saved by [`save_router`], validating the invariants the
/// routing walk relies on (every non-boundary node keeps its split and
/// in-range children; every boundary node maps to a valid shard).
pub fn load_router(path: &str) -> Result<crate::shard::ShardRouter> {
    let file = std::fs::File::open(path)?;
    let mut inp = BufReader::new(file);
    let mut magic = [0u8; 4];
    inp.read_exact(&mut magic)?;
    if &magic != ROUTER_MAGIC {
        return Err(Error::data("not an HCKR router file"));
    }
    let bad = |what: &str| Err(Error::data(format!("corrupt router file ({what})")));
    let n_shards = ru64(&mut inp)? as usize;
    let nn = ru64(&mut inp)? as usize;
    if nn == 0 || nn > (1usize << 32) {
        return bad("node count");
    }
    // Every shard is one retained node, so the count is bounded by the
    // node count; an unbounded value would abort on allocation below
    // instead of erroring.
    if n_shards == 0 || n_shards > nn {
        return bad("shard count");
    }
    let mut nodes = Vec::new();
    for _ in 0..nn {
        nodes.push(read_node(&mut inp)?);
    }
    let mut shard_of = Vec::with_capacity(nn);
    for _ in 0..nn {
        shard_of.push(match ru64(&mut inp)? {
            0 => None,
            v => Some(v as usize - 1),
        });
    }
    let mut seen = vec![false; n_shards];
    for (id, nd) in nodes.iter().enumerate() {
        match shard_of[id] {
            Some(s) => {
                if s >= n_shards || seen[s] {
                    return bad("shard index");
                }
                seen[s] = true;
            }
            None => {
                // `route` follows this node's split; a missing split, an
                // out-of-range child, or a split whose arity disagrees
                // with the child count would panic mid-query. (The
                // feature dimension is not recorded here; the shard-dir
                // loader re-checks splits against the shards' dim.)
                let Some(split) = &nd.split else {
                    return bad("non-boundary node without split");
                };
                validate_split(split, nd.children.len(), None)?;
                // The breadth-first compaction puts children strictly
                // after their parent, which also guarantees the routing
                // walk terminates; reject anything else (a cycle would
                // hang `route` forever).
                if nd.children.iter().any(|&c| c >= nn || c <= id) {
                    return bad("child link");
                }
            }
        }
    }
    if seen.iter().any(|s| !s) {
        return bad("unreached shard");
    }
    Ok(crate::shard::ShardRouter::from_parts(nodes, shard_of, n_shards))
}

/// Structural invariants the serving paths unwrap on: a corrupt file
/// that decodes cleanly must still fail at load time, not panic inside
/// a worker thread.
fn validate_shard(s: &crate::shard::Shard) -> Result<()> {
    let bad = |what: &str| Err(Error::data(format!("corrupt shard file ({what})")));
    let nn = s.nodes.len();
    for (l, nd) in s.nodes.iter().enumerate() {
        if nd.children.len() == 1 {
            return bad("single-child node");
        }
        for &ch in &nd.children {
            if ch >= nn || s.nodes[ch].parent != Some(l) {
                return bad("child link");
            }
        }
        if let Some(p) = nd.parent {
            if p >= nn || !s.nodes[p].children.contains(&l) {
                return bad("parent link");
            }
        } else if l != 0 {
            return bad("non-root without parent");
        }
        if nd.is_leaf() {
            let (Some(x), Some(w)) = (&s.leaf_x[l], &s.leaf_w[l]) else {
                return bad("leaf without blocks");
            };
            if x.rows() != nd.hi.saturating_sub(nd.lo)
                || w.rows() != x.rows()
                || x.cols() != s.dim
                || w.cols() != s.outputs
            {
                return bad("leaf block shape");
            }
            if nd.split.is_some() {
                return bad("leaf with split");
            }
        } else {
            let (Some(lm), Some(sig)) = (&s.landmarks[l], &s.sigma[l]) else {
                return bad("inner node without landmark state");
            };
            if s.sigma_chol[l].is_none() {
                return bad("inner node without landmark state");
            }
            if lm.cols() != s.dim || sig.rows() != lm.rows() || sig.cols() != lm.rows() {
                return bad("landmark state shape");
            }
            let Some(split) = &nd.split else {
                return bad("inner node without split");
            };
            // The in-shard routing walk follows this split over the
            // node's children; arity/dimension mismatches would panic
            // per query instead of failing the load.
            validate_split(split, nd.children.len(), Some(s.dim))?;
            // The climb into every inner node below the global root needs
            // its W factor: a silent None would skip a climb, not panic.
            if (l != 0 || s.c[0].is_some()) && s.wfac[l].is_none() {
                return bad("inner node without W");
            }
            if let Some(w) = &s.wfac[l] {
                if w.rows() != lm.rows() {
                    return bad("W shape");
                }
            }
        }
        if l != 0 {
            // c_l lives in the parent's landmark space; W_l maps into it.
            let Some(cm) = &s.c[l] else {
                return bad("non-root node without c state");
            };
            let p = nd.parent.unwrap();
            let Some(rp) = s.landmarks[p].as_ref().map(|m| m.rows()) else {
                return bad("parent landmark state");
            };
            if cm.rows() != rp || cm.cols() != s.outputs {
                return bad("c shape");
            }
            if let Some(w) = &s.wfac[l] {
                if w.cols() != rp {
                    return bad("W shape");
                }
            }
        }
    }
    // Above-the-cut state: the shard-root c, the entry landmarks and the
    // replicated climb must chain dimensionally, or the first query
    // through them panics in a worker instead of failing the load.
    if let Some(c0) = &s.c[0] {
        if c0.cols() != s.outputs {
            return bad("c shape");
        }
        if s.nodes[0].is_leaf() {
            let Some(e) = &s.entry else {
                return bad("missing entry state");
            };
            if c0.rows() != e.landmarks.rows() {
                return bad("c shape");
            }
        } else if s.wfac[0].as_ref().map(|w| w.cols()) != Some(c0.rows()) {
            return bad("W shape");
        }
        let mut cur = c0.rows();
        for step in &s.top {
            if step.w.rows() != cur
                || step.c.rows() != step.w.cols()
                || step.c.cols() != s.outputs
            {
                return bad("top step shape");
            }
            cur = step.w.cols();
        }
    } else if !s.top.is_empty() {
        return bad("top path without c state");
    }
    if let Some(e) = &s.entry {
        if e.landmarks.cols() != s.dim
            || e.sigma.rows() != e.landmarks.rows()
            || e.sigma.cols() != e.landmarks.rows()
        {
            return bad("entry state shape");
        }
    }
    Ok(())
}

// ---- primitives ----

pub(crate) fn wu64(out: &mut impl Write, v: u64) -> Result<()> {
    out.write_all(&v.to_le_bytes())?;
    Ok(())
}
pub(crate) fn wf64(out: &mut impl Write, v: f64) -> Result<()> {
    out.write_all(&v.to_le_bytes())?;
    Ok(())
}
pub(crate) fn ru64(inp: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    inp.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}
pub(crate) fn rf64(inp: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    inp.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

pub(crate) fn write_f64s(out: &mut impl Write, v: &[f64]) -> Result<()> {
    wu64(out, v.len() as u64)?;
    let mut bytes = Vec::with_capacity(v.len() * 8);
    for x in v {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    out.write_all(&bytes)?;
    Ok(())
}
pub(crate) fn read_f64s(inp: &mut impl Read) -> Result<Vec<f64>> {
    let n = ru64(inp)? as usize;
    if n > (1usize << 34) {
        return Err(Error::data("corrupt model file (vector too large)"));
    }
    let mut bytes = vec![0u8; n * 8];
    inp.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

pub(crate) fn write_usizes(out: &mut impl Write, v: &[usize]) -> Result<()> {
    wu64(out, v.len() as u64)?;
    for &x in v {
        wu64(out, x as u64)?;
    }
    Ok(())
}
pub(crate) fn read_usizes(inp: &mut impl Read) -> Result<Vec<usize>> {
    let n = ru64(inp)? as usize;
    if n > (1usize << 32) {
        return Err(Error::data("corrupt model file (index list too large)"));
    }
    (0..n).map(|_| ru64(inp).map(|v| v as usize)).collect()
}

pub(crate) fn write_mat(out: &mut impl Write, m: &Mat) -> Result<()> {
    wu64(out, m.rows() as u64)?;
    wu64(out, m.cols() as u64)?;
    write_f64s(out, m.as_slice())
}
pub(crate) fn read_mat(inp: &mut impl Read) -> Result<Mat> {
    let rows = ru64(inp)? as usize;
    let cols = ru64(inp)? as usize;
    let data = read_f64s(inp)?;
    if data.len() != rows * cols {
        return Err(Error::data("corrupt model file (matrix shape)"));
    }
    Ok(Mat::from_vec(rows, cols, data))
}
pub(crate) fn write_opt_mat(out: &mut impl Write, m: &Option<Mat>) -> Result<()> {
    match m {
        None => wu64(out, 0),
        Some(m) => {
            wu64(out, 1)?;
            write_mat(out, m)
        }
    }
}
pub(crate) fn read_opt_mat(inp: &mut impl Read) -> Result<Option<Mat>> {
    match ru64(inp)? {
        0 => Ok(None),
        1 => Ok(Some(read_mat(inp)?)),
        _ => Err(Error::data("corrupt model file (option tag)")),
    }
}

// ---- config / kernel / tree ----

pub(crate) fn write_config(out: &mut impl Write, c: &HConfig) -> Result<()> {
    write_kind(out, c.kind)?;
    wu64(out, c.rank as u64)?;
    wu64(out, c.n0 as u64)?;
    wf64(out, c.lambda_prime)?;
    write_rule(out, c.rule)?;
    wu64(out, c.seed)?;
    wu64(out, c.avoid_parent_landmarks as u64)?;
    Ok(())
}
pub(crate) fn read_config(inp: &mut impl Read) -> Result<HConfig> {
    Ok(HConfig {
        kind: read_kind(inp)?,
        rank: ru64(inp)? as usize,
        n0: ru64(inp)? as usize,
        lambda_prime: rf64(inp)?,
        rule: read_rule(inp)?,
        seed: ru64(inp)?,
        avoid_parent_landmarks: ru64(inp)? != 0,
    })
}

pub(crate) fn write_kind(out: &mut impl Write, k: KernelKind) -> Result<()> {
    match k {
        KernelKind::Gaussian { sigma } => {
            wu64(out, 0)?;
            wf64(out, sigma)
        }
        KernelKind::Laplace { sigma } => {
            wu64(out, 1)?;
            wf64(out, sigma)
        }
        KernelKind::Imq { sigma } => {
            wu64(out, 2)?;
            wf64(out, sigma)
        }
        KernelKind::Matern32 { sigma } => {
            wu64(out, 3)?;
            wf64(out, sigma)
        }
        KernelKind::TaperedGaussian { sigma, theta, ell } => {
            wu64(out, 4)?;
            wf64(out, sigma)?;
            wf64(out, theta)?;
            wu64(out, ell as u64)
        }
    }
}
pub(crate) fn read_kind(inp: &mut impl Read) -> Result<KernelKind> {
    Ok(match ru64(inp)? {
        0 => KernelKind::Gaussian { sigma: rf64(inp)? },
        1 => KernelKind::Laplace { sigma: rf64(inp)? },
        2 => KernelKind::Imq { sigma: rf64(inp)? },
        3 => KernelKind::Matern32 { sigma: rf64(inp)? },
        4 => KernelKind::TaperedGaussian {
            sigma: rf64(inp)?,
            theta: rf64(inp)?,
            ell: ru64(inp)? as u32,
        },
        _ => return Err(Error::data("corrupt model file (kernel tag)")),
    })
}

pub(crate) fn write_rule(out: &mut impl Write, r: SplitRule) -> Result<()> {
    match r {
        SplitRule::RandomProjection => wu64(out, 0),
        SplitRule::Pca { iters } => {
            wu64(out, 1)?;
            wu64(out, iters as u64)
        }
        SplitRule::KdTree => wu64(out, 2),
        SplitRule::KMeans { k, iters } => {
            wu64(out, 3)?;
            wu64(out, k as u64)?;
            wu64(out, iters as u64)
        }
    }
}
pub(crate) fn read_rule(inp: &mut impl Read) -> Result<SplitRule> {
    Ok(match ru64(inp)? {
        0 => SplitRule::RandomProjection,
        1 => SplitRule::Pca { iters: ru64(inp)? as usize },
        2 => SplitRule::KdTree,
        3 => SplitRule::KMeans { k: ru64(inp)? as usize, iters: ru64(inp)? as usize },
        _ => return Err(Error::data("corrupt model file (rule tag)")),
    })
}

pub(crate) fn write_node(out: &mut impl Write, nd: &Node) -> Result<()> {
    wu64(out, nd.parent.map(|p| p as u64 + 1).unwrap_or(0))?;
    write_usizes(out, &nd.children)?;
    wu64(out, nd.lo as u64)?;
    wu64(out, nd.hi as u64)?;
    wu64(out, nd.depth as u64)?;
    match &nd.split {
        None => wu64(out, 0)?,
        Some(Split::Hyperplane { dir, threshold }) => {
            wu64(out, 1)?;
            write_f64s(out, dir)?;
            wf64(out, *threshold)?;
        }
        Some(Split::Axis { axis, threshold }) => {
            wu64(out, 2)?;
            wu64(out, *axis as u64)?;
            wf64(out, *threshold)?;
        }
        Some(Split::Centers { centers }) => {
            wu64(out, 3)?;
            write_mat(out, centers)?;
        }
    }
    Ok(())
}
pub(crate) fn read_node(inp: &mut impl Read) -> Result<Node> {
    let parent_raw = ru64(inp)?;
    let parent = if parent_raw == 0 { None } else { Some(parent_raw as usize - 1) };
    let children = read_usizes(inp)?;
    let lo = ru64(inp)? as usize;
    let hi = ru64(inp)? as usize;
    let depth = ru64(inp)? as usize;
    let split = match ru64(inp)? {
        0 => None,
        1 => Some(Split::Hyperplane { dir: read_f64s(inp)?, threshold: rf64(inp)? }),
        2 => Some(Split::Axis { axis: ru64(inp)? as usize, threshold: rf64(inp)? }),
        3 => Some(Split::Centers { centers: read_mat(inp)? }),
        _ => return Err(Error::data("corrupt model file (split tag)")),
    };
    Ok(Node { parent, children, lo, hi, split, depth })
}

pub(crate) fn write_tree(out: &mut impl Write, t: &PartitionTree) -> Result<()> {
    wu64(out, t.n0 as u64)?;
    write_usizes(out, &t.perm)?;
    wu64(out, t.nodes.len() as u64)?;
    for nd in &t.nodes {
        write_node(out, nd)?;
    }
    Ok(())
}
pub(crate) fn read_tree(inp: &mut impl Read) -> Result<PartitionTree> {
    let n0 = ru64(inp)? as usize;
    let perm = read_usizes(inp)?;
    let nn = ru64(inp)? as usize;
    let mut nodes = Vec::with_capacity(nn);
    for _ in 0..nn {
        nodes.push(read_node(inp)?);
    }
    Ok(PartitionTree { nodes, perm, n0 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hkernel::HPredictor;
    use crate::kernels::Gaussian;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn tmpfile(tag: &str) -> String {
        let dir = std::env::temp_dir();
        dir.join(format!("hck_persist_test_{tag}_{}.bin", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn fitted(rule: SplitRule, seed: u64) -> (HFactors, Mat) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(80, 4, |_, _| rng.uniform(0.0, 1.0));
        let mut cfg = HConfig::new(Gaussian::new(0.5), 10).with_seed(seed).with_rule(rule);
        cfg.n0 = 10;
        let f = HFactors::build(&x, cfg).unwrap();
        let solver = crate::hkernel::HSolver::factor(&f, 0.05).unwrap();
        let y: Vec<f64> = (0..80).map(|i| (i as f64 * 0.1).sin()).collect();
        let w = solver.solve_mat_original(&Mat::from_vec(80, 1, y));
        (f, w)
    }

    #[test]
    fn roundtrip_preserves_predictions() {
        for (tag, rule) in [
            ("rp", SplitRule::RandomProjection),
            ("kmeans", SplitRule::KMeans { k: 3, iters: 10 }),
            ("kd", SplitRule::KdTree),
        ] {
            let (f, w) = fitted(rule, 7);
            let path = tmpfile(tag);
            save_model(&f, &w, &path).unwrap();
            let (f2, w2) = load_model(&path).unwrap();
            std::fs::remove_file(&path).ok();
            assert_eq!(f2.tree.perm, f.tree.perm);
            assert_eq!(f2.config.rank, f.config.rank);
            // Predictions must be bit-identical (same factors, same walk).
            let p1 = HPredictor::new(Arc::new(f), &w);
            let p2 = HPredictor::new(Arc::new(f2), &w2);
            let mut rng = Rng::new(11);
            for _ in 0..20 {
                let q: Vec<f64> = (0..4).map(|_| rng.uniform(0.0, 1.0)).collect();
                assert_eq!(p1.predict(&q), p2.predict(&q), "rule {tag}");
            }
        }
    }

    #[test]
    fn shard_roundtrip_preserves_predictions() {
        let (f, w) = fitted(SplitRule::RandomProjection, 21);
        let f = Arc::new(f);
        let pred = HPredictor::new(f.clone(), &w);
        let depth = 2.min(f.tree.depth());
        let shards = crate::shard::split_predictor(&pred, depth);
        let mut rng = Rng::new(23);
        let q = Mat::from_fn(12, 4, |_, _| rng.uniform(0.0, 1.0));
        for s in shards {
            let path = tmpfile(&format!("shard{}", s.id));
            let want = s.predict_batch(&q);
            save_shard(&s, &path).unwrap();
            let s2 = load_shard(&path).unwrap();
            std::fs::remove_file(&path).ok();
            assert_eq!(s2.id, s.id);
            assert_eq!(s2.nodes.len(), s.nodes.len());
            assert_eq!(s2.row_range(), s.row_range());
            // Same factors, same walk: predictions are bit-identical.
            let got = s2.predict_batch(&q);
            for i in 0..q.rows() {
                assert_eq!(got.row(i), want.row(i), "shard {} row {i}", s.id);
            }
        }
    }

    #[test]
    fn shard_rejects_model_file_and_vice_versa() {
        let (f, w) = fitted(SplitRule::RandomProjection, 25);
        let path = tmpfile("crossmagic");
        save_model(&f, &w, &path).unwrap();
        assert!(load_shard(&path).is_err());
        let f = Arc::new(f);
        let pred = HPredictor::new(f.clone(), &w);
        let shards = crate::shard::split_predictor(&pred, 1.min(f.tree.depth()));
        save_shard(&shards[0], &path).unwrap();
        assert!(load_model(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmpfile("garbage");
        std::fs::write(&path, b"definitely not a model").unwrap();
        assert!(load_model(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// Files that *decode* cleanly but violate the structural invariants
    /// the predictors unwrap on must fail the load, not panic later in a
    /// serving thread.
    #[test]
    fn rejects_structurally_corrupt_factors() {
        // A leaf whose basis block is missing (an Option tag flipped).
        let (mut f, w) = fitted(SplitRule::RandomProjection, 33);
        let leaf = f.tree.leaves()[0];
        assert!(f.u[leaf].is_some());
        f.u[leaf] = None;
        let path = tmpfile("corrupt_basis");
        save_model(&f, &w, &path).unwrap();
        assert!(load_model(&path).is_err());
        std::fs::remove_file(&path).ok();

        // A permutation with a duplicated entry.
        let (mut f, w) = fitted(SplitRule::RandomProjection, 35);
        f.tree.perm[0] = f.tree.perm[1];
        let path = tmpfile("corrupt_perm");
        save_model(&f, &w, &path).unwrap();
        assert!(load_model(&path).is_err());
        std::fs::remove_file(&path).ok();

        // A k-means split whose center count disagrees with its children
        // (routing would index children out of bounds per query).
        let (mut f, w) = fitted(SplitRule::KMeans { k: 3, iters: 10 }, 37);
        let inner = f
            .tree
            .nonleaves()
            .into_iter()
            .find(|&i| matches!(f.tree.nodes[i].split, Some(Split::Centers { .. })))
            .expect("kmeans tree has a Centers split");
        let truncated = match &f.tree.nodes[inner].split {
            Some(Split::Centers { centers }) => centers.row_range(0, centers.rows() - 1),
            _ => unreachable!(),
        };
        f.tree.nodes[inner].split = Some(Split::Centers { centers: truncated });
        let path = tmpfile("corrupt_split");
        save_model(&f, &w, &path).unwrap();
        assert!(load_model(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncated() {
        let (f, w) = fitted(SplitRule::RandomProjection, 9);
        let path = tmpfile("trunc");
        save_model(&f, &w, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load_model(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
