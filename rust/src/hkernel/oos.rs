//! Algorithm 3: out-of-sample prediction `z = wᵀ k_hierarchical(X, x)`.
//!
//! The x-independent phase (eqs. 20 of the paper) is a post-order pass
//! computing, for each node, the compressed mass of its weight block:
//! `e_l = U_lᵀ w_l` (leaf) or `e_l = W_lᵀ Σ_children e_c`, and the sibling
//! aggregates `c_m = Σ_p (Σ_{siblings l of m} e_l)`. Per query, only the
//! path from the routed leaf to the root is touched (eqs. 18, 21):
//! `d` starts as `Σ_{p(j)}^{-1} k(X̲_{p(j)}, x)`, climbs via `Wᵀ`, and the
//! prediction is the leaf term plus `Σ_path c_mᵀ d_m` — O(r²) per level
//! plus one leaf kernel vector, matching eq. (23).
//!
//! Supports multi-output weight matrices (n x m), which is how the
//! one-vs-all multiclass classifier evaluates all classes in one walk.

use super::build::HFactors;
use crate::linalg::{gemm, gemv, matmul, par_matmul, Mat, Trans};

/// Precomputed out-of-sample predictor for a fixed weight block `W`
/// (n x m, original order) — typically `W = (A + λI)^{-1} Y`.
///
/// Owns an `Arc` of the factors so fitted models can cache a long-lived
/// predictor (the precomputation is O(nr·m); rebuilding it per query
/// batch would dominate serving latency).
///
/// Fields are crate-visible so [`crate::shard::split_predictor`] can
/// extract the per-node path state (`c`, leaf weight blocks, leaf rows)
/// when cutting the model into subtree shards.
pub struct HPredictor {
    pub(crate) f: std::sync::Arc<HFactors>,
    /// c_m per non-root node (r_{p(m)} x m).
    pub(crate) c: Vec<Option<Mat>>,
    /// Per leaf: materialized point block (n_j x d), gathered once so
    /// both the scalar walk and the grouped batch path evaluate leaf
    /// kernels without per-call row copies. This is the predictor's one
    /// deliberate duplication (n·d words next to `f.x`) — the serving
    /// layout, same as [`crate::shard::Shard::leaf_x`].
    pub(crate) leaf_x: Vec<Option<Mat>>,
    /// Per leaf: weight block in tree order (n_j x m). The full tree-order
    /// weight copy is *not* retained — these blocks are its only owner.
    pub(crate) leaf_w: Vec<Option<Mat>>,
    /// Number of outputs m.
    m: usize,
}

impl HPredictor {
    /// Build the predictor (the O(nr·m) precomputation phase).
    pub fn new(f: std::sync::Arc<HFactors>, w_original: &Mat) -> HPredictor {
        assert_eq!(w_original.rows(), f.n(), "weight rows");
        let m = w_original.cols();
        let w_tree = f.rows_to_tree_order(w_original);
        let nn = f.tree.nodes.len();
        let mut e: Vec<Option<Mat>> = (0..nn).map(|_| None).collect();
        let mut c: Vec<Option<Mat>> = (0..nn).map(|_| None).collect();

        // e pass (post-order).
        for &i in &f.tree.postorder() {
            let nd = &f.tree.nodes[i];
            if nd.parent.is_none() {
                continue;
            }
            let ei = if nd.is_leaf() {
                // Fit-time precomputation at the top of the chain: the
                // parallel BLAS entries engage the pool on large blocks.
                let u = f.u[i].as_ref().unwrap();
                let wi = w_tree.row_range(nd.lo, nd.hi);
                par_matmul(u, Trans::Yes, &wi, Trans::No)
            } else {
                let r_own = f.landmark_idx[i].len();
                let mut esum = Mat::zeros(r_own, m);
                for &ch in &nd.children {
                    esum.axpy(1.0, e[ch].as_ref().unwrap());
                }
                let w = f.w[i].as_ref().unwrap();
                par_matmul(w, Trans::Yes, &esum, Trans::No)
            };
            e[i] = Some(ei);
        }
        // c pass: siblings' e through Σ_p.
        for p in f.tree.nonleaves() {
            let children = f.tree.nodes[p].children.clone();
            let rp = f.landmark_idx[p].len();
            let sig = f.sigma[p].as_ref().unwrap();
            let mut total = Mat::zeros(rp, m);
            for &ch in &children {
                total.axpy(1.0, e[ch].as_ref().unwrap());
            }
            for &ch in &children {
                let mut others = total.clone();
                others.axpy(-1.0, e[ch].as_ref().unwrap());
                c[ch] = Some(matmul(sig, Trans::No, &others, Trans::No));
            }
        }

        // Materialized leaf blocks (tree order): the serving layout; the
        // tree-order weight copy itself is dropped when `new` returns.
        let mut leaf_x: Vec<Option<Mat>> = (0..nn).map(|_| None).collect();
        let mut leaf_w: Vec<Option<Mat>> = (0..nn).map(|_| None).collect();
        for &l in &f.tree.leaves() {
            leaf_x[l] = Some(f.x.select_rows(f.tree.node_points(l)));
            let nd = &f.tree.nodes[l];
            leaf_w[l] = Some(w_tree.row_range(nd.lo, nd.hi));
        }
        HPredictor { f, c, leaf_x, leaf_w, m }
    }

    /// Number of outputs m.
    pub fn outputs(&self) -> usize {
        self.m
    }

    /// Predict for one query point: returns the m-vector
    /// `wᵀ k_hierarchical(X, x)` (one entry per output column).
    pub fn predict(&self, x: &[f64]) -> Vec<f64> {
        let f = self.f.as_ref();
        let m = self.outputs();
        let kind = f.config.kind;
        let path = f.tree.route(x);
        let leaf = *path.last().unwrap();

        // Leaf term: w_jᵀ k(X_j, x) over the materialized leaf blocks.
        let x_leaf = self.leaf_x[leaf].as_ref().unwrap();
        let w_leaf = self.leaf_w[leaf].as_ref().unwrap();
        let mut z = vec![0.0; m];
        for k_local in 0..x_leaf.rows() {
            let kv = kind.eval(x_leaf.row(k_local), x);
            if kv != 0.0 {
                let wrow = w_leaf.row(k_local);
                for (zi, wi) in z.iter_mut().zip(wrow.iter()) {
                    *zi += kv * wi;
                }
            }
        }
        if path.len() == 1 {
            return z; // single-leaf tree
        }

        // Path term: climb from the leaf, maintaining d.
        let parent = f.tree.nodes[leaf].parent.unwrap();
        let lm = f.landmarks[parent].as_ref().unwrap();
        let rp = lm.rows();
        let mut kvec = vec![0.0; rp];
        for a in 0..rp {
            kvec[a] = kind.eval(lm.row(a), x);
        }
        let mut d = f.sigma_chol[parent].as_ref().unwrap().solve(&kvec);

        // path = [root, ..., parent, leaf]; iterate bottom-up over the
        // non-root nodes: leaf, parent, ..., child-of-root.
        for idx in (1..path.len()).rev() {
            let mnode = path[idx];
            // z += c_mᵀ d
            if let Some(cm) = &self.c[mnode] {
                let mut contrib = vec![0.0; m];
                gemv(1.0, cm, Trans::Yes, &d, 0.0, &mut contrib);
                for (zi, v) in z.iter_mut().zip(contrib.iter()) {
                    *zi += v;
                }
            }
            // Climb: d ← W_mᵀ d for the *next* node up (skip once the next
            // node is the root — there is no W at the root's children...
            // rather, the child-of-root term used W of that child).
            let next = path[idx - 1];
            if idx >= 2 {
                // `next` is a non-root inner node with a W factor.
                let w = self.f.w[next].as_ref().unwrap();
                let mut dnew = vec![0.0; w.cols()];
                gemv(1.0, w, Trans::Yes, &d, 0.0, &mut dnew);
                d = dnew;
            }
            let _ = next;
        }
        z
    }

    /// Materialize the full column v = k_hierarchical(X, x) in **tree
    /// order** (O(n) per query; used by GP posterior variance, which needs
    /// the column itself rather than an inner product).
    pub fn column(f: &HFactors, x: &[f64]) -> Vec<f64> {
        let agg = super::densify::aggregate_bases(f);
        Self::column_with_agg(f, &agg, x)
    }

    /// [`HPredictor::column`] with the aggregate bases precomputed by the
    /// caller — the repeated-query path (e.g. the out-of-sample KPCA
    /// transform), which would otherwise rebuild the O(n·r) bases per
    /// column.
    pub fn column_with_agg(f: &HFactors, agg: &[Option<Mat>], x: &[f64]) -> Vec<f64> {
        let kind = f.config.kind;
        let path = f.tree.route(x);
        let leaf = *path.last().unwrap();
        let n = f.n();
        let mut v = vec![0.0; n];
        let nd = &f.tree.nodes[leaf];
        for (k_local, &orig) in f.tree.node_points(leaf).iter().enumerate() {
            v[nd.lo + k_local] = kind.eval(f.x.row(orig), x);
        }
        if path.len() > 1 {
            let parent = f.tree.nodes[leaf].parent.unwrap();
            let lm = f.landmarks[parent].as_ref().unwrap();
            let kvec: Vec<f64> = (0..lm.rows()).map(|a| kind.eval(lm.row(a), x)).collect();
            let mut d = f.sigma_chol[parent].as_ref().unwrap().solve(&kvec);
            for idx in (1..path.len()).rev() {
                let mnode = path[idx];
                let p = f.tree.nodes[mnode].parent.unwrap();
                let sig = f.sigma[p].as_ref().unwrap();
                let mut sd = vec![0.0; sig.rows()];
                gemv(1.0, sig, Trans::No, &d, 0.0, &mut sd);
                for &sib in &f.tree.nodes[p].children {
                    if sib == mnode {
                        continue;
                    }
                    let a = agg[sib].as_ref().unwrap();
                    let ndl = &f.tree.nodes[sib];
                    let mut block = vec![0.0; ndl.len()];
                    gemv(1.0, a, Trans::No, &sd, 0.0, &mut block);
                    v[ndl.lo..ndl.hi].copy_from_slice(&block);
                }
                if idx >= 2 {
                    let next = path[idx - 1];
                    let w = f.w[next].as_ref().unwrap();
                    let mut dnew = vec![0.0; w.cols()];
                    gemv(1.0, w, Trans::Yes, &d, 0.0, &mut dnew);
                    d = dnew;
                }
            }
        }
        v
    }

    /// Borrow the underlying factors.
    pub fn factors(&self) -> &std::sync::Arc<HFactors> {
        &self.f
    }

    /// Evaluate a group of queries (rows of `q`) that all route to the
    /// same `leaf`, as gemms across the group: one kernel block
    /// `K(X_leaf, Q)` for the leaf term, one `K(X̲_p, Q)` + triangular
    /// solve for the shared `d` state, then the path climb as r×r-by-g
    /// matrix products. Returns a (q.rows() x m) block.
    ///
    /// This is the grouped counterpart of [`HPredictor::predict`]: every
    /// query on the same leaf shares the whole root path, so the scalar
    /// walk batches into dense products with no per-query branching.
    pub fn predict_leaf_group(&self, leaf: usize, q: &Mat) -> Mat {
        let f = self.f.as_ref();
        let m = self.outputs();
        let g = q.rows();
        let kind = f.config.kind;

        // Leaf term: Z = W_leafᵀ K(X_leaf, Q)  (m x g), on the leaf
        // blocks materialized at construction. Top of the serving chain:
        // the parallel kernel/gemm entries split large groups across the
        // pool and degrade to the packed sequential core for small ones
        // (or when an enclosing pass already holds the pool).
        let x_leaf = self.leaf_x[leaf].as_ref().unwrap();
        let kq = crate::kernels::par_kernel_cross(kind, x_leaf, q);
        let w_leaf = self.leaf_w[leaf].as_ref().unwrap();
        let mut z = par_matmul(w_leaf, Trans::Yes, &kq, Trans::No);

        let path = {
            // Path root → leaf via parent pointers (routing already done).
            let mut p = vec![leaf];
            let mut cur = leaf;
            while let Some(par) = f.tree.nodes[cur].parent {
                p.push(par);
                cur = par;
            }
            p.reverse();
            p
        };
        if path.len() > 1 {
            // Shared d state: D = Σ_{p(leaf)}^{-1} K(X̲_{p(leaf)}, Q)  (r x g).
            let parent = f.tree.nodes[leaf].parent.unwrap();
            let lm = f.landmarks[parent].as_ref().unwrap();
            let kp = crate::kernels::par_kernel_cross(kind, lm, q);
            let mut d = f.sigma_chol[parent].as_ref().unwrap().solve_mat(&kp);

            for idx in (1..path.len()).rev() {
                let mnode = path[idx];
                if let Some(cm) = &self.c[mnode] {
                    // Z += c_mᵀ D
                    gemm(1.0, cm, Trans::Yes, &d, Trans::No, 1.0, &mut z);
                }
                if idx >= 2 {
                    let w = f.w[path[idx - 1]].as_ref().unwrap();
                    d = matmul(w, Trans::Yes, &d, Trans::No);
                }
            }
        }
        // Transpose to request-major (g x m).
        Mat::from_fn(g, m, |i, j| z[(j, i)])
    }

    /// Predict a batch of query points (rows of `q`), returning a
    /// (q.rows() x m) matrix. Queries are grouped by their routed leaf and
    /// each group is evaluated with [`HPredictor::predict_leaf_group`]
    /// (gemms across the group) instead of a per-query scalar walk;
    /// results come back in request order.
    pub fn predict_batch(&self, q: &Mat) -> Mat {
        grouped_eval(
            q,
            self.outputs(),
            |x| self.f.tree.route_leaf(x),
            |leaf, sub| self.predict_leaf_group(leaf, sub),
        )
    }
}

/// Long-lived, batched GP posterior-variance state:
/// `σ²(x) = k(x,x) − k(X,x)ᵀ (K + λI)^{-1} k(X,x)` over the hierarchical
/// kernel (paper eq. 4), built once and reused across requests.
///
/// Holds the three O(nr)-sized precomputations the per-query math needs —
/// the owned solver factorization ([`crate::hkernel::HSolver`] state
/// without the borrow), the aggregate bases used to materialize kernel
/// columns, and the factors themselves — so serving a variance request
/// costs one column materialization (O(nr)) plus one solver application
/// (O(nr)) per query, with the whole batch going through **one**
/// level-synchronous `solve_mat` instead of per-query solves.
///
/// Every query's variance is computed column-independently, so the result
/// for a given query is identical no matter how a batch is grouped — the
/// property that makes sharded variance match in-process variance exactly
/// (see [`crate::shard::ShardedPredictor`]).
pub struct HVariance {
    f: std::sync::Arc<HFactors>,
    parts: super::solve::SolverParts,
    /// Aggregate bases for column materialization, precomputed once.
    agg: Vec<Option<Mat>>,
    lambda: f64,
}

impl HVariance {
    /// Factor `(K + λI)` and precompute the column bases. O(nr²), once.
    pub fn new(f: std::sync::Arc<HFactors>, lambda: f64) -> crate::error::Result<HVariance> {
        let parts = super::solve::SolverParts::factor(&f, lambda)?;
        let agg = super::densify::aggregate_bases(&f);
        Ok(HVariance { f, parts, agg, lambda })
    }

    /// The noise variance λ this state was factored with.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Borrow the underlying factors.
    pub fn factors(&self) -> &std::sync::Arc<HFactors> {
        &self.f
    }

    /// Posterior variance for a batch of query rows, one σ² per row.
    ///
    /// Column materialization parallelizes across queries; the quadratic
    /// terms go through a single blocked solve. Non-negative by
    /// construction (clamped at 0, matching [`crate::gp::GpRegressor`]).
    pub fn variance_batch(&self, q: &Mat) -> Vec<f64> {
        let f = self.f.as_ref();
        let g = q.rows();
        if g == 0 {
            return Vec::new();
        }
        let n = f.n();
        let idx: Vec<usize> = (0..g).collect();
        let threads = crate::util::parallel::auto_threads(n.max(g));
        let cols = crate::util::parallel::parallel_map(threads, &idx, |&i| {
            HPredictor::column_with_agg(f, &self.agg, q.row(i))
        });
        let mut v = Mat::zeros(n, g);
        for (i, col) in cols.iter().enumerate() {
            v.set_col(i, col);
        }
        let sol = self.parts.solve_mat(f, &v);
        let prior = f.config.kind.diag_value();
        (0..g)
            .map(|i| {
                let mut quad = 0.0;
                for row in 0..n {
                    quad += v[(row, i)] * sol[(row, i)];
                }
                (prior - quad).max(0.0)
            })
            .collect()
    }
}

/// Lazily-built, shareable [`HVariance`]: the O(nr²) factorization runs
/// on the **first variance request** — never for mean-only traffic — and
/// every holder of the `Arc` (the in-process model and all shard
/// workers) sees the same state afterwards. A failed factorization is
/// cached too, so a broken state errors per request instead of
/// refactoring per request.
pub struct LazyVariance {
    f: std::sync::Arc<HFactors>,
    lambda: f64,
    cell: std::sync::OnceLock<std::result::Result<HVariance, String>>,
}

impl LazyVariance {
    /// Record what to build; costs nothing until [`LazyVariance::get`].
    pub fn new(f: std::sync::Arc<HFactors>, lambda: f64) -> LazyVariance {
        LazyVariance { f, lambda, cell: std::sync::OnceLock::new() }
    }

    /// The built state, factoring on first call.
    pub fn get(&self) -> std::result::Result<&HVariance, String> {
        self.cell
            .get_or_init(|| {
                HVariance::new(self.f.clone(), self.lambda).map_err(|e| e.to_string())
            })
            .as_ref()
            .map_err(|e| e.clone())
    }

    /// The noise variance λ the state will be factored with.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }
}

/// Group the rows of `q` by a routing key, evaluate each group as one
/// block, and scatter the results back in request order. Shared by
/// [`HPredictor::predict_batch`] and [`crate::shard::Shard::predict_batch`]
/// (same grouping semantics, different route/eval pairs). The BTreeMap
/// keeps the group evaluation order deterministic.
pub(crate) fn grouped_eval(
    q: &Mat,
    outputs: usize,
    route: impl Fn(&[f64]) -> usize,
    mut eval: impl FnMut(usize, &Mat) -> Mat,
) -> Mat {
    let mut out = Mat::zeros(q.rows(), outputs);
    let mut groups: std::collections::BTreeMap<usize, Vec<usize>> =
        std::collections::BTreeMap::new();
    for i in 0..q.rows() {
        groups.entry(route(q.row(i))).or_default().push(i);
    }
    for (key, idx) in groups {
        let sub = q.select_rows(&idx);
        let block = eval(key, &sub);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(block.row(k));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hkernel::build::HConfig;
    use crate::hkernel::densify::aggregate_bases;
    use crate::kernels::{Gaussian, KernelKind, Laplace};
    use crate::util::rng::Rng;

    fn build(
        n: usize,
        r: usize,
        n0: usize,
        kind: KernelKind,
        seed: u64,
    ) -> std::sync::Arc<HFactors> {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 3, |_, _| rng.uniform(0.0, 1.0));
        let mut cfg = HConfig::new(kind, r).with_seed(seed + 100);
        cfg.n0 = n0;
        cfg.lambda_prime = 0.0;
        std::sync::Arc::new(HFactors::build(&x, cfg).unwrap())
    }

    /// Oracle: materialize v = k_hierarchical(X, x) (tree order) from the
    /// definition via aggregate bases, then dot with w. Independent code
    /// path from HPredictor.
    fn oracle(f: &HFactors, w_tree: &Mat, x: &[f64]) -> Vec<f64> {
        let kind = f.config.kind;
        let path = f.tree.route(x);
        let leaf = *path.last().unwrap();
        let n = f.n();
        let m = w_tree.cols();
        let agg = aggregate_bases(f);
        let mut v = vec![0.0; n];
        // Leaf block.
        let nd = &f.tree.nodes[leaf];
        for (k_local, &orig) in f.tree.node_points(leaf).iter().enumerate() {
            v[nd.lo + k_local] = kind.eval(f.x.row(orig), x);
        }
        // For every node on the path (from leaf up), its siblings receive
        // AggU_l Σ_p d_m.
        if path.len() > 1 {
            let parent = f.tree.nodes[leaf].parent.unwrap();
            let lm = f.landmarks[parent].as_ref().unwrap();
            let kvec: Vec<f64> = (0..lm.rows()).map(|a| kind.eval(lm.row(a), x)).collect();
            let mut d = f.sigma_chol[parent].as_ref().unwrap().solve(&kvec);
            for idx in (1..path.len()).rev() {
                let mnode = path[idx];
                let p = f.tree.nodes[mnode].parent.unwrap();
                let sig = f.sigma[p].as_ref().unwrap();
                let mut sd = vec![0.0; sig.rows()];
                gemv(1.0, sig, Trans::No, &d, 0.0, &mut sd);
                for &sib in &f.tree.nodes[p].children {
                    if sib == mnode {
                        continue;
                    }
                    let a = agg[sib].as_ref().unwrap();
                    let ndl = &f.tree.nodes[sib];
                    let mut block = vec![0.0; ndl.len()];
                    gemv(1.0, a, Trans::No, &sd, 0.0, &mut block);
                    for (k_local, val) in block.iter().enumerate() {
                        v[ndl.lo + k_local] = *val;
                    }
                }
                if idx >= 2 {
                    let next = path[idx - 1];
                    let w = f.w[next].as_ref().unwrap();
                    let mut dnew = vec![0.0; w.cols()];
                    gemv(1.0, w, Trans::Yes, &d, 0.0, &mut dnew);
                    d = dnew;
                }
            }
        }
        // wᵀ v
        (0..m)
            .map(|j| (0..n).map(|i| w_tree[(i, j)] * v[i]).sum())
            .collect()
    }

    #[test]
    fn property_matches_oracle() {
        for (seed, kind, n0) in [
            (1u64, Gaussian::new(0.5), 6usize),
            (2, Gaussian::new(1.3), 15),
            (3, Laplace::new(0.7), 10),
        ] {
            let f = build(60, 6, n0, kind, seed);
            let mut rng = Rng::new(seed * 31);
            let w = Mat::from_fn(60, 2, |_, _| rng.normal());
            let pred = HPredictor::new(f.clone(), &w);
            let w_tree = f.rows_to_tree_order(&w);
            for _ in 0..10 {
                let x: Vec<f64> = (0..3).map(|_| rng.uniform(0.0, 1.0)).collect();
                let got = pred.predict(&x);
                let want = oracle(&f, &w_tree, &x);
                for j in 0..2 {
                    assert!(
                        (got[j] - want[j]).abs() < 1e-9 * (1.0 + want[j].abs()),
                        "{kind:?} n0={n0}: {} vs {}",
                        got[j],
                        want[j]
                    );
                }
            }
        }
    }

    /// End-to-end consistency: predicting at a *training* point must
    /// reproduce the corresponding entry of the fast matvec, because
    /// k_hierarchical(X, x_i) is the i-th column of the kernel matrix.
    #[test]
    fn training_point_prediction_matches_matvec() {
        let f = build(48, 6, 8, Gaussian::new(0.6), 5);
        let mut rng = Rng::new(77);
        let wvec: Vec<f64> = (0..48).map(|_| rng.normal()).collect();
        let w = Mat::from_vec(48, 1, wvec.clone());
        let pred = HPredictor::new(f.clone(), &w);
        // wᵀ K column i == (K w)_i by symmetry.
        let kw = crate::hkernel::matvec::hmatvec_original(&f, &wvec);
        let mut worst = 0.0f64;
        for i in 0..48 {
            let z = pred.predict(f.x.row(i))[0];
            worst = worst.max((z - kw[i]).abs());
        }
        assert!(worst < 1e-9, "worst {worst}");
    }

    #[test]
    fn single_leaf_predictor() {
        let f = build(10, 4, 64, Gaussian::new(0.5), 6);
        assert_eq!(f.tree.nodes.len(), 1);
        let w = Mat::from_fn(10, 1, |i, _| i as f64);
        let pred = HPredictor::new(f.clone(), &w);
        let x = vec![0.3, 0.6, 0.9];
        let got = pred.predict(&x)[0];
        let want: f64 = (0..10)
            .map(|i| (i as f64) * f.config.kind.eval(f.x.row(i), &x))
            .sum();
        assert!((got - want).abs() < 1e-12);
    }

    /// The grouped-gemm batch path must agree with the scalar walk to
    /// ≤ 1e-10 (the kernel block goes through the gemm expansion rather
    /// than per-pair distance evaluation, so the match is numerical, not
    /// bitwise) — across kernels, multi-output weights and batch sizes
    /// large enough that leaves receive multi-query groups.
    #[test]
    fn batch_matches_single() {
        for (seed, kind) in [(7u64, Gaussian::new(0.8)), (8, Laplace::new(0.6))] {
            let f = build(72, 5, 6, kind, seed);
            let mut rng = Rng::new(seed * 13);
            let w = Mat::from_fn(72, 3, |_, _| rng.normal());
            let pred = HPredictor::new(f.clone(), &w);
            for qn in [1usize, 5, 64] {
                let q = Mat::from_fn(qn, 3, |_, _| rng.uniform(0.0, 1.0));
                let batch = pred.predict_batch(&q);
                for i in 0..qn {
                    let single = pred.predict(q.row(i));
                    for j in 0..3 {
                        assert!(
                            (batch[(i, j)] - single[j]).abs()
                                <= 1e-10 * (1.0 + single[j].abs()),
                            "qn={qn} i={i} j={j}: {} vs {}",
                            batch[(i, j)],
                            single[j]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn leaf_group_matches_predict_on_training_leaves() {
        // Route training points (guaranteed multi-query groups when the
        // batch is larger than the leaf count).
        let f = build(60, 6, 10, Gaussian::new(0.5), 9);
        let mut rng = Rng::new(17);
        let w = Mat::from_fn(60, 2, |_, _| rng.normal());
        let pred = HPredictor::new(f.clone(), &w);
        let q = Mat::from_fn(40, 3, |i, j| f.x[(i % 60, j)]);
        let batch = pred.predict_batch(&q);
        for i in 0..40 {
            let single = pred.predict(q.row(i));
            for j in 0..2 {
                assert!((batch[(i, j)] - single[j]).abs() <= 1e-10 * (1.0 + single[j].abs()));
            }
        }
    }
}
