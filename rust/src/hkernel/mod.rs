//! The paper's contribution: the hierarchically compositional kernel and
//! its recursively low-rank compressed matrix algebra.
//!
//! - [`build`]: hierarchical factor construction — the matrix view of
//!   Section 3 (leaf blocks `A_ii`, bases `U_i`, middle factors `Σ_p`,
//!   changes of basis `W_p`), with the λ′ numerical stabilization of
//!   Section 4.3 and the landmark selection of Section 4.2.
//! - [`matvec`]: Algorithm 1 — `y = A b` in O(nr) via one post-order and
//!   one pre-order traversal.
//! - [`solve`]: a two-pass Sherman–Morrison–Woodbury factorization of
//!   `A + λI`, algebraically equivalent to the paper's Algorithm 2
//!   (O(nr²) factor, O(nr) per right-hand side), which also yields
//!   `log det(A + λI)` — the Gaussian-process MLE extension of Section 6.
//! - [`oos`]: Algorithm 3 — out-of-sample inner products
//!   `wᵀ k_hierarchical(X, x)` with O(nr) preprocessing and
//!   O(r² log(n/r) + dr) per query.
//! - [`densify`]: materializes the full kernel matrix (test oracle only).

pub mod build;
pub mod densify;
pub mod matvec;
pub mod oos;
pub mod persist;
pub mod solve;

pub use build::{size_rule, size_rule_from_rank, HConfig, HFactors};
pub use persist::{
    load_model, load_router, load_shard, save_model, save_router, save_shard,
};
pub use matvec::{hmatvec, hmatvec_mat, hmatvec_original, hmatvec_with_threads};
pub use oos::{HPredictor, HVariance, LazyVariance};
pub use solve::HSolver;
