//! Construction of the hierarchical factors (paper Section 3, items 1–6).
//!
//! The build runs in three phases so the expensive per-node block work
//! parallelizes while randomness stays on a single deterministic stream:
//!
//! 1. **Sampling** (sequential): landmark index sets X̲_i for every
//!    nonleaf node, drawn from one RNG in node-id order — the stream is
//!    identical whatever the thread count.
//! 2. **Landmark Grams** (parallel): Σ_i = K′(X̲_i, X̲_i) and its
//!    Cholesky, independent across nodes.
//! 3. **Blocks and bases** (parallel): leaf blocks A_ii, leaf bases U_i
//!    and inner changes-of-basis W_i, independent across nodes once every
//!    parent Σ_p is factored.
//!
//! Phases 2–3 engage threads only for evaluators that declare
//! [`BlockEvaluator::parallel_safe`] (the native one); the PJRT evaluator
//! wraps a single-threaded client and keeps the sequential path. Results
//! are written back in node-id order, so factor construction is bitwise
//! deterministic for every thread count.

use crate::error::{Error, Result};
use crate::kernels::{BlockEvaluator, KernelKind, NativeEvaluator};
use crate::linalg::{Cholesky, Mat};
use crate::obs;
use crate::partition::{PartitionTree, SplitRule};
use crate::util::parallel::{auto_threads, parallel_map};
use crate::util::rng::Rng;
use crate::util::timer::{Phases, Timer};

/// Configuration of the hierarchical kernel.
#[derive(Debug, Clone)]
pub struct HConfig {
    /// Base kernel (strictly PD family + bandwidth).
    pub kind: KernelKind,
    /// Landmark count r per nonleaf node (capped at the node size).
    pub rank: usize,
    /// Leaf capacity n0 (paper eq. 22 ties this to r; see [`size_rule`]).
    pub n0: usize,
    /// λ′ of Section 4.3: added to the *base kernel's* diagonal
    /// (k′(x,x′) = k(x,x′) + λ′ δ_{x,x′}) for conditioning of the
    /// landmark Gram matrices. Keep well below the training λ.
    pub lambda_prime: f64,
    /// Partitioning rule (Section 4.1; random projection recommended).
    pub rule: SplitRule,
    /// Seed for partitioning + landmark sampling.
    pub seed: u64,
    /// When sampling the landmark set X̲_i of a non-root node, exclude
    /// points that are already landmarks of the parent. The paper permits
    /// overlap (Propositions 1/5 celebrate the resulting exactness), and a
    /// shared landmark makes the per-node Schur factor
    /// G_i = Σ_i − W_i Σ_p W_iᵀ exactly singular (Appendix A notes its
    /// zero rows) — which the fast solver tolerates exactly thanks to the
    /// push-through Woodbury form (see `solve.rs`). Disjoint sampling is
    /// offered for conditioning experiments. Default: false (paper-faithful).
    pub avoid_parent_landmarks: bool,
}

impl HConfig {
    /// Sensible defaults for a given kernel and rank; n0 is set equal to
    /// the rank per the consolidated size rule (eq. 22).
    pub fn new(kind: KernelKind, rank: usize) -> HConfig {
        HConfig {
            kind,
            rank,
            n0: rank.max(1),
            lambda_prime: 1e-8,
            rule: SplitRule::RandomProjection,
            seed: 0,
            avoid_parent_landmarks: false,
        }
    }

    /// Builder-style seed override.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Builder-style split-rule override.
    pub fn with_rule(mut self, rule: SplitRule) -> Self {
        self.rule = rule;
        self
    }
}

/// The consolidated size rule of eq. (22): for a balanced binary tree of
/// depth j over n points, n0 = ceil(n / 2^j) and r = floor(n / 2^j).
pub fn size_rule(n: usize, j: u32) -> (usize, usize) {
    let denom = 1usize << j;
    let n0 = n.div_ceil(denom);
    let r = n / denom;
    (n0.max(1), r.max(1))
}

/// Choose the tree depth j so the level rank is as close as possible to
/// `r_target`, then apply eq. (22). Returns (n0, r, j).
pub fn size_rule_from_rank(n: usize, r_target: usize) -> (usize, usize, u32) {
    let r_target = r_target.max(1);
    let mut best = (n, n, 0u32);
    let mut best_diff = f64::INFINITY;
    let max_j = (usize::BITS - n.leading_zeros()).max(1);
    for j in 0..max_j {
        let (n0, r) = size_rule(n, j);
        let diff = ((r as f64).ln() - (r_target as f64).ln()).abs();
        if diff < best_diff {
            best_diff = diff;
            best = (n0, r, j);
        }
    }
    best
}

/// Per-node factors of the recursively low-rank compressed matrix.
///
/// Indexing follows the partition tree's node ids. `Option` entries are
/// populated according to role: leaves carry `a_leaf`/`u`; nonleaf nodes
/// carry `landmark*`/`sigma*`; nonleaf non-root nodes carry `w`.
pub struct HFactors {
    /// The partitioning tree (owns the permutation).
    pub tree: PartitionTree,
    /// Configuration used to build.
    pub config: HConfig,
    /// Training features (original order), kept for out-of-sample leaf
    /// kernel evaluations.
    pub x: Mat,
    /// Nonleaf i: original training indices of the landmark set X̲_i.
    pub landmark_idx: Vec<Vec<usize>>,
    /// Nonleaf i: landmark coordinates (r_i x d).
    pub landmarks: Vec<Option<Mat>>,
    /// Nonleaf i: Σ_i = K′(X̲_i, X̲_i)  (r_i x r_i).
    pub sigma: Vec<Option<Mat>>,
    /// Nonleaf i: Cholesky of Σ_i.
    pub sigma_chol: Vec<Option<Cholesky>>,
    /// Nonleaf non-root i: W_i = K′(X̲_i, X̲_p) Σ_p^{-1}  (r_i x r_p).
    pub w: Vec<Option<Mat>>,
    /// Leaf i: U_i = K′(X_i, X̲_p) Σ_p^{-1}  (n_i x r_p).
    pub u: Vec<Option<Mat>>,
    /// Leaf i: A_ii = K′(X_i, X_i)  (n_i x n_i).
    pub a_leaf: Vec<Option<Mat>>,
    /// Wall-clock breakdown of the build (partition / sample_landmarks /
    /// sigma_factor / node_factors). Not persisted with the factors;
    /// reloaded artifacts carry an empty breakdown.
    pub build_phases: Phases,
}

/// Phase-3 output for one node (computed off-thread, applied in order).
enum NodeFactor {
    Leaf { aii: Mat, u: Option<Mat> },
    Inner { w: Option<Mat> },
}

impl HFactors {
    /// Build tree + factors with the native block evaluator.
    pub fn build(x: &Mat, config: HConfig) -> Result<HFactors> {
        Self::build_with(x, config, &NativeEvaluator)
    }

    /// Build tree + factors with a custom (e.g. PJRT) block evaluator.
    pub fn build_with(
        x: &Mat,
        config: HConfig,
        eval: &dyn BlockEvaluator,
    ) -> Result<HFactors> {
        if x.rows() == 0 {
            return Err(Error::config("cannot build on an empty training set"));
        }
        let mut rng = Rng::new(config.seed);
        let t = Timer::start();
        let tree = {
            let _sp = obs::span("train.partition", "train");
            PartitionTree::build(x, config.n0.max(1), config.rule, &mut rng)
        };
        let partition_secs = t.secs();
        let mut f = Self::build_on_tree(x, config, tree, &mut rng, eval)?;
        // Keep "partition" first in the breakdown (it happened first).
        let mut phases = Phases::new();
        phases.add("partition", partition_secs);
        for (name, secs) in f.build_phases.entries() {
            phases.add(name, *secs);
        }
        f.build_phases = phases;
        Ok(f)
    }

    /// Build factors over an externally constructed tree (used by the
    /// partitioning experiments, which time tree building separately).
    pub fn build_on_tree(
        x: &Mat,
        config: HConfig,
        tree: PartitionTree,
        rng: &mut Rng,
        eval: &dyn BlockEvaluator,
    ) -> Result<HFactors> {
        let nn = tree.nodes.len();
        let kind = config.kind;
        let lp = config.lambda_prime;
        let threads = auto_threads(x.rows());
        let use_parallel = threads > 1 && eval.parallel_safe();

        let mut f = HFactors {
            // The one deliberate full-data copy of the build: HFactors
            // outlives the caller's borrow (predictors hold it in an
            // Arc), so it must own the coordinates for OOS leaf kernels.
            // Removing it entirely is the ROADMAP "streaming/out-of-core
            // build" item, not a borrow fix.
            x: x.clone(),
            landmark_idx: vec![Vec::new(); nn],
            landmarks: vec![None; nn],
            sigma: vec![None; nn],
            sigma_chol: vec![None; nn],
            w: vec![None; nn],
            u: vec![None; nn],
            a_leaf: vec![None; nn],
            build_phases: Phases::new(),
            tree,
            config,
        };
        let mut t = Timer::start();

        // --- Phase 1 (sequential): landmark sets for every nonleaf node
        // (Section 4.2: uniformly random samples of the node's own
        // points). Node ids are assigned parent-before-child by the tree
        // builder, so a node's parent landmarks are always available when
        // we get to it. One RNG stream in node-id order keeps sampling
        // independent of the thread count. ---
        let sp = obs::span("train.sample_landmarks", "train");
        for i in 0..nn {
            if f.tree.nodes[i].is_leaf() {
                continue;
            }
            let parent = f.tree.nodes[i].parent;
            // Sample against the tree's own index slice; a copy is made
            // only when parent-landmark exclusion actually filters it.
            let idx: Vec<usize> = {
                let pts: &[usize] = f.tree.node_points(i);
                let filtered: Option<Vec<usize>> = match parent {
                    Some(p) if f.config.avoid_parent_landmarks => {
                        let excluded: std::collections::HashSet<usize> =
                            f.landmark_idx[p].iter().copied().collect();
                        let kept: Vec<usize> =
                            pts.iter().copied().filter(|q| !excluded.contains(q)).collect();
                        // Keep at least one candidate; fall back to overlap
                        // if the exclusion would empty the pool.
                        if kept.is_empty() { None } else { Some(kept) }
                    }
                    _ => None,
                };
                let pool: &[usize] = filtered.as_deref().unwrap_or(pts);
                let r_i = f.config.rank.min(pool.len()).max(1);
                let mut idx: Vec<usize> =
                    rng.sample_indices(pool.len(), r_i).iter().map(|&k| pool[k]).collect();
                idx.sort_unstable(); // determinism niceties; order is irrelevant
                idx
            };
            f.landmarks[i] = Some(x.select_rows(&idx));
            f.landmark_idx[i] = idx;
        }
        drop(sp);
        f.build_phases.add("sample_landmarks", t.lap());

        // --- Phase 2 (parallel): Σ_i and its Cholesky per nonleaf. ---
        let sp = obs::span("train.sigma_factor", "train");
        let nonleaves: Vec<usize> =
            (0..nn).filter(|&i| !f.tree.nodes[i].is_leaf()).collect();
        let sig_results: Vec<Result<(Mat, Cholesky)>> = if use_parallel {
            parallel_map(threads, &nonleaves, |&i| sigma_factor(&f, i, kind, lp, &NativeEvaluator))
        } else {
            nonleaves.iter().map(|&i| sigma_factor(&f, i, kind, lp, eval)).collect()
        };
        for (&i, res) in nonleaves.iter().zip(sig_results) {
            let (sig, chol) = res?;
            f.sigma[i] = Some(sig);
            f.sigma_chol[i] = Some(chol);
        }
        drop(sp);
        f.build_phases.add("sigma_factor", t.lap());

        // --- Phase 3 (parallel): leaf blocks and bases; W for inner
        // nodes. Every parent Σ_p is factored by now. ---
        let sp = obs::span("train.node_factors", "train");
        let all_ids: Vec<usize> = (0..nn).collect();
        let node_results: Vec<NodeFactor> = if use_parallel {
            parallel_map(threads, &all_ids, |&i| node_factor(&f, i, kind, lp, &NativeEvaluator))
        } else {
            all_ids.iter().map(|&i| node_factor(&f, i, kind, lp, eval)).collect()
        };
        for (i, res) in node_results.into_iter().enumerate() {
            match res {
                NodeFactor::Leaf { aii, u } => {
                    f.a_leaf[i] = Some(aii);
                    f.u[i] = u;
                }
                NodeFactor::Inner { w } => {
                    f.w[i] = w;
                }
            }
        }
        drop(sp);
        f.build_phases.add("node_factors", t.lap());
        Ok(f)
    }

    /// Number of training points.
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Landmark count of node i's parent (the dimension of the c/d
    /// vectors attached to node i in Algorithms 1–3).
    pub fn parent_rank(&self, i: usize) -> usize {
        let p = self.tree.nodes[i].parent.expect("root has no parent rank");
        self.landmark_idx[p].len()
    }

    /// Memory footprint in f64 words of the stored factors (the paper's
    /// §4.5 estimate is ≈ 4nr for n0 = r): Σ |A_ii| + |U_i| + |Σ_p| + |W_p|.
    pub fn memory_words(&self) -> usize {
        let mut words = 0;
        for i in 0..self.tree.nodes.len() {
            if let Some(a) = &self.a_leaf[i] {
                words += a.rows() * a.cols();
            }
            if let Some(u) = &self.u[i] {
                words += u.rows() * u.cols();
            }
            if let Some(s) = &self.sigma[i] {
                words += s.rows() * s.cols();
            }
            if let Some(w) = &self.w[i] {
                words += w.rows() * w.cols();
            }
        }
        words
    }

    /// Permute a vector from original order into tree (block) order.
    pub fn to_tree_order(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.n());
        self.tree.perm.iter().map(|&orig| v[orig]).collect()
    }

    /// Permute a vector from tree order back to original order.
    pub fn from_tree_order(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.n());
        let mut out = vec![0.0; v.len()];
        for (pos, &orig) in self.tree.perm.iter().enumerate() {
            out[orig] = v[pos];
        }
        out
    }

    /// Permute matrix rows from original order into tree order.
    pub fn rows_to_tree_order(&self, m: &Mat) -> Mat {
        m.select_rows(&self.tree.perm)
    }

    /// Permute matrix rows from tree order back to original order.
    pub fn rows_from_tree_order(&self, m: &Mat) -> Mat {
        let mut inv = vec![0usize; self.n()];
        for (pos, &orig) in self.tree.perm.iter().enumerate() {
            inv[orig] = pos;
        }
        m.select_rows(&inv)
    }
}

/// Phase-2 work for one nonleaf node: Σ_i = K′(X̲_i, X̲_i) and its
/// Cholesky. Reads only phase-1 state.
fn sigma_factor<E: BlockEvaluator + ?Sized>(
    f: &HFactors,
    i: usize,
    kind: KernelKind,
    lp: f64,
    eval: &E,
) -> Result<(Mat, Cholesky)> {
    let lm = f.landmarks[i].as_ref().unwrap();
    let r_i = lm.rows();
    let mut sig = eval.block(kind, lm, lm);
    sig.symmetrize();
    // λ′ on the diagonal (coincident points of k′).
    for a in 0..r_i {
        sig[(a, a)] = kind.diag_value() + lp;
    }
    let chol = Cholesky::new_jittered(&sig, 30)
        .map_err(|e| Error::linalg(format!("Σ_{i} not PD even with jitter: {e}")))?;
    Ok((sig, chol))
}

/// Phase-3 work for one node: the leaf block A_ii and basis U_i, or the
/// inner change-of-basis W_i. Reads only phase-1/2 state.
fn node_factor<E: BlockEvaluator + ?Sized>(
    f: &HFactors,
    i: usize,
    kind: KernelKind,
    lp: f64,
    eval: &E,
) -> NodeFactor {
    let parent = f.tree.nodes[i].parent;
    if f.tree.nodes[i].is_leaf() {
        // Borrow the tree's index slice directly — this runs once per
        // leaf inside the build loop, so the per-node Vec copy was pure
        // allocator traffic.
        let pts: &[usize] = f.tree.node_points(i);
        let xi = f.x.select_rows(pts);
        let mut aii = eval.block(kind, &xi, &xi);
        aii.symmetrize();
        for a in 0..pts.len() {
            aii[(a, a)] = kind.diag_value() + lp;
        }
        let u = parent.map(|p| {
            let kxl = cross_with_identity(
                eval,
                kind,
                &xi,
                pts,
                f.landmarks[p].as_ref().unwrap(),
                &f.landmark_idx[p],
                lp,
            );
            // U_i = K′(X_i, X̲_p) Σ_p^{-1}
            f.sigma_chol[p].as_ref().unwrap().solve_right(&kxl)
        });
        NodeFactor::Leaf { aii, u }
    } else {
        let w = parent.map(|p| {
            let kll = cross_with_identity(
                eval,
                kind,
                f.landmarks[i].as_ref().unwrap(),
                &f.landmark_idx[i],
                f.landmarks[p].as_ref().unwrap(),
                &f.landmark_idx[p],
                lp,
            );
            // W_i = K′(X̲_i, X̲_p) Σ_p^{-1}
            f.sigma_chol[p].as_ref().unwrap().solve_right(&kll)
        });
        NodeFactor::Inner { w }
    }
}

/// K′(A, B) where both point sets carry original training indices:
/// evaluates the base kernel block and adds λ′ wherever the same original
/// point appears on both sides (the Kronecker δ of k′ = k + λ′δ).
fn cross_with_identity<E: BlockEvaluator + ?Sized>(
    eval: &E,
    kind: KernelKind,
    a: &Mat,
    a_idx: &[usize],
    b: &Mat,
    b_idx: &[usize],
    lambda_prime: f64,
) -> Mat {
    let mut k = eval.block(kind, a, b);
    if lambda_prime != 0.0 {
        use std::collections::HashMap;
        let bpos: HashMap<usize, usize> =
            b_idx.iter().enumerate().map(|(j, &orig)| (orig, j)).collect();
        for (i, &orig) in a_idx.iter().enumerate() {
            if let Some(&j) = bpos.get(&orig) {
                k[(i, j)] += lambda_prime;
            }
        }
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Gaussian;

    fn cloud(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, d, |_, _| rng.uniform(0.0, 1.0))
    }

    #[test]
    fn size_rule_matches_paper() {
        // eq. 22 with n = 1033, j = 3: n0 = ceil(1033/8) = 130, r = 129.
        assert_eq!(size_rule(1033, 3), (130, 129));
        assert_eq!(size_rule(16, 0), (16, 16));
        assert_eq!(size_rule(16, 2), (4, 4));
    }

    #[test]
    fn size_rule_from_rank_picks_nearest() {
        let (n0, r, j) = size_rule_from_rank(4096, 129);
        assert_eq!(j, 5);
        assert_eq!(r, 128);
        assert_eq!(n0, 128);
        let (_, r1, _) = size_rule_from_rank(4096, 4096);
        assert_eq!(r1, 4096);
    }

    #[test]
    fn factors_have_expected_shapes() {
        let x = cloud(64, 4, 1);
        let cfg = HConfig::new(Gaussian::new(0.6), 8).with_seed(3);
        let f = HFactors::build(&x, cfg).unwrap();
        let nn = f.tree.nodes.len();
        for i in 0..nn {
            let nd = &f.tree.nodes[i];
            if nd.is_leaf() {
                let a = f.a_leaf[i].as_ref().unwrap();
                assert_eq!(a.shape(), (nd.len(), nd.len()));
                let u = f.u[i].as_ref().unwrap();
                assert_eq!(u.rows(), nd.len());
                assert_eq!(u.cols(), f.parent_rank(i));
                assert!(f.sigma[i].is_none());
            } else {
                let r_i = f.landmark_idx[i].len();
                assert_eq!(r_i, 8.min(nd.len()));
                assert_eq!(f.sigma[i].as_ref().unwrap().shape(), (r_i, r_i));
                if nd.parent.is_some() {
                    let w = f.w[i].as_ref().unwrap();
                    assert_eq!(w.shape(), (r_i, f.parent_rank(i)));
                } else {
                    assert!(f.w[i].is_none());
                }
            }
        }
    }

    #[test]
    fn landmarks_are_node_points() {
        let x = cloud(64, 3, 2);
        let cfg = HConfig::new(Gaussian::new(0.5), 6).with_seed(5);
        let f = HFactors::build(&x, cfg).unwrap();
        for i in 0..f.tree.nodes.len() {
            if !f.tree.nodes[i].is_leaf() {
                let pts: std::collections::HashSet<usize> =
                    f.tree.node_points(i).iter().copied().collect();
                for &lm in &f.landmark_idx[i] {
                    assert!(pts.contains(&lm), "landmark {lm} outside node {i}");
                }
                // Distinct landmarks.
                let set: std::collections::HashSet<_> =
                    f.landmark_idx[i].iter().collect();
                assert_eq!(set.len(), f.landmark_idx[i].len());
            }
        }
    }

    #[test]
    fn u_satisfies_normal_equation() {
        // U_i Σ_p = K′(X_i, X̲_p)
        let x = cloud(32, 3, 7);
        let cfg = HConfig::new(Gaussian::new(0.7), 4).with_seed(9);
        let f = HFactors::build(&x, cfg).unwrap();
        for &leaf in &f.tree.leaves() {
            let p = f.tree.nodes[leaf].parent.unwrap();
            let u = f.u[leaf].as_ref().unwrap();
            let sig = f.sigma[p].as_ref().unwrap();
            let prod = crate::linalg::matmul(
                u,
                crate::linalg::Trans::No,
                sig,
                crate::linalg::Trans::No,
            );
            // Rebuild K′(X_i, X̲_p) directly.
            let pts = f.tree.node_points(leaf);
            let xi = x.select_rows(pts);
            let mut want = crate::kernels::kernel_cross(
                f.config.kind,
                &xi,
                f.landmarks[p].as_ref().unwrap(),
            );
            for (a, &orig) in pts.iter().enumerate() {
                if let Some(j) = f.landmark_idx[p].iter().position(|&l| l == orig) {
                    want[(a, j)] += f.config.lambda_prime;
                }
            }
            let mut diff = prod;
            diff.axpy(-1.0, &want);
            assert!(diff.max_abs() < 1e-8, "leaf {leaf}: {}", diff.max_abs());
        }
    }

    #[test]
    fn tree_order_roundtrip() {
        let x = cloud(20, 2, 8);
        let cfg = HConfig::new(Gaussian::new(0.5), 4).with_seed(1);
        let f = HFactors::build(&x, cfg).unwrap();
        let v: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let t = f.to_tree_order(&v);
        let back = f.from_tree_order(&t);
        assert_eq!(back, v);
        let m = Mat::from_fn(20, 2, |i, j| (i * 2 + j) as f64);
        let mb = f.rows_from_tree_order(&f.rows_to_tree_order(&m));
        assert_eq!(mb, m);
    }

    #[test]
    fn single_leaf_tree_ok() {
        let x = cloud(10, 2, 9);
        let mut cfg = HConfig::new(Gaussian::new(0.5), 4).with_seed(1);
        cfg.n0 = 100;
        let f = HFactors::build(&x, cfg).unwrap();
        assert_eq!(f.tree.nodes.len(), 1);
        assert!(f.a_leaf[0].is_some());
        assert!(f.u[0].is_none());
    }

    #[test]
    fn memory_about_4nr() {
        // Balanced binary, n0 = r: paper §4.5 says ≈ 4nr words.
        let n = 512;
        let r = 32;
        let x = cloud(n, 3, 10);
        let mut cfg = HConfig::new(Gaussian::new(0.5), r).with_seed(2);
        cfg.n0 = r;
        let f = HFactors::build(&x, cfg).unwrap();
        let words = f.memory_words() as f64;
        let expect = 4.0 * (n * r) as f64;
        assert!(
            words > 0.7 * expect && words < 1.3 * expect,
            "words={words} expect≈{expect}"
        );
    }

    #[test]
    fn empty_training_rejected() {
        let x = Mat::zeros(0, 3);
        assert!(HFactors::build(&x, HConfig::new(Gaussian::new(1.0), 4)).is_err());
    }
}
