//! Fast direct solver for `(K_hierarchical + λI) w = y` — the role of the
//! paper's Algorithm 2, at the same O(nr²) factorization / O(nr) per-rhs
//! cost, plus log-determinant for free.
//!
//! Derivation (DESIGN.md §2). The telescoping decomposition of Appendix A
//! gives, in matrix form,
//!
//! ```text
//! A + λI = D + Σ_{nonleaf i} B_i G_i B_iᵀ
//! ```
//!
//! with `D` the block-diagonal of leaf Schur complements
//! `H_j = A_jj + λI − U_j Σ_p U_jᵀ`, `G_i = Σ_i − W_i Σ_p W_iᵀ`
//! (`G_root = Σ_root`), and nested bases `B_i = stack_j (U_j | B_j W_j)`.
//! Eliminating the low-rank terms bottom-up with the *push-through*
//! Woodbury identity
//!
//! ```text
//! (H + B G Bᵀ)^{-1} = H^{-1} − H^{-1} B (I + G Ŝ)^{-1} G Bᵀ H^{-1},
//! Ŝ = Bᵀ H^{-1} B,
//! ```
//!
//! which — unlike the classical form — needs no `G^{-1}`, so it stays
//! exact even when `G_i` is singular (the paper's Appendix A notes `G_i`
//! has exact zero rows whenever a landmark is shared between a node and
//! its parent). Sylvester's identity gives the determinant along the way:
//! `det(H + BGBᵀ) = det(H) · det(I + G Ŝ)`.
//!
//! All per-node quantities are r×r; leaves contribute one Cholesky of
//! `H_j` (n0×n0) and the n0×r block `Z_j = H_j^{-1} U_j`.

use super::build::HFactors;
use crate::error::Result;
use crate::linalg::{gemm, matmul, par_gemm, par_matmul, Cholesky, Lu, Mat, Trans};
use crate::obs;
use crate::util::parallel::{auto_threads, parallel_map};

/// Per-leaf factorization state.
struct LeafState {
    /// Cholesky of H_j = A_jj + λI − U_j Σ_p U_jᵀ.
    chol: Cholesky,
    /// Z_j = H_j^{-1} U_j (n_j x r_p); empty for a root leaf.
    zu: Mat,
}

/// Per-nonleaf factorization state.
struct NodeState {
    /// Ŝ_i = Σ_{children} S_child (r_i x r_i).
    shat: Mat,
    /// G_i = Σ_i − W_i Σ_p W_iᵀ (root: Σ_root).
    g: Mat,
    /// LU of (I + G_i Ŝ_i).
    lu: Lu,
}

/// Owned factorization state of `(K_hierarchical + λI)` — the solver
/// without the borrow of its factors. Long-lived serving state (the
/// batched GP variance pass, [`crate::hkernel::oos::HVariance`]) holds a
/// `SolverParts` next to an `Arc<HFactors>`; [`HSolver`] is the
/// borrowed-view wrapper every transient caller uses.
pub(crate) struct SolverParts {
    lambda: f64,
    leaf: Vec<Option<LeafState>>,
    node: Vec<Option<NodeState>>,
    logdet: f64,
}

/// Factorized `(K_hierarchical + λI)`; solves and log-determinant.
pub struct HSolver<'a> {
    f: &'a HFactors,
    parts: SolverParts,
}

impl SolverParts {
    /// Factor `A + λI` where A is the hierarchical kernel matrix described
    /// by `f`. `lambda` is the ridge regularization (the paper's λ − λ′,
    /// since λ′ is already inside the factors).
    ///
    /// The per-leaf factorizations (one n0×n0 Cholesky + the Z/S blocks
    /// each) are independent and run across the scoped-thread pool. The
    /// r×r inner-node chain runs **level-synchronously** (as in
    /// [`crate::hkernel::matvec`]): a node needs only its children's `S`
    /// blocks, so all inner nodes of one depth factor concurrently,
    /// deepest level first. Results are applied in node-id order and the
    /// per-node log-det contributions are summed in post-order, so the
    /// result is bitwise identical for every thread count.
    pub(crate) fn factor(f: &HFactors, lambda: f64) -> Result<SolverParts> {
        let nn = f.tree.nodes.len();
        let mut leaf: Vec<Option<LeafState>> = (0..nn).map(|_| None).collect();
        let mut node: Vec<Option<NodeState>> = (0..nn).map(|_| None).collect();
        // Per-node log-det contribution, reduced in post-order at the end.
        let mut ld: Vec<f64> = vec![0.0; nn];
        // S_child per node, consumed by the parent.
        let mut s: Vec<Option<Mat>> = (0..nn).map(|_| None).collect();
        let threads = auto_threads(f.n());
        let post = f.tree.postorder();

        // --- Leaves (parallel): H_j, Cholesky, Z_j, S_j. ---
        let leaves = f.tree.leaves();
        {
            let _sp = obs::span_with("factor.leaves", "train", || {
                format!("{{\"leaves\":{}}}", leaves.len())
            });
            let louts = parallel_map(threads, &leaves, |&i| leaf_factor(f, i, lambda));
            for (&i, res) in leaves.iter().zip(louts) {
                let (state, sj, ldj) = res?;
                leaf[i] = Some(state);
                s[i] = sj;
                ld[i] = ldj;
            }
        }

        // --- Inner nodes (level-synchronous, deepest first): children S
        // blocks are finalized one level down, so every node of a level
        // is independent given the levels below. ---
        for (depth, ids) in inner_levels(f).iter().enumerate().rev() {
            if ids.is_empty() {
                continue;
            }
            let _sp = obs::span_with("factor.level", "train", || {
                format!("{{\"level\":{depth},\"nodes\":{}}}", ids.len())
            });
            let outs = parallel_map(threads, ids, |&i| inner_factor(f, i, &s));
            for (&i, res) in ids.iter().zip(outs) {
                let (state, si, ldi) = res?;
                node[i] = Some(state);
                s[i] = si;
                ld[i] = ldi;
            }
        }

        // Deterministic reduction: the same order the sequential
        // factorization accumulated in.
        let mut logdet = 0.0;
        for &i in &post {
            logdet += ld[i];
        }
        Ok(SolverParts { lambda, leaf, node, logdet })
    }

    /// Solve (A + λI) W = Y for a block of right-hand sides, **tree
    /// order**. O(n·n0 + n·r + (n/n0)·r²) per column after factoring.
    ///
    /// Every sweep engages the persistent worker pool: the upward pass
    /// parallelizes across leaves and then runs the t̂/t accumulation
    /// level-synchronously (a node needs only its children's finalized
    /// `t` blocks, so all inner nodes of one depth run concurrently,
    /// deepest level first), the downward pass runs level-synchronously
    /// the other way (each node's correction depends only on its
    /// parent's, shallowest first), and the finish is a parallel
    /// per-leaf write into disjoint row windows. Work items are applied
    /// in node-id order and each node accumulates its children in the
    /// tree's fixed child order — the output is bitwise identical for
    /// every thread count.
    pub(crate) fn solve_mat(&self, f: &HFactors, y: &Mat) -> Mat {
        let n = f.n();
        assert_eq!(y.rows(), n, "solve rhs rows");
        let m = y.cols();
        let nn = f.tree.nodes.len();

        // Single-leaf tree.
        if nn == 1 {
            return self.leaf[0].as_ref().unwrap().chol.solve_mat(y);
        }

        // ---- Upward: per-leaf z (parallel — each leaf's triangular
        // solves are independent), then per-node t̂ / t level by level,
        // deepest first. ----
        let mut z: Vec<Option<Mat>> = (0..nn).map(|_| None).collect();
        let mut t: Vec<Option<Mat>> = (0..nn).map(|_| None).collect();
        let mut that: Vec<Option<Mat>> = (0..nn).map(|_| None).collect();
        let threads = auto_threads(n);
        let leaves = f.tree.leaves();
        let sp_up = obs::span("solve.upward", "train");
        let leaf_zt = parallel_map(threads, &leaves, |&i| {
            let nd = &f.tree.nodes[i];
            let st = self.leaf[i].as_ref().unwrap();
            let yi = y.row_range(nd.lo, nd.hi);
            let zi = st.chol.solve_mat(&yi);
            // t_j = U_jᵀ z_j
            let u = f.u[i].as_ref().unwrap();
            let ti = matmul(u, Trans::Yes, &zi, Trans::No);
            (zi, ti)
        });
        for (&i, (zi, ti)) in leaves.iter().zip(leaf_zt) {
            z[i] = Some(zi);
            t[i] = Some(ti);
        }
        // A node's children all sit exactly one level deeper (leaf
        // children were finalized by the leaf pass above, inner children
        // by the previous — deeper — iteration), so every node of a
        // level is independent given the levels below.
        let levels = inner_levels(f);
        for ids in levels.iter().rev() {
            if ids.is_empty() {
                continue;
            }
            let outs = parallel_map(threads, ids, |&i| {
                let nd = &f.tree.nodes[i];
                let st = self.node[i].as_ref().unwrap();
                let r_i = st.shat.rows();
                let mut th = Mat::zeros(r_i, m);
                for &ch in &nd.children {
                    th.axpy(1.0, t[ch].as_ref().unwrap());
                }
                let ti = if nd.parent.is_some() {
                    // t_i = W_iᵀ (t̂ − Ŝ Φ(t̂))
                    let phi_t = phi(&st.g, &st.lu, &th);
                    let mut corr = th.clone();
                    gemm(-1.0, &st.shat, Trans::No, &phi_t, Trans::No, 1.0, &mut corr);
                    let w = f.w[i].as_ref().unwrap();
                    Some(matmul(w, Trans::Yes, &corr, Trans::No))
                } else {
                    None
                };
                (th, ti)
            });
            for (&i, (th, ti)) in ids.iter().zip(outs) {
                that[i] = Some(th);
                if let Some(ti) = ti {
                    t[i] = Some(ti);
                }
            }
        }

        drop(sp_up);

        // ---- Downward (level-synchronous, shallowest first): per inner
        // node, u_i = q_i + Φ(t̂_i − Ŝ_i q_i) with q_i = W_i u_{p(i)}
        // computed on the fly from the parent's (finalized) u; the root
        // has q = 0. Nodes of one level only read one level up. ----
        let sp_down = obs::span("solve.downward", "train");
        let mut u: Vec<Option<Mat>> = (0..nn).map(|_| None).collect();
        for ids in levels.iter() {
            if ids.is_empty() {
                continue;
            }
            let outs = parallel_map(threads, ids, |&i| {
                let st = self.node[i].as_ref().unwrap();
                let th = that[i].as_ref().unwrap();
                match f.tree.nodes[i].parent {
                    None => phi(&st.g, &st.lu, th),
                    Some(p) => {
                        // q_i = W_i u_p
                        let w = f.w[i].as_ref().unwrap();
                        let qi = matmul(w, Trans::No, u[p].as_ref().unwrap(), Trans::No);
                        let mut rhs = th.clone();
                        gemm(-1.0, &st.shat, Trans::No, &qi, Trans::No, 1.0, &mut rhs);
                        let mut ui = phi(&st.g, &st.lu, &rhs);
                        ui.axpy(1.0, &qi);
                        ui
                    }
                }
            });
            for (&i, ui) in ids.iter().zip(outs) {
                u[i] = Some(ui);
            }
        }

        drop(sp_down);

        // ---- Leaf finish (parallel over disjoint row windows):
        // w_ch = z_ch − Z_ch u_{p(ch)}. ----
        let _sp_fin = obs::span("solve.leaf_finish", "train");
        let mut out = Mat::zeros(n, m);
        let ranges: Vec<(usize, usize)> = leaves
            .iter()
            .map(|&l| {
                let nd = &f.tree.nodes[l];
                (nd.lo * m, nd.hi * m)
            })
            .collect();
        {
            let slices = crate::util::parallel::disjoint_slices(out.as_mut_slice(), &ranges);
            // Move each leaf's z block into its work item (each is
            // consumed exactly once) — no extra O(n·m) copy.
            let items: Vec<(usize, Mat, &mut [f64])> = leaves
                .iter()
                .zip(slices)
                .map(|(&l, window)| (l, z[l].take().unwrap(), window))
                .collect();
            crate::util::parallel::run_parallel(threads, items, |(l, mut wch, window)| {
                let p = f.tree.nodes[l].parent.unwrap();
                let st_l = self.leaf[l].as_ref().unwrap();
                gemm(
                    -1.0,
                    &st_l.zu,
                    Trans::No,
                    u[p].as_ref().unwrap(),
                    Trans::No,
                    1.0,
                    &mut wch,
                );
                window.copy_from_slice(wch.as_slice());
            });
        }
        out
    }
}

impl<'a> HSolver<'a> {
    /// Factor `A + λI` where A is the hierarchical kernel matrix
    /// described by `f`. `lambda` is the ridge regularization (the
    /// paper's λ − λ′, since λ′ is already inside the factors). Leaves
    /// factor in parallel and the r×r inner chain runs
    /// level-synchronously; the result is bitwise identical for every
    /// thread count.
    pub fn factor(f: &'a HFactors, lambda: f64) -> Result<HSolver<'a>> {
        Ok(HSolver { f, parts: SolverParts::factor(f, lambda)? })
    }

    /// The regularization this solver was factored with.
    pub fn lambda(&self) -> f64 {
        self.parts.lambda
    }

    /// log det(A + λI).
    pub fn logdet(&self) -> f64 {
        self.parts.logdet
    }

    /// Solve (A + λI) W = Y for a block of right-hand sides, **tree
    /// order**. O(n·n0 + n·r + (n/n0)·r²) per column after factoring;
    /// every sweep is level-synchronous across the persistent worker
    /// pool and bitwise deterministic for every thread count.
    pub fn solve_mat(&self, y: &Mat) -> Mat {
        self.parts.solve_mat(self.f, y)
    }

    /// Solve for a single right-hand side (tree order).
    pub fn solve(&self, y: &[f64]) -> Vec<f64> {
        let ym = Mat::from_vec(y.len(), 1, y.to_vec());
        self.solve_mat(&ym).col(0)
    }

    /// Solve with rhs/solution in **original order**.
    pub fn solve_original(&self, y: &[f64]) -> Vec<f64> {
        let yt = self.f.to_tree_order(y);
        let wt = self.solve(&yt);
        self.f.from_tree_order(&wt)
    }

    /// Solve a block of rhs in original order.
    pub fn solve_mat_original(&self, y: &Mat) -> Mat {
        let yt = self.f.rows_to_tree_order(y);
        let wt = self.solve_mat(&yt);
        self.f.rows_from_tree_order(&wt)
    }
}

/// Φ(M) = (I + G Ŝ)^{-1} (G M) — the push-through capacitance apply.
fn phi(g: &Mat, lu: &Lu, m: &Mat) -> Mat {
    let gm = matmul(g, Trans::No, m, Trans::No);
    lu.solve_mat(&gm)
}

/// Inner (nonleaf) node ids grouped by depth, index = depth. The
/// level-synchronous schedule of [`HSolver::factor`] and
/// [`HSolver::solve_mat`] walks these groups deepest-first (upward) or
/// shallowest-first (downward).
fn inner_levels(f: &HFactors) -> Vec<Vec<usize>> {
    let mut levels: Vec<Vec<usize>> = vec![Vec::new(); f.tree.depth() + 1];
    for (i, nd) in f.tree.nodes.iter().enumerate() {
        if !nd.is_leaf() {
            levels[nd.depth].push(i);
        }
    }
    levels
}

/// Factorization work for one inner node: Ŝ_i from the children's S
/// blocks, G_i, the LU of (I + G Ŝ), and this node's outgoing S_i.
/// Reads only finalized deeper-level state — the parallel unit of the
/// inner pass of [`HSolver::factor`]. Returns (state, S_i, logdet
/// contribution).
fn inner_factor(
    f: &HFactors,
    i: usize,
    s: &[Option<Mat>],
) -> Result<(NodeState, Option<Mat>, f64)> {
    let nd = &f.tree.nodes[i];
    let r_i = f.landmark_idx[i].len();
    // Ŝ_i = Σ_children S_child
    let mut shat = Mat::zeros(r_i, r_i);
    for &ch in &nd.children {
        shat.axpy(1.0, s[ch].as_ref().unwrap());
    }
    shat.symmetrize();
    // G_i. The r×r chain goes through the parallel BLAS entries: on wide
    // levels this runs inside the level's own parallel pass and degrades
    // to the packed sequential core, but at the narrow top of the tree
    // (ultimately a single root node per level) the row-panel split is
    // the only parallelism available — bitwise identical either way.
    let sig = f.sigma[i].as_ref().unwrap();
    let mut g = sig.clone();
    if let Some(p) = nd.parent {
        let w = f.w[i].as_ref().unwrap();
        let sp = f.sigma[p].as_ref().unwrap();
        let wsp = par_matmul(w, Trans::No, sp, Trans::No);
        par_gemm(-1.0, &wsp, Trans::No, w, Trans::Yes, 1.0, &mut g);
        g.symmetrize();
    }
    // (I + G Ŝ)
    let mut igs = par_matmul(&g, Trans::No, &shat, Trans::No);
    igs.add_diag(1.0);
    let lu = Lu::new(&igs)?;
    let ldi = lu.logabsdet();
    let si = if nd.parent.is_some() {
        // T_i = Ŝ − Ŝ Φ(Ŝ), S_i = W_iᵀ T_i W_i
        let phi_s = phi(&g, &lu, &shat);
        let mut t = shat.clone();
        par_gemm(-1.0, &shat, Trans::No, &phi_s, Trans::No, 1.0, &mut t);
        let w = f.w[i].as_ref().unwrap();
        let tw = par_matmul(&t, Trans::No, w, Trans::No);
        Some(par_matmul(w, Trans::Yes, &tw, Trans::No))
    } else {
        None
    };
    Ok((NodeState { shat, g, lu }, si, ldi))
}

/// Factorization work for one leaf: the Schur complement
/// H_j = A_jj + λI − U_j Σ_p U_jᵀ, its Cholesky, Z_j = H_j^{-1} U_j and
/// S_j = U_jᵀ Z_j. Independent across leaves — the parallel unit of
/// [`HSolver::factor`]. Returns (state, S_j, logdet contribution).
fn leaf_factor(
    f: &HFactors,
    i: usize,
    lambda: f64,
) -> Result<(LeafState, Option<Mat>, f64)> {
    let nd = &f.tree.nodes[i];
    let a = f.a_leaf[i].as_ref().unwrap();
    let mut h = a.clone();
    h.add_diag(lambda);
    if let Some(p) = nd.parent {
        // H_j = A + λI − U Σ_p Uᵀ. Parallel BLAS entries: with many
        // leaves these run inside the per-leaf parallel pass (degrading
        // to the packed sequential core); on trees with few large leaf
        // blocks the row-panel split keeps the cores busy instead.
        let u = f.u[i].as_ref().unwrap();
        let sig = f.sigma[p].as_ref().unwrap();
        let us = par_matmul(u, Trans::No, sig, Trans::No);
        par_gemm(-1.0, &us, Trans::No, u, Trans::Yes, 1.0, &mut h);
        h.symmetrize();
        let chol = Cholesky::new_jittered(&h, 30)?;
        let zu = chol.solve_mat(u);
        let ldj = chol.logdet();
        // S_j = U_jᵀ Z_j
        let sj = par_matmul(u, Trans::Yes, &zu, Trans::No);
        Ok((LeafState { chol, zu }, Some(sj), ldj))
    } else {
        // Single-leaf tree: A + λI is the whole matrix.
        let chol = Cholesky::new_jittered(&h, 30)?;
        let ldj = chol.logdet();
        Ok((LeafState { chol, zu: Mat::zeros(nd.len(), 0) }, None, ldj))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hkernel::build::HConfig;
    use crate::hkernel::densify::densify;
    use crate::kernels::{Gaussian, Imq, KernelKind, Laplace};
    use crate::partition::SplitRule;
    use crate::util::rng::Rng;

    fn build_custom(
        n: usize,
        r: usize,
        n0: usize,
        kind: KernelKind,
        seed: u64,
        avoid: bool,
        rule: SplitRule,
    ) -> HFactors {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 4, |_, _| rng.uniform(0.0, 1.0));
        let mut cfg = HConfig::new(kind, r).with_seed(seed * 3 + 7).with_rule(rule);
        cfg.n0 = n0;
        cfg.avoid_parent_landmarks = avoid;
        HFactors::build(&x, cfg).unwrap()
    }

    fn kmeans3() -> SplitRule {
        SplitRule::KMeans { k: 3, iters: 10 }
    }

    fn dense_solve(f: &HFactors, lambda: f64, y: &Mat) -> Mat {
        let mut k = densify(f);
        k.add_diag(lambda);
        Cholesky::new_jittered(&k, 10).unwrap().solve_mat(y)
    }

    /// Property: solver equals dense solve across kernels, tree shapes,
    /// arities, and both landmark-overlap regimes (G singular or not).
    #[test]
    fn property_matches_dense_solve() {
        let cases = vec![
            build_custom(60, 6, 6, Gaussian::new(0.5), 1, true, SplitRule::RandomProjection),
            build_custom(60, 6, 6, Gaussian::new(0.5), 2, false, SplitRule::RandomProjection),
            build_custom(57, 5, 12, Laplace::new(0.8), 3, false, SplitRule::RandomProjection),
            build_custom(64, 8, 8, Imq::new(0.6), 4, true, SplitRule::KdTree),
            build_custom(72, 6, 9, Gaussian::new(1.1), 5, false, kmeans3()),
        ];
        let lambda = 0.05;
        for f in &cases {
            let solver = HSolver::factor(f, lambda).unwrap();
            let mut rng = Rng::new(99);
            let y = Mat::from_fn(f.n(), 2, |_, _| rng.normal());
            let got = solver.solve_mat(&y);
            let want = dense_solve(f, lambda, &y);
            let mut diff = got.clone();
            diff.axpy(-1.0, &want);
            let rel = diff.fro_norm() / want.fro_norm();
            assert!(rel < 1e-8, "rel err {rel} (n={})", f.n());
        }
    }

    #[test]
    fn logdet_matches_dense() {
        for (seed, avoid) in [(1u64, true), (2, false)] {
            let rp = SplitRule::RandomProjection;
            let f = build_custom(50, 5, 10, Gaussian::new(0.6), seed, avoid, rp);
            let lambda = 0.1;
            let solver = HSolver::factor(&f, lambda).unwrap();
            let mut k = densify(&f);
            k.add_diag(lambda);
            let want = Cholesky::new_jittered(&k, 5).unwrap().logdet();
            assert!(
                (solver.logdet() - want).abs() < 1e-7 * (1.0 + want.abs()),
                "logdet {} vs {}",
                solver.logdet(),
                want
            );
        }
    }

    #[test]
    fn residual_is_small() {
        // (A + λI) w must reproduce y through the fast matvec as well.
        let f = build_custom(80, 8, 8, Gaussian::new(0.5), 7, false, SplitRule::RandomProjection);
        let lambda = 0.02;
        let solver = HSolver::factor(&f, lambda).unwrap();
        let mut rng = Rng::new(3);
        let y: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        let w = solver.solve(&y);
        let mut aw = crate::hkernel::matvec::hmatvec(&f, &w);
        for (awi, wi) in aw.iter_mut().zip(w.iter()) {
            *awi += lambda * wi;
        }
        let num: f64 = aw.iter().zip(y.iter()).map(|(a, b)| (a - b) * (a - b)).sum();
        let den: f64 = y.iter().map(|b| b * b).sum();
        assert!((num / den).sqrt() < 1e-8, "residual {}", (num / den).sqrt());
    }

    #[test]
    fn single_leaf_solver() {
        let f = build_custom(12, 4, 64, Gaussian::new(0.5), 8, true, SplitRule::RandomProjection);
        assert_eq!(f.tree.nodes.len(), 1);
        let solver = HSolver::factor(&f, 0.3).unwrap();
        let y: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let got = solver.solve(&y);
        let want = dense_solve(&f, 0.3, &Mat::from_vec(12, 1, y.clone()));
        for i in 0..12 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-10);
        }
        // logdet too.
        let mut k = densify(&f);
        k.add_diag(0.3);
        let ld = Cholesky::new_jittered(&k, 5).unwrap().logdet();
        assert!((solver.logdet() - ld).abs() < 1e-9);
    }

    #[test]
    fn original_order_wrappers() {
        let f = build_custom(40, 5, 8, Gaussian::new(0.7), 9, false, SplitRule::RandomProjection);
        let solver = HSolver::factor(&f, 0.05).unwrap();
        let mut rng = Rng::new(5);
        let y: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let wo = solver.solve_original(&y);
        let wt = solver.solve(&f.to_tree_order(&y));
        assert_eq!(f.to_tree_order(&wo), wt);
        let ym = Mat::from_vec(40, 1, y);
        let wm = solver.solve_mat_original(&ym);
        for i in 0..40 {
            assert!((wm[(i, 0)] - wo[i]).abs() < 1e-14);
        }
    }

    #[test]
    fn deep_tree_small_leaves() {
        // n0 much smaller than r exercises rank capping (r_i = min(r, n_i)).
        let f = build_custom(64, 16, 4, Gaussian::new(0.5), 10, false, SplitRule::RandomProjection);
        let solver = HSolver::factor(&f, 0.05).unwrap();
        let mut rng = Rng::new(6);
        let y = Mat::from_fn(64, 1, |_, _| rng.normal());
        let got = solver.solve_mat(&y);
        let want = dense_solve(&f, 0.05, &y);
        let mut diff = got;
        diff.axpy(-1.0, &want);
        assert!(diff.fro_norm() / want.fro_norm() < 1e-8);
    }
}
