//! Algorithm 1: O(nr) matrix-vector multiplication `y = A b`.
//!
//! One post-order traversal accumulates the compressed coefficients
//! `c_i = U_iᵀ b_i` (leaves) / `c_i = W_iᵀ Σ_j c_j` (inner nodes), one
//! pre-order traversal pushes the sibling interactions `d` back down, and
//! leaves finish with `y_i = A_ii b_i + U_i d_i`.
//!
//! ## Parallel execution
//!
//! Every per-node quantity is computed from already-finalized inputs and
//! written to node-private storage, so the traversals parallelize
//! *level-synchronously*: all nodes of one tree level run concurrently
//! (children are one level deeper and therefore already done in the
//! upward pass; ancestors are shallower and already done in the downward
//! pass), the sibling exchange runs concurrently across parents (each
//! parent owns its children's `d`), and the leaf finish writes disjoint
//! `[lo, hi)` windows of `y`. No work item shares an accumulator and all
//! results are applied in node-id order, so the output is **bitwise
//! identical for every thread count** — the deterministic fallback is
//! simply `threads = 1`. The thread count defaults to
//! [`crate::util::parallel::default_threads`] (`HCK_THREADS` env knob)
//! and can be pinned per call with [`hmatvec_with_threads`].

use super::build::HFactors;
use crate::linalg::{gemv, Trans};
use crate::util::parallel::{auto_threads, disjoint_slices, parallel_map, run_parallel};

/// y = K_hierarchical b, both in **tree order**, using the adaptive
/// thread count (serial below [`crate::util::parallel::AUTO_MIN_N`]
/// points). Multi-column version: [`hmatvec_mat`].
pub fn hmatvec(f: &HFactors, b: &[f64]) -> Vec<f64> {
    hmatvec_with_threads(f, b, auto_threads(f.n()))
}

/// y = K_hierarchical b with an explicit thread count (1 = the exact
/// sequential reference; results are bitwise identical regardless).
pub fn hmatvec_with_threads(f: &HFactors, b: &[f64], threads: usize) -> Vec<f64> {
    let n = f.n();
    assert_eq!(b.len(), n, "hmatvec length");
    let nn = f.tree.nodes.len();
    let mut y = vec![0.0; n];

    // Single-leaf tree: dense block multiply.
    if nn == 1 {
        let a = f.a_leaf[0].as_ref().unwrap();
        gemv(1.0, a, Trans::No, b, 0.0, &mut y);
        return y;
    }

    // c[i], d[i] live in the parent's landmark space (len = parent_rank).
    let mut c: Vec<Vec<f64>> = vec![Vec::new(); nn];
    let mut d: Vec<Vec<f64>> = vec![Vec::new(); nn];

    // Non-root nodes grouped by depth (level-synchronous schedule).
    let max_depth = f.tree.depth();
    let mut by_depth: Vec<Vec<usize>> = vec![Vec::new(); max_depth + 1];
    for (i, nd) in f.tree.nodes.iter().enumerate() {
        if nd.parent.is_some() {
            by_depth[nd.depth].push(i);
        }
    }

    // ---- Upward, deepest level first: compute c. ----
    for depth in (1..=max_depth).rev() {
        let ids = &by_depth[depth];
        if ids.is_empty() {
            continue;
        }
        let results = parallel_map(threads, ids, |&i| {
            let nd = &f.tree.nodes[i];
            let rp = f.parent_rank(i);
            let mut ci = vec![0.0; rp];
            if nd.is_leaf() {
                let u = f.u[i].as_ref().unwrap();
                gemv(1.0, u, Trans::Yes, &b[nd.lo..nd.hi], 0.0, &mut ci);
            } else {
                // Sum of children c (each of length = own rank), then W_iᵀ.
                let r_own = f.landmark_idx[i].len();
                let mut csum = vec![0.0; r_own];
                for &ch in &nd.children {
                    for (s, v) in csum.iter_mut().zip(c[ch].iter()) {
                        *s += v;
                    }
                }
                let w = f.w[i].as_ref().unwrap();
                gemv(1.0, w, Trans::Yes, &csum, 0.0, &mut ci);
            }
            ci
        });
        for (&i, ci) in ids.iter().zip(results) {
            c[i] = ci;
        }
    }

    // ---- Sibling exchange: d_l = Σ_p (Σ_{siblings i of l} c_i). Each
    // parent owns its children's d, so parents run concurrently. ----
    let parents = f.tree.nonleaves();
    let exchanged = parallel_map(threads, &parents, |&p| {
        let children = &f.tree.nodes[p].children;
        let rp = f.landmark_idx[p].len();
        let sig = f.sigma[p].as_ref().unwrap();
        let mut total = vec![0.0; rp];
        for &ch in children {
            for (t, v) in total.iter_mut().zip(c[ch].iter()) {
                *t += v;
            }
        }
        let mut out = Vec::with_capacity(children.len());
        for &ch in children {
            // others = total − c_ch
            let others: Vec<f64> =
                total.iter().zip(c[ch].iter()).map(|(t, v)| t - v).collect();
            let mut dch = vec![0.0; rp];
            gemv(1.0, sig, Trans::No, &others, 0.0, &mut dch);
            out.push((ch, dch));
        }
        out
    });
    for set in exchanged {
        for (ch, dch) in set {
            d[ch] = dch;
        }
    }

    // ---- Downward, shallowest level first: push d through W. A node's
    // own d is final once its parent's level has run. ----
    for depth in 1..=max_depth {
        let pushers: Vec<usize> = by_depth[depth]
            .iter()
            .copied()
            .filter(|&i| !f.tree.nodes[i].is_leaf())
            .collect();
        if pushers.is_empty() {
            continue;
        }
        let pushed = parallel_map(threads, &pushers, |&i| {
            // wd = W_i d_i, forwarded to every child of i.
            let w = f.w[i].as_ref().unwrap();
            let r_own = f.landmark_idx[i].len();
            let mut wd = vec![0.0; r_own];
            gemv(1.0, w, Trans::No, &d[i], 0.0, &mut wd);
            wd
        });
        for (&i, wd) in pushers.iter().zip(pushed) {
            for &ch in &f.tree.nodes[i].children {
                for (dc, v) in d[ch].iter_mut().zip(wd.iter()) {
                    *dc += v;
                }
            }
        }
    }

    // ---- Leaf finish: y_i = A_ii b_i + U_i d_i over disjoint windows. ----
    let leaves = f.tree.leaves();
    let ranges: Vec<(usize, usize)> =
        leaves.iter().map(|&l| (f.tree.nodes[l].lo, f.tree.nodes[l].hi)).collect();
    {
        let slices = disjoint_slices(&mut y, &ranges);
        let items: Vec<(usize, &mut [f64])> =
            leaves.iter().copied().zip(slices).collect();
        run_parallel(threads, items, |(leaf, ys)| {
            let nd = &f.tree.nodes[leaf];
            let a = f.a_leaf[leaf].as_ref().unwrap();
            gemv(1.0, a, Trans::No, &b[nd.lo..nd.hi], 0.0, ys);
            let u = f.u[leaf].as_ref().unwrap();
            gemv(1.0, u, Trans::No, &d[leaf], 1.0, ys);
        });
    }
    y
}

/// Multi-column matvec Y = K_hierarchical B (tree order).
///
/// Columns are independent, so for multi-rhs blocks the columns fan out
/// across the thread pool; any threads left over (m smaller than the
/// pool) go to the level-parallel traversal *inside* each column, so
/// narrow blocks on wide machines keep their intra-column speedup.
/// Since the per-column traversal is bitwise identical for every thread
/// count, so is the block result.
pub fn hmatvec_mat(f: &HFactors, b: &crate::linalg::Mat) -> crate::linalg::Mat {
    let m = b.cols();
    let mut y = crate::linalg::Mat::zeros(b.rows(), m);
    let threads = auto_threads(f.n());
    let outer = threads.min(m);
    if outer > 1 {
        let inner = (threads / outer).max(1);
        let cols: Vec<usize> = (0..m).collect();
        let results =
            parallel_map(outer, &cols, |&j| hmatvec_with_threads(f, &b.col(j), inner));
        for (j, col) in results.iter().enumerate() {
            y.set_col(j, col);
        }
    } else {
        for j in 0..m {
            let col = hmatvec(f, &b.col(j));
            y.set_col(j, &col);
        }
    }
    y
}

/// y = K_hierarchical b in **original order** (permutes in and out).
pub fn hmatvec_original(f: &HFactors, b: &[f64]) -> Vec<f64> {
    let bt = f.to_tree_order(b);
    let yt = hmatvec(f, &bt);
    f.from_tree_order(&yt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hkernel::build::HConfig;
    use crate::hkernel::densify::densify;
    use crate::kernels::{Gaussian, KernelKind, Laplace};
    use crate::linalg::Mat;
    use crate::partition::SplitRule;
    use crate::util::rng::Rng;

    fn build(n: usize, r: usize, n0: usize, kind: KernelKind, seed: u64) -> HFactors {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 4, |_, _| rng.uniform(0.0, 1.0));
        let mut cfg = HConfig::new(kind, r).with_seed(seed * 7 + 1);
        cfg.n0 = n0;
        HFactors::build(&x, cfg).unwrap()
    }

    /// Property: Algorithm 1 equals the dense densified matvec across
    /// random instances, kernels, tree shapes and arities.
    #[test]
    fn property_matches_dense() {
        let cases: Vec<(HFactors, u64)> = vec![
            (build(60, 6, 6, Gaussian::new(0.5), 1), 11),
            (build(60, 6, 15, Gaussian::new(1.2), 2), 12),
            (build(47, 5, 9, Laplace::new(0.7), 3), 13),
            (build(33, 16, 16, Gaussian::new(0.4), 4), 14),
        ];
        for (f, s) in cases {
            let k = densify(&f);
            let mut rng = Rng::new(s);
            for _ in 0..3 {
                let b: Vec<f64> = (0..f.n()).map(|_| rng.normal()).collect();
                let fast = hmatvec(&f, &b);
                let mut slow = vec![0.0; f.n()];
                crate::linalg::gemv(1.0, &k, crate::linalg::Trans::No, &b, 0.0, &mut slow);
                for i in 0..f.n() {
                    assert!(
                        (fast[i] - slow[i]).abs() < 1e-9 * (1.0 + slow[i].abs()),
                        "mismatch at {i}: {} vs {}",
                        fast[i],
                        slow[i]
                    );
                }
            }
        }
    }

    /// Thread count must not change the result at all (the parallel
    /// schedule computes the same values and applies them in the same
    /// order; see the module docs).
    #[test]
    fn thread_count_is_bitwise_irrelevant() {
        for (f, seed) in [
            (build(96, 8, 8, Gaussian::new(0.5), 21), 31u64),
            (build(70, 6, 10, Laplace::new(0.9), 22), 32),
        ] {
            let mut rng = Rng::new(seed);
            let b: Vec<f64> = (0..f.n()).map(|_| rng.normal()).collect();
            let y1 = hmatvec_with_threads(&f, &b, 1);
            for threads in [2usize, 3, 4, 8] {
                let yt = hmatvec_with_threads(&f, &b, threads);
                assert_eq!(y1, yt, "threads={threads}");
            }
        }
    }

    #[test]
    fn kmeans_arity_tree_matches_dense() {
        let mut rng = Rng::new(5);
        let x = Mat::from_fn(72, 3, |_, _| rng.uniform(0.0, 1.0));
        let mut cfg = HConfig::new(Gaussian::new(0.5), 6).with_seed(6);
        cfg.n0 = 9;
        cfg.rule = SplitRule::KMeans { k: 3, iters: 10 };
        let f = HFactors::build(&x, cfg).unwrap();
        let k = densify(&f);
        let b: Vec<f64> = (0..72).map(|_| rng.normal()).collect();
        let fast = hmatvec(&f, &b);
        let mut slow = vec![0.0; 72];
        crate::linalg::gemv(1.0, &k, crate::linalg::Trans::No, &b, 0.0, &mut slow);
        for i in 0..72 {
            assert!((fast[i] - slow[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn single_leaf_matvec() {
        let f = build(10, 4, 64, Gaussian::new(0.5), 7);
        assert_eq!(f.tree.nodes.len(), 1);
        let b = vec![1.0; 10];
        let y = hmatvec(&f, &b);
        let k = densify(&f);
        let mut want = vec![0.0; 10];
        crate::linalg::gemv(1.0, &k, crate::linalg::Trans::No, &b, 0.0, &mut want);
        for i in 0..10 {
            assert!((y[i] - want[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn original_order_wrapper_consistent() {
        let f = build(40, 5, 8, Gaussian::new(0.6), 8);
        let mut rng = Rng::new(9);
        let b: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let yo = hmatvec_original(&f, &b);
        let yt = hmatvec(&f, &f.to_tree_order(&b));
        assert_eq!(f.to_tree_order(&yo), yt);
    }

    #[test]
    fn matvec_mat_matches_columns() {
        let f = build(30, 4, 6, Gaussian::new(0.5), 10);
        let mut rng = Rng::new(11);
        let b = Mat::from_fn(30, 3, |_, _| rng.normal());
        let y = hmatvec_mat(&f, &b);
        for j in 0..3 {
            let col = hmatvec(&f, &b.col(j));
            assert_eq!(y.col(j), col);
        }
    }
}
