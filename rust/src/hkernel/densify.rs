//! Materialization of the full hierarchical kernel matrix (test oracle).
//!
//! `densify` reconstructs K_hierarchical(X, X) in tree order from the
//! factors, following the matrix view of Section 3 (Figure 2): exact leaf
//! diagonal blocks, and `AggU_i Σ_p AggU_jᵀ` sibling blocks with the
//! nested aggregate bases `AggU_p = [stack AggU_i] W_p` of item 6.
//! O(n²) — used only by tests and small-scale experiments (Theorem 4
//! norm comparisons, kernel PCA at dense scale).

use super::build::HFactors;
use crate::linalg::{gemm, matmul, Mat, Trans};

/// Aggregate bases AggU_i (n_i x r_parent) for every non-root node.
pub fn aggregate_bases(f: &HFactors) -> Vec<Option<Mat>> {
    let nn = f.tree.nodes.len();
    let mut agg: Vec<Option<Mat>> = vec![None; nn];
    // Post-order: children before parents.
    for id in f.tree.postorder() {
        let nd = &f.tree.nodes[id];
        if nd.parent.is_none() {
            continue; // root has no parent basis
        }
        if nd.is_leaf() {
            agg[id] = Some(f.u[id].as_ref().unwrap().clone());
        } else {
            // Stack children aggregates (they are contiguous in tree
            // order), then multiply by W_i.
            let r_own = f.landmark_idx[id].len();
            let mut stacked = Mat::zeros(nd.len(), r_own);
            let mut row = 0usize;
            for &c in &nd.children {
                let a = agg[c].as_ref().expect("child aggregate missing");
                for i in 0..a.rows() {
                    stacked.row_mut(row + i).copy_from_slice(a.row(i));
                }
                row += a.rows();
            }
            let w = f.w[id].as_ref().unwrap();
            agg[id] = Some(matmul(&stacked, Trans::No, w, Trans::No));
        }
    }
    agg
}

/// Full K_hierarchical(X, X) in **tree order**.
pub fn densify(f: &HFactors) -> Mat {
    let n = f.n();
    let mut k = Mat::zeros(n, n);
    // Leaf diagonal blocks.
    for &leaf in &f.tree.leaves() {
        let nd = &f.tree.nodes[leaf];
        let a = f.a_leaf[leaf].as_ref().unwrap();
        for i in 0..nd.len() {
            let src = a.row(i);
            k.row_mut(nd.lo + i)[nd.lo..nd.hi].copy_from_slice(src);
        }
    }
    // Sibling off-diagonal blocks.
    let agg = aggregate_bases(f);
    for p in f.tree.nonleaves() {
        let sig = f.sigma[p].as_ref().unwrap();
        let children = f.tree.nodes[p].children.clone();
        for (ci, &i) in children.iter().enumerate() {
            let ai = agg[i].as_ref().unwrap();
            let ai_sig = matmul(ai, Trans::No, sig, Trans::No);
            for &j in children.iter().skip(ci + 1) {
                let aj = agg[j].as_ref().unwrap();
                let block = matmul(&ai_sig, Trans::No, aj, Trans::Yes);
                let (li, lj) = (f.tree.nodes[i].lo, f.tree.nodes[j].lo);
                for a in 0..block.rows() {
                    let row = block.row(a);
                    k.row_mut(li + a)[lj..lj + block.cols()].copy_from_slice(row);
                    for (b, &v) in row.iter().enumerate() {
                        k[(lj + b, li + a)] = v;
                    }
                }
            }
        }
    }
    k
}

/// Full K_hierarchical(X, X) in **original order** (rows and columns).
pub fn densify_original_order(f: &HFactors) -> Mat {
    let kt = densify(f);
    // Apply the inverse permutation on both sides.
    let n = f.n();
    let mut out = Mat::zeros(n, n);
    for a in 0..n {
        let oa = f.tree.perm[a];
        for b in 0..n {
            out[(oa, f.tree.perm[b])] = kt[(a, b)];
        }
    }
    out
}

/// Dense matrix of the *base* kernel K′(X, X) in tree order (with the λ′
/// diagonal), for Theorem 4 style comparisons.
pub fn densify_exact_base(f: &HFactors) -> Mat {
    let xt = f.rows_to_tree_order(&f.x);
    let mut k = crate::kernels::kernel_block(f.config.kind, &xt);
    for i in 0..k.rows() {
        k[(i, i)] += f.config.lambda_prime;
    }
    k
}

/// Dense Nyström kernel matrix (in tree order) using the root's landmark
/// set: K(X, X̲) Σ^{-1} K(X̲, X). Reference for Theorem 4.
pub fn densify_root_nystrom(f: &HFactors) -> Mat {
    let root = 0usize;
    assert!(!f.tree.nodes[root].is_leaf(), "single-leaf tree has no landmarks");
    let xt = f.rows_to_tree_order(&f.x);
    let lm = f.landmarks[root].as_ref().unwrap();
    let kxl = crate::kernels::kernel_cross(f.config.kind, &xt, lm);
    let u = f.sigma_chol[root].as_ref().unwrap().solve_right(&kxl);
    let mut out = Mat::zeros(xt.rows(), xt.rows());
    gemm(1.0, &u, Trans::No, &kxl, Trans::Yes, 0.0, &mut out);
    out.symmetrize();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hkernel::build::HConfig;
    use crate::kernels::{Gaussian, Imq, KernelKind, Laplace};
    use crate::linalg::Cholesky;
    use crate::partition::SplitRule;
    use crate::util::rng::Rng;

    fn cloud(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, d, |_, _| rng.uniform(0.0, 1.0))
    }

    fn build(n: usize, d: usize, r: usize, kind: KernelKind, seed: u64) -> HFactors {
        let x = cloud(n, d, seed);
        let mut cfg = HConfig::new(kind, r).with_seed(seed + 1);
        cfg.n0 = r;
        HFactors::build(&x, cfg).unwrap()
    }

    #[test]
    fn densify_is_symmetric_with_exact_leaf_blocks() {
        let f = build(48, 3, 6, Gaussian::new(0.5), 1);
        let k = densify(&f);
        assert!(k.is_symmetric(1e-12));
        // Leaf diag blocks equal the exact base kernel (+λ′ diag).
        let exact = densify_exact_base(&f);
        for &leaf in &f.tree.leaves() {
            let nd = &f.tree.nodes[leaf];
            for a in nd.lo..nd.hi {
                for b in nd.lo..nd.hi {
                    assert!(
                        (k[(a, b)] - exact[(a, b)]).abs() < 1e-12,
                        "leaf block mismatch at ({a},{b})"
                    );
                }
            }
        }
    }

    /// Theorem 6: the hierarchical kernel matrix is (strictly) PD.
    #[test]
    fn property_positive_definite_across_kernels_and_trees() {
        for (seed, kind) in [
            (1u64, Gaussian::new(0.3)),
            (2, Gaussian::new(1.5)),
            (3, Laplace::new(0.5)),
            (4, Imq::new(0.8)),
        ] {
            for rule in [SplitRule::RandomProjection, SplitRule::KMeans { k: 3, iters: 10 }] {
                let x = cloud(60, 4, seed * 13 + 5);
                let mut cfg = HConfig::new(kind, 7).with_seed(seed).with_rule(rule);
                cfg.n0 = 7;
                cfg.lambda_prime = 0.0; // strict PD must hold without help
                let f = HFactors::build(&x, cfg).unwrap();
                let k = densify(&f);
                assert!(
                    Cholesky::new_jittered(&k, 6).map(|c| c.jitter < 1e-8).unwrap_or(false),
                    "not PD for {kind:?} {rule:?} seed {seed}"
                );
            }
        }
    }

    /// Proposition 1 (one-level tree = k_compositional): rows at landmark
    /// points reproduce the exact kernel.
    #[test]
    fn property_exact_at_root_landmarks() {
        let x = cloud(40, 3, 9);
        let mut cfg = HConfig::new(Gaussian::new(0.6), 8).with_seed(4);
        cfg.n0 = 20; // two leaves under the root: one-level compositional
        cfg.lambda_prime = 0.0;
        let f = HFactors::build(&x, cfg).unwrap();
        assert_eq!(f.tree.depth(), 1, "want a one-level tree");
        let k = densify(&f);
        let exact = densify_exact_base(&f);
        // Tree-order positions of root landmarks.
        let mut pos_of = vec![usize::MAX; 40];
        for (pos, &orig) in f.tree.perm.iter().enumerate() {
            pos_of[orig] = pos;
        }
        for &lm in &f.landmark_idx[0] {
            let p = pos_of[lm];
            for b in 0..40 {
                assert!(
                    (k[(p, b)] - exact[(p, b)]).abs() < 1e-9,
                    "row of landmark {lm} differs at col {b}: {} vs {}",
                    k[(p, b)],
                    exact[(p, b)]
                );
            }
        }
    }

    /// Theorem 4: ‖K − K_compositional‖ < ‖K − K_Nyström‖ for the same
    /// (root) landmark set, in Frobenius and 2-norm.
    #[test]
    fn property_theorem4_norm_improvement() {
        for seed in [1u64, 2, 3, 4, 5] {
            let x = cloud(50, 3, 100 + seed);
            let mut cfg = HConfig::new(Gaussian::new(0.4), 6).with_seed(seed);
            cfg.n0 = 25; // one level: k_compositional
            cfg.lambda_prime = 0.0;
            let f = HFactors::build(&x, cfg).unwrap();
            let exact = densify_exact_base(&f);
            let comp = densify(&f);
            let nys = densify_root_nystrom(&f);
            let dc = {
                let mut d = exact.clone();
                d.axpy(-1.0, &comp);
                d
            };
            let dn = {
                let mut d = exact.clone();
                d.axpy(-1.0, &nys);
                d
            };
            assert!(
                dc.fro_norm() < dn.fro_norm(),
                "Frobenius: {} !< {}",
                dc.fro_norm(),
                dn.fro_norm()
            );
            assert!(
                dc.norm2_est(60) < dn.norm2_est(60) + 1e-12,
                "2-norm: {} !< {}",
                dc.norm2_est(60),
                dn.norm2_est(60)
            );
        }
    }

    #[test]
    fn densify_original_order_permutes_consistently() {
        let f = build(30, 3, 5, Gaussian::new(0.5), 11);
        let kt = densify(&f);
        let ko = densify_original_order(&f);
        for (pos_a, &oa) in f.tree.perm.iter().enumerate() {
            for (pos_b, &ob) in f.tree.perm.iter().enumerate() {
                assert_eq!(kt[(pos_a, pos_b)], ko[(oa, ob)]);
            }
        }
    }

    #[test]
    fn single_leaf_densify_is_exact() {
        let x = cloud(12, 2, 12);
        let mut cfg = HConfig::new(Gaussian::new(0.5), 4);
        cfg.n0 = 50;
        let f = HFactors::build(&x, cfg).unwrap();
        let k = densify(&f);
        let exact = densify_exact_base(&f);
        let mut d = k;
        d.axpy(-1.0, &exact);
        assert!(d.max_abs() < 1e-12);
    }
}
