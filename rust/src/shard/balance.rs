//! Replica-aware remote fan-out: [`RemoteShardedPredictor`].
//!
//! The remote counterpart of [`super::ShardedPredictor`]: the same
//! [`super::ShardRouter`] scatter and request-order gather, but each
//! sub-batch travels over the `HCKW` wire
//! ([`crate::shard::remote`]) to whichever `hck shard-worker` process
//! currently looks least loaded among the shard's replicas.
//!
//! **Replication.** Workers announce which shards they serve at
//! `hello`; any shard served by several workers has replicas. The
//! replica map is built once at [`RemoteShardedPredictor::connect`],
//! which also rejects topologies with uncovered shards or workers that
//! disagree on dim/outputs.
//!
//! **Rebalancing.** Every [`STATS_EVERY`]-th predict refreshes each
//! worker's cached load signals via the `stats` wire command
//! (queue-depth sum, peak busy fraction from the per-shard
//! [`crate::coordinator::metrics::ShardSnapshot`]s). A sub-batch then
//! goes to the replica with the lowest score: locally-outstanding
//! requests + remote queue depth, busy fraction as tie-break.
//!
//! **Failover.** A replica that fails with a *transport* or
//! *shard-local* error merely moves the sub-batch to the next replica
//! in score order; only when every replica of a shard has failed does
//! the request surface a typed [`PredictError::Shard`] naming the shard
//! and the last cause. Request-shaped errors (bad request, unsupported
//! column) return immediately — every replica would refuse them the
//! same way.

use super::remote::RemoteWorkerClient;
use super::router::ShardRouter;
use super::ShardBlock;
use crate::coordinator::metrics::{ShardSnapshot, WorkerSnapshot};
use crate::coordinator::Predictor;
use crate::error::{Error, Result};
use crate::infer::{
    Capabilities, InferResult, PredictError, PredictRequest, PredictResponse, Want,
};
use crate::linalg::Mat;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Refresh the cached worker load signals every this many predicts (the
/// first predict primes them).
const STATS_EVERY: u64 = 16;

/// A [`Predictor`] that fans each batch out to remote shard workers,
/// balancing across replicas and failing over when one dies mid-batch.
pub struct RemoteShardedPredictor {
    router: ShardRouter,
    /// Clients serving each shard, indexed by shard id (≥1 per shard,
    /// enforced at connect).
    replicas: Vec<Vec<Arc<RemoteWorkerClient>>>,
    /// Every distinct worker, for stats polling and metrics.
    clients: Vec<Arc<RemoteWorkerClient>>,
    dim: usize,
    outputs: usize,
    /// Whether **every** worker can serve the variance column (the
    /// capability is the AND across workers — any replica may be asked).
    variance: bool,
    normalization: Option<Vec<(f64, f64)>>,
    /// Predict counter driving the stats-refresh cadence.
    polls: AtomicU64,
}

impl RemoteShardedPredictor {
    /// Connect to `workers`, ask each what it serves (`hello`), and
    /// build the shard → replicas map against `router`. Errors if any
    /// worker is unreachable, workers disagree on dim/outputs, a worker
    /// announces a shard the router does not know, or any routed shard
    /// ends up with no replica.
    pub fn connect(
        router: ShardRouter,
        workers: &[String],
        timeout: Duration,
    ) -> Result<RemoteShardedPredictor> {
        if workers.is_empty() {
            return Err(Error::config("remote serving needs at least one worker address"));
        }
        let n_shards = router.shards();
        let mut replicas: Vec<Vec<Arc<RemoteWorkerClient>>> =
            (0..n_shards).map(|_| Vec::new()).collect();
        let mut clients = Vec::with_capacity(workers.len());
        let mut dim_out: Option<(usize, usize)> = None;
        let mut variance = true;
        for addr in workers {
            let c = Arc::new(RemoteWorkerClient::new(addr, timeout));
            let hello = c
                .hello()
                .map_err(|e| Error::Serve(format!("worker {addr}: {}", e.message())))?;
            match dim_out {
                None => dim_out = Some((hello.dim, hello.outputs)),
                Some((d, o)) if d == hello.dim && o == hello.outputs => {}
                Some((d, o)) => {
                    return Err(Error::data(format!(
                        "worker {addr} serves dim {} / outputs {} but earlier \
                         workers serve {d} / {o}",
                        hello.dim, hello.outputs
                    )));
                }
            }
            variance &= hello.variance;
            for &(id, _lo, _hi) in &hello.shards {
                if id >= n_shards {
                    return Err(Error::data(format!(
                        "worker {addr} serves shard {id} but the router only \
                         knows shards 0..{n_shards}"
                    )));
                }
                replicas[id].push(c.clone());
            }
            clients.push(c);
        }
        for (sid, r) in replicas.iter().enumerate() {
            if r.is_empty() {
                return Err(Error::data(format!(
                    "shard {sid} has no replica among the {} worker(s)",
                    workers.len()
                )));
            }
        }
        let (dim, outputs) = dim_out
            .ok_or_else(|| Error::config("remote serving needs at least one worker address"))?;
        Ok(RemoteShardedPredictor {
            router,
            replicas,
            clients,
            dim,
            outputs,
            variance,
            normalization: None,
            polls: AtomicU64::new(0),
        })
    }

    /// Connect against a shard directory's router and recorded
    /// normalization (the shards themselves live in the workers): what
    /// `hck serve --shard-dir dir/ --workers a:p,b:p` runs.
    pub fn connect_dir(
        dir: &str,
        workers: &[String],
        timeout: Duration,
    ) -> Result<RemoteShardedPredictor> {
        let (router, normalization) = super::load_router_parts(dir)?;
        let mut rp = Self::connect(router, workers, timeout)?;
        rp.normalization = normalization;
        Ok(rp)
    }

    /// Record feature-normalization ranges applied before routing
    /// (`None` clears them).
    pub fn with_normalization(mut self, ranges: Option<Vec<(f64, f64)>>) -> Self {
        self.normalization = ranges;
        self
    }

    /// Number of shards the router knows.
    pub fn shards(&self) -> usize {
        self.replicas.len()
    }

    /// Replica count per shard, indexed by shard id.
    pub fn replica_counts(&self) -> Vec<usize> {
        self.replicas.iter().map(|r| r.len()).collect()
    }

    /// Refresh the cached per-worker load signals on a fixed predict
    /// cadence. Best effort with a single attempt each — a dead worker
    /// keeps its stale (high) score until it answers again.
    fn maybe_refresh_stats(&self) {
        // ORDERING: Relaxed — refresh-cadence heuristic only; stats
        // results are published inside each client, not by this counter.
        if self.polls.fetch_add(1, Ordering::Relaxed) % STATS_EVERY != 0 {
            return;
        }
        for c in &self.clients {
            let _ = c.stats();
        }
    }

    /// Serve one shard's sub-batch, walking the shard's replicas from
    /// least to most loaded and failing over on transport or shard-local
    /// errors. A reply with impossible shape or non-finite values is
    /// treated as a failed replica, never gathered.
    fn eval_shard(&self, sid: usize, q: &Mat, want: Want) -> InferResult<ShardBlock> {
        let reps = &self.replicas[sid];
        let mut order: Vec<usize> = (0..reps.len()).collect();
        order.sort_by_key(|&k| reps[k].load_score());
        let mut last: Option<PredictError> = None;
        for k in order {
            let c = &reps[k];
            c.begin_request();
            let got = c.predict_shard(sid, q, want);
            c.end_request();
            match got {
                Ok(block) => match validate_block(&block, q.rows(), self.outputs, want) {
                    Ok(()) => return Ok(block),
                    Err(why) => {
                        last = Some(PredictError::Transport {
                            worker: c.addr().to_string(),
                            message: format!("untrustworthy reply: {why}"),
                        });
                    }
                },
                // Worker unreachable, or its shard-local evaluation
                // failed: another replica may well succeed.
                Err(e @ PredictError::Transport { .. }) | Err(e @ PredictError::Shard { .. }) => {
                    last = Some(e);
                }
                // Request-shaped errors would repeat identically on
                // every replica — surface them unchanged.
                Err(e) => return Err(e),
            }
        }
        let detail = match last {
            Some(e) => e.message(),
            None => "shard has no replicas".into(),
        };
        Err(PredictError::Shard {
            shard: sid,
            message: format!("all {} replica(s) failed; last: {detail}", reps.len()),
        })
    }
}

/// Shape/sanity gate on a remote reply before it is gathered: row
/// count, output width, variance/route lengths against the request, and
/// finiteness. The wire peer is another process — a truncated or buggy
/// worker must read as a failed replica, not as silent NaN rows.
fn validate_block(
    b: &ShardBlock,
    rows: usize,
    outputs: usize,
    want: Want,
) -> std::result::Result<(), String> {
    if b.mean.rows() != rows || b.mean.cols() != outputs {
        return Err(format!(
            "mean block is {}x{}, want {rows}x{outputs}",
            b.mean.rows(),
            b.mean.cols()
        ));
    }
    for i in 0..rows {
        if b.mean.row(i).iter().any(|v| !v.is_finite()) {
            return Err(format!("non-finite mean in reply row {i}"));
        }
    }
    match (&b.variance, want.variance) {
        (Some(v), true) => {
            if v.len() != rows {
                return Err(format!("variance column has {} rows, want {rows}", v.len()));
            }
            if v.iter().any(|x| !x.is_finite()) {
                return Err("non-finite variance in reply".into());
            }
        }
        (None, true) => return Err("variance requested but missing from reply".into()),
        _ => {}
    }
    if want.leaf_route {
        match &b.routes {
            Some(r) if r.len() == rows => {}
            Some(r) => {
                return Err(format!("route column has {} rows, want {rows}", r.len()))
            }
            None => return Err("leaf routes requested but missing from reply".into()),
        }
    }
    Ok(())
}

impl Predictor for RemoteShardedPredictor {
    fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse> {
        crate::infer::validate_queries(&req.queries, self.dim)?;
        Predictor::capabilities(self).check(req.want)?;
        self.maybe_refresh_stats();
        let normalized =
            crate::infer::normalized_queries(req, self.normalization.as_deref());
        let q: &Mat = normalized.as_ref().unwrap_or(&req.queries);
        let t = Instant::now();
        // Scatter: request indices per destination shard (identical to
        // the in-process ShardedPredictor — the router is the same).
        let mut per: Vec<Vec<usize>> = (0..self.replicas.len()).map(|_| Vec::new()).collect();
        for i in 0..q.rows() {
            per[self.router.route(q.row(i))].push(i);
        }
        let jobs: Vec<(usize, Vec<usize>, Mat)> = per
            .into_iter()
            .enumerate()
            .filter(|(_, idx)| !idx.is_empty())
            .map(|(sid, idx)| {
                let sub = q.select_rows(&idx);
                (sid, idx, sub)
            })
            .collect();
        // Fan out: one scoped thread per destination shard. These
        // threads spend their lives blocked on sockets, so they ride
        // plain scoped threads instead of occupying pool workers (the
        // same reasoning that keeps shard workers off the pool).
        let blocks: Vec<InferResult<ShardBlock>> = std::thread::scope(|s| {
            let handles: Vec<_> = jobs
                .iter()
                .map(|(sid, _, sub)| {
                    let sid = *sid;
                    s.spawn(move || self.eval_shard(sid, sub, req.want))
                })
                .collect();
            handles
                .into_iter()
                .zip(jobs.iter())
                .map(|(h, (sid, _, _))| {
                    h.join().unwrap_or_else(|_| {
                        Err(PredictError::Shard {
                            shard: *sid,
                            message: "remote fan-out thread panicked".into(),
                        })
                    })
                })
                .collect()
        });
        // Gather in request order; any shard whose replicas are all
        // gone aborts the request with its typed error.
        let mut mean = Mat::zeros(q.rows(), self.outputs);
        let mut variance = if req.want.variance { Some(vec![0.0; q.rows()]) } else { None };
        let mut routes = if req.want.leaf_route {
            Some(vec![
                crate::infer::LeafRoute { shard: None, rows_lo: 0, rows_hi: 0 };
                q.rows()
            ])
        } else {
            None
        };
        for ((_, idx, _), block) in jobs.iter().zip(blocks) {
            let block = block?;
            for (k, &i) in idx.iter().enumerate() {
                mean.row_mut(i).copy_from_slice(block.mean.row(k));
            }
            if let (Some(out), Some(v)) = (variance.as_mut(), block.variance.as_ref()) {
                for (k, &i) in idx.iter().enumerate() {
                    out[i] = v[k];
                }
            }
            if let (Some(out), Some(r)) = (routes.as_mut(), block.routes.as_ref()) {
                for (k, &i) in idx.iter().enumerate() {
                    out[i] = r[k];
                }
            }
        }
        let per_query_ns = t.elapsed().as_nanos() as f64 / q.rows() as f64;
        Ok(PredictResponse { mean, variance, routes, per_query_ns })
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn outputs(&self) -> usize {
        self.outputs
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { mean: true, variance: self.variance, leaf_route: true }
    }

    fn shard_metrics(&self) -> Vec<ShardSnapshot> {
        // The authoritative per-shard counters live in the workers; the
        // per-worker exposition below carries them. Local aggregation
        // would double-count replicated shards.
        Vec::new()
    }

    fn worker_metrics(&self) -> Vec<WorkerSnapshot> {
        self.clients
            .iter()
            .map(|c| match c.stats() {
                Ok(shards) => WorkerSnapshot {
                    worker: c.addr().to_string(),
                    reconnects: c.reconnects(),
                    reachable: true,
                    shards,
                },
                Err(_) => WorkerSnapshot {
                    worker: c.addr().to_string(),
                    reconnects: c.reconnects(),
                    reachable: false,
                    shards: Vec::new(),
                },
            })
            .collect()
    }
}
