//! Replica-aware remote fan-out with a self-healing lifecycle:
//! [`RemoteShardedPredictor`].
//!
//! The remote counterpart of [`super::ShardedPredictor`]: the same
//! [`super::ShardRouter`] scatter and request-order gather, but each
//! sub-batch travels over the `HCKW` wire
//! ([`crate::shard::remote`]) to whichever `hck shard-worker` process
//! currently looks least loaded among the shard's replicas.
//!
//! **Dynamic registry.** The worker set is no longer frozen at connect:
//! every worker lives in a registry entry with a lifecycle state —
//! `active` (serving), `draining` (finishing in-flight work, no new
//! batches), `retired` (kept only for metrics continuity). Replicas can
//! be attached ([`RemoteShardedPredictor::attach_worker`]) and drained
//! ([`RemoteShardedPredictor::drain_worker`]) at runtime, by the
//! operator (the `worker_add`/`worker_drain` admin protocol commands)
//! or by the supervisor's [`ScalePolicy`].
//!
//! **Supervisor.** A background loop ticks every
//! [`ResilienceConfig::supervise_every`]: it refreshes worker load
//! signals, retires draining replicas whose outstanding count reached
//! zero, and — when a [`ScalePolicy`] is configured — attaches standby
//! replicas under sustained load and drains redundant ones when load
//! subsides. [`RemoteShardedPredictor::reconcile`] runs the same pass
//! synchronously, so tests and admin commands never sleep-as-sync.
//!
//! **Drain/handoff.** Draining is two-sided: the router stops routing
//! new sub-batches to the replica *and* sends the `drain` wire command
//! so the worker refuses predicts from any other router. In-flight
//! requests finish normally and the entry only moves to `retired` once
//! its outstanding count hits zero — a rebalance never drops a request.
//!
//! **Circuit breakers + hedging.** Each replica carries a breaker
//! ([`crate::shard::remote::BreakerConfig`]): consecutive predict
//! failures open it, predicts fast-fail and route around until a
//! half-open probe succeeds. Separately, when a shard has ≥2 usable
//! replicas, a sub-batch that straggles past the hedge deadline
//! (fixed via [`ResilienceConfig::hedge_after_ms`], or derived as
//! 2 × the recent p95 latency) is re-issued to a sibling replica and
//! the first answer wins — both replicas compute the same block, so
//! the hedge is numerically invisible.
//!
//! **Failover.** A replica that fails with a *transport*, *shard-local*
//! or *draining* error merely moves the sub-batch to the next replica
//! in score order; only when every active replica of a shard has failed
//! does the request surface a typed [`PredictError::Shard`] naming the
//! shard and the last cause. Request-shaped errors (bad request,
//! unsupported column) return immediately — every replica would refuse
//! them the same way.

use super::remote::{BreakerConfig, RemoteWorkerClient};
use super::router::ShardRouter;
use super::ShardBlock;
use crate::coordinator::metrics::{ShardSnapshot, WorkerSnapshot};
use crate::coordinator::Predictor;
use crate::error::{Error, Result};
use crate::infer::{
    Capabilities, InferResult, PredictError, PredictRequest, PredictResponse, Want,
};
use crate::linalg::Mat;
use crate::obs;
use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Refresh the cached worker load signals every this many predicts (the
/// first predict primes them; the supervisor refreshes on its own tick
/// as well, so idle periods stay fresh too).
const STATS_EVERY: u64 = 16;

/// Ring capacity of the recent shard-eval latency window (hedge
/// deadline source).
const LAT_RING: usize = 512;

/// Samples required before an auto-derived hedge deadline activates —
/// hedging off a cold estimate would double-send half the warmup.
const LAT_WARMUP: usize = 32;

/// Lifecycle states of a registry entry (an `AtomicU8`).
const STATE_ACTIVE: u8 = 0;
const STATE_DRAINING: u8 = 1;
const STATE_RETIRED: u8 = 2;

fn state_name(state: u8) -> &'static str {
    match state {
        STATE_DRAINING => "draining",
        STATE_RETIRED => "retired",
        _ => "active",
    }
}

/// Resilience knobs for the remote fan-out.
#[derive(Debug, Clone, Copy)]
pub struct ResilienceConfig {
    /// Consecutive predict failures that open a replica's breaker.
    pub breaker_failures: u32,
    /// How long an open breaker fast-fails before a half-open probe.
    pub breaker_cooldown: Duration,
    /// Hedge deadline: `None` derives 2×p95 from recent latencies
    /// (after warmup), `Some(0)` disables hedging, `Some(ms)` is a
    /// fixed deadline.
    pub hedge_after_ms: Option<u64>,
    /// Reply deadline for the background stats poll (shorter than the
    /// predict timeout so a hung worker cannot stall signal refresh).
    pub stats_timeout: Duration,
    /// Supervisor tick period (drain reconciliation, stats refresh,
    /// scale policy).
    pub supervise_every: Duration,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig {
            breaker_failures: 5,
            breaker_cooldown: Duration::from_secs(1),
            hedge_after_ms: None,
            stats_timeout: Duration::from_millis(250),
            supervise_every: Duration::from_millis(500),
        }
    }
}

/// Autoscaling policy the supervisor applies: attach standby replicas
/// under sustained load, drain redundant ones when it subsides.
#[derive(Debug, Clone, Default)]
pub struct ScalePolicy {
    /// Standby worker addresses the supervisor may attach, in order.
    pub standby: Vec<String>,
    /// Attach the next standby when the peak per-worker busy fraction
    /// exceeds this (0 disables attaching).
    pub attach_busy: f64,
    /// Drain the most recently attached redundant replica when the
    /// peak busy fraction falls below this (0 disables retiring).
    pub retire_busy: f64,
}

/// One registry entry: a worker client plus its lifecycle state and the
/// shards it announced at handshake.
struct WorkerEntry {
    client: Arc<RemoteWorkerClient>,
    shards: Vec<usize>,
    state: AtomicU8,
}

impl WorkerEntry {
    fn state(&self) -> u8 {
        // ORDERING: SeqCst — lifecycle control plane; pairs with the
        // stores in Core::drain / Core::reconcile.
        self.state.load(Ordering::SeqCst)
    }

    fn set_state(&self, s: u8) {
        // ORDERING: SeqCst — lifecycle control plane; pairs with the
        // loads in WorkerEntry::state.
        self.state.store(s, Ordering::SeqCst)
    }
}

/// Recent shard-eval latency ring (ns), feeding the hedge deadline.
struct LatWindow {
    buf: Vec<u64>,
    pos: usize,
}

/// Shared state between the predictor, its fan-out threads, and the
/// supervisor.
struct Core {
    router: ShardRouter,
    workers: RwLock<Vec<Arc<WorkerEntry>>>,
    dim: usize,
    outputs: usize,
    /// Whether **every** worker can serve the variance column (the
    /// capability is the AND across workers — any replica may be
    /// asked; attach rejects workers that would break it).
    variance: bool,
    timeout: Duration,
    cfg: ResilienceConfig,
    policy: Option<ScalePolicy>,
    /// Predict counter driving the stats-refresh cadence.
    polls: AtomicU64,
    lat: Mutex<LatWindow>,
}

impl Core {
    fn entries(&self) -> Vec<Arc<WorkerEntry>> {
        // A panicking writer cannot corrupt a Vec<Arc<_>> beyond its
        // own aborted mutation; recover the data through the poison so
        // serving never deadlocks on a poisoned registry.
        let g = match self.workers.read() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        };
        g.clone()
    }

    /// One synchronous supervisor pass: retire drained replicas,
    /// refresh load signals, apply the scale policy.
    fn supervise(&self) {
        self.reconcile();
        self.refresh_stats();
        self.apply_policy();
    }

    /// Move draining entries whose outstanding count reached zero to
    /// `retired` — the drain-completion edge of the lifecycle.
    fn reconcile(&self) {
        for e in self.entries() {
            if e.state() == STATE_DRAINING && e.client.outstanding() == 0 {
                e.set_state(STATE_RETIRED);
                let _sp = obs::span_with("remote.drain", "remote", || {
                    format!("{{\"worker\":\"{}\",\"phase\":\"retired\"}}", e.client.addr())
                });
            }
        }
    }

    /// Refresh every live worker's cached load signals (single attempt
    /// each, short stats timeout — a dead worker keeps its stale score).
    fn refresh_stats(&self) {
        for e in self.entries() {
            if e.state() != STATE_RETIRED {
                let _ = e.client.stats();
            }
        }
    }

    /// Apply the scale policy, at most one action per tick: attach the
    /// next absent standby when peak busy exceeds `attach_busy`; drain
    /// the most recent redundant active replica when it falls below
    /// `retire_busy`.
    fn apply_policy(&self) {
        let Some(policy) = &self.policy else { return };
        let entries = self.entries();
        let active: Vec<&Arc<WorkerEntry>> =
            entries.iter().filter(|e| e.state() == STATE_ACTIVE).collect();
        let peak_busy = active
            .iter()
            .map(|e| e.client.load_score().1 as f64 / 1e6)
            .fold(0.0f64, f64::max);
        if policy.attach_busy > 0.0 && peak_busy > policy.attach_busy {
            let absent = policy.standby.iter().find(|addr| {
                !entries
                    .iter()
                    .any(|e| e.client.addr() == addr.as_str() && e.state() != STATE_RETIRED)
            });
            if let Some(addr) = absent {
                if let Err(e) = self.attach(addr) {
                    eprintln!("balance: cannot attach standby {addr}: {e}");
                }
            }
            return;
        }
        if policy.retire_busy > 0.0 && peak_busy < policy.retire_busy && active.len() > 1 {
            // Most recently attached redundant replica first (reverse
            // registry order), so scale-down unwinds scale-up.
            let redundant = entries.iter().rev().find(|e| {
                e.state() == STATE_ACTIVE
                    && e.shards.iter().all(|&sid| {
                        entries.iter().any(|o| {
                            !Arc::ptr_eq(o, e)
                                && o.state() == STATE_ACTIVE
                                && o.shards.contains(&sid)
                        })
                    })
            });
            if let Some(e) = redundant {
                let addr = e.client.addr().to_string();
                if let Err(err) = self.drain(&addr) {
                    eprintln!("balance: cannot drain {addr}: {err}");
                }
            }
        }
    }

    /// Attach a worker at runtime: handshake, validate against the
    /// topology, register as `active`. Rejects duplicates of a live
    /// entry; a retired entry with the same address is replaced (so
    /// Prometheus never sees two live series for one worker label).
    fn attach(&self, addr: &str) -> Result<()> {
        {
            let g = match self.workers.read() {
                Ok(g) => g,
                Err(poison) => poison.into_inner(),
            };
            if g.iter().any(|e| e.client.addr() == addr && e.state() != STATE_RETIRED) {
                return Err(Error::config(format!("worker {addr} is already attached")));
            }
        }
        let (entry, dim_out, has_var) =
            handshake(addr, self.timeout, &self.cfg, self.router.shards())?;
        if dim_out != (self.dim, self.outputs) {
            return Err(Error::data(format!(
                "worker {addr} serves dim {} / outputs {} but this router serves {} / {}",
                dim_out.0, dim_out.1, self.dim, self.outputs
            )));
        }
        if self.variance && !has_var {
            return Err(Error::data(format!(
                "worker {addr} has no variance state but this router serves the \
                 variance column"
            )));
        }
        let _sp = obs::span_with("balance.scale", "balance", || {
            format!("{{\"action\":\"attach\",\"worker\":\"{addr}\"}}")
        });
        let mut g = match self.workers.write() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        };
        g.retain(|e| !(e.client.addr() == addr && e.state() == STATE_RETIRED));
        g.push(entry);
        Ok(())
    }

    /// Begin draining a worker: refuse if it is not active or if any of
    /// its shards would be left with no active replica, then stop
    /// routing to it and send the `drain` wire command. The supervisor
    /// (or [`Core::reconcile`]) retires it once outstanding hits zero.
    fn drain(&self, addr: &str) -> Result<()> {
        let entries = self.entries();
        let Some(target) = entries.iter().find(|e| e.client.addr() == addr) else {
            return Err(Error::config(format!("no attached worker at {addr}")));
        };
        if target.state() != STATE_ACTIVE {
            return Err(Error::config(format!(
                "worker {addr} is {} — only active workers can drain",
                state_name(target.state())
            )));
        }
        for &sid in &target.shards {
            let covered = entries.iter().any(|o| {
                !Arc::ptr_eq(o, target) && o.state() == STATE_ACTIVE && o.shards.contains(&sid)
            });
            if !covered {
                return Err(Error::config(format!(
                    "draining {addr} would leave shard {sid} with no active replica"
                )));
            }
        }
        let _sp = obs::span_with("remote.drain", "remote", || {
            format!("{{\"worker\":\"{addr}\",\"phase\":\"drain\"}}")
        });
        // Router-side gate first: no new sub-batch routes here from now
        // on, even if the wire command below fails.
        target.set_state(STATE_DRAINING);
        target.client.note_drain();
        if let Err(e) = target.client.drain_worker() {
            eprintln!(
                "balance: drain command to {addr} failed ({}); draining locally anyway",
                e.message()
            );
        }
        Ok(())
    }

    /// The usable replicas of a shard, least-loaded first; replicas
    /// with a blocking (open, cooling-down) breaker sort last so the
    /// balancer routes around them without burning their fast-fail.
    fn replicas_for(&self, sid: usize) -> Vec<Arc<WorkerEntry>> {
        let mut reps: Vec<Arc<WorkerEntry>> = self
            .entries()
            .into_iter()
            .filter(|e| e.state() == STATE_ACTIVE && e.shards.contains(&sid))
            .collect();
        reps.sort_by_key(|e| (e.client.breaker_blocked(), e.client.load_score()));
        reps
    }

    /// Record one successful shard-eval latency (ns) into the ring.
    fn note_latency(&self, ns: u64) {
        let mut g = match self.lat.lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        };
        if g.buf.len() < LAT_RING {
            g.buf.push(ns);
        } else {
            let pos = g.pos;
            g.buf[pos] = ns;
            g.pos = (pos + 1) % LAT_RING;
        }
    }

    /// The current hedge deadline, if hedging is enabled and warm:
    /// a fixed `hedge_after_ms`, or 2 × the recent p95 (floored at
    /// 5 ms so noise cannot hedge every request), capped at the
    /// predict timeout.
    fn hedge_deadline(&self) -> Option<Duration> {
        match self.cfg.hedge_after_ms {
            Some(0) => None,
            Some(ms) => Some(Duration::from_millis(ms).min(self.timeout)),
            None => {
                let sorted = {
                    let g = match self.lat.lock() {
                        Ok(g) => g,
                        Err(poison) => poison.into_inner(),
                    };
                    if g.buf.len() < LAT_WARMUP {
                        return None;
                    }
                    let mut v = g.buf.clone();
                    v.sort_unstable();
                    v
                };
                let idx = (sorted.len() * 95 / 100).min(sorted.len() - 1);
                let ns = sorted[idx].saturating_mul(2).max(5_000_000);
                Some(Duration::from_nanos(ns).min(self.timeout))
            }
        }
    }

    /// Refresh the cached per-worker load signals on a fixed predict
    /// cadence (the supervisor also refreshes on its own tick).
    fn maybe_refresh_stats(&self) {
        // ORDERING: Relaxed — refresh-cadence heuristic only; stats
        // results are published inside each client, not by this counter.
        if self.polls.fetch_add(1, Ordering::Relaxed) % STATS_EVERY != 0 {
            return;
        }
        self.refresh_stats();
    }

    /// Serve one shard's sub-batch, walking the shard's active replicas
    /// from least to most loaded and failing over on transport,
    /// shard-local, or draining errors. When ≥2 replicas are usable and
    /// a hedge deadline is known, the least-loaded pair runs the hedged
    /// protocol first.
    fn eval_shard(&self, sid: usize, q: &Mat, want: Want) -> InferResult<ShardBlock> {
        let reps = self.replicas_for(sid);
        if reps.is_empty() {
            return Err(PredictError::Shard {
                shard: sid,
                message: "shard has no active replica".into(),
            });
        }
        let t = Instant::now();
        let mut last: Option<PredictError> = None;
        let mut k = 0usize;
        if reps.len() >= 2 {
            if let Some(deadline) = self.hedge_deadline() {
                match self.eval_hedged(sid, q, want, &reps[0], &reps[1], deadline) {
                    Ok(block) => {
                        self.note_latency(t.elapsed().as_nanos() as u64);
                        return Ok(block);
                    }
                    Err(e) if failover_ok(&e) => {
                        last = Some(e);
                        k = 2;
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        while k < reps.len() {
            let c = &reps[k].client;
            match eval_one(c, sid, q, want, self.outputs) {
                Ok(block) => {
                    self.note_latency(t.elapsed().as_nanos() as u64);
                    return Ok(block);
                }
                Err(e) if failover_ok(&e) => last = Some(e),
                // Request-shaped errors would repeat identically on
                // every replica — surface them unchanged.
                Err(e) => return Err(e),
            }
            k += 1;
        }
        let detail = match last {
            Some(e) => e.message(),
            None => "shard has no replicas".into(),
        };
        Err(PredictError::Shard {
            shard: sid,
            message: format!("all {} replica(s) failed; last: {detail}", reps.len()),
        })
    }

    /// Hedged eval over the two least-loaded replicas: the primary runs
    /// on a detached thread; if it straggles past `deadline`, the same
    /// sub-batch is re-issued to the sibling and the first answer wins.
    /// Both replicas hold identical shard state, so whichever answers
    /// is bitwise the same block.
    fn eval_hedged(
        &self,
        sid: usize,
        q: &Mat,
        want: Want,
        primary: &Arc<WorkerEntry>,
        sibling: &Arc<WorkerEntry>,
        deadline: Duration,
    ) -> InferResult<ShardBlock> {
        let (tx, rx) = mpsc::channel();
        let q1 = q.clone();
        let outputs = self.outputs;
        let p2 = primary.clone();
        // Detached on purpose: a scoped thread would force joining the
        // straggler, stalling the hedge's whole point. The thread owns
        // clones of everything it touches and reports through the
        // channel; if the receiver is gone (we returned early), the
        // send fails silently and the thread exits.
        let spawned = std::thread::Builder::new()
            .name("hck-hedge-primary".into())
            .spawn(move || {
                let _ = tx.send(eval_one(&p2.client, sid, &q1, want, outputs));
            });
        if spawned.is_err() {
            // Out of threads: hedging is an optimization, not a
            // requirement — evaluate the primary synchronously.
            return eval_one(&primary.client, sid, q, want, self.outputs);
        }
        match rx.recv_timeout(deadline) {
            Ok(Ok(block)) => Ok(block),
            // Primary failed fast → ordinary failover to the sibling.
            Ok(Err(e)) if failover_ok(&e) => {
                eval_one(&sibling.client, sid, q, want, self.outputs)
            }
            Ok(Err(e)) => Err(e),
            // Deadline passed (or the thread died): hedge to the
            // sibling; if the sibling fails, give the straggler until
            // the full predict timeout before giving up on the pair.
            Err(_) => {
                primary.client.note_hedge();
                let _sp = obs::span_with("remote.hedge", "remote", || {
                    format!(
                        "{{\"shard\":{sid},\"slow\":\"{}\",\"hedge\":\"{}\"}}",
                        primary.client.addr(),
                        sibling.client.addr()
                    )
                });
                match eval_one(&sibling.client, sid, q, want, self.outputs) {
                    Ok(block) => Ok(block),
                    Err(sibling_err) => match rx.recv_timeout(self.timeout) {
                        Ok(Ok(block)) => Ok(block),
                        _ => Err(sibling_err),
                    },
                }
            }
        }
    }
}

/// Whether an error moves the sub-batch to the next replica (transport,
/// shard-local, or a planned drain) rather than aborting the request.
fn failover_ok(e: &PredictError) -> bool {
    matches!(
        e,
        PredictError::Transport { .. }
            | PredictError::Shard { .. }
            | PredictError::Draining { .. }
    )
}

/// One replica eval: in-flight accounting around the wire predict, and
/// a shape/finiteness gate on the reply before it may be gathered.
fn eval_one(
    c: &RemoteWorkerClient,
    sid: usize,
    q: &Mat,
    want: Want,
    outputs: usize,
) -> InferResult<ShardBlock> {
    c.begin_request();
    let got = c.predict_shard(sid, q, want);
    c.end_request();
    let block = got?;
    match validate_block(&block, q.rows(), outputs, want) {
        Ok(()) => Ok(block),
        Err(why) => Err(PredictError::Transport {
            worker: c.addr().to_string(),
            message: format!("untrustworthy reply: {why}"),
        }),
    }
}

/// Handshake one worker: build its client, `hello` it, and validate the
/// announced shards against the router's shard count.
fn handshake(
    addr: &str,
    timeout: Duration,
    cfg: &ResilienceConfig,
    n_shards: usize,
) -> Result<(Arc<WorkerEntry>, (usize, usize), bool)> {
    let breaker =
        BreakerConfig { failures: cfg.breaker_failures, cooldown: cfg.breaker_cooldown };
    let client =
        Arc::new(RemoteWorkerClient::with_config(addr, timeout, cfg.stats_timeout, breaker));
    let hello = client
        .hello()
        .map_err(|e| Error::Serve(format!("worker {addr}: {}", e.message())))?;
    let mut shards = Vec::with_capacity(hello.shards.len());
    for &(id, _lo, _hi) in &hello.shards {
        if id >= n_shards {
            return Err(Error::data(format!(
                "worker {addr} serves shard {id} but the router only knows \
                 shards 0..{n_shards}"
            )));
        }
        shards.push(id);
    }
    let entry =
        Arc::new(WorkerEntry { client, shards, state: AtomicU8::new(STATE_ACTIVE) });
    Ok((entry, (hello.dim, hello.outputs), hello.variance))
}

/// A [`Predictor`] that fans each batch out to remote shard workers,
/// balancing across replicas, hedging stragglers, and failing over when
/// one dies mid-batch — with a supervisor thread keeping the replica
/// registry healthy at runtime.
pub struct RemoteShardedPredictor {
    core: Arc<Core>,
    normalization: Option<Vec<(f64, f64)>>,
    stop: Arc<AtomicBool>,
    supervisor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl RemoteShardedPredictor {
    /// Connect to `workers` with default resilience settings and no
    /// scale policy. See [`RemoteShardedPredictor::connect_with`].
    pub fn connect(
        router: ShardRouter,
        workers: &[String],
        timeout: Duration,
    ) -> Result<RemoteShardedPredictor> {
        Self::connect_with(router, workers, timeout, ResilienceConfig::default(), None)
    }

    /// Connect to `workers`, ask each what it serves (`hello`), and
    /// build the dynamic registry against `router`. Errors if any
    /// worker is unreachable, workers disagree on dim/outputs, a worker
    /// announces a shard the router does not know, or any routed shard
    /// ends up with no replica. Starts the supervisor thread.
    pub fn connect_with(
        router: ShardRouter,
        workers: &[String],
        timeout: Duration,
        cfg: ResilienceConfig,
        policy: Option<ScalePolicy>,
    ) -> Result<RemoteShardedPredictor> {
        if workers.is_empty() {
            return Err(Error::config("remote serving needs at least one worker address"));
        }
        let n_shards = router.shards();
        let mut entries: Vec<Arc<WorkerEntry>> = Vec::with_capacity(workers.len());
        let mut dim_out: Option<(usize, usize)> = None;
        let mut variance = true;
        for addr in workers {
            let (entry, d_o, has_var) = handshake(addr, timeout, &cfg, n_shards)?;
            match dim_out {
                None => dim_out = Some(d_o),
                Some((d, o)) if (d, o) == d_o => {}
                Some((d, o)) => {
                    return Err(Error::data(format!(
                        "worker {addr} serves dim {} / outputs {} but earlier \
                         workers serve {d} / {o}",
                        d_o.0, d_o.1
                    )));
                }
            }
            variance &= has_var;
            entries.push(entry);
        }
        for sid in 0..n_shards {
            if !entries.iter().any(|e| e.shards.contains(&sid)) {
                return Err(Error::data(format!(
                    "shard {sid} has no replica among the {} worker(s)",
                    workers.len()
                )));
            }
        }
        let (dim, outputs) = dim_out
            .ok_or_else(|| Error::config("remote serving needs at least one worker address"))?;
        let core = Arc::new(Core {
            router,
            workers: RwLock::new(entries),
            dim,
            outputs,
            variance,
            timeout,
            cfg,
            policy,
            polls: AtomicU64::new(0),
            lat: Mutex::new(LatWindow { buf: Vec::new(), pos: 0 }),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let rp = RemoteShardedPredictor {
            core,
            normalization: None,
            stop,
            supervisor: Mutex::new(None),
        };
        rp.spawn_supervisor()?;
        Ok(rp)
    }

    /// Connect against a shard directory's router and recorded
    /// normalization (the shards themselves live in the workers): what
    /// `hck serve --shard-dir dir/ --workers a:p,b:p` runs.
    pub fn connect_dir(
        dir: &str,
        workers: &[String],
        timeout: Duration,
    ) -> Result<RemoteShardedPredictor> {
        Self::connect_dir_with(dir, workers, timeout, ResilienceConfig::default(), None)
    }

    /// [`RemoteShardedPredictor::connect_dir`] with explicit resilience
    /// settings and an optional scale policy.
    pub fn connect_dir_with(
        dir: &str,
        workers: &[String],
        timeout: Duration,
        cfg: ResilienceConfig,
        policy: Option<ScalePolicy>,
    ) -> Result<RemoteShardedPredictor> {
        let (router, normalization) = super::load_router_parts(dir)?;
        let mut rp = Self::connect_with(router, workers, timeout, cfg, policy)?;
        rp.normalization = normalization;
        Ok(rp)
    }

    fn spawn_supervisor(&self) -> Result<()> {
        let core = self.core.clone();
        let stop = self.stop.clone();
        let join = std::thread::Builder::new()
            .name("hck-balance-supervisor".into())
            .spawn(move || supervisor_loop(core, stop))
            .map_err(|e| Error::config(format!("cannot spawn balance supervisor: {e}")))?;
        let mut g = match self.supervisor.lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        };
        *g = Some(join);
        Ok(())
    }

    /// Record feature-normalization ranges applied before routing
    /// (`None` clears them).
    pub fn with_normalization(mut self, ranges: Option<Vec<(f64, f64)>>) -> Self {
        self.normalization = ranges;
        self
    }

    /// Number of shards the router knows.
    pub fn shards(&self) -> usize {
        self.core.router.shards()
    }

    /// **Active** replica count per shard, indexed by shard id.
    pub fn replica_counts(&self) -> Vec<usize> {
        let entries = self.core.entries();
        (0..self.core.router.shards())
            .map(|sid| {
                entries
                    .iter()
                    .filter(|e| e.state() == STATE_ACTIVE && e.shards.contains(&sid))
                    .count()
            })
            .collect()
    }

    /// Attach a worker at runtime (admin `worker_add`, or a test).
    pub fn attach_worker(&self, addr: &str) -> Result<()> {
        self.core.attach(addr)
    }

    /// Begin draining a worker at runtime (admin `worker_drain`).
    pub fn drain_worker(&self, addr: &str) -> Result<()> {
        self.core.drain(addr)
    }

    /// Run one synchronous supervisor pass (drain reconciliation, stats
    /// refresh, scale policy) — the deterministic alternative to
    /// waiting for the supervisor tick.
    pub fn reconcile(&self) {
        self.core.supervise();
    }

    /// `(address, lifecycle state, outstanding requests)` per registry
    /// entry, in registry order.
    pub fn worker_states(&self) -> Vec<(String, &'static str, usize)> {
        self.core
            .entries()
            .iter()
            .map(|e| {
                (e.client.addr().to_string(), state_name(e.state()), e.client.outstanding())
            })
            .collect()
    }
}

impl Drop for RemoteShardedPredictor {
    fn drop(&mut self) {
        // ORDERING: SeqCst — one-shot shutdown flag; pairs with the
        // load in supervisor_loop.
        self.stop.store(true, Ordering::SeqCst);
        let join = {
            let mut g = match self.supervisor.lock() {
                Ok(g) => g,
                Err(poison) => poison.into_inner(),
            };
            g.take()
        };
        if let Some(j) = join {
            let _ = j.join();
        }
    }
}

/// The supervisor body: tick [`Core::supervise`] every
/// `supervise_every`, polling the stop flag at 10 ms so shutdown is
/// prompt regardless of the tick period.
fn supervisor_loop(core: Arc<Core>, stop: Arc<AtomicBool>) {
    let mut last_tick: Option<Instant> = None;
    loop {
        // ORDERING: SeqCst — shutdown control plane; pairs with the
        // store in RemoteShardedPredictor::drop.
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let due = match last_tick {
            None => true,
            Some(t) => t.elapsed() >= core.cfg.supervise_every,
        };
        if due {
            last_tick = Some(Instant::now());
            core.supervise();
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Shape/sanity gate on a remote reply before it is gathered: row
/// count, output width, variance/route lengths against the request, and
/// finiteness. The wire peer is another process — a truncated or buggy
/// worker must read as a failed replica, not as silent NaN rows.
fn validate_block(
    b: &ShardBlock,
    rows: usize,
    outputs: usize,
    want: Want,
) -> std::result::Result<(), String> {
    if b.mean.rows() != rows || b.mean.cols() != outputs {
        return Err(format!(
            "mean block is {}x{}, want {rows}x{outputs}",
            b.mean.rows(),
            b.mean.cols()
        ));
    }
    for i in 0..rows {
        if b.mean.row(i).iter().any(|v| !v.is_finite()) {
            return Err(format!("non-finite mean in reply row {i}"));
        }
    }
    match (&b.variance, want.variance) {
        (Some(v), true) => {
            if v.len() != rows {
                return Err(format!("variance column has {} rows, want {rows}", v.len()));
            }
            if v.iter().any(|x| !x.is_finite()) {
                return Err("non-finite variance in reply".into());
            }
        }
        (None, true) => return Err("variance requested but missing from reply".into()),
        _ => {}
    }
    if want.leaf_route {
        match &b.routes {
            Some(r) if r.len() == rows => {}
            Some(r) => {
                return Err(format!("route column has {} rows, want {rows}", r.len()))
            }
            None => return Err("leaf routes requested but missing from reply".into()),
        }
    }
    Ok(())
}

impl Predictor for RemoteShardedPredictor {
    fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse> {
        crate::infer::validate_queries(&req.queries, self.core.dim)?;
        Predictor::capabilities(self).check(req.want)?;
        self.core.maybe_refresh_stats();
        let normalized =
            crate::infer::normalized_queries(req, self.normalization.as_deref());
        let q: &Mat = normalized.as_ref().unwrap_or(&req.queries);
        let t = Instant::now();
        // Scatter: request indices per destination shard (identical to
        // the in-process ShardedPredictor — the router is the same).
        let mut per: Vec<Vec<usize>> =
            (0..self.core.router.shards()).map(|_| Vec::new()).collect();
        for i in 0..q.rows() {
            per[self.core.router.route(q.row(i))].push(i);
        }
        let jobs: Vec<(usize, Vec<usize>, Mat)> = per
            .into_iter()
            .enumerate()
            .filter(|(_, idx)| !idx.is_empty())
            .map(|(sid, idx)| {
                let sub = q.select_rows(&idx);
                (sid, idx, sub)
            })
            .collect();
        // Fan out: one scoped thread per destination shard. These
        // threads spend their lives blocked on sockets, so they ride
        // plain scoped threads instead of occupying pool workers (the
        // same reasoning that keeps shard workers off the pool).
        let blocks: Vec<InferResult<ShardBlock>> = std::thread::scope(|s| {
            let handles: Vec<_> = jobs
                .iter()
                .map(|(sid, _, sub)| {
                    let sid = *sid;
                    s.spawn(move || self.core.eval_shard(sid, sub, req.want))
                })
                .collect();
            handles
                .into_iter()
                .zip(jobs.iter())
                .map(|(h, (sid, _, _))| {
                    h.join().unwrap_or_else(|_| {
                        Err(PredictError::Shard {
                            shard: *sid,
                            message: "remote fan-out thread panicked".into(),
                        })
                    })
                })
                .collect()
        });
        // Gather in request order; any shard whose replicas are all
        // gone aborts the request with its typed error.
        let mut mean = Mat::zeros(q.rows(), self.core.outputs);
        let mut variance = if req.want.variance { Some(vec![0.0; q.rows()]) } else { None };
        let mut routes = if req.want.leaf_route {
            Some(vec![
                crate::infer::LeafRoute { shard: None, rows_lo: 0, rows_hi: 0 };
                q.rows()
            ])
        } else {
            None
        };
        for ((_, idx, _), block) in jobs.iter().zip(blocks) {
            let block = block?;
            for (k, &i) in idx.iter().enumerate() {
                mean.row_mut(i).copy_from_slice(block.mean.row(k));
            }
            if let (Some(out), Some(v)) = (variance.as_mut(), block.variance.as_ref()) {
                for (k, &i) in idx.iter().enumerate() {
                    out[i] = v[k];
                }
            }
            if let (Some(out), Some(r)) = (routes.as_mut(), block.routes.as_ref()) {
                for (k, &i) in idx.iter().enumerate() {
                    out[i] = r[k];
                }
            }
        }
        let per_query_ns = t.elapsed().as_nanos() as f64 / q.rows() as f64;
        Ok(PredictResponse { mean, variance, routes, per_query_ns })
    }

    fn dim(&self) -> usize {
        self.core.dim
    }

    fn outputs(&self) -> usize {
        self.core.outputs
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { mean: true, variance: self.core.variance, leaf_route: true }
    }

    fn shard_metrics(&self) -> Vec<ShardSnapshot> {
        // The authoritative per-shard counters live in the workers; the
        // per-worker exposition below carries them. Local aggregation
        // would double-count replicated shards.
        Vec::new()
    }

    fn worker_metrics(&self) -> Vec<WorkerSnapshot> {
        self.core
            .entries()
            .iter()
            .map(|e| {
                let c = &e.client;
                let base = WorkerSnapshot {
                    worker: c.addr().to_string(),
                    reconnects: c.reconnects(),
                    reachable: false,
                    state: state_name(e.state()).to_string(),
                    breaker_opens: c.breaker_opens(),
                    drains: c.drains(),
                    hedges: c.hedges(),
                    shards: Vec::new(),
                };
                if e.state() == STATE_RETIRED {
                    // Retired replicas are not polled — the entry stays
                    // for counter continuity, flagged unreachable.
                    return base;
                }
                match c.stats() {
                    Ok(shards) => WorkerSnapshot { reachable: true, shards, ..base },
                    Err(_) => base,
                }
            })
            .collect()
    }

    fn admin(&self, cmd: &str, arg: &str) -> InferResult<Json> {
        let ok = |addr: &str| {
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("worker", Json::Str(addr.to_string())),
            ]))
        };
        match cmd {
            "worker_add" => match self.attach_worker(arg) {
                Ok(()) => ok(arg),
                Err(e) => Err(PredictError::BadRequest(e.to_string())),
            },
            "worker_drain" => match self.drain_worker(arg) {
                Ok(()) => ok(arg),
                Err(e) => Err(PredictError::BadRequest(e.to_string())),
            },
            "workers" => {
                // Reconcile first so the reply reflects completed
                // drains, not the last supervisor tick.
                self.core.reconcile();
                let rows = self
                    .core
                    .entries()
                    .iter()
                    .map(|e| {
                        Json::obj(vec![
                            ("worker", Json::Str(e.client.addr().to_string())),
                            ("state", Json::Str(state_name(e.state()).to_string())),
                            (
                                "outstanding",
                                Json::Num(e.client.outstanding() as f64),
                            ),
                            ("breaker", Json::Str(e.client.breaker_state().to_string())),
                        ])
                    })
                    .collect();
                Ok(Json::obj(vec![("workers", Json::Arr(rows))]))
            }
            other => Err(PredictError::Unsupported(format!(
                "unknown admin command '{other}' (worker_add | worker_drain | workers)"
            ))),
        }
    }
}
