//! Remote shard workers: the `HCKW` wire format and both endpoints.
//!
//! A [`RemoteWorker`] is the serving side of distributed sharding: it
//! owns one (or several) loaded [`Shard`]s behind the same per-shard
//! [`ShardWorker`] queues the in-process path uses, and answers typed
//! predict/stats/hello frames over TCP. A [`RemoteWorkerClient`] is the
//! router's per-worker handle: one lazily-(re)connected stream, a
//! per-request timeout, bounded exponential-backoff reconnects, and the
//! cached load signals the balancer scores replicas by
//! ([`crate::shard::balance::RemoteShardedPredictor`]).
//!
//! ## Wire format
//!
//! Every frame is `b"HCKW"` + a little-endian `u64` payload length
//! (capped at [`MAX_FRAME`] against attacker-chosen allocations) + the
//! payload. The first payload byte is a command/reply tag; the body
//! reuses the `hkernel/persist.rs` primitives (`wu64`/`wf64`/
//! `write_mat`/`write_f64s`), so the encoding discipline — explicit
//! little-endian scalars, bounded counts, typed decode errors — is the
//! same one the `HCKS`/`HCKR` artifacts already pin.
//!
//! | tag | direction | body |
//! |-----|-----------|------|
//! | `CMD_PREDICT`  | client → worker | want flags, shard id, query [`Mat`] |
//! | `CMD_STATS`    | client → worker | — |
//! | `CMD_HELLO`    | client → worker | — |
//! | `CMD_SHUTDOWN` | client → worker | — |
//! | `CMD_DRAIN`    | client → worker | — (stop accepting predicts; finish in-flight) |
//! | `REPLY_BLOCK`  | worker → client | a [`ShardBlock`] (mean, variance?, routes?) |
//! | `REPLY_ERR`    | worker → client | a typed [`PredictError`] |
//! | `REPLY_STATS`  | worker → client | one [`ShardSnapshot`] per served shard |
//! | `REPLY_HELLO`  | worker → client | dim, outputs, variance flag, served shard ids/ranges |
//! | `REPLY_OK`     | worker → client | — (shutdown ack) |
//!
//! A malformed frame (wrong magic, oversized claimed length, torn
//! payload) earns the sender a best-effort typed error frame and costs
//! it **its own connection only** — the accept loop keeps serving
//! everyone else. No panic idiom survives on this path (`hck-lint`
//! gates `shard/`).

use super::fault::{self, FaultAction, FaultSite};
use super::worker::ShardWorker;
use super::{Shard, ShardBlock};
use crate::coordinator::metrics::ShardSnapshot;
use crate::error::{Error, Result};
use crate::hkernel::persist::{read_mat, rf64, ru64, wf64, write_f64s, write_mat, wu64};
use crate::hkernel::LazyVariance;
use crate::infer::{InferResult, LeafRoute, PredictError, Want};
use crate::linalg::Mat;
use crate::obs;
use std::io::Read;
use std::net::{TcpListener, TcpStream};
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Frame magic: the wire cousin of `HCKS`/`HCKR`/`HCKN`.
pub const WIRE_MAGIC: &[u8; 4] = b"HCKW";

/// Hard cap on a frame's claimed payload length. A `u64` length field is
/// attacker-chosen input; the cap bounds the allocation a hostile (or
/// corrupt) peer can demand before the first payload byte arrives.
pub const MAX_FRAME: u64 = 1 << 28;

const CMD_PREDICT: u8 = 1;
const CMD_STATS: u8 = 2;
const CMD_HELLO: u8 = 3;
const CMD_SHUTDOWN: u8 = 4;
const CMD_DRAIN: u8 = 5;
const REPLY_BLOCK: u8 = 0x81;
const REPLY_ERR: u8 = 0x82;
const REPLY_STATS: u8 = 0x83;
const REPLY_HELLO: u8 = 0x84;
const REPLY_OK: u8 = 0x85;

// ---------------------------------------------------------------------------
// Frame transport
// ---------------------------------------------------------------------------

/// Outcome of pulling one frame off a stream. The worker's connection
/// handler and the client's reply read share this so both sides apply
/// the same framing rules.
pub(crate) enum FrameRead {
    /// A complete, size-sane payload.
    Frame(Vec<u8>),
    /// Clean EOF before any byte of a frame — the peer hung up politely.
    Closed,
    /// The read timeout fired before any byte arrived (idle connection;
    /// the worker uses this to poll its stop flag).
    TimedOut,
    /// The bytes violate the framing rules: wrong magic, a claimed
    /// length outside `(0, MAX_FRAME]`, or a connection torn mid-frame.
    Malformed(String),
    /// Any other transport failure (including a timeout mid-frame,
    /// after which the stream offset is unknowable).
    Io(String),
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Fill `buf` completely, classifying every failure mode.
fn read_exactly(stream: &mut TcpStream, buf: &mut [u8]) -> std::result::Result<(), FrameRead> {
    let mut got = 0usize;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => return Err(FrameRead::Malformed("connection closed mid-frame".into())),
            Ok(k) => got += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => {
                return Err(FrameRead::Io("read timed out mid-frame".into()))
            }
            Err(e) => return Err(FrameRead::Io(e.to_string())),
        }
    }
    Ok(())
}

/// Read one `HCKW` frame. Never allocates more than [`MAX_FRAME`] bytes
/// no matter what the peer claims.
pub(crate) fn read_frame(stream: &mut TcpStream) -> FrameRead {
    let mut magic = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match stream.read(&mut magic[got..]) {
            Ok(0) => {
                return if got == 0 {
                    FrameRead::Closed
                } else {
                    FrameRead::Malformed("connection closed mid-frame (magic)".into())
                };
            }
            Ok(k) => got += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) && got == 0 => return FrameRead::TimedOut,
            Err(e) if is_timeout(&e) => {
                return FrameRead::Io("read timed out mid-frame (magic)".into())
            }
            Err(e) => return FrameRead::Io(e.to_string()),
        }
    }
    if &magic != WIRE_MAGIC {
        return FrameRead::Malformed(format!("bad frame magic {magic:?} (want {WIRE_MAGIC:?})"));
    }
    let mut lenb = [0u8; 8];
    if let Err(m) = read_exactly(stream, &mut lenb) {
        return m;
    }
    let len = u64::from_le_bytes(lenb);
    if len == 0 || len > MAX_FRAME {
        return FrameRead::Malformed(format!(
            "claimed frame length {len} outside (0, {MAX_FRAME}]"
        ));
    }
    let mut payload = vec![0u8; len as usize];
    if let Err(m) = read_exactly(stream, &mut payload) {
        return m;
    }
    FrameRead::Frame(payload)
}

/// Write one `HCKW` frame (magic + LE length + payload) and flush it.
pub(crate) fn write_frame(stream: &mut impl std::io::Write, payload: &[u8]) -> Result<()> {
    stream.write_all(WIRE_MAGIC)?;
    stream.write_all(&(payload.len() as u64).to_le_bytes())?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Payload codecs (persist-primitive encodings over in-memory buffers)
// ---------------------------------------------------------------------------

fn ru8(inp: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    inp.read_exact(&mut b)?;
    Ok(b[0])
}

fn wstr(out: &mut impl std::io::Write, s: &str) -> Result<()> {
    wu64(out, s.len() as u64)?;
    out.write_all(s.as_bytes())?;
    Ok(())
}

fn rstr(inp: &mut impl Read) -> Result<String> {
    let n = ru64(inp)? as usize;
    if n > (1 << 20) {
        return Err(Error::data("wire string length exceeds the 1 MiB cap"));
    }
    let mut buf = vec![0u8; n];
    inp.read_exact(&mut buf)?;
    String::from_utf8(buf).map_err(|_| Error::data("wire string is not UTF-8"))
}

fn encode_predict(shard: usize, q: &Mat, want: Want) -> Result<Vec<u8>> {
    let mut p = vec![CMD_PREDICT, want.mean as u8, want.variance as u8, want.leaf_route as u8];
    wu64(&mut p, shard as u64)?;
    write_mat(&mut p, q)?;
    Ok(p)
}

fn decode_predict(mut cur: &[u8]) -> Result<(usize, Want, Mat)> {
    let mut flags = [0u8; 3];
    cur.read_exact(&mut flags)?;
    let want = Want { mean: flags[0] != 0, variance: flags[1] != 0, leaf_route: flags[2] != 0 };
    let shard = ru64(&mut cur)? as usize;
    let q = read_mat(&mut cur)?;
    Ok((shard, want, q))
}

fn encode_block(b: &ShardBlock) -> Result<Vec<u8>> {
    let mut p = vec![REPLY_BLOCK];
    write_mat(&mut p, &b.mean)?;
    match &b.variance {
        Some(v) => {
            p.push(1);
            write_f64s(&mut p, v)?;
        }
        None => p.push(0),
    }
    match &b.routes {
        Some(rs) => {
            p.push(1);
            wu64(&mut p, rs.len() as u64)?;
            for r in rs {
                match r.shard {
                    Some(s) => {
                        p.push(1);
                        wu64(&mut p, s as u64)?;
                    }
                    None => {
                        p.push(0);
                        wu64(&mut p, 0)?;
                    }
                }
                wu64(&mut p, r.rows_lo as u64)?;
                wu64(&mut p, r.rows_hi as u64)?;
            }
        }
        None => p.push(0),
    }
    Ok(p)
}

fn decode_block(mut cur: &[u8]) -> Result<ShardBlock> {
    let mean = read_mat(&mut cur)?;
    let variance = match ru8(&mut cur)? {
        0 => None,
        _ => Some(crate::hkernel::persist::read_f64s(&mut cur)?),
    };
    let routes = match ru8(&mut cur)? {
        0 => None,
        _ => {
            let n = ru64(&mut cur)? as usize;
            if n > (1 << 24) {
                return Err(Error::data("route count exceeds the wire cap"));
            }
            let mut rs = Vec::with_capacity(n);
            for _ in 0..n {
                let has_shard = ru8(&mut cur)? != 0;
                let sid = ru64(&mut cur)? as usize;
                let rows_lo = ru64(&mut cur)? as usize;
                let rows_hi = ru64(&mut cur)? as usize;
                rs.push(LeafRoute {
                    shard: if has_shard { Some(sid) } else { None },
                    rows_lo,
                    rows_hi,
                });
            }
            Some(rs)
        }
    };
    Ok(ShardBlock { mean, variance, routes })
}

fn encode_err(e: &PredictError) -> Result<Vec<u8>> {
    let (kind, shard, worker, message) = match e {
        PredictError::BadRequest(m) => (1u8, 0u64, "", m.as_str()),
        PredictError::Unsupported(m) => (2, 0, "", m.as_str()),
        PredictError::Shard { shard, message } => (3, *shard as u64, "", message.as_str()),
        PredictError::Transport { worker, message } => {
            (4, 0, worker.as_str(), message.as_str())
        }
        PredictError::Internal(m) => (5, 0, "", m.as_str()),
        PredictError::Draining { worker } => {
            (6, 0, worker.as_str(), "worker is draining (not accepting new batches)")
        }
    };
    let mut p = vec![REPLY_ERR, kind];
    wu64(&mut p, shard)?;
    wstr(&mut p, worker)?;
    wstr(&mut p, message)?;
    Ok(p)
}

fn decode_err(mut cur: &[u8]) -> PredictError {
    fn inner(cur: &mut &[u8]) -> Result<PredictError> {
        let kind = ru8(cur)?;
        let shard = ru64(cur)? as usize;
        let worker = rstr(cur)?;
        let message = rstr(cur)?;
        Ok(match kind {
            1 => PredictError::BadRequest(message),
            2 => PredictError::Unsupported(message),
            3 => PredictError::Shard { shard, message },
            4 => PredictError::Transport { worker, message },
            5 => PredictError::Internal(message),
            6 => PredictError::Draining { worker },
            other => {
                PredictError::Internal(format!("unknown remote error kind {other}: {message}"))
            }
        })
    }
    match inner(&mut cur) {
        Ok(e) => e,
        Err(e) => PredictError::Internal(format!("undecodable remote error frame: {e}")),
    }
}

fn encode_stats(snaps: &[ShardSnapshot]) -> Result<Vec<u8>> {
    let mut p = vec![REPLY_STATS];
    wu64(&mut p, snaps.len() as u64)?;
    for s in snaps {
        wu64(&mut p, s.shard as u64)?;
        wu64(&mut p, s.rows_lo as u64)?;
        wu64(&mut p, s.rows_hi as u64)?;
        wu64(&mut p, s.queue_depth as u64)?;
        wu64(&mut p, s.batches)?;
        wu64(&mut p, s.requests)?;
        wf64(&mut p, s.mean_batch_size)?;
        wf64(&mut p, s.ns_per_query)?;
        wf64(&mut p, s.queue_wait_ns)?;
        wf64(&mut p, s.busy_frac)?;
        wu64(&mut p, s.dropped)?;
    }
    Ok(p)
}

fn decode_stats(mut cur: &[u8]) -> Result<Vec<ShardSnapshot>> {
    let n = ru64(&mut cur)? as usize;
    if n > (1 << 20) {
        return Err(Error::data("stats shard count exceeds the wire cap"));
    }
    let mut snaps = Vec::with_capacity(n);
    for _ in 0..n {
        snaps.push(ShardSnapshot {
            shard: ru64(&mut cur)? as usize,
            rows_lo: ru64(&mut cur)? as usize,
            rows_hi: ru64(&mut cur)? as usize,
            queue_depth: ru64(&mut cur)? as usize,
            batches: ru64(&mut cur)?,
            requests: ru64(&mut cur)?,
            mean_batch_size: rf64(&mut cur)?,
            ns_per_query: rf64(&mut cur)?,
            queue_wait_ns: rf64(&mut cur)?,
            busy_frac: rf64(&mut cur)?,
            dropped: ru64(&mut cur)?,
        });
    }
    Ok(snaps)
}

/// What a worker reports to `hello`: enough for a router to build its
/// replica map and negotiate capabilities without any side channel.
#[derive(Debug, Clone)]
pub struct RemoteHello {
    /// Feature dimension the served shards expect.
    pub dim: usize,
    /// Output columns per prediction.
    pub outputs: usize,
    /// Whether this worker can serve the posterior-variance column.
    pub variance: bool,
    /// Served shards as `(global shard id, rows_lo, rows_hi)`.
    pub shards: Vec<(usize, usize, usize)>,
}

fn encode_hello(served: &Served) -> Result<Vec<u8>> {
    let mut p = vec![REPLY_HELLO];
    wu64(&mut p, served.dim as u64)?;
    wu64(&mut p, served.outputs as u64)?;
    p.push(served.variance as u8);
    wu64(&mut p, served.ids.len() as u64)?;
    for (k, &id) in served.ids.iter().enumerate() {
        wu64(&mut p, id as u64)?;
        wu64(&mut p, served.ranges[k].0 as u64)?;
        wu64(&mut p, served.ranges[k].1 as u64)?;
    }
    Ok(p)
}

fn decode_hello(mut cur: &[u8]) -> Result<RemoteHello> {
    let dim = ru64(&mut cur)? as usize;
    let outputs = ru64(&mut cur)? as usize;
    let variance = ru8(&mut cur)? != 0;
    let n = ru64(&mut cur)? as usize;
    if n > (1 << 20) {
        return Err(Error::data("hello shard count exceeds the wire cap"));
    }
    let mut shards = Vec::with_capacity(n);
    for _ in 0..n {
        let id = ru64(&mut cur)? as usize;
        let lo = ru64(&mut cur)? as usize;
        let hi = ru64(&mut cur)? as usize;
        shards.push((id, lo, hi));
    }
    Ok(RemoteHello { dim, outputs, variance, shards })
}

// ---------------------------------------------------------------------------
// Worker endpoint
// ---------------------------------------------------------------------------

/// Everything a connection handler needs, shared across connections.
struct Served {
    workers: Vec<ShardWorker>,
    /// Global shard id per worker (positional).
    ids: Vec<usize>,
    /// Global row range per worker (positional).
    ranges: Vec<(usize, usize)>,
    dim: usize,
    outputs: usize,
    variance: bool,
    /// This worker's bound address — names the worker in typed
    /// [`PredictError::Draining`] replies and fault-rule selectors.
    addr: String,
    /// Set by the `drain` wire command: predicts are refused with a
    /// typed Draining error while stats/hello keep answering, so the
    /// router can watch the outstanding count reach zero.
    draining: AtomicBool,
}

/// A running remote shard worker: a TCP accept loop over one
/// [`ShardWorker`] queue per served shard. Dropping (or
/// [`RemoteWorker::shutdown`]) stops the accept loop, closes the
/// listener, and joins the per-shard workers.
pub struct RemoteWorker {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl RemoteWorker {
    /// Bind `addr:port` (port 0 picks an ephemeral port — read it back
    /// with [`RemoteWorker::addr`]) and serve the given shards. Pass the
    /// shared [`LazyVariance`] state to serve the variance column; bare
    /// shard directories have none, so the CLI worker serves
    /// mean + routes.
    pub fn serve(
        bind: &str,
        shards: Vec<Shard>,
        variance: Option<Arc<LazyVariance>>,
    ) -> Result<RemoteWorker> {
        let listener = TcpListener::bind(bind)
            .map_err(|e| Error::config(format!("shard-worker: cannot bind {bind}: {e}")))?;
        Self::serve_listener(listener, shards, variance)
    }

    /// Serve on an already-bound listener.
    pub fn serve_listener(
        listener: TcpListener,
        shards: Vec<Shard>,
        variance: Option<Arc<LazyVariance>>,
    ) -> Result<RemoteWorker> {
        if shards.is_empty() {
            return Err(Error::config("shard-worker: no shards to serve"));
        }
        let addr = listener.local_addr()?;
        // Non-blocking accept so the loop can poll the stop flag.
        listener.set_nonblocking(true)?;
        let dim = shards[0].dim;
        let outputs = shards[0].outputs;
        for s in &shards {
            if s.dim != dim || s.outputs != outputs {
                return Err(Error::data("shard-worker: shards disagree on dim/outputs"));
            }
        }
        let ids: Vec<usize> = shards.iter().map(|s| s.id).collect();
        let ranges: Vec<(usize, usize)> = shards.iter().map(|s| s.row_range()).collect();
        let has_var = variance.is_some();
        let workers: Vec<ShardWorker> =
            shards.into_iter().map(|s| ShardWorker::spawn(s, variance.clone())).collect();
        let served = Arc::new(Served {
            workers,
            ids,
            ranges,
            dim,
            outputs,
            variance: has_var,
            addr: addr.to_string(),
            draining: AtomicBool::new(false),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let s2 = stop.clone();
        let join = std::thread::Builder::new()
            .name("hck-remote-accept".into())
            .spawn(move || accept_loop(listener, served, s2))
            .map_err(|e| {
                Error::config(format!("shard-worker: cannot spawn accept thread: {e}"))
            })?;
        Ok(RemoteWorker { addr, stop, join: Some(join) })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    /// Block until the accept loop exits (a `shutdown` wire command or a
    /// signal) — the CLI worker's main thread parks here.
    pub fn wait(mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }

    /// Stop accepting, close the listener, and join every thread.
    pub fn shutdown(self) {
        // Drop does the work; the method exists for call-site clarity.
    }

    fn halt(&mut self) {
        // ORDERING: SeqCst — one-shot shutdown flag; pairs with the
        // loads in accept_loop and the connection handlers.
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for RemoteWorker {
    fn drop(&mut self) {
        self.halt();
    }
}

fn accept_loop(listener: TcpListener, served: Arc<Served>, stop: Arc<AtomicBool>) {
    loop {
        // ORDERING: SeqCst — shutdown control plane, one load per turn;
        // pairs with the stores in RemoteWorker::halt and dispatch.
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((conn, _)) => {
                let served = served.clone();
                let stop2 = stop.clone();
                let spawned = std::thread::Builder::new()
                    .name("hck-remote-conn".into())
                    .spawn(move || handle_conn(conn, served, stop2));
                if let Err(e) = spawned {
                    // Out of threads: shed this connection, keep serving.
                    eprintln!("shard-worker: dropping connection (cannot spawn handler: {e})");
                }
            }
            Err(e) if is_timeout(&e) => std::thread::sleep(Duration::from_millis(5)),
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn handle_conn(mut conn: TcpStream, served: Arc<Served>, stop: Arc<AtomicBool>) {
    // A finite read timeout turns the blocking read into a poll, so an
    // idle connection still notices the stop flag.
    if conn.set_read_timeout(Some(Duration::from_millis(200))).is_err() {
        return;
    }
    let _ = conn.set_nodelay(true);
    loop {
        // ORDERING: SeqCst — shutdown control plane; pairs with the
        // stores in RemoteWorker::halt and dispatch.
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let payload = match read_frame(&mut conn) {
            FrameRead::Frame(p) => p,
            FrameRead::TimedOut => continue,
            FrameRead::Closed => return,
            FrameRead::Malformed(m) => {
                // Best-effort typed reject, then drop *this* connection
                // only — the stream offset is unknowable after a framing
                // violation, but the accept loop keeps serving.
                let err = PredictError::BadRequest(format!("malformed frame: {m}"));
                if let Ok(b) = encode_err(&err) {
                    let _ = write_frame(&mut conn, &b);
                }
                return;
            }
            FrameRead::Io(_) => return,
        };
        // Worker-site fault injection: the decoded frame names the op
        // (and shard, for predicts), so seeded chaos tests can target
        // exactly one behavior without timing luck.
        if let Some((op, shard)) = frame_op(&payload) {
            match fault::check(FaultSite::Worker, op, shard, &served.addr) {
                Some(FaultAction::Stall(ms)) => std::thread::sleep(Duration::from_millis(ms)),
                // Tear the connection down with no reply: the client
                // sees EOF mid-exchange, exactly like a crashed worker.
                Some(FaultAction::Drop) => return,
                // Violate the framing rules on purpose: the client's
                // read must classify this as malformed, never gather it.
                Some(FaultAction::Corrupt) => {
                    let _ = std::io::Write::write_all(&mut conn, b"XCKW\x00garbage\x00");
                    let _ = std::io::Write::flush(&mut conn);
                    return;
                }
                Some(FaultAction::Fail) => {
                    let err = injected_failure(op, shard);
                    match encode_err(&err) {
                        Ok(b) if write_frame(&mut conn, &b).is_ok() => continue,
                        _ => return,
                    }
                }
                None => {}
            }
        }
        let bytes = match dispatch(&payload, &served, &stop) {
            Ok(b) => b,
            Err(e) => match encode_err(&e) {
                Ok(b) => b,
                Err(_) => return,
            },
        };
        if write_frame(&mut conn, &bytes).is_err() {
            return;
        }
    }
}

/// Classify a decoded frame for fault-rule matching: the op name, plus
/// the target shard for predict frames (shard id sits after the tag and
/// three want flags, as a LE u64).
fn frame_op(payload: &[u8]) -> Option<(&'static str, Option<usize>)> {
    let (&tag, body) = payload.split_first()?;
    match tag {
        CMD_PREDICT => {
            let shard = if body.len() >= 11 {
                let mut le = [0u8; 8];
                le.copy_from_slice(&body[3..11]);
                Some(u64::from_le_bytes(le) as usize)
            } else {
                None
            };
            Some(("predict", shard))
        }
        CMD_STATS => Some(("stats", None)),
        CMD_HELLO => Some(("hello", None)),
        CMD_SHUTDOWN => Some(("shutdown", None)),
        CMD_DRAIN => Some(("drain", None)),
        _ => None,
    }
}

/// The typed error an injected `fail` rule produces at the worker site.
fn injected_failure(op: &str, shard: Option<usize>) -> PredictError {
    match (op, shard) {
        ("predict", Some(shard)) => {
            PredictError::Shard { shard, message: "injected fault: fail".into() }
        }
        _ => PredictError::Internal(format!("injected fault: fail ({op})")),
    }
}

/// Serve one decoded frame. Every failure is a typed [`PredictError`]
/// the caller turns into a `REPLY_ERR` frame — a request can never kill
/// the worker process.
fn dispatch(payload: &[u8], served: &Served, stop: &AtomicBool) -> InferResult<Vec<u8>> {
    let Some((&tag, body)) = payload.split_first() else {
        return Err(PredictError::BadRequest("empty frame payload".into()));
    };
    let encode_fail =
        |e: Error| PredictError::Internal(format!("wire encode failed: {e}"));
    match tag {
        CMD_PREDICT => {
            // ORDERING: SeqCst — the drain edge; pairs with the store in
            // the CMD_DRAIN arm so no predict accepted after the drain
            // ack can slip past the gate.
            if served.draining.load(Ordering::SeqCst) {
                return Err(PredictError::Draining { worker: served.addr.clone() });
            }
            let (shard, want, q) = decode_predict(body)
                .map_err(|e| PredictError::BadRequest(format!("bad predict frame: {e}")))?;
            let Some(pos) = served.ids.iter().position(|&id| id == shard) else {
                return Err(PredictError::Shard {
                    shard,
                    message: format!(
                        "this worker does not serve shard {shard} (serves {:?})",
                        served.ids
                    ),
                });
            };
            if q.rows() == 0 {
                return Err(PredictError::BadRequest("empty query batch".into()));
            }
            if q.cols() != served.dim {
                return Err(PredictError::BadRequest(format!(
                    "queries have {} columns; the served shards expect {}",
                    q.cols(),
                    served.dim
                )));
            }
            if want.variance && !served.variance {
                return Err(PredictError::Unsupported(
                    "this shard-worker has no variance state (serve from a GP model)".into(),
                ));
            }
            let rrx = served.workers[pos].submit(q, want);
            match rrx.recv() {
                Ok(Ok(block)) => encode_block(&block).map_err(encode_fail),
                Ok(Err(e)) => Err(e),
                Err(_) => Err(PredictError::Shard {
                    shard,
                    message: "worker thread is gone (dropped the sub-batch)".into(),
                }),
            }
        }
        CMD_STATS => {
            let snaps: Vec<ShardSnapshot> =
                served.workers.iter().map(|w| w.snapshot()).collect();
            encode_stats(&snaps).map_err(encode_fail)
        }
        CMD_HELLO => encode_hello(served).map_err(encode_fail),
        CMD_DRAIN => {
            // Graceful drain: refuse new predicts from now on, but keep
            // answering stats/hello so the router can watch in-flight
            // work finish. In-flight sub-batches already queued on the
            // ShardWorkers complete and are replied to normally — the
            // gate sits at frame admission, not in the workers.
            // ORDERING: SeqCst — pairs with the load in CMD_PREDICT.
            served.draining.store(true, Ordering::SeqCst);
            Ok(vec![REPLY_OK])
        }
        CMD_SHUTDOWN => {
            // ORDERING: SeqCst — one-shot shutdown edge; pairs with the
            // loads in accept_loop and handle_conn.
            stop.store(true, Ordering::SeqCst);
            Ok(vec![REPLY_OK])
        }
        other => Err(PredictError::BadRequest(format!("unknown wire command tag {other}"))),
    }
}

/// Load the requested shards of a directory and serve them until a
/// `shutdown` wire command (or a signal) — the body of
/// `hck shard-worker`. `indices: None` serves every shard in the
/// directory (a full replica).
pub fn run_worker(dir: &str, indices: Option<&[usize]>, bind: &str) -> Result<()> {
    let shards = super::load_shards_from_dir(dir, indices)?;
    let ids: Vec<usize> = shards.iter().map(|s| s.id).collect();
    let worker = RemoteWorker::serve(bind, shards, None)?;
    eprintln!(
        "shard-worker: serving shards {ids:?} from {dir} on {} \
         (HCKW wire: predict/stats/hello/shutdown)",
        worker.addr()
    );
    worker.wait();
    Ok(())
}

// ---------------------------------------------------------------------------
// Client endpoint
// ---------------------------------------------------------------------------

/// How many send attempts a predict RPC gets (1 initial + bounded
/// jittered-backoff reconnects).
const PREDICT_ATTEMPTS: u32 = 3;

/// Reconnect backoff bounds: decorrelated jitter in
/// `[BACKOFF_BASE_MS, min(3·prev, BACKOFF_CAP_MS)]`. The jitter
/// de-synchronizes a fleet of routers reconnecting after a mass worker
/// restart (no thundering herd); the cap bounds the worst-case stall a
/// single retry can add.
const BACKOFF_BASE_MS: u64 = 10;
const BACKOFF_CAP_MS: u64 = 500;

/// Circuit-breaker state machine values (an `AtomicU8`).
const BREAKER_CLOSED: u8 = 0;
const BREAKER_OPEN: u8 = 1;
const BREAKER_HALF_OPEN: u8 = 2;

/// Per-replica circuit-breaker thresholds.
#[derive(Debug, Clone, Copy)]
pub struct BreakerConfig {
    /// Consecutive predict failures that open the breaker.
    pub failures: u32,
    /// How long an open breaker fast-fails before admitting one
    /// half-open probe.
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig { failures: 5, cooldown: Duration::from_secs(1) }
    }
}

/// The router's handle to one remote worker: a lazily-(re)connected
/// stream with per-request timeouts, a per-replica circuit breaker,
/// plus the cached load signals the balancer sorts replicas by. One
/// RPC is in flight per client at a time (the stream mutex serializes
/// request/reply pairs); the router fans out across *clients*
/// concurrently.
///
/// **Breaker.** [`BreakerConfig::failures`] consecutive predict
/// failures open the breaker: predicts fast-fail with a typed
/// transport error (no connect, no retry budget burned) until
/// [`BreakerConfig::cooldown`] elapses, after which exactly one probe
/// is admitted (half-open). A successful probe closes the breaker; a
/// failed one re-opens it for another cooldown. Stats/hello/control
/// RPCs bypass the breaker — they *are* the health checks.
pub struct RemoteWorkerClient {
    addr: String,
    stream: Mutex<Option<TcpStream>>,
    timeout: Duration,
    /// Separate (shorter) deadline for the background stats poll: a
    /// hung worker must never stall balance-signal refresh for the
    /// full predict timeout.
    stats_timeout: Duration,
    breaker_cfg: BreakerConfig,
    breaker: AtomicU8,
    consec_failures: AtomicU32,
    /// When the breaker last opened (drives the half-open cooldown).
    opened_at: Mutex<Option<Instant>>,
    breaker_opens: AtomicU64,
    /// Drain requests issued to this worker (metrics).
    drains: AtomicU64,
    /// Hedged sub-batches issued *away* from this straggling worker.
    hedges: AtomicU64,
    /// Jitter source for the decorrelated reconnect backoff, seeded
    /// from the address so tests are reproducible per worker.
    backoff_rng: Mutex<Rng>,
    connected_once: AtomicBool,
    reconnects: AtomicU64,
    outstanding: AtomicUsize,
    /// Total queue depth across the worker's shards at the last stats
    /// poll (the balancer's primary remote signal).
    queue_depth: AtomicUsize,
    /// Peak per-shard busy fraction at the last stats poll, in ppm
    /// (atomically storable tie-break signal).
    busy_ppm: AtomicU64,
}

impl RemoteWorkerClient {
    /// A handle to `host:port`. Nothing connects until the first RPC.
    /// The stats poll gets the lesser of `timeout` and 250 ms; tune
    /// both with [`RemoteWorkerClient::with_config`].
    pub fn new(addr: &str, timeout: Duration) -> RemoteWorkerClient {
        Self::with_config(
            addr,
            timeout,
            timeout.min(Duration::from_millis(250)),
            BreakerConfig::default(),
        )
    }

    /// Full-control constructor: predict timeout, stats-poll timeout,
    /// and breaker thresholds.
    pub fn with_config(
        addr: &str,
        timeout: Duration,
        stats_timeout: Duration,
        breaker_cfg: BreakerConfig,
    ) -> RemoteWorkerClient {
        // FNV-1a over the address: a stable, dependency-free seed so
        // each worker's jitter stream is distinct but reproducible.
        let mut seed: u64 = 0xcbf2_9ce4_8422_2325;
        for &b in addr.as_bytes() {
            seed ^= b as u64;
            seed = seed.wrapping_mul(0x100_0000_01b3);
        }
        RemoteWorkerClient {
            addr: addr.to_string(),
            stream: Mutex::new(None),
            timeout,
            stats_timeout,
            breaker_cfg,
            breaker: AtomicU8::new(BREAKER_CLOSED),
            consec_failures: AtomicU32::new(0),
            opened_at: Mutex::new(None),
            breaker_opens: AtomicU64::new(0),
            drains: AtomicU64::new(0),
            hedges: AtomicU64::new(0),
            backoff_rng: Mutex::new(Rng::new(seed)),
            connected_once: AtomicBool::new(false),
            reconnects: AtomicU64::new(0),
            outstanding: AtomicUsize::new(0),
            queue_depth: AtomicUsize::new(0),
            busy_ppm: AtomicU64::new(0),
        }
    }

    /// The worker address this client talks to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// How many times the connection was re-established after a failure.
    pub fn reconnects(&self) -> u64 {
        // ORDERING: Relaxed — monotone statistics counter.
        self.reconnects.load(Ordering::Relaxed)
    }

    /// How many times the circuit breaker opened.
    pub fn breaker_opens(&self) -> u64 {
        // ORDERING: Relaxed — monotone statistics counter.
        self.breaker_opens.load(Ordering::Relaxed)
    }

    /// How many drain requests were issued toward this worker.
    pub fn drains(&self) -> u64 {
        // ORDERING: Relaxed — monotone statistics counter.
        self.drains.load(Ordering::Relaxed)
    }

    /// How many hedged sub-batches were re-issued away from this
    /// worker after it straggled past the hedge deadline.
    pub fn hedges(&self) -> u64 {
        // ORDERING: Relaxed — monotone statistics counter.
        self.hedges.load(Ordering::Relaxed)
    }

    /// Count a drain issued toward this worker (balancer bookkeeping).
    pub(crate) fn note_drain(&self) {
        // ORDERING: Relaxed — monotone statistics counter.
        self.drains.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a hedge fired against this straggling worker.
    pub(crate) fn note_hedge(&self) {
        // ORDERING: Relaxed — monotone statistics counter.
        self.hedges.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests currently in flight on this client (the drain monitor's
    /// signal: a draining replica retires when this reaches zero).
    pub(crate) fn outstanding(&self) -> usize {
        // ORDERING: Relaxed — load gauge; the drain monitor re-polls.
        self.outstanding.load(Ordering::Relaxed)
    }

    /// Breaker state name for metrics (`closed` / `open` / `half_open`).
    pub fn breaker_state(&self) -> &'static str {
        // ORDERING: SeqCst — breaker control plane; cheap at this rate.
        match self.breaker.load(Ordering::SeqCst) {
            BREAKER_OPEN => "open",
            BREAKER_HALF_OPEN => "half_open",
            _ => "closed",
        }
    }

    fn cooldown_elapsed(&self) -> bool {
        let g = match self.opened_at.lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        };
        match *g {
            Some(t) => t.elapsed() >= self.breaker_cfg.cooldown,
            None => true,
        }
    }

    /// Whether predicts are currently fast-failed (open breaker, still
    /// cooling down). The balancer sorts such replicas last.
    pub(crate) fn breaker_blocked(&self) -> bool {
        // ORDERING: SeqCst — breaker control plane; pairs with the
        // transitions in breaker_admit/record_success/reopen.
        self.breaker.load(Ordering::SeqCst) == BREAKER_OPEN && !self.cooldown_elapsed()
    }

    /// Gate a predict on the breaker. Closed/half-open admit; open
    /// admits exactly one probe per cooldown (the winning CAS flips
    /// OPEN → HALF_OPEN; losers keep fast-failing).
    fn breaker_admit(&self) -> bool {
        // ORDERING: SeqCst — breaker control plane; pairs with the
        // stores in record_success/record_failure/reopen.
        let state = self.breaker.load(Ordering::SeqCst);
        if state != BREAKER_OPEN {
            return true;
        }
        if !self.cooldown_elapsed() {
            return false;
        }
        // ORDERING: SeqCst — exactly one thread wins the half-open
        // probe slot; pairs with the loads above.
        self.breaker
            .compare_exchange(
                BREAKER_OPEN,
                BREAKER_HALF_OPEN,
                Ordering::SeqCst,
                Ordering::SeqCst,
            )
            .is_ok()
    }

    /// A predict round trip proved the worker alive: reset the failure
    /// streak and close the breaker (half-open probe succeeded).
    fn record_success(&self) {
        // ORDERING: Relaxed — streak counter; the state store below is
        // the synchronizing edge.
        self.consec_failures.store(0, Ordering::Relaxed);
        // ORDERING: SeqCst — breaker control plane; pairs with
        // breaker_admit's loads.
        self.breaker.store(BREAKER_CLOSED, Ordering::SeqCst);
    }

    /// A predict failed: a failed half-open probe re-opens immediately;
    /// a closed breaker opens once the streak hits the threshold.
    fn record_failure(&self) {
        // ORDERING: SeqCst — breaker control plane; pairs with the
        // transitions in breaker_admit.
        let state = self.breaker.load(Ordering::SeqCst);
        if state == BREAKER_HALF_OPEN {
            self.reopen();
            return;
        }
        // ORDERING: Relaxed — streak counter; reopen() publishes state.
        let n = self.consec_failures.fetch_add(1, Ordering::Relaxed) + 1;
        if state == BREAKER_CLOSED && n >= self.breaker_cfg.failures {
            self.reopen();
        }
    }

    fn reopen(&self) {
        {
            let mut g = match self.opened_at.lock() {
                Ok(g) => g,
                Err(poison) => poison.into_inner(),
            };
            *g = Some(Instant::now());
        }
        // ORDERING: SeqCst — breaker control plane; pairs with
        // breaker_admit. swap (not store) so concurrent failures count
        // one open, not several.
        let prev = self.breaker.swap(BREAKER_OPEN, Ordering::SeqCst);
        // ORDERING: Relaxed — streak counter reset.
        self.consec_failures.store(0, Ordering::Relaxed);
        if prev != BREAKER_OPEN {
            // ORDERING: Relaxed — monotone statistics counter.
            self.breaker_opens.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One decorrelated-jitter backoff step:
    /// `uniform(BASE, clamp(3·prev, BASE+1, CAP))` milliseconds.
    fn backoff_ms(&self, prev: u64) -> u64 {
        let hi = prev.saturating_mul(3).clamp(BACKOFF_BASE_MS + 1, BACKOFF_CAP_MS);
        let mut rng = match self.backoff_rng.lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        };
        BACKOFF_BASE_MS + rng.below((hi - BACKOFF_BASE_MS + 1) as usize) as u64
    }

    /// Balance score: locally-outstanding requests plus the remote
    /// queue depth from the last stats poll, with the peak busy
    /// fraction (ppm) as tie-break. Lower is less loaded.
    pub(crate) fn load_score(&self) -> (usize, u64) {
        // ORDERING: Relaxed — heuristic load gauges; tearing between
        // the two loads only perturbs replica choice, never correctness.
        (
            self.outstanding.load(Ordering::Relaxed)
                + self.queue_depth.load(Ordering::Relaxed),
            self.busy_ppm.load(Ordering::Relaxed),
        )
    }

    /// Mark a request in flight on this client (balance signal).
    pub(crate) fn begin_request(&self) {
        // ORDERING: Relaxed — load gauge for replica scoring only.
        self.outstanding.fetch_add(1, Ordering::Relaxed);
    }

    /// Mark a request finished on this client.
    pub(crate) fn end_request(&self) {
        // ORDERING: Relaxed — load gauge for replica scoring only.
        self.outstanding.fetch_sub(1, Ordering::Relaxed);
    }

    fn transport(&self, message: impl Into<String>) -> PredictError {
        PredictError::Transport { worker: self.addr.clone(), message: message.into() }
    }

    fn connect(&self) -> InferResult<TcpStream> {
        use std::net::ToSocketAddrs;
        let mut addrs = self
            .addr
            .to_socket_addrs()
            .map_err(|e| self.transport(format!("bad address: {e}")))?;
        let Some(sa) = addrs.next() else {
            return Err(self.transport("address resolves to nothing"));
        };
        let s = TcpStream::connect_timeout(&sa, self.timeout)
            .map_err(|e| self.transport(format!("connect failed: {e}")))?;
        s.set_read_timeout(Some(self.timeout))
            .map_err(|e| self.transport(format!("set_read_timeout: {e}")))?;
        s.set_write_timeout(Some(self.timeout))
            .map_err(|e| self.transport(format!("set_write_timeout: {e}")))?;
        let _ = s.set_nodelay(true);
        Ok(s)
    }

    /// One request/reply round trip with bounded reconnect: up to
    /// `attempts` tries, sleeping a decorrelated-jitter backoff
    /// ([`BACKOFF_BASE_MS`]..[`BACKOFF_CAP_MS`] ms) before each retry.
    /// `op`/`shard` name the RPC for fault-rule matching;
    /// `read_timeout` is the reply deadline for this RPC (predicts use
    /// the full timeout, stats polls a shorter one). Every failure mode
    /// comes back as a typed [`PredictError::Transport`] — the balancer
    /// decides whether another replica absorbs the work.
    fn rpc(
        &self,
        payload: &[u8],
        attempts: u32,
        op: &'static str,
        shard: Option<usize>,
        read_timeout: Duration,
    ) -> InferResult<Vec<u8>> {
        // One in-flight request per connection: the mutex both owns the
        // stream and serializes request/reply pairs on it.
        let mut guard = match self.stream.lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        };
        let mut last: Option<PredictError> = None;
        let mut prev_ms = BACKOFF_BASE_MS;
        for attempt in 0..attempts.max(1) {
            if attempt > 0 {
                let ms = self.backoff_ms(prev_ms);
                prev_ms = ms;
                let _sp = obs::span_with("remote.retry", "remote", || {
                    format!(
                        "{{\"worker\":\"{}\",\"attempt\":{attempt},\"backoff_ms\":{ms}}}",
                        self.addr
                    )
                });
                std::thread::sleep(Duration::from_millis(ms));
            }
            // Client-site fault injection, once per attempt: stalls
            // happen on top of the real RPC; drop/fail/corrupt replace
            // it with the corresponding transport failure.
            match fault::check(FaultSite::Client, op, shard, &self.addr) {
                Some(FaultAction::Stall(ms)) => {
                    std::thread::sleep(Duration::from_millis(ms))
                }
                Some(FaultAction::Drop) => {
                    *guard = None;
                    last = Some(self.transport("injected fault: connection dropped"));
                    continue;
                }
                Some(FaultAction::Fail) => {
                    last = Some(self.transport("injected fault: fail"));
                    continue;
                }
                Some(FaultAction::Corrupt) => {
                    *guard = None;
                    last = Some(self.transport("injected fault: corrupt reply"));
                    continue;
                }
                None => {}
            }
            if guard.is_none() {
                match self.connect() {
                    Ok(s) => {
                        // ORDERING: Relaxed — statistics counter; the
                        // stream itself is published via the mutex.
                        if self.connected_once.swap(true, Ordering::Relaxed) {
                            // ORDERING: Relaxed — statistics counter.
                            self.reconnects.fetch_add(1, Ordering::Relaxed);
                        }
                        *guard = Some(s);
                    }
                    Err(e) => {
                        last = Some(e);
                        continue;
                    }
                }
            }
            let Some(stream) = guard.as_mut() else { continue };
            // Per-RPC reply deadline: predicts wait the full timeout,
            // background stats polls a shorter one (a hung worker must
            // not stall balance-signal refresh).
            let set = stream.set_read_timeout(Some(read_timeout));
            if let Err(e) = set {
                *guard = None;
                last = Some(self.transport(format!("set_read_timeout: {e}")));
                continue;
            }
            let sent = {
                let _sp = obs::span_with("remote.send", "remote", || {
                    format!(
                        "{{\"worker\":\"{}\",\"bytes\":{}}}",
                        self.addr,
                        payload.len()
                    )
                });
                write_frame(stream, payload)
            };
            if let Err(e) = sent {
                *guard = None;
                last = Some(self.transport(format!("send failed: {e}")));
                continue;
            }
            let got = {
                let _sp = obs::span_with("remote.wait", "remote", || {
                    format!("{{\"worker\":\"{}\"}}", self.addr)
                });
                read_frame(stream)
            };
            match got {
                FrameRead::Frame(p) => return Ok(p),
                FrameRead::TimedOut | FrameRead::Io(_) => {
                    *guard = None;
                    last = Some(self.transport("reply timed out or tore mid-frame"));
                }
                FrameRead::Closed => {
                    *guard = None;
                    last = Some(self.transport("worker closed the connection"));
                }
                FrameRead::Malformed(m) => {
                    *guard = None;
                    last = Some(self.transport(format!("malformed reply frame: {m}")));
                }
            }
        }
        Err(match last {
            Some(e) => e,
            None => self.transport("no RPC attempts made"),
        })
    }

    /// Typed predict for one shard's sub-batch, gated by the circuit
    /// breaker: an open breaker fast-fails without touching the socket
    /// (the balancer routes around), and the first predict after the
    /// cooldown rides through as the half-open probe.
    pub fn predict_shard(&self, shard: usize, q: &Mat, want: Want) -> InferResult<ShardBlock> {
        if !self.breaker_admit() {
            return Err(self.transport(
                "circuit breaker open (fast-fail; worker quarantined until a \
                 half-open probe succeeds)",
            ));
        }
        let payload = match encode_predict(shard, q, want) {
            Ok(p) => p,
            Err(e) => {
                // Local encode failure says nothing about worker health.
                return Err(PredictError::Internal(format!("wire encode failed: {e}")));
            }
        };
        let reply = match self.rpc(&payload, PREDICT_ATTEMPTS, "predict", Some(shard), self.timeout)
        {
            Ok(r) => r,
            Err(e) => {
                self.record_failure();
                return Err(e);
            }
        };
        match reply.split_first() {
            Some((&REPLY_BLOCK, body)) => match decode_block(body) {
                Ok(b) => {
                    self.record_success();
                    Ok(b)
                }
                Err(e) => {
                    self.record_failure();
                    Err(self.transport(format!("bad predict reply: {e}")))
                }
            },
            Some((&REPLY_ERR, body)) => {
                // A typed error reply proves the transport and the
                // worker's frame loop alive — that is breaker-success
                // even when the evaluation itself failed (a draining or
                // overloaded worker is healthy, not broken).
                self.record_success();
                Err(decode_err(body))
            }
            _ => {
                self.record_failure();
                Err(self.transport("unexpected predict reply tag"))
            }
        }
    }

    /// Poll the worker's per-shard counters (the `stats` wire command)
    /// and refresh the cached balance signals. Single attempt — a dead
    /// worker must not stall the poller in reconnect backoff.
    pub fn stats(&self) -> InferResult<Vec<ShardSnapshot>> {
        let reply = self.rpc(&[CMD_STATS], 1, "stats", None, self.stats_timeout)?;
        match reply.split_first() {
            Some((&REPLY_STATS, body)) => {
                let snaps = decode_stats(body)
                    .map_err(|e| self.transport(format!("bad stats reply: {e}")))?;
                let depth: usize = snaps.iter().map(|s| s.queue_depth).sum();
                let busy = snaps.iter().map(|s| s.busy_frac).fold(0.0f64, f64::max);
                // ORDERING: Relaxed — heuristic balance caches; tearing
                // only perturbs replica choice, never correctness.
                self.queue_depth.store(depth, Ordering::Relaxed);
                self.busy_ppm.store((busy * 1e6) as u64, Ordering::Relaxed);
                Ok(snaps)
            }
            Some((&REPLY_ERR, body)) => Err(decode_err(body)),
            _ => Err(self.transport("unexpected stats reply tag")),
        }
    }

    /// Ask the worker what it serves (the `hello` wire command).
    pub fn hello(&self) -> InferResult<RemoteHello> {
        let reply = self.rpc(&[CMD_HELLO], 2, "hello", None, self.timeout)?;
        match reply.split_first() {
            Some((&REPLY_HELLO, body)) => decode_hello(body)
                .map_err(|e| self.transport(format!("bad hello reply: {e}"))),
            Some((&REPLY_ERR, body)) => Err(decode_err(body)),
            _ => Err(self.transport("unexpected hello reply tag")),
        }
    }

    /// Ask the worker process to stop (the `shutdown` wire command).
    pub fn shutdown_worker(&self) -> InferResult<()> {
        let reply = self.rpc(&[CMD_SHUTDOWN], 1, "shutdown", None, self.timeout)?;
        match reply.first() {
            Some(&REPLY_OK) => Ok(()),
            Some(&REPLY_ERR) => Err(decode_err(&reply[1..])),
            _ => Err(self.transport("unexpected shutdown reply tag")),
        }
    }

    /// Ask the worker to stop accepting new predicts while finishing
    /// in-flight ones (the `drain` wire command). The worker keeps
    /// answering stats/hello, so the router can watch the drain
    /// complete before retiring the replica.
    pub fn drain_worker(&self) -> InferResult<()> {
        let reply = self.rpc(&[CMD_DRAIN], 2, "drain", None, self.timeout)?;
        match reply.first() {
            Some(&REPLY_OK) => Ok(()),
            Some(&REPLY_ERR) => Err(decode_err(&reply[1..])),
            _ => Err(self.transport("unexpected drain reply tag")),
        }
    }
}
