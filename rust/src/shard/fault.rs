//! Deterministic fault injection for the remote serving tier.
//!
//! Distributed-failure behavior (circuit breakers, drain/handoff,
//! hedged requests, reconnect backoff) must be exercised by *seeded*
//! tests, not timing luck. This module is the shared switchboard: the
//! client transport ([`crate::shard::remote::RemoteWorkerClient`]) and
//! the worker's connection handler both consult [`check`] at their
//! I/O boundaries and act out whatever the installed [`FaultPlan`]
//! dictates — stall for a fixed time, drop the connection, return a
//! typed failure, or corrupt the reply framing.
//!
//! ## `HCK_FAULT` grammar
//!
//! ```text
//! HCK_FAULT = rule (";" rule)*
//! rule      = action [":" key "=" value ("," key "=" value)*]
//! action    = "stall" | "drop" | "fail" | "corrupt"
//! ```
//!
//! Selector keys (all optional — an absent key matches everything):
//!
//! | key      | meaning                                              |
//! |----------|------------------------------------------------------|
//! | `ms`     | stall duration in milliseconds (default 50)          |
//! | `site`   | `client` or `worker` — which endpoint acts           |
//! | `op`     | `predict`, `stats`, `hello`, `shutdown`, `drain`     |
//! | `shard`  | only predict frames for this global shard id         |
//! | `worker` | substring match on the worker address                |
//! | `after`  | skip the first N matching events (default 0)         |
//! | `times`  | fire at most N times (default unlimited)             |
//!
//! Example: `stall:site=client,op=predict,worker=:7981,ms=200,times=2`
//! stalls the first two predict RPCs the router sends toward any worker
//! whose address contains `:7981`, by 200 ms each, then gets out of the
//! way. Rules are evaluated in order; the first rule that matches *and*
//! is inside its `after`/`times` window fires.
//!
//! Tests install plans directly with [`install`] (no env mutation, no
//! cross-test races beyond the shared global — serialize with a lock);
//! operators use the `HCK_FAULT` environment variable, parsed once on
//! first use. Parse errors are reported to stderr and ignored — a typo
//! in a chaos drill must never take down real serving.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Which endpoint consults the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// The router-side transport, before sending a frame.
    Client,
    /// The worker's connection handler, after decoding a frame.
    Worker,
}

/// What a fired rule does at the consulting site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Sleep this many milliseconds, then proceed normally.
    Stall(u64),
    /// Tear the connection down without a reply.
    Drop,
    /// Return a typed injected failure.
    Fail,
    /// Emit bytes that violate the `HCKW` framing rules.
    Corrupt,
}

/// One parsed rule: an action plus selectors and a firing window.
#[derive(Debug)]
pub struct FaultRule {
    action: FaultAction,
    site: Option<FaultSite>,
    op: Option<String>,
    shard: Option<usize>,
    worker: Option<String>,
    after: u64,
    times: u64,
    /// How many events have matched the selectors so far (the
    /// `after`/`times` window is carved out of this count).
    matched: AtomicU64,
}

impl FaultRule {
    fn matches(&self, site: FaultSite, op: &str, shard: Option<usize>, worker: &str) -> bool {
        if let Some(s) = self.site {
            if s != site {
                return false;
            }
        }
        if let Some(o) = &self.op {
            if o != op {
                return false;
            }
        }
        if let Some(want) = self.shard {
            if shard != Some(want) {
                return false;
            }
        }
        if let Some(w) = &self.worker {
            if !worker.contains(w.as_str()) {
                return false;
            }
        }
        true
    }

    /// Count a matching event; fire iff it lands in the window.
    fn fire(&self) -> Option<FaultAction> {
        // ORDERING: Relaxed — the counter only sequences this rule's own
        // window; no other memory is published through it.
        let n = self.matched.fetch_add(1, Ordering::Relaxed);
        (n >= self.after && n < self.after.saturating_add(self.times)).then_some(self.action)
    }
}

/// An ordered set of [`FaultRule`]s, as parsed from the `HCK_FAULT`
/// grammar or built by a test.
#[derive(Debug, Default)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Parse a plan from the `HCK_FAULT` grammar (see module docs).
    pub fn parse(spec: &str) -> std::result::Result<FaultPlan, String> {
        let mut rules = Vec::new();
        for raw in spec.split(';') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            let (action_str, kv) = match raw.split_once(':') {
                Some((a, rest)) => (a.trim(), rest),
                None => (raw, ""),
            };
            let mut ms = 50u64;
            let mut site = None;
            let mut op = None;
            let mut shard = None;
            let mut worker = None;
            let mut after = 0u64;
            let mut times = u64::MAX;
            for pair in kv.split(',') {
                let pair = pair.trim();
                if pair.is_empty() {
                    continue;
                }
                let Some((k, v)) = pair.split_once('=') else {
                    return Err(format!("fault rule '{raw}': expected key=value, got '{pair}'"));
                };
                let (k, v) = (k.trim(), v.trim());
                match k {
                    "ms" => {
                        ms = v
                            .parse()
                            .map_err(|_| format!("fault rule '{raw}': bad ms '{v}'"))?
                    }
                    "site" => {
                        site = Some(match v {
                            "client" => FaultSite::Client,
                            "worker" => FaultSite::Worker,
                            other => {
                                return Err(format!(
                                    "fault rule '{raw}': site must be client|worker, got '{other}'"
                                ))
                            }
                        })
                    }
                    "op" => {
                        if !matches!(v, "predict" | "stats" | "hello" | "shutdown" | "drain") {
                            return Err(format!(
                                "fault rule '{raw}': op must be \
                                 predict|stats|hello|shutdown|drain, got '{v}'"
                            ));
                        }
                        op = Some(v.to_string());
                    }
                    "shard" => {
                        shard = Some(
                            v.parse()
                                .map_err(|_| format!("fault rule '{raw}': bad shard '{v}'"))?,
                        )
                    }
                    "worker" => worker = Some(v.to_string()),
                    "after" => {
                        after = v
                            .parse()
                            .map_err(|_| format!("fault rule '{raw}': bad after '{v}'"))?
                    }
                    "times" => {
                        times = v
                            .parse()
                            .map_err(|_| format!("fault rule '{raw}': bad times '{v}'"))?
                    }
                    other => {
                        return Err(format!("fault rule '{raw}': unknown key '{other}'"))
                    }
                }
            }
            let action = match action_str {
                "stall" => FaultAction::Stall(ms),
                "drop" => FaultAction::Drop,
                "fail" => FaultAction::Fail,
                "corrupt" => FaultAction::Corrupt,
                other => {
                    return Err(format!(
                        "fault rule '{raw}': action must be stall|drop|fail|corrupt, \
                         got '{other}'"
                    ))
                }
            };
            rules.push(FaultRule {
                action,
                site,
                op,
                shard,
                worker,
                after,
                times,
                matched: AtomicU64::new(0),
            });
        }
        Ok(FaultPlan { rules })
    }

    /// Number of parsed rules (an empty plan injects nothing).
    pub fn len(&self) -> usize {
        self.rules.len()
    }

    /// Whether the plan has no rules.
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }
}

/// The installed plan. `ARMED` is the fast path: serving traffic pays
/// one relaxed load when no plan is installed, never a mutex.
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);
static ENV_LOADED: AtomicBool = AtomicBool::new(false);
static ARMED: AtomicBool = AtomicBool::new(false);

fn plan_guard() -> std::sync::MutexGuard<'static, Option<Arc<FaultPlan>>> {
    // A panicking holder cannot corrupt an Option<Arc<_>>; recover the
    // data through the poison so fault checks never panic themselves.
    match PLAN.lock() {
        Ok(g) => g,
        Err(poison) => poison.into_inner(),
    }
}

/// Install a plan programmatically (test hook). `None` disarms
/// injection entirely. Also marks the environment as consumed, so an
/// installed plan is never overridden by a stale `HCK_FAULT` value.
pub fn install(plan: Option<FaultPlan>) {
    let mut g = plan_guard();
    let armed = plan.is_some();
    *g = plan.map(Arc::new);
    // ORDERING: SeqCst — arming must not be reordered before the plan
    // store above (the guard's release covers the plan; SeqCst keeps
    // the two flags coherent for concurrent checkers).
    ENV_LOADED.store(true, Ordering::SeqCst);
    ARMED.store(armed, Ordering::SeqCst);
}

/// Remove any installed plan (test hook).
pub fn clear() {
    install(None);
}

fn active() -> Option<Arc<FaultPlan>> {
    // ORDERING: SeqCst — pairs with the stores in `install`; the
    // once-per-process env parse below must observe them.
    if !ENV_LOADED.load(Ordering::SeqCst) {
        let mut g = plan_guard();
        // ORDERING: SeqCst — re-check under the lock so exactly one
        // thread parses the environment.
        if !ENV_LOADED.swap(true, Ordering::SeqCst) {
            if let Ok(spec) = std::env::var("HCK_FAULT") {
                match FaultPlan::parse(&spec) {
                    Ok(p) if !p.is_empty() => {
                        *g = Some(Arc::new(p));
                        // ORDERING: SeqCst — publish arming after the plan.
                        ARMED.store(true, Ordering::SeqCst);
                    }
                    Ok(_) => {}
                    Err(e) => eprintln!("HCK_FAULT ignored (parse error): {e}"),
                }
            }
        }
    }
    // ORDERING: SeqCst — the no-plan fast path; pairs with `install`.
    if !ARMED.load(Ordering::SeqCst) {
        return None;
    }
    plan_guard().clone()
}

/// Consult the installed plan at an I/O boundary. Returns the action of
/// the first rule whose selectors match and whose `after`/`times`
/// window admits this event. The caller acts it out (sleep, drop,
/// typed failure, corrupt bytes) — this function never blocks.
pub fn check(
    site: FaultSite,
    op: &str,
    shard: Option<usize>,
    worker: &str,
) -> Option<FaultAction> {
    let plan = active()?;
    for rule in &plan.rules {
        if rule.matches(site, op, shard, worker) {
            if let Some(action) = rule.fire() {
                return Some(action);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The plan is process-global; serialize tests that install one.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn parses_full_grammar() {
        let p = FaultPlan::parse(
            "stall:site=client,op=predict,worker=:7981,ms=200,times=2; \
             drop:op=stats; fail:shard=3,after=1; corrupt",
        )
        .unwrap();
        assert_eq!(p.len(), 4);
        assert_eq!(p.rules[0].action, FaultAction::Stall(200));
        assert_eq!(p.rules[0].site, Some(FaultSite::Client));
        assert_eq!(p.rules[0].times, 2);
        assert_eq!(p.rules[1].action, FaultAction::Drop);
        assert_eq!(p.rules[2].shard, Some(3));
        assert_eq!(p.rules[2].after, 1);
        assert_eq!(p.rules[3].action, FaultAction::Corrupt);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "explode",
            "stall:ms=abc",
            "fail:site=router",
            "drop:op=dance",
            "fail:shard=x",
            "stall:novalue",
            "fail:wat=1",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad} should not parse");
        }
        // Empty / whitespace specs are an empty plan, not an error.
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ").unwrap().is_empty());
    }

    #[test]
    fn selectors_gate_matching() {
        let _g = locked();
        install(Some(
            FaultPlan::parse("fail:site=worker,op=predict,shard=2,worker=:79").unwrap(),
        ));
        // All selectors line up → fires.
        assert_eq!(
            check(FaultSite::Worker, "predict", Some(2), "127.0.0.1:7981"),
            Some(FaultAction::Fail)
        );
        // Any selector off → no fire.
        assert_eq!(check(FaultSite::Client, "predict", Some(2), "127.0.0.1:7981"), None);
        assert_eq!(check(FaultSite::Worker, "stats", Some(2), "127.0.0.1:7981"), None);
        assert_eq!(check(FaultSite::Worker, "predict", Some(1), "127.0.0.1:7981"), None);
        assert_eq!(check(FaultSite::Worker, "predict", Some(2), "10.0.0.1:80"), None);
        clear();
    }

    #[test]
    fn after_times_window_is_deterministic() {
        let _g = locked();
        install(Some(FaultPlan::parse("drop:op=predict,after=1,times=2").unwrap()));
        let hit = || check(FaultSite::Client, "predict", Some(0), "w");
        assert_eq!(hit(), None); // event 0: skipped by after=1
        assert_eq!(hit(), Some(FaultAction::Drop)); // event 1
        assert_eq!(hit(), Some(FaultAction::Drop)); // event 2
        assert_eq!(hit(), None); // window exhausted
        assert_eq!(hit(), None);
        clear();
    }

    #[test]
    fn first_matching_rule_in_window_wins() {
        let _g = locked();
        install(Some(FaultPlan::parse("stall:op=predict,times=1,ms=7; fail:op=predict").unwrap()));
        assert_eq!(
            check(FaultSite::Client, "predict", None, "w"),
            Some(FaultAction::Stall(7))
        );
        // First rule exhausted → falls through to the second.
        assert_eq!(check(FaultSite::Client, "predict", None, "w"), Some(FaultAction::Fail));
        clear();
    }

    #[test]
    fn cleared_plan_injects_nothing() {
        let _g = locked();
        install(Some(FaultPlan::parse("fail").unwrap()));
        assert!(check(FaultSite::Client, "predict", None, "w").is_some());
        clear();
        assert_eq!(check(FaultSite::Client, "predict", None, "w"), None);
    }
}
