//! Sharded serving of a fitted hierarchical model.
//!
//! The Algorithm-3 out-of-sample path touches one root-to-leaf path per
//! query — O(r² log(n/r) + dr) after precomputation — so a fitted model
//! partitions naturally along the tree: cut the partition tree at depth
//! D and every subtree below the cut becomes a **shard** that can answer
//! any query routed into its domain *by itself*. This is the
//! block-partitioned direction of the Rebrova et al. / Tu et al. line of
//! work (PAPERS.md), applied to serving rather than factorization.
//!
//! - [`split`]: cut a fitted [`crate::hkernel::HPredictor`] into
//!   [`Shard`]s. Each shard owns its subtree's factors (leaf blocks,
//!   leaf weight rows, landmark Grams, `W` climbs), the precomputed
//!   Algorithm-3 `c` state of its nodes, and a **replicated copy of the
//!   top-of-tree path state** (the `c`/`W` pairs from just above the
//!   shard root to the child of the global root), so no shard ever needs
//!   another shard — or the coordinator — to finish a prediction.
//! - [`router`]: walks only the top D levels of the tree to map a query
//!   to its shard.
//! - [`worker`]: one thread + queue per shard, and a
//!   [`worker::ShardedPredictor`] that scatters a batch across the
//!   workers, gathers the per-shard results and reassembles them in
//!   request order. It implements [`crate::coordinator::Predictor`], so
//!   it drops behind the existing dynamic batcher unchanged.
//!
//! Within a shard, co-routed queries are grouped by destination leaf and
//! evaluated as gemms across the group (leaf kernel block, shared-path
//! climb), mirroring [`crate::hkernel::HPredictor::predict_batch`].
//!
//! Shards serialize independently ([`crate::hkernel::persist::save_shard`]),
//! so a worker process can load only its slice of the model.
//!
//! For serving across hosts, [`remote`] wraps one-or-more shards in a
//! TCP worker endpoint speaking the length-prefixed `HCKW` wire format,
//! and [`balance`] provides [`balance::RemoteShardedPredictor`] — the
//! same scatter/gather as [`worker::ShardedPredictor`] but fanning out
//! to replicated remote workers with telemetry-driven replica choice
//! and mid-batch failover (`hck shard-worker` / `hck serve --workers`).
//! The remote layer self-heals: replicas attach/drain/retire at runtime
//! under a supervisor loop, per-replica circuit breakers quarantine
//! flapping workers, stragglers are hedged to sibling replicas, and
//! [`fault`] injects deterministic faults (`HCK_FAULT`) so all of it is
//! testable without real outages.

pub mod balance;
pub mod fault;
pub mod remote;
pub mod router;
pub mod split;
pub mod worker;

pub use balance::{RemoteShardedPredictor, ResilienceConfig, ScalePolicy};
pub use remote::{BreakerConfig, RemoteHello, RemoteWorker, RemoteWorkerClient};
pub use router::ShardRouter;
pub use split::{boundary_nodes, depth_for_shards, split_predictor};
pub use worker::{ShardWorker, ShardedPredictor};

use crate::error::{Error, Result};
use crate::hkernel::{HPredictor, LazyVariance};
use crate::infer::{InferResult, LeafRoute, PredictError, Want};
use crate::kernels::{kernel_cross, par_kernel_cross, KernelKind};
use crate::linalg::{gemm, matmul, par_matmul, Cholesky, Mat, Trans};
use crate::partition::{follow_split, Node};

/// Cut a fitted predictor at `depth` and write a **self-contained shard
/// directory**: one `HCKR` router file (`router.hckr`), one `HCKS` file
/// per shard (`shard0000.hcks`, …), and — when the model carries
/// feature-normalization stats — a `norm.hckn` file so the sharded
/// serving path preprocesses raw queries identically. Another process
/// can serve the directory with [`load_shard_dir`] — no model, no
/// retraining (`hck shard --model m.hckm --out dir/` →
/// `hck serve --shard-dir dir/`). Returns the number of shards written.
pub fn save_shard_dir(
    pred: &HPredictor,
    depth: usize,
    dir: &str,
    normalization: Option<&[(f64, f64)]>,
) -> Result<usize> {
    std::fs::create_dir_all(dir)?;
    let dir = std::path::Path::new(dir);
    // A re-shard over an existing directory must not leave files from a
    // previous (different) cut behind — stale shardNNNN.hcks beyond the
    // new count (or a stale norm.hckn) would make the directory
    // unservable or silently wrong.
    for entry in std::fs::read_dir(dir)? {
        let p = entry?.path();
        let stale = p.extension().map(|x| x == "hcks").unwrap_or(false)
            || p.file_name().map(|f| f == "norm.hckn").unwrap_or(false);
        if stale {
            std::fs::remove_file(&p)?;
        }
    }
    let tree = &pred.factors().tree;
    let boundary = boundary_nodes(tree, depth);
    let router = ShardRouter::new(tree, &boundary);
    crate::hkernel::save_router(&router, &dir.join("router.hckr").to_string_lossy())?;
    if let Some(ranges) = normalization {
        save_norm_file(&dir.join("norm.hckn"), ranges)?;
    }
    let shards = split_predictor(pred, depth);
    for s in &shards {
        let path = dir.join(format!("shard{:04}.hcks", s.id));
        crate::hkernel::save_shard(s, &path.to_string_lossy())?;
    }
    Ok(shards.len())
}

/// Load a shard directory written by [`save_shard_dir`] into a ready
/// [`ShardedPredictor`] (router + one long-lived worker per shard, with
/// the recorded feature normalization re-attached when present).
pub fn load_shard_dir(dir: &str) -> Result<ShardedPredictor> {
    let dirp = std::path::Path::new(dir);
    let router = crate::hkernel::load_router(&dirp.join("router.hckr").to_string_lossy())?;
    let mut shard_paths: Vec<std::path::PathBuf> = std::fs::read_dir(dirp)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "hcks").unwrap_or(false))
        .collect();
    shard_paths.sort();
    let mut shards = Vec::with_capacity(shard_paths.len());
    for p in &shard_paths {
        shards.push(crate::hkernel::load_shard(&p.to_string_lossy())?);
    }
    shards.sort_by_key(|s| s.id);
    // Validate here with errors (a bad directory must not assert inside
    // `from_parts` and take the server down with a panic).
    if shards.is_empty() {
        return Err(Error::data(format!("shard directory '{dir}' holds no .hcks files")));
    }
    if shards.len() != router.shards() {
        return Err(Error::data(format!(
            "shard directory '{dir}' holds {} shards but the router expects {}",
            shards.len(),
            router.shards()
        )));
    }
    // Shards must tile [0, n) exactly: start at row 0 with no gaps
    // between consecutive shards (a shard mixed in from a different cut
    // of the same model would otherwise serve silently wrong rows).
    let mut covered = 0usize;
    for (i, s) in shards.iter().enumerate() {
        if s.id != i {
            return Err(Error::data(format!(
                "shard directory '{dir}': missing or duplicate shard id {i}"
            )));
        }
        let (lo, hi) = s.row_range();
        if lo != covered {
            return Err(Error::data(format!(
                "shard directory '{dir}': shard {i} covers rows [{lo}, {hi}) \
                 but coverage so far ends at {covered}"
            )));
        }
        if s.dim != shards[0].dim || s.outputs != shards[0].outputs {
            return Err(Error::data(format!(
                "shard directory '{dir}': shard {i} disagrees on dim/outputs"
            )));
        }
        covered = hi;
    }
    let (dim, outputs) = (shards[0].dim, shards[0].outputs);
    // The router file does not record the feature dimension; re-check
    // its splits against the shards' dim so a mismatched router fails
    // here instead of panicking mid-route.
    {
        let (nodes, shard_of, _) = router.parts();
        for (nd, of) in nodes.iter().zip(shard_of) {
            if of.is_none() {
                // load_router validated this already, but surface a
                // corrupt artifact as a typed error, not a panic.
                let split = nd.split.as_ref().ok_or_else(|| {
                    Error::data("router artifact: non-boundary node lacks a split")
                })?;
                crate::hkernel::persist::validate_split(split, nd.children.len(), Some(dim))?;
            }
        }
    }
    let norm_path = dirp.join("norm.hckn");
    let normalization = if norm_path.exists() {
        let ranges = load_norm_file(&norm_path)?;
        if ranges.len() != dim {
            return Err(Error::data(format!(
                "shard directory '{dir}': norm.hckn has {} columns but the shards expect {dim}",
                ranges.len()
            )));
        }
        Some(ranges)
    } else {
        None
    };
    Ok(ShardedPredictor::from_parts(router, shards, dim, outputs)
        .with_normalization(normalization))
}

const NORM_MAGIC: &[u8; 4] = b"HCKN";

/// Write the per-column (min, max) normalization ranges of a shard
/// directory (`norm.hckn`), over the shared persist primitives.
fn save_norm_file(path: &std::path::Path, ranges: &[(f64, f64)]) -> Result<()> {
    use crate::hkernel::persist::{wf64, wu64};
    use std::io::Write as _;
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    out.write_all(NORM_MAGIC)?;
    wu64(&mut out, ranges.len() as u64)?;
    for &(lo, hi) in ranges {
        wf64(&mut out, lo)?;
        wf64(&mut out, hi)?;
    }
    out.flush()?;
    Ok(())
}

/// Load a shard directory's router and recorded normalization
/// **without** the shards themselves — what the remote fan-out front
/// (`hck serve --shard-dir dir/ --workers …`) needs locally; the shards
/// live inside `hck shard-worker` processes.
pub fn load_router_parts(dir: &str) -> Result<(ShardRouter, Option<Vec<(f64, f64)>>)> {
    let dirp = std::path::Path::new(dir);
    let router = crate::hkernel::load_router(&dirp.join("router.hckr").to_string_lossy())?;
    let norm_path = dirp.join("norm.hckn");
    let normalization =
        if norm_path.exists() { Some(load_norm_file(&norm_path)?) } else { None };
    Ok((router, normalization))
}

/// Load selected shards of a directory written by [`save_shard_dir`]
/// (`None` = every shard — a full replica), for a worker process that
/// serves only its slice of the model. Unlike [`load_shard_dir`] the
/// result need not tile `[0, n)`; it must only be non-empty and agree
/// on dim/outputs.
pub fn load_shards_from_dir(dir: &str, indices: Option<&[usize]>) -> Result<Vec<Shard>> {
    let dirp = std::path::Path::new(dir);
    let mut shards = Vec::new();
    match indices {
        Some(idx) => {
            for &i in idx {
                let p = dirp.join(format!("shard{i:04}.hcks"));
                if !p.exists() {
                    return Err(Error::data(format!(
                        "shard directory '{dir}' has no shard index {i} ({})",
                        p.display()
                    )));
                }
                shards.push(crate::hkernel::load_shard(&p.to_string_lossy())?);
            }
        }
        None => {
            let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dirp)?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.extension().map(|x| x == "hcks").unwrap_or(false))
                .collect();
            paths.sort();
            for p in &paths {
                shards.push(crate::hkernel::load_shard(&p.to_string_lossy())?);
            }
            shards.sort_by_key(|s| s.id);
        }
    }
    if shards.is_empty() {
        return Err(Error::data(format!("shard directory '{dir}' holds no shards to serve")));
    }
    for s in &shards {
        if s.dim != shards[0].dim || s.outputs != shards[0].outputs {
            return Err(Error::data(format!(
                "shard directory '{dir}': shards disagree on dim/outputs"
            )));
        }
    }
    Ok(shards)
}

/// Read `norm.hckn` written by [`save_norm_file`].
fn load_norm_file(path: &std::path::Path) -> Result<Vec<(f64, f64)>> {
    use crate::hkernel::persist::{rf64, ru64};
    use std::io::Read as _;
    let mut inp = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    inp.read_exact(&mut magic)?;
    if &magic != NORM_MAGIC {
        return Err(Error::data("not an HCKN normalization file"));
    }
    let d = ru64(&mut inp)? as usize;
    if d > (1usize << 24) {
        return Err(Error::data("corrupt normalization file (column count)"));
    }
    let mut ranges = Vec::with_capacity(d);
    for _ in 0..d {
        ranges.push((rf64(&mut inp)?, rf64(&mut inp)?));
    }
    Ok(ranges)
}

/// Landmark state of the shard root's *global parent*, replicated into
/// the shard: the `d` recurrence of Algorithm 3 starts at the routed
/// leaf's parent, which for a single-leaf shard lies above the cut.
pub struct EntryState {
    /// Landmark coordinates X̲_p (r x d).
    pub landmarks: Mat,
    /// Σ_p = K′(X̲_p, X̲_p) (kept for persistence).
    pub sigma: Mat,
    /// Cholesky of Σ_p (derived from `sigma`; rebuilt on load).
    pub chol: Cholesky,
}

/// One step of the replicated top-of-tree climb: after a shard finishes
/// its in-subtree path, each remaining ancestor `g` contributes
/// `d ← W_gᵀ d` followed by `z += c_gᵀ d` (eqs. 18/21 continued above
/// the cut). Steps are ordered from just above the shard root up to the
/// child of the global root.
pub struct TopStep {
    /// W_g (r_g x r_{p(g)}).
    pub w: Mat,
    /// c_g (r_{p(g)} x m).
    pub c: Mat,
}

/// One shard's slice of a typed response: the columns of a
/// [`crate::infer::PredictResponse`] for a co-routed sub-batch, in the
/// sub-batch's request order. Produced by [`Shard::predict_typed`] and
/// gathered back by [`ShardedPredictor`].
pub struct ShardBlock {
    /// Mean block (sub-batch rows x outputs).
    pub mean: Mat,
    /// Posterior variance per sub-batch row, when requested.
    pub variance: Option<Vec<f64>>,
    /// Routed leaf per sub-batch row, when requested.
    pub routes: Option<Vec<LeafRoute>>,
}

/// A self-contained subtree shard of a fitted hierarchical model.
///
/// Node ids are **local** (the shard root is node 0); `Node::lo`/`hi`
/// keep their **global** tree-order positions so shard leaves remain
/// identifiable against the unsharded tree (and weight blocks stay
/// addressable during the split). All factor storage is owned — a worker
/// holding a `Shard` needs nothing else to serve its domain.
pub struct Shard {
    /// Shard index (ascending by global row range).
    pub id: usize,
    /// Global node id of the subtree root (diagnostics / persistence).
    pub root_global: usize,
    /// Base kernel.
    pub kind: KernelKind,
    /// Feature dimension d.
    pub dim: usize,
    /// Output columns m.
    pub outputs: usize,
    /// Local subtree nodes (parent/children are local ids; lo/hi global).
    pub nodes: Vec<Node>,
    /// Per local leaf: coordinates of the leaf's training points (n_j x d).
    pub leaf_x: Vec<Option<Mat>>,
    /// Per local leaf: weight block in tree order (n_j x m).
    pub leaf_w: Vec<Option<Mat>>,
    /// Per local node: Algorithm-3 `c` matrix (r_{p} x m). `None` only at
    /// a local root that is also the global root.
    pub c: Vec<Option<Mat>>,
    /// Per local nonleaf: landmark coordinates (r x d).
    pub landmarks: Vec<Option<Mat>>,
    /// Per local nonleaf: Σ = K′(X̲, X̲).
    pub sigma: Vec<Option<Mat>>,
    /// Per local nonleaf: Cholesky of Σ (derived; rebuilt on load).
    pub sigma_chol: Vec<Option<Cholesky>>,
    /// Per local inner node that is not the global root: the W factor
    /// used when the path climbs *into* this node.
    pub wfac: Vec<Option<Mat>>,
    /// Landmark state of the shard root's global parent (`None` iff the
    /// shard root is the global root).
    pub entry: Option<EntryState>,
    /// Replicated climb steps above the shard root (empty iff the shard
    /// root is the global root or a direct child of it).
    pub top: Vec<TopStep>,
}

impl Shard {
    /// Number of training rows owned by this shard.
    pub fn len(&self) -> usize {
        self.nodes[0].hi - self.nodes[0].lo
    }

    /// Whether the shard owns no rows (never true for a well-formed cut).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Global tree-order row range `[lo, hi)` of the shard's domain.
    pub fn row_range(&self) -> (usize, usize) {
        (self.nodes[0].lo, self.nodes[0].hi)
    }

    /// Route a query from the shard root to a **local** leaf id.
    pub fn route_leaf(&self, x: &[f64]) -> usize {
        let mut id = 0usize;
        while let Some(split) = &self.nodes[id].split {
            id = follow_split(split, &self.nodes[id].children, x);
        }
        id
    }

    /// Evaluate a group of queries (rows of `q`) that all route to the
    /// same local `leaf`, as gemms across the group. Returns a
    /// (q.rows() x m) block — the shard-local mirror of
    /// [`crate::hkernel::HPredictor::predict_leaf_group`], continued
    /// through the replicated [`TopStep`] climb above the cut.
    pub fn predict_leaf_group(&self, leaf: usize, q: &Mat) -> Mat {
        let m = self.outputs;
        let g = q.rows();
        let nd = &self.nodes[leaf];

        // Leaf term: Z = W_leafᵀ K(X_leaf, Q)  (m x g). The parallel
        // kernel/gemm entries split a large co-routed group across the
        // worker pool (shard workers are plain threads, so the pool is
        // available to them) and fall back to the packed sequential core
        // for small groups — bitwise identical either way, so sharded
        // means stay exactly equal to the in-process path.
        // hck-lint: allow(serving-no-panic): leaf factors for every leaf
        // this shard owns are materialized by Shard::from_factors before
        // the worker accepts jobs; a violated invariant panics into the
        // worker's catch_unwind and surfaces as a typed Shard error.
        let x_leaf = self.leaf_x[leaf].as_ref().unwrap();
        let kq = par_kernel_cross(self.kind, x_leaf, q);
        // hck-lint: allow(serving-no-panic): same construction invariant
        // and catch_unwind containment as leaf_x above.
        let w_leaf = self.leaf_w[leaf].as_ref().unwrap();
        let mut z = par_matmul(w_leaf, Trans::Yes, &kq, Trans::No);

        // Local path root → leaf via parent pointers.
        let mut path = vec![leaf];
        let mut cur = leaf;
        while let Some(p) = self.nodes[cur].parent {
            path.push(p);
            cur = p;
        }
        path.reverse();

        // d initialization at the routed leaf's parent: in-shard when the
        // leaf is below the shard root, else the replicated entry state.
        let init = if path.len() > 1 {
            // hck-lint: allow(serving-no-panic): path.len() > 1 means the
            // leaf sits strictly below the shard root, so its parent and
            // that parent's factors exist by construction; a violation
            // panics into the worker's catch_unwind (typed Shard error).
            let p = self.nodes[leaf].parent.unwrap();
            // hck-lint: allow(serving-no-panic): same invariant — interior
            // nodes of this shard carry landmarks and sigma_chol.
            Some((self.landmarks[p].as_ref().unwrap(), self.sigma_chol[p].as_ref().unwrap()))
        } else {
            self.entry.as_ref().map(|e| (&e.landmarks, &e.chol))
        };
        let Some((lm, chol)) = init else {
            // Single-node global tree: the leaf term is the prediction.
            return Mat::from_fn(g, m, |i, j| z[(j, i)]);
        };
        let kp = kernel_cross(self.kind, lm, q);
        let mut d = chol.solve_mat(&kp);

        // Climb the in-shard path bottom-up (includes the shard root's
        // own c, which lives in its global parent's landmark space).
        for idx in (0..path.len()).rev() {
            let mnode = path[idx];
            let Some(cm) = &self.c[mnode] else {
                // Local root == global root: nothing above.
                return Mat::from_fn(g, m, |i, j| z[(j, i)]);
            };
            gemm(1.0, cm, Trans::Yes, &d, Trans::No, 1.0, &mut z);
            if idx >= 1 {
                if let Some(w) = &self.wfac[path[idx - 1]] {
                    d = matmul(w, Trans::Yes, &d, Trans::No);
                }
            }
        }
        // Replicated climb above the cut.
        for step in &self.top {
            d = matmul(&step.w, Trans::Yes, &d, Trans::No);
            gemm(1.0, &step.c, Trans::Yes, &d, Trans::No, 1.0, &mut z);
        }
        Mat::from_fn(g, m, |i, j| z[(j, i)])
    }

    /// Predict a batch of queries already routed to this shard, grouping
    /// co-routed queries by destination leaf. Results in request order.
    pub fn predict_batch(&self, q: &Mat) -> Mat {
        crate::hkernel::oos::grouped_eval(
            q,
            self.outputs,
            |x| self.route_leaf(x),
            |leaf, sub| self.predict_leaf_group(leaf, sub),
        )
    }

    /// Serve one typed sub-batch: the mean via the leaf-grouped gemm
    /// path, plus the variance and route columns when requested — the
    /// worker-side unit of the scatter/gather in
    /// [`crate::shard::ShardedPredictor`].
    ///
    /// `variance` is the *global* lazily-built state (shared by every
    /// worker through an `Arc`, factored on the first variance request):
    /// the posterior variance needs the full kernel column over all n
    /// training points, so it cannot be computed from one shard's slice.
    /// Shards loaded from a bare shard directory have none and reject
    /// variance requests with a typed error.
    pub fn predict_typed(
        &self,
        q: &Mat,
        want: Want,
        variance: Option<&LazyVariance>,
    ) -> InferResult<ShardBlock> {
        let mean = self.predict_batch(q);
        let variance = if want.variance {
            let hv = variance
                .ok_or_else(|| {
                    PredictError::Unsupported(
                        "variance unavailable: shards were loaded without the model's \
                         factors (serve from the HCKM artifact instead)"
                            .into(),
                    )
                })?
                .get()
                .map_err(PredictError::Internal)?;
            Some(hv.variance_batch(q))
        } else {
            None
        };
        let routes = if want.leaf_route {
            Some(
                (0..q.rows())
                    .map(|i| {
                        let leaf = self.route_leaf(q.row(i));
                        let nd = &self.nodes[leaf];
                        LeafRoute { shard: Some(self.id), rows_lo: nd.lo, rows_hi: nd.hi }
                    })
                    .collect(),
            )
        } else {
            None
        };
        Ok(ShardBlock { mean, variance, routes })
    }

    /// Memory footprint of the shard's owned factors, in f64 words
    /// (replicated entry/top state included). Does not count the shared
    /// [`LazyVariance`] state, which is one `Arc` across all workers.
    pub fn memory_words(&self) -> usize {
        let mat = |m: &Option<Mat>| m.as_ref().map_or(0, |m| m.rows() * m.cols());
        let mut words = 0;
        for i in 0..self.nodes.len() {
            words += mat(&self.leaf_x[i])
                + mat(&self.leaf_w[i])
                + mat(&self.c[i])
                + mat(&self.landmarks[i])
                + mat(&self.sigma[i])
                + mat(&self.wfac[i]);
        }
        if let Some(e) = &self.entry {
            words += e.landmarks.rows() * e.landmarks.cols() + e.sigma.rows() * e.sigma.cols();
        }
        for s in &self.top {
            words += s.w.rows() * s.w.cols() + s.c.rows() * s.c.cols();
        }
        words
    }
}
