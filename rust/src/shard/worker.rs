//! Shard worker threads and the multi-replica scatter/gather predictor.
//!
//! Workers speak the typed protocol: each job carries a sub-batch plus
//! the request's [`Want`] flags, and replies with a typed
//! `Result<ShardBlock, PredictError>`. A panic inside a worker is caught
//! and surfaced as [`PredictError::Shard`] — the worker thread survives
//! and keeps draining its queue ("bad sub-batch ≠ dead worker").
//!
//! Worker threads evaluate their leaf-grouped gemms through the packed
//! BLAS-3 core ([`crate::linalg::blas`]); a large co-routed group may
//! additionally fan its kernel block and weight product out over the
//! shared worker pool (`par_kernel_cross`/`par_matmul` in
//! [`crate::shard::Shard::predict_leaf_group`]), which is safe because
//! shard workers are ordinary threads, not pool workers — and bitwise
//! neutral, so sharded means still match the in-process path exactly.

use super::router::ShardRouter;
use super::split::{boundary_nodes, split_predictor};
use super::{Shard, ShardBlock};
use crate::coordinator::metrics::ShardSnapshot;
use crate::coordinator::Predictor;
use crate::hkernel::{HPredictor, LazyVariance};
use crate::infer::{
    Capabilities, InferResult, PredictError, PredictRequest, PredictResponse, Want,
};
use crate::linalg::Mat;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;
use std::time::Instant;

/// Per-shard serving counters, updated by the worker thread and read by
/// [`ShardedPredictor::shard_metrics`].
struct WorkerMetrics {
    /// Jobs submitted but not yet finished (instantaneous queue depth).
    queued: AtomicUsize,
    /// Sub-batches served.
    batches: AtomicU64,
    /// Queries served.
    requests: AtomicU64,
    /// Wall time spent inside `Shard::predict_typed`, in ns.
    busy_ns: AtomicU64,
    /// Total time sub-batches sat queued before the worker picked them
    /// up, in ns (snapshot reports the per-sub-batch mean).
    queue_wait_ns: AtomicU64,
    /// Queries that came back as errors instead of predictions
    /// (worker panics, unsupported columns, dead reply channels).
    dropped: AtomicU64,
    /// When the worker was spawned — the denominator of `busy_frac`.
    started: Instant,
}

impl WorkerMetrics {
    fn new() -> WorkerMetrics {
        WorkerMetrics {
            queued: AtomicUsize::new(0),
            batches: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            busy_ns: AtomicU64::new(0),
            queue_wait_ns: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            started: Instant::now(),
        }
    }
}

/// One sub-batch of co-routed queries plus its reply channel.
struct Job {
    q: Mat,
    want: Want,
    enqueued: Instant,
    resp: SyncSender<InferResult<ShardBlock>>,
}

/// A long-lived thread owning one [`Shard`] and draining its queue.
pub struct ShardWorker {
    id: usize,
    row_range: (usize, usize),
    tx: SyncSender<Job>,
    metrics: Arc<WorkerMetrics>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ShardWorker {
    /// Spawn the worker thread around a shard, optionally sharing the
    /// global lazy variance state (one `Arc` across all workers; the
    /// factorization runs on the first variance request).
    pub fn spawn(shard: Shard, variance: Option<Arc<LazyVariance>>) -> ShardWorker {
        let id = shard.id;
        let row_range = shard.row_range();
        let (tx, rx) = sync_channel::<Job>(1024);
        let metrics = Arc::new(WorkerMetrics::new());
        let m2 = metrics.clone();
        let join = std::thread::Builder::new()
            .name(format!("hck-shard-{id}"))
            .spawn(move || {
                // Channel disconnect (all senders dropped) ends the loop.
                while let Ok(job) = rx.recv() {
                    let t = Instant::now();
                    // ORDERING: Relaxed — monotone statistics counter
                    // read only by snapshots; the job and its reply are
                    // published via the channels, never via metrics.
                    m2.queue_wait_ns.fetch_add(
                        t.duration_since(job.enqueued).as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                    crate::obs::record_span_between(
                        "shard.queue_wait",
                        "shard",
                        job.enqueued,
                        t,
                        0,
                    );
                    let _sp = crate::obs::span_with("shard.eval", "shard", || {
                        format!("{{\"shard\":{id},\"rows\":{}}}", job.q.rows())
                    });
                    // A panic must not kill the worker for the rest of the
                    // service lifetime: contain it to this sub-batch. The
                    // shard is immutable (&self evaluation), so reuse after
                    // an unwind is sound; the caller sees a typed
                    // shard_failure for just this sub-batch.
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || shard.predict_typed(&job.q, job.want, variance.as_deref()),
                    ))
                    .unwrap_or_else(|_| {
                        Err(PredictError::Shard {
                            shard: id,
                            message: "worker panicked evaluating a sub-batch".into(),
                        })
                    });
                    // ORDERING: Relaxed — queue-depth gauge; pairs with
                    // the bump in submit(), same statistics rationale.
                    m2.queued.fetch_sub(1, Ordering::Relaxed);
                    match out {
                        Ok(block) => {
                            // ORDERING: Relaxed — statistics counters;
                            // the block itself travels over the channel.
                            m2.busy_ns
                                .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                            m2.batches.fetch_add(1, Ordering::Relaxed);
                            m2.requests.fetch_add(job.q.rows() as u64, Ordering::Relaxed);
                            let _ = job.resp.send(Ok(block));
                        }
                        Err(e) => {
                            // ORDERING: Relaxed — statistics counter.
                            m2.dropped.fetch_add(job.q.rows() as u64, Ordering::Relaxed);
                            let _ = job.resp.send(Err(e));
                        }
                    }
                }
            })
            // hck-lint: allow(serving-no-panic): one-time shard-pool
            // assembly before any request is accepted; a host that
            // cannot spawn worker threads cannot serve, and the
            // constructor has no error channel.
            .expect("spawn shard worker");
        ShardWorker { id, row_range, tx, metrics, join: Some(join) }
    }

    /// Enqueue a sub-batch; the typed reply arrives on the returned
    /// receiver. `pub(crate)` so the remote worker endpoint
    /// ([`crate::shard::remote`]) can feed the same per-shard queues.
    pub(crate) fn submit(
        &self,
        q: Mat,
        want: Want,
    ) -> std::sync::mpsc::Receiver<InferResult<ShardBlock>> {
        let (rtx, rrx) = sync_channel(1);
        // ORDERING: Relaxed — queue-depth gauge only; the job is
        // published by the channel send, not by this counter.
        self.metrics.queued.fetch_add(1, Ordering::Relaxed);
        if self.tx.send(Job { q, want, enqueued: Instant::now(), resp: rtx }).is_err() {
            // ORDERING: Relaxed — undo the gauge bump when the worker
            // is already gone; same rationale as above.
            self.metrics.queued.fetch_sub(1, Ordering::Relaxed);
        }
        rrx
    }

    /// Point-in-time view of this worker's counters.
    pub fn snapshot(&self) -> ShardSnapshot {
        // ORDERING: Relaxed — monotone statistics counters; the
        // snapshot tolerates tearing between counters and needs no
        // ordering with job memory (replies travel over channels).
        let batches = self.metrics.batches.load(Ordering::Relaxed);
        let requests = self.metrics.requests.load(Ordering::Relaxed);
        let busy_ns = self.metrics.busy_ns.load(Ordering::Relaxed);
        let wait_ns = self.metrics.queue_wait_ns.load(Ordering::Relaxed);
        let lifetime_ns = self.metrics.started.elapsed().as_nanos() as f64;
        ShardSnapshot {
            // ORDERING: Relaxed — same statistics rationale as above.
            shard: self.id,
            rows_lo: self.row_range.0,
            rows_hi: self.row_range.1,
            queue_depth: self.metrics.queued.load(Ordering::Relaxed),
            batches,
            requests,
            mean_batch_size: if batches > 0 { requests as f64 / batches as f64 } else { 0.0 },
            ns_per_query: if requests > 0 { busy_ns as f64 / requests as f64 } else { 0.0 },
            queue_wait_ns: if batches > 0 { wait_ns as f64 / batches as f64 } else { 0.0 },
            busy_frac: if lifetime_ns > 0.0 {
                (busy_ns as f64 / lifetime_ns).clamp(0.0, 1.0)
            } else {
                0.0
            },
            // ORDERING: Relaxed — same statistics rationale as above.
            dropped: self.metrics.dropped.load(Ordering::Relaxed),
        }
    }
}

impl Drop for ShardWorker {
    fn drop(&mut self) {
        // Replacing tx closes the worker's channel; recv() then errors
        // and the thread exits.
        drop(std::mem::replace(&mut self.tx, sync_channel(1).0));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Multi-replica serving front: a [`ShardRouter`] over the top tree
/// levels plus one [`ShardWorker`] per shard. `predict` scatters a batch
/// across the per-shard queues, the workers evaluate their sub-batches
/// concurrently (leaf-grouped gemms inside each shard, plus the shared
/// variance/route columns when requested), and the results are gathered
/// back **in request order**. A failing shard aborts the request with a
/// typed [`PredictError::Shard`] naming the shard — not with NaNs and
/// not by killing the worker. Implements [`Predictor`], so it slots
/// behind the coordinator's dynamic batcher.
pub struct ShardedPredictor {
    router: ShardRouter,
    workers: Vec<ShardWorker>,
    dim: usize,
    outputs: usize,
    /// Per-column (min, max) feature normalization applied to every
    /// incoming batch before routing, when the model that produced the
    /// shards was trained on normalized features (see
    /// [`crate::model::ModelSchema::normalization`]). `None` = identity.
    normalization: Option<Vec<(f64, f64)>>,
    /// Global lazy variance state shared by every worker; present iff
    /// the predictor was built from a model with the `variance`
    /// capability ([`ShardedPredictor::from_model`]). The O(nr²)
    /// factorization runs on the first variance request, never for
    /// mean-only traffic.
    variance: Option<Arc<LazyVariance>>,
    /// The source model's schema JSON, when built from an artifact (the
    /// TCP `schema` command reports it through the sharded front too).
    schema: Option<Json>,
}

impl ShardedPredictor {
    /// Split a fitted predictor at `depth` and spawn one worker per
    /// shard.
    pub fn new(pred: &HPredictor, depth: usize) -> ShardedPredictor {
        Self::build(pred, depth, None)
    }

    /// The one split-and-assemble recipe: cut the tree, build the
    /// router, spawn the workers (shared by [`ShardedPredictor::new`]
    /// and [`ShardedPredictor::from_model`], which differ only in the
    /// attached state).
    fn build(
        pred: &HPredictor,
        depth: usize,
        variance: Option<Arc<LazyVariance>>,
    ) -> ShardedPredictor {
        let f = pred.factors();
        let boundary = boundary_nodes(&f.tree, depth);
        let router = ShardRouter::new(&f.tree, &boundary);
        let shards = split_predictor(pred, depth);
        Self::assemble(router, shards, f.x.cols(), pred.outputs(), variance)
    }

    /// Assemble from pre-built parts (e.g. shards loaded from disk).
    ///
    /// Shards must arrive in boundary order (ascending row range, ids
    /// 0..k) — the router returns positional indices, so an out-of-order
    /// vector (say, a directory glob that sorts "shard10" before
    /// "shard2") would misroute every query while still returning
    /// finite numbers. Checked here instead.
    pub fn from_parts(
        router: ShardRouter,
        shards: Vec<Shard>,
        dim: usize,
        outputs: usize,
    ) -> ShardedPredictor {
        Self::assemble(router, shards, dim, outputs, None)
    }

    /// Number of shards (== workers).
    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    /// Record feature-normalization ranges to apply to every batch
    /// before routing (`None` clears them). The shard-directory loader
    /// and [`ShardedPredictor::from_model`] use this to carry the
    /// artifact's preprocessing stats onto the sharded serving path.
    pub fn with_normalization(mut self, ranges: Option<Vec<(f64, f64)>>) -> Self {
        self.normalization = ranges;
        self
    }

    /// Split any hierarchical-backed [`crate::model::Model`] (e.g. one
    /// loaded from an `HCKM` artifact) at `depth`, carrying the model's
    /// recorded feature normalization, its variance capability (GP
    /// models) and its schema onto the sharded path. Errors for engines
    /// without a partition tree instead of panicking.
    pub fn from_model(
        model: &dyn crate::model::Model,
        depth: usize,
    ) -> crate::error::Result<ShardedPredictor> {
        let pred = model.hierarchical_predictor().ok_or_else(|| {
            crate::error::Error::config(format!(
                "sharding requires a hierarchical-factor model; '{}' has none",
                model.schema().kind.name()
            ))
        })?;
        let mut sp = Self::build(pred, depth, model.variance_state());
        sp.normalization = model.schema().normalization.clone();
        sp.schema = Some(model.schema().to_json());
        Ok(sp)
    }

    /// Shared assembly: validate boundary order and spawn one worker per
    /// shard with the (optional) shared variance state attached.
    fn assemble(
        router: ShardRouter,
        shards: Vec<Shard>,
        dim: usize,
        outputs: usize,
        variance: Option<Arc<LazyVariance>>,
    ) -> ShardedPredictor {
        assert_eq!(router.shards(), shards.len(), "router/shard count mismatch");
        let mut covered = None;
        for (i, s) in shards.iter().enumerate() {
            assert_eq!(s.id, i, "shard {} passed at position {i}: not in boundary order", s.id);
            let (lo, hi) = s.row_range();
            if let Some(prev) = covered {
                assert_eq!(lo, prev, "shard {i} row range [{lo}, {hi}) leaves a gap");
            }
            covered = Some(hi);
        }
        let workers = shards
            .into_iter()
            .map(|s| ShardWorker::spawn(s, variance.clone()))
            .collect();
        ShardedPredictor {
            router,
            workers,
            dim,
            outputs,
            normalization: None,
            variance,
            schema: None,
        }
    }
}

impl Predictor for ShardedPredictor {
    fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse> {
        crate::infer::validate_queries(&req.queries, self.dim)?;
        Predictor::capabilities(self).check(req.want)?;
        // Apply the recorded training normalization (raw features on the
        // wire, exactly like the unsharded Arc<dyn Model> path — the
        // decision itself is the shared helper, so the paths can't
        // drift).
        let normalized =
            crate::infer::normalized_queries(req, self.normalization.as_deref());
        let q: &Mat = normalized.as_ref().unwrap_or(&req.queries);
        let t = Instant::now();
        // Scatter: request indices per destination shard.
        let mut per: Vec<Vec<usize>> = (0..self.workers.len()).map(|_| Vec::new()).collect();
        for i in 0..q.rows() {
            per[self.router.route(q.row(i))].push(i);
        }
        // Dispatch every non-empty sub-batch before blocking on replies,
        // so the workers run concurrently.
        let mut pending = Vec::new();
        for (sid, idx) in per.into_iter().enumerate() {
            if idx.is_empty() {
                continue;
            }
            let sub = q.select_rows(&idx);
            let rrx = self.workers[sid].submit(sub, req.want);
            pending.push((sid, idx, rrx));
        }
        // Gather in request order: mean always, variance/route columns
        // when requested. Any shard failure aborts the whole request
        // with a typed error naming the shard.
        let mut mean = Mat::zeros(q.rows(), self.outputs);
        let mut variance = if req.want.variance { Some(vec![0.0; q.rows()]) } else { None };
        let mut routes = if req.want.leaf_route {
            Some(vec![crate::infer::LeafRoute { shard: None, rows_lo: 0, rows_hi: 0 }; q.rows()])
        } else {
            None
        };
        for (sid, idx, rrx) in pending {
            match rrx.recv() {
                Ok(Ok(block)) => {
                    for (k, &i) in idx.iter().enumerate() {
                        mean.row_mut(i).copy_from_slice(block.mean.row(k));
                    }
                    if let (Some(out), Some(v)) = (variance.as_mut(), block.variance.as_ref()) {
                        for (k, &i) in idx.iter().enumerate() {
                            out[i] = v[k];
                        }
                    }
                    if let (Some(out), Some(r)) = (routes.as_mut(), block.routes.as_ref()) {
                        for (k, &i) in idx.iter().enumerate() {
                            out[i] = r[k];
                        }
                    }
                }
                Ok(Err(e)) => return Err(e),
                Err(_) => {
                    // The worker's queue or thread is gone entirely.
                    // ORDERING: Relaxed — statistics counter; the typed
                    // error below is the real signal to the caller.
                    self.workers[sid]
                        .metrics
                        .dropped
                        .fetch_add(idx.len() as u64, Ordering::Relaxed);
                    return Err(PredictError::Shard {
                        shard: sid,
                        message: "worker thread is gone (dropped the sub-batch)".into(),
                    });
                }
            }
        }
        let per_query_ns = t.elapsed().as_nanos() as f64 / q.rows() as f64;
        Ok(PredictResponse { mean, variance, routes, per_query_ns })
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn outputs(&self) -> usize {
        self.outputs
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { mean: true, variance: self.variance.is_some(), leaf_route: true }
    }

    fn schema_json(&self) -> Option<Json> {
        self.schema.clone()
    }

    fn shard_metrics(&self) -> Vec<ShardSnapshot> {
        self.workers.iter().map(|w| w.snapshot()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hkernel::{HConfig, HFactors};
    use crate::kernels::Gaussian;
    use crate::util::rng::Rng;

    fn fitted(n: usize, seed: u64) -> HPredictor {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 3, |_, _| rng.uniform(0.0, 1.0));
        let mut cfg = HConfig::new(Gaussian::new(0.6), 6).with_seed(seed);
        cfg.n0 = 6;
        let f = std::sync::Arc::new(HFactors::build(&x, cfg).unwrap());
        let w = Mat::from_fn(n, 2, |_, _| rng.normal());
        HPredictor::new(f, &w)
    }

    #[test]
    fn sharded_matches_unsharded_and_counts_metrics() {
        let pred = fitted(90, 11);
        let depth = 1;
        let sharded = ShardedPredictor::new(&pred, depth);
        assert!(sharded.shards() >= 2);
        let mut rng = Rng::new(7);
        let q = Mat::from_fn(33, 3, |_, _| rng.uniform(0.0, 1.0));
        let want = pred.predict_batch(&q);
        let got = sharded.predict_batch(&q);
        for i in 0..33 {
            for j in 0..2 {
                assert!(
                    (got[(i, j)] - want[(i, j)]).abs() <= 1e-10 * (1.0 + want[(i, j)].abs()),
                    "({i},{j}): {} vs {}",
                    got[(i, j)],
                    want[(i, j)]
                );
            }
        }
        let snaps = sharded.shard_metrics();
        assert_eq!(snaps.len(), sharded.shards());
        let served: u64 = snaps.iter().map(|s| s.requests).sum();
        assert_eq!(served, 33);
        assert!(snaps.iter().all(|s| s.queue_depth == 0 && s.dropped == 0));
        assert!(snaps.iter().any(|s| s.ns_per_query > 0.0));
        // Telemetry sanity: a served shard measured a queue wait, and
        // busy_frac is a fraction of the worker's lifetime.
        assert!(snaps.iter().any(|s| s.batches > 0 && s.queue_wait_ns > 0.0));
        assert!(snaps.iter().all(|s| (0.0..=1.0).contains(&s.busy_frac)));
    }

    #[test]
    fn typed_routes_report_shard_and_leaf_ranges() {
        let pred = fitted(80, 17);
        let sharded = ShardedPredictor::new(&pred, 1);
        let mut rng = Rng::new(3);
        let q = Mat::from_fn(12, 3, |_, _| rng.uniform(0.0, 1.0));
        let resp = sharded
            .predict(&PredictRequest::new(q.clone(), Want::mean_only().with_leaf_route()))
            .unwrap();
        let routes = resp.routes.unwrap();
        assert_eq!(routes.len(), 12);
        let tree = &pred.factors().tree;
        for (i, r) in routes.iter().enumerate() {
            assert!(r.shard.is_some());
            let leaf = tree.route_leaf(q.row(i));
            assert_eq!((r.rows_lo, r.rows_hi), (tree.nodes[leaf].lo, tree.nodes[leaf].hi));
        }
        // Variance is not available without the model's factors.
        let err = sharded
            .predict(&PredictRequest::new(q, Want::mean_only().with_variance()))
            .unwrap_err();
        assert_eq!(err.kind(), "unsupported");
    }

    #[test]
    fn workers_shut_down_cleanly() {
        let pred = fitted(60, 13);
        let sharded = ShardedPredictor::new(&pred, 1);
        let q = Mat::from_fn(4, 3, |i, j| (i + j) as f64 * 0.1);
        let _ = sharded.predict_batch(&q);
        drop(sharded); // must join without hanging
    }
}
