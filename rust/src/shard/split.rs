//! Cutting a fitted model into subtree shards at a tree depth.

use super::{EntryState, Shard, TopStep};
use crate::hkernel::HPredictor;
use crate::partition::{Node, PartitionTree};

/// The shard boundary at `depth`: every node at exactly `depth`, plus
/// every leaf shallower than `depth` (subtrees that bottom out early).
/// The boundary nodes' row ranges partition `[0, n)`; results are sorted
/// ascending by range start. `depth = 0` yields the single shard `[root]`.
pub fn boundary_nodes(tree: &PartitionTree, depth: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut stack = vec![0usize];
    while let Some(id) = stack.pop() {
        let nd = &tree.nodes[id];
        if nd.depth == depth || (nd.is_leaf() && nd.depth < depth) {
            out.push(id);
        } else {
            for &c in &nd.children {
                stack.push(c);
            }
        }
    }
    out.sort_by_key(|&i| tree.nodes[i].lo);
    out
}

/// Smallest depth whose boundary has at least `want` shards, capped at
/// the tree depth (beyond which every shard is a single leaf).
pub fn depth_for_shards(tree: &PartitionTree, want: usize) -> usize {
    let want = want.max(1);
    let max = tree.depth();
    for d in 0..=max {
        if boundary_nodes(tree, d).len() >= want {
            return d;
        }
    }
    max
}

/// Clone a per-node factor that a trained tree guarantees present.
fn req<T: Clone>(o: &Option<T>) -> T {
    // hck-lint: allow(serving-no-panic): shard assembly from a trained
    // factorization runs before any request is accepted; a missing
    // interior factor means the training artifact is corrupt, and
    // assembly must abort loudly rather than serve wrong answers.
    o.as_ref().unwrap().clone()
}

/// Split a fitted predictor into self-contained [`Shard`]s at `depth`.
///
/// Each shard clones its slice of the factors (subtree nodes, leaf
/// blocks + weight rows, landmark Grams, `W` climbs, Algorithm-3 `c`
/// state) and replicates the shared top-of-tree path state (entry
/// landmarks + the `c`/`W` climb steps above the cut), so the union of
/// shards answers exactly like the unsharded predictor with no shared
/// storage between workers.
pub fn split_predictor(pred: &HPredictor, depth: usize) -> Vec<Shard> {
    let f = pred.f.as_ref();
    let tree = &f.tree;
    let m = pred.outputs();
    let boundary = boundary_nodes(tree, depth);

    boundary
        .iter()
        .enumerate()
        .map(|(sid, &b)| {
            // Collect the subtree below (and including) b in preorder;
            // preorder keeps parents before children, so local parent
            // links resolve forward like the global tree's.
            let mut subtree = Vec::new();
            let mut stack = vec![b];
            while let Some(id) = stack.pop() {
                subtree.push(id);
                for &c in tree.nodes[id].children.iter().rev() {
                    stack.push(c);
                }
            }
            let mut local_of = std::collections::HashMap::new();
            for (l, &g) in subtree.iter().enumerate() {
                local_of.insert(g, l);
            }

            let nn = subtree.len();
            let mut nodes = Vec::with_capacity(nn);
            let mut leaf_x: Vec<Option<crate::linalg::Mat>> = (0..nn).map(|_| None).collect();
            let mut leaf_w: Vec<Option<crate::linalg::Mat>> = (0..nn).map(|_| None).collect();
            let mut c: Vec<Option<crate::linalg::Mat>> = (0..nn).map(|_| None).collect();
            let mut landmarks: Vec<Option<crate::linalg::Mat>> =
                (0..nn).map(|_| None).collect();
            let mut sigma: Vec<Option<crate::linalg::Mat>> = (0..nn).map(|_| None).collect();
            let mut sigma_chol: Vec<Option<crate::linalg::Cholesky>> =
                (0..nn).map(|_| None).collect();
            let mut wfac: Vec<Option<crate::linalg::Mat>> = (0..nn).map(|_| None).collect();

            for (l, &g) in subtree.iter().enumerate() {
                let nd = &tree.nodes[g];
                nodes.push(Node {
                    parent: if g == b { None } else { nd.parent.map(|p| local_of[&p]) },
                    children: nd.children.iter().map(|ch| local_of[ch]).collect(),
                    lo: nd.lo,
                    hi: nd.hi,
                    split: nd.split.clone(),
                    depth: nd.depth,
                });
                c[l] = pred.c[g].clone();
                if nd.is_leaf() {
                    leaf_x[l] = pred.leaf_x[g].clone();
                    leaf_w[l] = pred.leaf_w[g].clone();
                } else {
                    landmarks[l] = f.landmarks[g].clone();
                    sigma[l] = f.sigma[g].clone();
                    sigma_chol[l] = f.sigma_chol[g].clone();
                    if g != 0 {
                        wfac[l] = f.w[g].clone();
                    }
                }
            }

            // Replicated entry state: the shard root's global parent.
            let entry = tree.nodes[b].parent.map(|p| EntryState {
                landmarks: req(&f.landmarks[p]),
                sigma: req(&f.sigma[p]),
                chol: req(&f.sigma_chol[p]),
            });

            // Replicated climb steps: ancestors of b from just above the
            // shard root up to the child of the global root.
            let mut top = Vec::new();
            let mut anc = tree.nodes[b].parent;
            while let Some(g) = anc {
                if tree.nodes[g].parent.is_some() {
                    top.push(TopStep { w: req(&f.w[g]), c: req(&pred.c[g]) });
                }
                anc = tree.nodes[g].parent;
            }

            Shard {
                id: sid,
                root_global: b,
                kind: f.config.kind,
                dim: f.x.cols(),
                outputs: m,
                nodes,
                leaf_x,
                leaf_w,
                c,
                landmarks,
                sigma,
                sigma_chol,
                wfac,
                entry,
                top,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hkernel::{HConfig, HFactors};
    use crate::kernels::Gaussian;
    use crate::linalg::Mat;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn fitted(n: usize, r: usize, n0: usize, seed: u64) -> (Arc<HFactors>, HPredictor) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 3, |_, _| rng.uniform(0.0, 1.0));
        let mut cfg = HConfig::new(Gaussian::new(0.6), r).with_seed(seed + 5);
        cfg.n0 = n0;
        let f = Arc::new(HFactors::build(&x, cfg).unwrap());
        let w = Mat::from_fn(n, 2, |_, _| rng.normal());
        let pred = HPredictor::new(f.clone(), &w);
        (f, pred)
    }

    #[test]
    fn boundary_partitions_rows() {
        let (f, _) = fitted(96, 8, 8, 1);
        for depth in 0..=f.tree.depth() + 1 {
            let b = boundary_nodes(&f.tree, depth);
            let mut pos = 0;
            for &id in &b {
                assert_eq!(f.tree.nodes[id].lo, pos, "depth {depth}");
                pos = f.tree.nodes[id].hi;
            }
            assert_eq!(pos, 96);
        }
        // Depth 0 is the single root shard; beyond the tree depth the
        // boundary is exactly the leaf set.
        assert_eq!(boundary_nodes(&f.tree, 0), vec![0]);
        assert_eq!(
            boundary_nodes(&f.tree, f.tree.depth() + 3),
            f.tree.leaves()
        );
    }

    #[test]
    fn depth_for_shards_monotone() {
        let (f, _) = fitted(128, 8, 8, 2);
        assert_eq!(depth_for_shards(&f.tree, 1), 0);
        let d4 = depth_for_shards(&f.tree, 4);
        assert!(boundary_nodes(&f.tree, d4).len() >= 4);
        assert!(boundary_nodes(&f.tree, d4.saturating_sub(1)).len() < 4 || d4 == 0);
        // Impossible requests cap at the leaf level.
        assert_eq!(depth_for_shards(&f.tree, 10_000), f.tree.depth());
    }

    #[test]
    fn shards_are_self_contained_slices() {
        let (f, pred) = fitted(120, 6, 6, 3);
        let depth = 2.min(f.tree.depth());
        let shards = split_predictor(&pred, depth);
        let mut covered = 0;
        for s in &shards {
            let (lo, hi) = s.row_range();
            assert_eq!(lo, covered);
            covered = hi;
            assert_eq!(s.outputs, 2);
            assert_eq!(s.dim, 3);
            // Local root has no parent; every local leaf carries blocks.
            assert!(s.nodes[0].parent.is_none());
            for (l, nd) in s.nodes.iter().enumerate() {
                if nd.is_leaf() {
                    assert!(s.leaf_x[l].is_some() && s.leaf_w[l].is_some());
                    assert_eq!(s.leaf_x[l].as_ref().unwrap().rows(), nd.hi - nd.lo);
                } else {
                    assert!(s.landmarks[l].is_some() && s.sigma_chol[l].is_some());
                }
            }
            // Top replication matches the shard root's global depth.
            let gd = f.tree.nodes[s.root_global].depth;
            assert_eq!(s.top.len(), gd.saturating_sub(1));
            assert_eq!(s.entry.is_some(), gd > 0);
            assert!(s.memory_words() > 0);
        }
        assert_eq!(covered, 120);
    }
}
