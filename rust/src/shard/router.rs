//! Query → shard routing over the top levels of the partition tree.

use crate::partition::{follow_split, Node, PartitionTree};

/// Routes queries by walking only the tree levels **above** the shard
/// cut: the walk starts at the global root and stops at the first
/// boundary node, returning that shard's index. O(D · d) per query —
/// independent of the shard subtree sizes.
pub struct ShardRouter {
    /// The top nodes of the tree, re-indexed compactly: the global root
    /// is node 0 and children of retained inner nodes follow. Boundary
    /// nodes are retained without children.
    nodes: Vec<Node>,
    /// For each retained node: `Some(shard)` iff it is a boundary node.
    shard_of: Vec<Option<usize>>,
    n_shards: usize,
}

impl ShardRouter {
    /// Build a router for the given boundary (as produced by
    /// [`super::split::boundary_nodes`]; shard ids follow its order).
    pub fn new(tree: &PartitionTree, boundary: &[usize]) -> ShardRouter {
        let shard_by_global: std::collections::HashMap<usize, usize> =
            boundary.iter().enumerate().map(|(s, &g)| (g, s)).collect();
        // Keep only the nodes on or above the cut, breadth-first so
        // parents precede children in the compact index.
        let mut keep = Vec::new();
        let mut queue = std::collections::VecDeque::from([0usize]);
        while let Some(g) = queue.pop_front() {
            keep.push(g);
            if !shard_by_global.contains_key(&g) {
                for &c in &tree.nodes[g].children {
                    queue.push_back(c);
                }
            }
        }
        let local_of: std::collections::HashMap<usize, usize> =
            keep.iter().enumerate().map(|(l, &g)| (g, l)).collect();
        let mut nodes = Vec::with_capacity(keep.len());
        let mut shard_of = Vec::with_capacity(keep.len());
        for &g in &keep {
            let nd = &tree.nodes[g];
            let is_boundary = shard_by_global.contains_key(&g);
            nodes.push(Node {
                parent: nd.parent.map(|p| local_of[&p]),
                children: if is_boundary {
                    Vec::new()
                } else {
                    nd.children.iter().map(|c| local_of[c]).collect()
                },
                lo: nd.lo,
                hi: nd.hi,
                split: if is_boundary { None } else { nd.split.clone() },
                depth: nd.depth,
            });
            shard_of.push(shard_by_global.get(&g).copied());
        }
        ShardRouter { nodes, shard_of, n_shards: boundary.len() }
    }

    /// Number of shards behind this router.
    pub fn shards(&self) -> usize {
        self.n_shards
    }

    /// Internal view for persistence ([`crate::hkernel::persist::save_router`]).
    pub(crate) fn parts(&self) -> (&[Node], &[Option<usize>], usize) {
        (&self.nodes, &self.shard_of, self.n_shards)
    }

    /// Reassemble from persisted parts. The caller
    /// ([`crate::hkernel::persist::load_router`]) validates the routing
    /// invariants before handing the router out.
    pub(crate) fn from_parts(
        nodes: Vec<Node>,
        shard_of: Vec<Option<usize>>,
        n_shards: usize,
    ) -> ShardRouter {
        ShardRouter { nodes, shard_of, n_shards }
    }

    /// Route a query to its shard index.
    pub fn route(&self, x: &[f64]) -> usize {
        let mut id = 0usize;
        loop {
            if let Some(s) = self.shard_of[id] {
                return s;
            }
            let split = self.nodes[id]
                .split
                .as_ref()
                // hck-lint: allow(serving-no-panic): load_router and
                // load_shard_dir validate every non-boundary split at
                // artifact-load time, before serving starts; route() is
                // the per-query hot path and stays unwrap-free of
                // recoverable states by that validation.
                .expect("router invariant: non-boundary nodes keep their split");
            id = follow_split(split, &self.nodes[id].children, x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::partition::SplitRule;
    use crate::shard::split::boundary_nodes;
    use crate::util::rng::Rng;

    #[test]
    fn router_agrees_with_full_tree_walk() {
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(200, 4, |_, _| rng.uniform(0.0, 1.0));
        for rule in [SplitRule::RandomProjection, SplitRule::KMeans { k: 3, iters: 8 }] {
            let tree = PartitionTree::build(&x, 10, rule, &mut rng);
            for depth in 0..=tree.depth() {
                let boundary = boundary_nodes(&tree, depth);
                let router = ShardRouter::new(&tree, &boundary);
                assert_eq!(router.shards(), boundary.len());
                for _ in 0..50 {
                    let q: Vec<f64> = (0..4).map(|_| rng.uniform(-0.2, 1.2)).collect();
                    // The full walk's leaf must lie inside the routed
                    // shard's row range: the router truncates the same
                    // deterministic walk at the cut.
                    let leaf = tree.route_leaf(&q);
                    let s = router.route(&q);
                    let b = boundary[s];
                    assert!(
                        tree.nodes[leaf].lo >= tree.nodes[b].lo
                            && tree.nodes[leaf].hi <= tree.nodes[b].hi,
                        "rule {rule:?} depth {depth}: leaf {leaf} outside shard {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn single_shard_router() {
        let mut rng = Rng::new(5);
        let x = Mat::from_fn(40, 3, |_, _| rng.uniform(0.0, 1.0));
        let tree = PartitionTree::build(&x, 8, SplitRule::RandomProjection, &mut rng);
        let router = ShardRouter::new(&tree, &boundary_nodes(&tree, 0));
        assert_eq!(router.shards(), 1);
        assert_eq!(router.route(&[0.5, 0.5, 0.5]), 0);
    }
}
