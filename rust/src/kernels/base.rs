//! Base kernels: Gaussian, Laplace, inverse multiquadric, Matérn-3/2.

/// Which pairwise distance a kernel consumes. Determines whether the gemm
/// expansion applies (squared L2) or a direct tiled loop is used (L1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Squared Euclidean distance.
    SqL2,
    /// Manhattan distance.
    L1,
}

/// Identifies a kernel family + bandwidth; the serializable description
/// used by configs, the CLI and the AOT artifact manifest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KernelKind {
    /// exp(−|x−y|²/(2σ²))
    Gaussian { sigma: f64 },
    /// exp(−|x−y|₁/σ)
    Laplace { sigma: f64 },
    /// σ/√(|x−y|² + σ²)  (normalized so k(x,x)=1; the paper's σ²/√(·)
    /// differs only by the constant factor σ, which KRR absorbs)
    Imq { sigma: f64 },
    /// (1 + √3 t/σ) exp(−√3 t/σ), t = |x−y|₂ (extension; not in paper §5)
    Matern32 { sigma: f64 },
    /// Covariance tapering (paper §1.2, third approach): the Gaussian
    /// kernel multiplied by a compactly supported Wendland-φ_{ℓ,1} taper
    /// of range θ — zero beyond ‖x−y‖₂ ≥ θ. The product of two PD
    /// kernels is PD (Schur); strict PD of the Wendland factor on R^d
    /// requires ℓ ≥ ⌊d/2⌋ + 2, which [`tapered_gaussian`] enforces.
    TaperedGaussian { sigma: f64, theta: f64, ell: u32 },
}

/// Construct a tapered Gaussian valid in dimension `d`:
/// k(x,y) = exp(−t²/2σ²) · (1 − t/θ)₊^{ℓ+1} ((ℓ+1)t/θ + 1),
/// ℓ = ⌊d/2⌋ + 2 (Wendland's condition for positive definiteness).
pub fn tapered_gaussian(sigma: f64, theta: f64, d: usize) -> KernelKind {
    KernelKind::TaperedGaussian { sigma, theta, ell: (d as u32) / 2 + 2 }
}

impl KernelKind {
    /// Family name (for artifact lookup / reports).
    pub fn family(&self) -> &'static str {
        match self {
            KernelKind::Gaussian { .. } => "gaussian",
            KernelKind::Laplace { .. } => "laplace",
            KernelKind::Imq { .. } => "imq",
            KernelKind::Matern32 { .. } => "matern32",
            KernelKind::TaperedGaussian { .. } => "tapered_gaussian",
        }
    }

    /// Bandwidth parameter.
    pub fn sigma(&self) -> f64 {
        match self {
            KernelKind::Gaussian { sigma }
            | KernelKind::Laplace { sigma }
            | KernelKind::Imq { sigma }
            | KernelKind::Matern32 { sigma }
            | KernelKind::TaperedGaussian { sigma, .. } => *sigma,
        }
    }

    /// Same family, different bandwidth.
    pub fn with_sigma(&self, sigma: f64) -> KernelKind {
        match self {
            KernelKind::Gaussian { .. } => KernelKind::Gaussian { sigma },
            KernelKind::Laplace { .. } => KernelKind::Laplace { sigma },
            KernelKind::Imq { .. } => KernelKind::Imq { sigma },
            KernelKind::Matern32 { .. } => KernelKind::Matern32 { sigma },
            KernelKind::TaperedGaussian { theta, ell, .. } => {
                KernelKind::TaperedGaussian { sigma, theta: *theta, ell: *ell }
            }
        }
    }

    /// Parse "family:sigma" (e.g. "gaussian:1.5").
    pub fn parse(text: &str) -> Result<KernelKind, String> {
        let (fam, sig) = text.split_once(':').unwrap_or((text, "1.0"));
        let sigma: f64 = sig.parse().map_err(|_| format!("bad sigma '{sig}'"))?;
        if sigma <= 0.0 {
            return Err("sigma must be positive".into());
        }
        match fam {
            "gaussian" => Ok(KernelKind::Gaussian { sigma }),
            "laplace" => Ok(KernelKind::Laplace { sigma }),
            "imq" => Ok(KernelKind::Imq { sigma }),
            "matern32" => Ok(KernelKind::Matern32 { sigma }),
            _ => Err(format!("unknown kernel family '{fam}'")),
        }
    }

    /// Distance metric this kernel consumes.
    pub fn metric(&self) -> Metric {
        match self {
            KernelKind::Laplace { .. } => Metric::L1,
            _ => Metric::SqL2,
        }
    }

    /// Apply the scalar profile to a distance value (squared L2 distance
    /// for SqL2-metric kernels, L1 distance for the Laplace kernel).
    #[inline]
    pub fn profile(&self, dist: f64) -> f64 {
        match self {
            KernelKind::Gaussian { sigma } => (-dist / (2.0 * sigma * sigma)).exp(),
            KernelKind::Laplace { sigma } => (-dist / sigma).exp(),
            KernelKind::Imq { sigma } => sigma / (dist + sigma * sigma).sqrt(),
            KernelKind::Matern32 { sigma } => {
                let t = dist.max(0.0).sqrt() * 3f64.sqrt() / sigma;
                (1.0 + t) * (-t).exp()
            }
            KernelKind::TaperedGaussian { sigma, theta, ell } => {
                // dist is the squared L2 distance.
                let t = dist.max(0.0).sqrt();
                let u = t / theta;
                if u >= 1.0 {
                    return 0.0;
                }
                let gauss = (-dist / (2.0 * sigma * sigma)).exp();
                let base = 1.0 - u;
                let wendland = base.powi(*ell as i32 + 1) * ((*ell as f64 + 1.0) * u + 1.0);
                gauss * wendland
            }
        }
    }

    /// Evaluate k(x, x') on two points.
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        match self.metric() {
            Metric::SqL2 => self.profile(crate::linalg::matrix::sqdist(x, y)),
            Metric::L1 => self.profile(crate::linalg::matrix::l1dist(x, y)),
        }
    }

    /// k(x, x) — all supported kernels are normalized to 1 at zero.
    pub fn diag_value(&self) -> f64 {
        1.0
    }
}

/// Trait view of a kernel (object-safe), for code that is generic over the
/// base kernel. [`KernelKind`] implements it; custom kernels can too.
pub trait Kernel: Send + Sync {
    /// Evaluate on a pair of points.
    fn eval(&self, x: &[f64], y: &[f64]) -> f64;
    /// Value on the diagonal k(x, x).
    fn diag_value(&self) -> f64;
    /// Structured description, if this is a built-in family.
    fn kind(&self) -> Option<KernelKind> {
        None
    }
}

impl Kernel for KernelKind {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        KernelKind::eval(self, x, y)
    }
    fn diag_value(&self) -> f64 {
        1.0
    }
    fn kind(&self) -> Option<KernelKind> {
        Some(*self)
    }
}

/// Convenience constructors mirroring the paper's notation.
pub struct Gaussian;
impl Gaussian {
    /// Gaussian (squared-exponential) kernel with bandwidth σ — eq. (5).
    pub fn new(sigma: f64) -> KernelKind {
        KernelKind::Gaussian { sigma }
    }
}
/// Laplace kernel (Section 5.4).
pub struct Laplace;
impl Laplace {
    pub fn new(sigma: f64) -> KernelKind {
        KernelKind::Laplace { sigma }
    }
}
/// Inverse multiquadric kernel (Section 5.4).
pub struct Imq;
impl Imq {
    pub fn new(sigma: f64) -> KernelKind {
        KernelKind::Imq { sigma }
    }
}
/// Matérn-3/2 kernel (extension).
pub struct Matern32;
impl Matern32 {
    pub fn new(sigma: f64) -> KernelKind {
        KernelKind::Matern32 { sigma }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tapered_gaussian_properties() {
        let d = 5;
        let k = tapered_gaussian(0.8, 0.5, d);
        let x = [0.1, 0.2, 0.3, 0.4, 0.5];
        // Unit diagonal.
        assert!((k.eval(&x, &x) - 1.0).abs() < 1e-15);
        // Compact support: zero at distance >= theta.
        let mut far = x;
        far[0] += 0.6;
        assert_eq!(k.eval(&x, &far), 0.0);
        // Inside the support: equals gaussian * wendland and is below the
        // plain gaussian.
        let mut near = x;
        near[0] += 0.2;
        let v = k.eval(&x, &near);
        let g = Gaussian::new(0.8).eval(&x, &near);
        assert!(v > 0.0 && v < g, "taper must shrink: {v} vs {g}");
        // PD: kernel matrix on random points factorizes.
        let mut rng = crate::util::rng::Rng::new(3);
        let pts = crate::linalg::Mat::from_fn(40, d, |_, _| rng.uniform(0.0, 1.0));
        let km = crate::kernels::compute::kernel_block(k, &pts);
        assert!(crate::linalg::Cholesky::new_jittered(&km, 6)
            .map(|c| c.jitter < 1e-8)
            .unwrap_or(false));
        // Sparsity: with theta = 0.5 on the unit cube many entries vanish.
        let zeros = km.as_slice().iter().filter(|&&v| v == 0.0).count();
        assert!(zeros > 100, "expected sparsity, got {zeros} zeros");
    }

    #[test]
    fn tapered_gaussian_ell_rule() {
        match tapered_gaussian(1.0, 1.0, 9) {
            KernelKind::TaperedGaussian { ell, .. } => assert_eq!(ell, 6),
            _ => unreachable!(),
        }
    }

    #[test]
    fn gaussian_matches_formula() {
        let k = Gaussian::new(2.0);
        let x = [0.0, 0.0];
        let y = [3.0, 4.0];
        // |x-y|^2 = 25, sigma = 2 -> exp(-25/8)
        assert!((k.eval(&x, &y) - (-25.0f64 / 8.0).exp()).abs() < 1e-15);
        assert_eq!(k.eval(&x, &x), 1.0);
    }

    #[test]
    fn laplace_uses_l1() {
        let k = Laplace::new(2.0);
        let x = [0.0, 0.0];
        let y = [3.0, -4.0];
        assert!((k.eval(&x, &y) - (-7.0f64 / 2.0).exp()).abs() < 1e-15);
    }

    #[test]
    fn imq_normalized_at_zero() {
        let k = Imq::new(0.7);
        let x = [1.0, 2.0];
        assert!((k.eval(&x, &x) - 1.0).abs() < 1e-15);
        let y = [2.0, 2.0];
        assert!((k.eval(&x, &y) - 0.7 / (1.0f64 + 0.49).sqrt()).abs() < 1e-15);
    }

    #[test]
    fn matern_decreasing() {
        let k = Matern32::new(1.0);
        let x = [0.0];
        assert!((k.eval(&x, &x) - 1.0).abs() < 1e-12);
        let a = k.eval(&x, &[0.5]);
        let b = k.eval(&x, &[1.5]);
        assert!(a > b && b > 0.0);
    }

    #[test]
    fn parse_roundtrip() {
        let k = KernelKind::parse("gaussian:1.5").unwrap();
        assert_eq!(k, Gaussian::new(1.5));
        assert_eq!(k.family(), "gaussian");
        assert_eq!(k.sigma(), 1.5);
        assert_eq!(KernelKind::parse("laplace").unwrap(), Laplace::new(1.0));
        assert!(KernelKind::parse("foo:1").is_err());
        assert!(KernelKind::parse("gaussian:-1").is_err());
        assert!(KernelKind::parse("gaussian:x").is_err());
    }

    #[test]
    fn with_sigma_preserves_family() {
        let k = Imq::new(1.0).with_sigma(3.0);
        assert_eq!(k, Imq::new(3.0));
    }

    #[test]
    fn metric_assignment() {
        assert_eq!(Gaussian::new(1.0).metric(), Metric::SqL2);
        assert_eq!(Laplace::new(1.0).metric(), Metric::L1);
        assert_eq!(Imq::new(1.0).metric(), Metric::SqL2);
    }

    #[test]
    fn symmetry_random_points() {
        let mut rng = crate::util::rng::Rng::new(1);
        for kind in [
            Gaussian::new(0.8),
            Laplace::new(1.3),
            Imq::new(0.5),
            Matern32::new(2.0),
        ] {
            for _ in 0..20 {
                let x: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
                let y: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
                assert!((kind.eval(&x, &y) - kind.eval(&y, &x)).abs() < 1e-15);
                assert!(kind.eval(&x, &y) <= 1.0 + 1e-12);
                assert!(kind.eval(&x, &y) > 0.0);
            }
        }
    }
}
