//! Base kernel functions and block evaluation.
//!
//! The paper's construction is agnostic to the base kernel as long as it is
//! strictly positive-definite; Section 5 experiments with the Gaussian,
//! Laplace and inverse-multiquadric kernels. All three are implemented
//! here, plus Matérn-3/2 as an extension. Block evaluation K(X, Y) is the
//! compute hot spot: for `L2`-based kernels it uses the
//! |x−y|² = |x|² + |y|² − 2⟨x,y⟩ gemm expansion (the same tiling the L1
//! Pallas kernel implements on TPU), and the [`BlockEvaluator`] trait lets
//! the PJRT runtime substitute the AOT-compiled XLA path at runtime.

pub mod base;
pub mod compute;

pub use base::{tapered_gaussian, Gaussian, Imq, Kernel, KernelKind, Laplace, Matern32};
pub use compute::{
    kernel_block, kernel_cross, par_kernel_block, par_kernel_cross, BlockEvaluator,
    NativeEvaluator,
};
