//! Kernel block evaluation — the compute hot spot.
//!
//! `K(X, Y)` for point blocks X (m x d) and Y (n x d) dominates the cost
//! of instantiating the hierarchical factors, the Nyström features and the
//! exact baseline. For squared-L2 kernels it is computed through the
//! expansion |x−y|² = |x|² + |y|² − 2⟨x,y⟩ with the squared-norm terms
//! and the kernel profile **fused into the packed gemm as a per-strip
//! epilogue** ([`crate::linalg::gemm_epilogue`]): each output strip is
//! finished while still cache-hot, with no second full sweep over an
//! intermediate Gram buffer — exactly the tiling the L1 Pallas kernel
//! performs on TPU (python/compile/kernels/pairwise.py). The packed
//! core underneath runs the runtime-dispatched SIMD microkernel
//! ([`crate::linalg::simd`]), so the fused epilogue path inherits the
//! AVX2/FMA or NEON tiles with no changes here. The L1-metric Laplace
//! kernel uses a blocked direct loop.
//!
//! [`par_kernel_cross`] / [`par_kernel_block`] are the pool-parallel
//! variants for top-of-chain call sites (exact/Nyström/KPCA fits, the
//! leaf-grouped serving path): disjoint output row panels, bitwise
//! identical to the sequential evaluation for every thread count.
//!
//! [`BlockEvaluator`] abstracts the implementation so the PJRT runtime
//! (`crate::runtime`) can substitute the AOT-compiled XLA executable for
//! the same computation at runtime.

use super::base::{KernelKind, Metric};
use crate::linalg::blas::{par_gemm_epilogue, Trans};
use crate::linalg::matrix::{l1dist, Mat};
use crate::util::parallel::{default_threads, disjoint_slices, run_parallel};

/// Strategy interface for evaluating kernel blocks.
///
/// Deliberately NOT `Send + Sync`: the PJRT implementation wraps the
/// `xla` crate's client/executables, which are single-threaded (`Rc`
/// internals). Factor construction is single-threaded anyway; the fitted
/// models the coordinator shares across threads hold no evaluator.
pub trait BlockEvaluator {
    /// Fill `out` (m x n) with K(X, Y) for the given kernel.
    fn eval_block(&self, kind: KernelKind, x: &Mat, y: &Mat, out: &mut Mat);

    /// Allocate-and-return convenience.
    fn block(&self, kind: KernelKind, x: &Mat, y: &Mat) -> Mat {
        let mut out = Mat::zeros(x.rows(), y.rows());
        self.eval_block(kind, x, y, &mut out);
        out
    }

    /// Whether the parallel factor-construction path may be used with
    /// this evaluator. Must return `true` only if block evaluation is
    /// stateless and produces results identical to [`NativeEvaluator`]
    /// (the parallel path dispatches blocks through per-thread native
    /// evaluation; see `hkernel::build`). The PJRT evaluator keeps the
    /// default `false`: its client is single-threaded.
    fn parallel_safe(&self) -> bool {
        false
    }
}

/// Pure-Rust evaluator (always available; f64 precision).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeEvaluator;

impl BlockEvaluator for NativeEvaluator {
    fn eval_block(&self, kind: KernelKind, x: &Mat, y: &Mat, out: &mut Mat) {
        eval_block_threads(1, kind, x, y, out);
    }

    fn parallel_safe(&self) -> bool {
        true
    }
}

/// Shared implementation behind the sequential evaluator and the
/// `par_kernel_*` entries; `threads = 1` is the sequential path.
fn eval_block_threads(threads: usize, kind: KernelKind, x: &Mat, y: &Mat, out: &mut Mat) {
    assert_eq!(x.cols(), y.cols(), "kernel block: dim mismatch");
    assert_eq!(out.shape(), (x.rows(), y.rows()));
    match kind.metric() {
        Metric::SqL2 => sql2_block(threads, kind, x, y, out),
        Metric::L1 => l1_block(threads, kind, x, y, out),
    }
}

/// Squared-L2 kernels via the gemm expansion, with the norm terms and
/// the kernel profile fused into the packed core's per-strip epilogue —
/// every K tile leaves the gemm already finished, with no second O(mn)
/// sweep over a Gram intermediate.
fn sql2_block(threads: usize, kind: KernelKind, x: &Mat, y: &Mat, out: &mut Mat) {
    let m = x.rows();
    let n = y.rows();
    let xn: Vec<f64> = (0..m).map(|i| sq_norm(x.row(i))).collect();
    let yn: Vec<f64> = (0..n).map(|j| sq_norm(y.row(j))).collect();
    let epi = |i: usize, j0: usize, seg: &mut [f64]| {
        let xi = xn[i];
        for (off, v) in seg.iter_mut().enumerate() {
            // Guard tiny negative values from cancellation.
            let d2 = (*v + xi + yn[j0 + off]).max(0.0);
            *v = kind.profile(d2);
        }
    };
    // out = profile(-2 X Yᵀ + |x|² + |y|²), strip by strip.
    par_gemm_epilogue(threads, -2.0, x, Trans::No, y, Trans::Yes, 0.0, out, &epi);
}

/// L1-metric kernels: blocked direct evaluation, row-panel parallel when
/// `threads > 1` (each output entry is an independent pure function of
/// its point pair, so the split cannot change a bit).
fn l1_block(threads: usize, kind: KernelKind, x: &Mat, y: &Mat, out: &mut Mat) {
    let m = x.rows();
    let n = y.rows();
    if m == 0 || n == 0 {
        return;
    }
    let par_ok = m * n * x.cols().max(1) >= crate::linalg::blas::PAR_MIN_VOLUME;
    let threads = if par_ok { threads.max(1) } else { 1 };
    let chunk = m.div_ceil(threads);
    let ranges: Vec<(usize, usize)> = (0..threads)
        .map(|t| (t * chunk, ((t + 1) * chunk).min(m)))
        .filter(|&(lo, hi)| lo < hi)
        .collect();
    let elems: Vec<(usize, usize)> = ranges.iter().map(|&(lo, hi)| (lo * n, hi * n)).collect();
    let slices = disjoint_slices(out.as_mut_slice(), &elems);
    let items: Vec<((usize, usize), &mut [f64])> = ranges.into_iter().zip(slices).collect();
    run_parallel(threads, items, |((lo, hi), c)| l1_rows(kind, x, y, lo, hi, c));
}

/// The blocked direct loop over rows [lo, hi) of K(X, Y), writing into a
/// slice that covers exactly those rows.
fn l1_rows(kind: KernelKind, x: &Mat, y: &Mat, lo: usize, hi: usize, c: &mut [f64]) {
    const B: usize = 32;
    let n = y.rows();
    for i0 in (lo..hi).step_by(B) {
        for j0 in (0..n).step_by(B) {
            for i in i0..(i0 + B).min(hi) {
                let xi = x.row(i);
                let off = (i - lo) * n;
                let row = &mut c[off..off + n];
                for j in j0..(j0 + B).min(n) {
                    row[j] = kind.profile(l1dist(xi, y.row(j)));
                }
            }
        }
    }
}

#[inline]
fn sq_norm(v: &[f64]) -> f64 {
    crate::linalg::matrix::dot(v, v)
}

/// Evaluate the symmetric kernel matrix K(X, X) with exact symmetry and
/// exact unit diagonal.
pub fn kernel_block(kind: KernelKind, x: &Mat) -> Mat {
    kernel_block_threads(1, kind, x)
}

/// Evaluate the cross matrix K(X, Y) with the native evaluator.
pub fn kernel_cross(kind: KernelKind, x: &Mat, y: &Mat) -> Mat {
    NativeEvaluator.block(kind, x, y)
}

/// [`kernel_block`] evaluated across the persistent worker pool — for
/// top-of-chain call sites (exact-KRR / KPCA fits build an n×n block
/// here). Bitwise identical to the sequential evaluation.
pub fn par_kernel_block(kind: KernelKind, x: &Mat) -> Mat {
    kernel_block_threads(default_threads(), kind, x)
}

/// [`kernel_cross`] evaluated across the persistent worker pool — for
/// top-of-chain call sites (Nyström feature maps, batched leaf-group
/// evaluation). Inside an enclosing parallel region it degrades to the
/// sequential path; either way the result is bitwise identical.
pub fn par_kernel_cross(kind: KernelKind, x: &Mat, y: &Mat) -> Mat {
    let mut out = Mat::zeros(x.rows(), y.rows());
    eval_block_threads(default_threads(), kind, x, y, &mut out);
    out
}

fn kernel_block_threads(threads: usize, kind: KernelKind, x: &Mat) -> Mat {
    let mut out = Mat::zeros(x.rows(), x.rows());
    eval_block_threads(threads, kind, x, x, &mut out);
    out.symmetrize();
    for i in 0..x.rows() {
        out[(i, i)] = kind.diag_value();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::base::{Gaussian, Imq, Laplace, Matern32};
    use crate::util::rng::Rng;

    fn naive_block(kind: KernelKind, x: &Mat, y: &Mat) -> Mat {
        Mat::from_fn(x.rows(), y.rows(), |i, j| kind.eval(x.row(i), y.row(j)))
    }

    #[test]
    fn gemm_expansion_matches_naive() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(13, 6, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(9, 6, |_, _| rng.uniform(0.0, 1.0));
        for kind in [Gaussian::new(0.7), Imq::new(0.9), Matern32::new(1.1)] {
            let fast = kernel_cross(kind, &x, &y);
            let slow = naive_block(kind, &x, &y);
            let mut diff = fast.clone();
            diff.axpy(-1.0, &slow);
            assert!(diff.max_abs() < 1e-12, "{kind:?}: {}", diff.max_abs());
        }
    }

    #[test]
    fn laplace_matches_naive() {
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(40, 5, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(37, 5, |_, _| rng.uniform(0.0, 1.0));
        let kind = Laplace::new(0.6);
        let fast = kernel_cross(kind, &x, &y);
        let slow = naive_block(kind, &x, &y);
        let mut diff = fast.clone();
        diff.axpy(-1.0, &slow);
        assert!(diff.max_abs() < 1e-14);
    }

    #[test]
    fn symmetric_block_unit_diag() {
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(20, 4, |_, _| rng.uniform(0.0, 1.0));
        let k = kernel_block(Gaussian::new(0.5), &x);
        assert!(k.is_symmetric(0.0));
        for i in 0..20 {
            assert_eq!(k[(i, i)], 1.0);
        }
    }

    #[test]
    fn kernel_matrix_is_pd() {
        // Strict PD base kernels on distinct points -> Cholesky succeeds.
        let mut rng = Rng::new(4);
        let x = Mat::from_fn(30, 3, |_, _| rng.uniform(0.0, 1.0));
        for kind in [Gaussian::new(0.8), Laplace::new(0.8), Imq::new(0.8)] {
            let k = kernel_block(kind, &x);
            assert!(
                crate::linalg::Cholesky::new_jittered(&k, 8).is_ok(),
                "{kind:?} not PD"
            );
        }
    }

    #[test]
    fn empty_blocks() {
        let x = Mat::zeros(0, 3);
        let y = Mat::zeros(5, 3);
        let k = kernel_cross(Gaussian::new(1.0), &x, &y);
        assert_eq!(k.shape(), (0, 5));
    }

    /// The pool-parallel entries must match the sequential evaluator
    /// bitwise (row panels are independent), for both metrics and for
    /// blocks large enough to actually engage the pool.
    #[test]
    fn par_kernel_matches_sequential_bitwise() {
        // Blocks large enough to clear the parallel-volume gate, so the
        // pool path is genuinely exercised against the sequential one.
        let mut rng = Rng::new(9);
        let x = Mat::from_fn(601, 8, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(299, 8, |_, _| rng.uniform(0.0, 1.0));
        for kind in [Gaussian::new(0.6), Laplace::new(0.8)] {
            let seq = kernel_cross(kind, &x, &y);
            let par = par_kernel_cross(kind, &x, &y);
            assert_eq!(seq.as_slice(), par.as_slice(), "{kind:?}");
        }
        let seq = kernel_block(Gaussian::new(0.7), &x);
        let par = par_kernel_block(Gaussian::new(0.7), &x);
        assert_eq!(seq.as_slice(), par.as_slice());
    }

    #[test]
    fn cancellation_guard() {
        // Identical points at large coordinates: d2 could go slightly
        // negative without the guard; profile must return exactly 1.
        let x = Mat::from_vec(2, 2, vec![1e8, -1e8, 1e8, -1e8]);
        let k = kernel_cross(Gaussian::new(1.0), &x, &x);
        for v in k.as_slice() {
            assert!(*v <= 1.0 && *v >= 0.0);
        }
    }
}
