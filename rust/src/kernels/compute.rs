//! Kernel block evaluation — the compute hot spot.
//!
//! `K(X, Y)` for point blocks X (m x d) and Y (n x d) dominates the cost
//! of instantiating the hierarchical factors, the Nyström features and the
//! exact baseline. For squared-L2 kernels it is computed through the
//! expansion |x−y|² = |x|² + |y|² − 2⟨x,y⟩, turning the O(mnd) distance
//! work into one gemm plus O(mn) post-processing — exactly the tiling the
//! L1 Pallas kernel performs on TPU (python/compile/kernels/pairwise.py).
//! The L1-metric Laplace kernel uses a blocked direct loop.
//!
//! [`BlockEvaluator`] abstracts the implementation so the PJRT runtime
//! (`crate::runtime`) can substitute the AOT-compiled XLA executable for
//! the same computation at runtime.

use super::base::{KernelKind, Metric};
use crate::linalg::blas::{gemm, Trans};
use crate::linalg::matrix::{l1dist, Mat};

/// Strategy interface for evaluating kernel blocks.
///
/// Deliberately NOT `Send + Sync`: the PJRT implementation wraps the
/// `xla` crate's client/executables, which are single-threaded (`Rc`
/// internals). Factor construction is single-threaded anyway; the fitted
/// models the coordinator shares across threads hold no evaluator.
pub trait BlockEvaluator {
    /// Fill `out` (m x n) with K(X, Y) for the given kernel.
    fn eval_block(&self, kind: KernelKind, x: &Mat, y: &Mat, out: &mut Mat);

    /// Allocate-and-return convenience.
    fn block(&self, kind: KernelKind, x: &Mat, y: &Mat) -> Mat {
        let mut out = Mat::zeros(x.rows(), y.rows());
        self.eval_block(kind, x, y, &mut out);
        out
    }

    /// Whether the parallel factor-construction path may be used with
    /// this evaluator. Must return `true` only if block evaluation is
    /// stateless and produces results identical to [`NativeEvaluator`]
    /// (the parallel path dispatches blocks through per-thread native
    /// evaluation; see `hkernel::build`). The PJRT evaluator keeps the
    /// default `false`: its client is single-threaded.
    fn parallel_safe(&self) -> bool {
        false
    }
}

/// Pure-Rust evaluator (always available; f64 precision).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeEvaluator;

impl BlockEvaluator for NativeEvaluator {
    fn eval_block(&self, kind: KernelKind, x: &Mat, y: &Mat, out: &mut Mat) {
        assert_eq!(x.cols(), y.cols(), "kernel block: dim mismatch");
        assert_eq!(out.shape(), (x.rows(), y.rows()));
        match kind.metric() {
            Metric::SqL2 => sql2_block(kind, x, y, out),
            Metric::L1 => l1_block(kind, x, y, out),
        }
    }

    fn parallel_safe(&self) -> bool {
        true
    }
}

/// Squared-L2 kernels via the gemm expansion.
fn sql2_block(kind: KernelKind, x: &Mat, y: &Mat, out: &mut Mat) {
    let m = x.rows();
    let n = y.rows();
    // out = -2 X Yᵀ
    gemm(-2.0, x, Trans::No, y, Trans::Yes, 0.0, out);
    // Row norms.
    let xn: Vec<f64> = (0..m).map(|i| sq_norm(x.row(i))).collect();
    let yn: Vec<f64> = (0..n).map(|j| sq_norm(y.row(j))).collect();
    for i in 0..m {
        let xi = xn[i];
        let row = out.row_mut(i);
        for j in 0..n {
            // Guard tiny negative values from cancellation.
            let d2 = (row[j] + xi + yn[j]).max(0.0);
            row[j] = kind.profile(d2);
        }
    }
}

/// L1-metric kernels: blocked direct evaluation.
fn l1_block(kind: KernelKind, x: &Mat, y: &Mat, out: &mut Mat) {
    const B: usize = 32;
    let m = x.rows();
    let n = y.rows();
    for i0 in (0..m).step_by(B) {
        for j0 in (0..n).step_by(B) {
            for i in i0..(i0 + B).min(m) {
                let xi = x.row(i);
                let row = out.row_mut(i);
                for j in j0..(j0 + B).min(n) {
                    row[j] = kind.profile(l1dist(xi, y.row(j)));
                }
            }
        }
    }
}

#[inline]
fn sq_norm(v: &[f64]) -> f64 {
    crate::linalg::matrix::dot(v, v)
}

/// Evaluate the symmetric kernel matrix K(X, X) with exact symmetry and
/// exact unit diagonal.
pub fn kernel_block(kind: KernelKind, x: &Mat) -> Mat {
    let mut out = NativeEvaluator.block(kind, x, x);
    out.symmetrize();
    for i in 0..x.rows() {
        out[(i, i)] = kind.diag_value();
    }
    out
}

/// Evaluate the cross matrix K(X, Y) with the native evaluator.
pub fn kernel_cross(kind: KernelKind, x: &Mat, y: &Mat) -> Mat {
    NativeEvaluator.block(kind, x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::base::{Gaussian, Imq, Laplace, Matern32};
    use crate::util::rng::Rng;

    fn naive_block(kind: KernelKind, x: &Mat, y: &Mat) -> Mat {
        Mat::from_fn(x.rows(), y.rows(), |i, j| kind.eval(x.row(i), y.row(j)))
    }

    #[test]
    fn gemm_expansion_matches_naive() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(13, 6, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(9, 6, |_, _| rng.uniform(0.0, 1.0));
        for kind in [Gaussian::new(0.7), Imq::new(0.9), Matern32::new(1.1)] {
            let fast = kernel_cross(kind, &x, &y);
            let slow = naive_block(kind, &x, &y);
            let mut diff = fast.clone();
            diff.axpy(-1.0, &slow);
            assert!(diff.max_abs() < 1e-12, "{kind:?}: {}", diff.max_abs());
        }
    }

    #[test]
    fn laplace_matches_naive() {
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(40, 5, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(37, 5, |_, _| rng.uniform(0.0, 1.0));
        let kind = Laplace::new(0.6);
        let fast = kernel_cross(kind, &x, &y);
        let slow = naive_block(kind, &x, &y);
        let mut diff = fast.clone();
        diff.axpy(-1.0, &slow);
        assert!(diff.max_abs() < 1e-14);
    }

    #[test]
    fn symmetric_block_unit_diag() {
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(20, 4, |_, _| rng.uniform(0.0, 1.0));
        let k = kernel_block(Gaussian::new(0.5), &x);
        assert!(k.is_symmetric(0.0));
        for i in 0..20 {
            assert_eq!(k[(i, i)], 1.0);
        }
    }

    #[test]
    fn kernel_matrix_is_pd() {
        // Strict PD base kernels on distinct points -> Cholesky succeeds.
        let mut rng = Rng::new(4);
        let x = Mat::from_fn(30, 3, |_, _| rng.uniform(0.0, 1.0));
        for kind in [Gaussian::new(0.8), Laplace::new(0.8), Imq::new(0.8)] {
            let k = kernel_block(kind, &x);
            assert!(
                crate::linalg::Cholesky::new_jittered(&k, 8).is_ok(),
                "{kind:?} not PD"
            );
        }
    }

    #[test]
    fn empty_blocks() {
        let x = Mat::zeros(0, 3);
        let y = Mat::zeros(5, 3);
        let k = kernel_cross(Gaussian::new(1.0), &x, &y);
        assert_eq!(k.shape(), (0, 5));
    }

    #[test]
    fn cancellation_guard() {
        // Identical points at large coordinates: d2 could go slightly
        // negative without the guard; profile must return exactly 1.
        let x = Mat::from_vec(2, 2, vec![1e8, -1e8, 1e8, -1e8]);
        let k = kernel_cross(Gaussian::new(1.0), &x, &x);
        for v in k.as_slice() {
            assert!(*v <= 1.0 && *v >= 0.0);
        }
    }
}
