//! The prediction service: request queue → dynamic batcher → model.

use super::metrics::Metrics;
use crate::linalg::Mat;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Anything that can serve batched predictions. Implemented by
/// [`crate::learn::KrrModel`] and [`crate::shard::ShardedPredictor`];
/// custom predictors (e.g. a long-lived Algorithm-3
/// [`crate::hkernel::HPredictor`]) can plug in too.
pub trait Predictor: Send + Sync + 'static {
    /// Predict raw outputs for a batch of query rows.
    fn predict_batch(&self, q: &Mat) -> Mat;
    /// Expected feature dimension.
    fn dim(&self) -> usize;
    /// Number of output columns.
    fn outputs(&self) -> usize;
    /// Per-shard counters, when the predictor is sharded (default: none).
    fn shard_metrics(&self) -> Vec<super::metrics::ShardSnapshot> {
        Vec::new()
    }
}

impl Predictor for crate::learn::KrrModel {
    fn predict_batch(&self, q: &Mat) -> Mat {
        self.predict(q)
    }
    fn dim(&self) -> usize {
        self.dim()
    }
    fn outputs(&self) -> usize {
        self.outputs()
    }
}

/// Dynamic batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many requests are queued.
    pub max_batch: usize,
    /// ... or once the oldest queued request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

struct Request {
    features: Vec<f64>,
    enqueued: Instant,
    resp: SyncSender<Vec<f64>>,
}

/// Handle to a running prediction service (batcher thread owns the model).
pub struct PredictionService {
    tx: SyncSender<Request>,
    pub metrics: Arc<Metrics>,
    /// Shared handle to the predictor (the batcher thread holds another
    /// clone); kept so [`PredictionService::snapshot`] can attach
    /// per-shard counters.
    model: Arc<dyn Predictor>,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    dim: usize,
}

impl PredictionService {
    /// Start the batcher thread around a predictor.
    pub fn start(model: Arc<dyn Predictor>, policy: BatchPolicy) -> PredictionService {
        let (tx, rx) = sync_channel::<Request>(4096);
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let dim = model.dim();
        let m2 = metrics.clone();
        let s2 = stop.clone();
        let model2 = model.clone();
        let join = std::thread::Builder::new()
            .name("hck-batcher".into())
            .spawn(move || batcher_loop(model2, rx, m2, s2, policy))
            .expect("spawn batcher");
        PredictionService { tx, metrics, model, stop, join: Some(join), dim }
    }

    /// Start the batcher around any artifact-loaded [`crate::model::Model`]
    /// — the uniform serving entry point for `hck serve --model` (the
    /// service needs no engine-specific plumbing; the model describes
    /// itself through its schema).
    pub fn start_model(
        model: Arc<dyn crate::model::Model>,
        policy: BatchPolicy,
    ) -> PredictionService {
        Self::start(Arc::new(model), policy)
    }

    /// Feature dimension the service expects (0 if unknown).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Service-level counters with the predictor's per-shard counters
    /// attached (empty `shards` for single-replica predictors).
    pub fn snapshot(&self) -> super::metrics::MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        snap.shards = self.model.shard_metrics();
        snap
    }

    /// Synchronous predict: enqueue and wait for the batch to flush.
    pub fn predict(&self, features: Vec<f64>) -> crate::error::Result<Vec<f64>> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Request { features, enqueued: Instant::now(), resp: rtx })
            .map_err(|_| crate::error::Error::serve("service stopped"))?;
        rrx.recv().map_err(|_| crate::error::Error::serve("service dropped request"))
    }

    /// Stop the batcher and join it.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Drop tx by replacing with a dummy? tx dropped with self after join.
        if let Some(j) = self.join.take() {
            // Closing the channel unblocks recv; mark stop and send nothing.
            drop(std::mem::replace(&mut self.tx, sync_channel(1).0));
            let _ = j.join();
        }
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            drop(std::mem::replace(&mut self.tx, sync_channel(1).0));
            let _ = j.join();
        }
    }
}

fn batcher_loop(
    model: Arc<dyn Predictor>,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    policy: BatchPolicy,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(policy.max_batch);
    loop {
        if stop.load(Ordering::SeqCst) && pending.is_empty() {
            // Drain whatever is still in the channel before exiting.
            match rx.try_recv() {
                Ok(req) => pending.push(req),
                Err(_) => break,
            }
        }
        // Block for the first request of a batch.
        if pending.is_empty() {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(req) => pending.push(req),
                Err(RecvTimeoutError::Timeout) => {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Fill the batch until max_batch or the deadline of the oldest.
        let deadline = pending[0].enqueued + policy.max_wait;
        while pending.len() < policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => pending.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Dispatch.
        let batch = std::mem::take(&mut pending);
        let d = batch[0].features.len();
        let mut q = Mat::zeros(batch.len(), d);
        for (i, req) in batch.iter().enumerate() {
            if req.features.len() == d {
                q.row_mut(i).copy_from_slice(&req.features);
            }
        }
        let preds = model.predict_batch(&q);
        let done = Instant::now();
        // Record metrics BEFORE releasing responders, so a client that
        // returns from predict() always observes its own request counted.
        let lats: Vec<f64> =
            batch.iter().map(|r| (done - r.enqueued).as_secs_f64()).collect();
        metrics.record_batch(&lats);
        for (i, req) in batch.into_iter().enumerate() {
            let _ = req.resp.send(preds.row(i).to_vec());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A trivial predictor: output = [sum of features].
    struct SumModel;
    impl Predictor for SumModel {
        fn predict_batch(&self, q: &Mat) -> Mat {
            Mat::from_fn(q.rows(), 1, |i, _| q.row(i).iter().sum())
        }
        fn dim(&self) -> usize {
            3
        }
        fn outputs(&self) -> usize {
            1
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let svc = PredictionService::start(Arc::new(SumModel), BatchPolicy::default());
        let out = svc.predict(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(out, vec![6.0]);
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.requests, 1);
        svc.shutdown();
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let svc = Arc::new(PredictionService::start(
            Arc::new(SumModel),
            BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(20) },
        ));
        let mut handles = Vec::new();
        for k in 0..32 {
            let s = svc.clone();
            handles.push(std::thread::spawn(move || {
                let out = s.predict(vec![k as f64, 0.0, 1.0]).unwrap();
                assert_eq!(out[0], k as f64 + 1.0);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.requests, 32);
        assert!(
            snap.mean_batch_size > 1.0,
            "expected batching, got mean size {}",
            snap.mean_batch_size
        );
    }

    #[test]
    fn shutdown_is_clean() {
        let svc = PredictionService::start(Arc::new(SumModel), BatchPolicy::default());
        let _ = svc.predict(vec![0.0; 3]).unwrap();
        svc.shutdown(); // must not hang or panic
    }
}
