//! The prediction service: request queue → dynamic batcher → model.
//!
//! The service speaks the typed inference protocol end to end: requests
//! enter as (features, [`Want`]) pairs, the batcher folds a dynamic batch
//! into one [`PredictRequest`] for the cheap columns (mean, routes) plus
//! a second sub-batch call covering only the members that asked for the
//! expensive variance column, and every client gets back its own slice
//! of the [`crate::infer::PredictResponse`] — or a typed, clonable
//! [`PredictError`]. Malformed requests are rejected at enqueue time and
//! never reach (or panic inside) the batcher thread; a member whose
//! evaluation fails cannot error unrelated requests merged into the
//! same batch (the batcher re-evaluates members individually on
//! failure).

use super::metrics::Metrics;
use crate::infer::{
    Capabilities, InferResult, LeafRoute, PredictError, PredictRequest, PredictResponse, Want,
};
use crate::linalg::Mat;
use crate::obs;
use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Anything that can serve typed batched predictions. Implemented by
/// [`crate::learn::KrrModel`], [`crate::shard::ShardedPredictor`] and
/// `Arc<dyn` [`crate::model::Model`]`>`; custom predictors can plug in
/// by implementing [`Predictor::predict`].
pub trait Predictor: Send + Sync + 'static {
    /// Serve one typed request — the single inference entry point.
    fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse>;

    /// Expected feature dimension (0 = unknown; skips validation).
    fn dim(&self) -> usize;

    /// Number of output columns.
    fn outputs(&self) -> usize;

    /// What this predictor can serve (default: mean only).
    fn capabilities(&self) -> Capabilities {
        Capabilities::mean_only()
    }

    /// Full model schema as JSON, when the predictor wraps a
    /// self-describing artifact (the TCP `schema` command).
    fn schema_json(&self) -> Option<Json> {
        None
    }

    /// Per-shard counters, when the predictor is sharded (default: none).
    fn shard_metrics(&self) -> Vec<super::metrics::ShardSnapshot> {
        Vec::new()
    }

    /// Per-remote-worker counters, when the predictor fans out to
    /// remote shard workers (default: none). Implemented by
    /// [`crate::shard::RemoteShardedPredictor`].
    fn worker_metrics(&self) -> Vec<super::metrics::WorkerSnapshot> {
        Vec::new()
    }

    /// Runtime administration hook (the TCP `worker_add` /
    /// `worker_drain` / `workers` protocol commands). Predictors
    /// without a dynamic replica topology refuse every command;
    /// [`crate::shard::RemoteShardedPredictor`] implements the
    /// lifecycle verbs.
    fn admin(&self, cmd: &str, _arg: &str) -> crate::infer::InferResult<Json> {
        Err(crate::infer::PredictError::Unsupported(format!(
            "admin command '{cmd}' is not supported by this predictor"
        )))
    }

    /// Mean-only convenience (benches/tests); panics on a rejected
    /// request — use [`Predictor::predict`] for typed errors.
    fn predict_batch(&self, q: &Mat) -> Mat {
        match self.predict(&PredictRequest::mean_of(q)) {
            Ok(resp) => resp.mean,
            // hck-lint: allow(serving-no-panic): documented panicking
            // convenience for in-process benches/tests; the serving path
            // proper goes through predict() and stays typed.
            Err(e) => panic!("predict_batch: {e}"),
        }
    }
}

impl Predictor for crate::learn::KrrModel {
    fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse> {
        crate::infer::validate_queries(&req.queries, self.dim())?;
        Predictor::capabilities(self).check(req.want)?;
        let t = Instant::now();
        let mean = crate::learn::KrrModel::predict(self, &req.queries);
        let routes = if req.want.leaf_route {
            // Capabilities admit leaf_route only for the hierarchical
            // engine; disagreement here is a typed internal error, not
            // a panic that would kill the batcher thread.
            let pred = self.hierarchical_predictor().ok_or_else(|| {
                PredictError::Internal(
                    "leaf_route capability admitted without a partition tree".into(),
                )
            })?;
            Some(crate::model::routes_of_tree(&pred.factors().tree, &req.queries))
        } else {
            None
        };
        let per_query_ns = t.elapsed().as_nanos() as f64 / req.queries.rows() as f64;
        Ok(PredictResponse { mean, variance: None, routes, per_query_ns })
    }
    fn dim(&self) -> usize {
        self.dim()
    }
    fn outputs(&self) -> usize {
        self.outputs()
    }
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            mean: true,
            variance: false,
            leaf_route: self.hierarchical_predictor().is_some(),
        }
    }
}

/// Dynamic batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many requests are queued.
    pub max_batch: usize,
    /// ... or once the oldest queued request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// One query's slice of a batched [`PredictResponse`] — what a client of
/// [`PredictionService::predict_typed`] receives.
#[derive(Debug, Clone)]
pub struct QueryReply {
    /// Predicted mean (one entry per output column).
    pub mean: Vec<f64>,
    /// Posterior variance σ², when requested.
    pub variance: Option<f64>,
    /// Routed partition-tree leaf, when requested.
    pub route: Option<LeafRoute>,
    /// Per-query evaluation time of the batch this query rode in (ns).
    pub per_query_ns: f64,
    /// Service-minted request id (also returned by
    /// [`PredictionService::submit`] and echoed on v2 protocol replies);
    /// tags this query's `coord.*` trace spans.
    pub request_id: u64,
}

/// Process-wide request-id mint: ids are unique across every service in
/// the process so traces with several services never collide; 0 is
/// reserved for "no request".
static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

struct Request {
    features: Vec<f64>,
    want: Want,
    request_id: u64,
    enqueued: Instant,
    resp: SyncSender<InferResult<QueryReply>>,
}

/// Handle to a running prediction service (batcher thread owns the model).
pub struct PredictionService {
    tx: SyncSender<Request>,
    pub metrics: Arc<Metrics>,
    /// Shared handle to the predictor (the batcher thread holds another
    /// clone); kept so [`PredictionService::snapshot`] can attach
    /// per-shard counters.
    model: Arc<dyn Predictor>,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    dim: usize,
    caps: Capabilities,
}

impl PredictionService {
    /// Start the batcher thread around a predictor.
    pub fn start(model: Arc<dyn Predictor>, policy: BatchPolicy) -> PredictionService {
        let (tx, rx) = sync_channel::<Request>(4096);
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let dim = model.dim();
        let caps = model.capabilities();
        let m2 = metrics.clone();
        let s2 = stop.clone();
        let model2 = model.clone();
        let join = std::thread::Builder::new()
            .name("hck-batcher".into())
            .spawn(move || batcher_loop(model2, rx, m2, s2, policy))
            // hck-lint: allow(serving-no-panic): one-time service
            // assembly, before any request is accepted — failing to
            // spawn the batcher thread means the process cannot serve
            // at all, and the constructor has no error channel.
            .expect("spawn batcher");
        PredictionService { tx, metrics, model, stop, join: Some(join), dim, caps }
    }

    /// Start the batcher around any artifact-loaded [`crate::model::Model`]
    /// — the uniform serving entry point for `hck serve --model` (the
    /// service needs no engine-specific plumbing; the model describes
    /// itself through its schema).
    pub fn start_model(
        model: Arc<dyn crate::model::Model>,
        policy: BatchPolicy,
    ) -> PredictionService {
        Self::start(Arc::new(model), policy)
    }

    /// Feature dimension the service expects (0 if unknown).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// What the predictor behind this service can serve.
    pub fn capabilities(&self) -> Capabilities {
        self.caps
    }

    /// The predictor's full schema JSON, when it wraps an artifact.
    pub fn schema_json(&self) -> Option<Json> {
        self.model.schema_json()
    }

    /// Forward a runtime admin command to the predictor (replica
    /// lifecycle, when the predictor has one).
    pub fn admin(&self, cmd: &str, arg: &str) -> crate::infer::InferResult<Json> {
        self.model.admin(cmd, arg)
    }

    /// Service-level counters with the predictor's per-shard counters
    /// attached (empty `shards` for single-replica predictors).
    pub fn snapshot(&self) -> super::metrics::MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        snap.shards = self.model.shard_metrics();
        snap.workers = self.model.worker_metrics();
        snap
    }

    /// Validate and enqueue one query without blocking on the reply; the
    /// receiver resolves when the batch flushes. The TCP layer uses this
    /// to dispatch every row of a multi-query frame before gathering, so
    /// one frame becomes one dynamic batch instead of N round trips.
    /// Returns the minted request id alongside the receiver; the same id
    /// comes back on the [`QueryReply`] and tags the query's `coord.*`
    /// trace spans.
    pub fn submit(
        &self,
        features: Vec<f64>,
        want: Want,
    ) -> InferResult<(u64, Receiver<InferResult<QueryReply>>)> {
        crate::infer::validate_features(&features, self.dim)?;
        self.caps.check(want)?;
        // ORDERING: Relaxed — atomicity alone guarantees unique ids;
        // the request itself travels (and is published) through the
        // channel send below.
        let request_id = NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Request { features, want, request_id, enqueued: Instant::now(), resp: rtx })
            .map_err(|_| PredictError::Internal("service stopped".into()))?;
        Ok((request_id, rrx))
    }

    /// Synchronous typed predict: enqueue and wait for the batch to flush.
    pub fn predict_typed(&self, features: Vec<f64>, want: Want) -> InferResult<QueryReply> {
        let (_id, rrx) = self.submit(features, want)?;
        rrx.recv()
            .map_err(|_| PredictError::Internal("service dropped request".into()))?
    }

    /// Synchronous mean-only predict (the v1 surface, kept for existing
    /// clients and examples).
    pub fn predict(&self, features: Vec<f64>) -> crate::error::Result<Vec<f64>> {
        Ok(self.predict_typed(features, Want::mean_only())?.mean)
    }

    /// Stop the batcher and join it.
    pub fn shutdown(mut self) {
        // ORDERING: SeqCst — one-shot shutdown flag; pairs with the
        // loads in batcher_loop and keeps the channel close below
        // unambiguously after the flag flip.
        self.stop.store(true, Ordering::SeqCst);
        // Drop tx by replacing with a dummy? tx dropped with self after join.
        if let Some(j) = self.join.take() {
            // Closing the channel unblocks recv; mark stop and send nothing.
            drop(std::mem::replace(&mut self.tx, sync_channel(1).0));
            let _ = j.join();
        }
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        // ORDERING: SeqCst — same shutdown edge as [`Self::shutdown`].
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            drop(std::mem::replace(&mut self.tx, sync_channel(1).0));
            let _ = j.join();
        }
    }
}

fn batcher_loop(
    model: Arc<dyn Predictor>,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    policy: BatchPolicy,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(policy.max_batch);
    loop {
        // ORDERING: SeqCst — shutdown control plane, one load per loop
        // turn; pairs with the stores in shutdown()/drop().
        if stop.load(Ordering::SeqCst) && pending.is_empty() {
            // Drain whatever is still in the channel before exiting.
            match rx.try_recv() {
                Ok(req) => pending.push(req),
                Err(_) => break,
            }
        }
        // Block for the first request of a batch.
        if pending.is_empty() {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(req) => pending.push(req),
                Err(RecvTimeoutError::Timeout) => {
                    // ORDERING: SeqCst — shutdown check on the idle
                    // timeout path; same pairing as above.
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Fill the batch until max_batch or the deadline of the oldest.
        let deadline = pending[0].enqueued + policy.max_wait;
        while pending.len() < policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => pending.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Dispatch the batch as one typed request (plus a variance
        // sub-batch below). Enqueue-time validation checks each row
        // against the model dimension; when the model reports dim() == 0
        // (unknown), rows of a different length than the batch's first
        // cannot be merged — reject them with a typed error instead of
        // silently zero-filling.
        let full = std::mem::take(&mut pending);
        let d = full[0].features.len();
        let (batch, mismatched): (Vec<Request>, Vec<Request>) =
            full.into_iter().partition(|req| req.features.len() == d);
        for req in mismatched {
            let _ = req.resp.send(Err(PredictError::BadRequest(format!(
                "expected {d} features (from the first request of the batch), got {}",
                req.features.len()
            ))));
        }
        if batch.is_empty() {
            continue;
        }
        let mut q = Mat::zeros(batch.len(), d);
        let mut want_all = Want::mean_only();
        let mut var_idx: Vec<usize> = Vec::new();
        for (i, req) in batch.iter().enumerate() {
            q.row_mut(i).copy_from_slice(&req.features);
            if req.want.leaf_route {
                want_all.leaf_route = true;
            }
            if req.want.variance {
                var_idx.push(i);
            }
        }
        // Variance is the one expensive optional column (an O(n·r)
        // kernel column + solve per query): when only *some* members
        // asked, evaluate it as a second call over just their rows, so
        // mean-only members of a mixed batch never pay for it; when
        // *every* member asked, fold it into the single main call (no
        // second pass, no recomputed means). Routes are a cheap tree
        // walk, so folding them across the batch is always fine.
        let all_variance = var_idx.len() == batch.len() && !var_idx.is_empty();
        if all_variance {
            want_all.variance = true;
        }
        let q_var = if all_variance || var_idx.is_empty() {
            None
        } else {
            Some(q.select_rows(&var_idx))
        };
        // Trace the batch window: one coord.queue_wait span per member
        // (enqueue → execution start, tagged with its request id), one
        // coord.batch span over the model call(s), and one coord.execute
        // span per member covering the shared execution window.
        let exec_start = Instant::now();
        if obs::is_enabled() {
            for req in &batch {
                obs::record_span_between(
                    "coord.queue_wait",
                    "coord",
                    req.enqueued,
                    exec_start,
                    req.request_id,
                );
            }
        }
        let sp_batch = obs::span_with("coord.batch", "coord", || {
            format!("{{\"batch\":{},\"variance_rows\":{}}}", batch.len(), var_idx.len())
        });
        let resp = model.predict(&PredictRequest::new(q, want_all));
        let var_resp = match (&resp, q_var) {
            (Ok(_), Some(qv)) => {
                Some(model.predict(&PredictRequest::new(qv, Want::mean_only().with_variance())))
            }
            _ => None,
        };
        drop(sp_batch);
        let done = Instant::now();
        if obs::is_enabled() {
            for req in &batch {
                obs::record_span_between(
                    "coord.execute",
                    "coord",
                    exec_start,
                    done,
                    req.request_id,
                );
            }
        }
        // Record metrics BEFORE releasing responders, so a client that
        // returns from predict() always observes its own request counted.
        let lats: Vec<f64> =
            batch.iter().map(|r| (done - r.enqueued).as_secs_f64()).collect();
        metrics.record_batch(&lats);
        match resp {
            Ok(resp) => {
                // var_idx was built in batch order, so a running cursor
                // maps each variance-requesting member to its row of the
                // variance sub-batch.
                let mut vk = 0usize;
                for (i, req) in batch.into_iter().enumerate() {
                    let route = if req.want.leaf_route {
                        resp.routes.as_ref().map(|r| r[i])
                    } else {
                        None
                    };
                    let reply = if req.want.variance {
                        let k = vk;
                        vk += 1;
                        match &var_resp {
                            Some(Ok(v)) => Ok(QueryReply {
                                mean: resp.mean.row(i).to_vec(),
                                variance: v.variance.as_ref().map(|vv| vv[k]),
                                route,
                                per_query_ns: v.per_query_ns,
                                request_id: req.request_id,
                            }),
                            Some(Err(e)) => Err(e.clone()),
                            // No sub-batch ran: the whole batch wanted
                            // variance and the main call carried it.
                            None => Ok(QueryReply {
                                mean: resp.mean.row(i).to_vec(),
                                variance: resp.variance.as_ref().map(|v| v[i]),
                                route,
                                per_query_ns: resp.per_query_ns,
                                request_id: req.request_id,
                            }),
                        }
                    } else {
                        Ok(QueryReply {
                            mean: resp.mean.row(i).to_vec(),
                            variance: None,
                            route,
                            per_query_ns: resp.per_query_ns,
                            request_id: req.request_id,
                        })
                    };
                    let _ = req.resp.send(reply);
                }
            }
            Err(e) if batch.len() == 1 => {
                for req in batch {
                    let _ = req.resp.send(Err(e.clone()));
                }
            }
            Err(_) => {
                // Contain the failure: re-evaluate each member on its
                // own so one member's failing column or shard cannot
                // error unrelated requests merged into the same dynamic
                // batch. Error batches are rare (validation happens at
                // enqueue), so the per-member retry cost is acceptable.
                for req in batch {
                    let _sp = obs::span_req("coord.member_eval", "coord", req.request_id);
                    let mut q1 = Mat::zeros(1, req.features.len());
                    q1.row_mut(0).copy_from_slice(&req.features);
                    let reply = model.predict(&PredictRequest::new(q1, req.want)).map(
                        |resp| QueryReply {
                            mean: resp.mean.row(0).to_vec(),
                            variance: resp.variance.as_ref().map(|v| v[0]),
                            route: resp.routes.as_ref().map(|r| r[0]),
                            per_query_ns: resp.per_query_ns,
                            request_id: req.request_id,
                        },
                    );
                    let _ = req.resp.send(reply);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A trivial predictor: output = [sum of features].
    struct SumModel;
    impl Predictor for SumModel {
        fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse> {
            let q = &req.queries;
            Ok(PredictResponse::of_mean(Mat::from_fn(q.rows(), 1, |i, _| {
                q.row(i).iter().sum()
            })))
        }
        fn dim(&self) -> usize {
            3
        }
        fn outputs(&self) -> usize {
            1
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let svc = PredictionService::start(Arc::new(SumModel), BatchPolicy::default());
        let out = svc.predict(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(out, vec![6.0]);
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.requests, 1);
        svc.shutdown();
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let svc = Arc::new(PredictionService::start(
            Arc::new(SumModel),
            BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(20) },
        ));
        let mut handles = Vec::new();
        for k in 0..32 {
            let s = svc.clone();
            handles.push(std::thread::spawn(move || {
                let out = s.predict(vec![k as f64, 0.0, 1.0]).unwrap();
                assert_eq!(out[0], k as f64 + 1.0);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.requests, 32);
        assert!(
            snap.mean_batch_size > 1.0,
            "expected batching, got mean size {}",
            snap.mean_batch_size
        );
    }

    /// `submit` mints a fresh id per request and the batcher echoes it on
    /// the reply — the pairing the TCP v2 layer relies on.
    #[test]
    fn request_ids_are_minted_and_echoed() {
        let svc = PredictionService::start(Arc::new(SumModel), BatchPolicy::default());
        let (id1, rx1) = svc.submit(vec![1.0, 0.0, 0.0], Want::mean_only()).unwrap();
        let (id2, rx2) = svc.submit(vec![2.0, 0.0, 0.0], Want::mean_only()).unwrap();
        assert_ne!(id1, 0, "0 is reserved for 'no request'");
        assert!(id2 > id1, "ids are strictly increasing: {id1} then {id2}");
        let r1 = rx1.recv().unwrap().unwrap();
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r1.request_id, id1);
        assert_eq!(r2.request_id, id2);
        assert_eq!(r1.mean, vec![1.0]);
        assert_eq!(r2.mean, vec![2.0]);
        svc.shutdown();
    }

    #[test]
    fn shutdown_is_clean() {
        let svc = PredictionService::start(Arc::new(SumModel), BatchPolicy::default());
        let _ = svc.predict(vec![0.0; 3]).unwrap();
        svc.shutdown(); // must not hang or panic
    }

    /// Malformed requests come back as typed errors at enqueue time and
    /// never poison the batcher: good requests keep working afterwards.
    #[test]
    fn bad_requests_error_without_killing_the_service() {
        let svc = PredictionService::start(Arc::new(SumModel), BatchPolicy::default());
        let err = svc.predict_typed(vec![1.0], Want::mean_only()).unwrap_err();
        assert_eq!(err.kind(), "bad_request");
        let err = svc
            .predict_typed(vec![0.0, f64::NAN, 1.0], Want::mean_only())
            .unwrap_err();
        assert_eq!(err.kind(), "bad_request");
        let err = svc.predict_typed(vec![], Want::mean_only()).unwrap_err();
        assert_eq!(err.kind(), "bad_request");
        // Capability negotiation: SumModel serves the mean only.
        let err = svc
            .predict_typed(vec![0.0; 3], Want::mean_only().with_variance())
            .unwrap_err();
        assert_eq!(err.kind(), "unsupported");
        // The worker loop is still alive.
        let ok = svc.predict_typed(vec![1.0, 1.0, 1.0], Want::mean_only()).unwrap();
        assert_eq!(ok.mean, vec![3.0]);
        assert!(ok.variance.is_none() && ok.route.is_none());
        svc.shutdown();
    }

    /// A predictor that fails whole batches containing a poison marker —
    /// the shape of a shard failure or a broken variance factorization.
    struct Poison;
    impl Predictor for Poison {
        fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse> {
            let q = &req.queries;
            if (0..q.rows()).any(|i| q.row(i)[0] == 13.0) {
                return Err(PredictError::Internal("poisoned".into()));
            }
            Ok(PredictResponse::of_mean(Mat::from_fn(q.rows(), 1, |i, _| {
                q.row(i).iter().sum()
            })))
        }
        fn dim(&self) -> usize {
            3
        }
        fn outputs(&self) -> usize {
            1
        }
    }

    /// One member's evaluation failure must not error unrelated requests
    /// merged into the same dynamic batch: the batcher re-evaluates the
    /// members individually and only the failing one sees the error.
    #[test]
    fn batch_errors_are_contained_to_the_failing_member() {
        let svc = Arc::new(PredictionService::start(
            Arc::new(Poison),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(30) },
        ));
        let mut handles = Vec::new();
        for k in 0..4 {
            let s = svc.clone();
            handles.push(std::thread::spawn(move || {
                let feats = if k == 0 {
                    vec![13.0, 0.0, 0.0]
                } else {
                    vec![k as f64, 1.0, 0.0]
                };
                (k, s.predict_typed(feats, Want::mean_only()))
            }));
        }
        for h in handles {
            let (k, res) = h.join().unwrap();
            if k == 0 {
                assert_eq!(res.unwrap_err().kind(), "internal");
            } else {
                assert_eq!(res.unwrap().mean, vec![k as f64 + 1.0]);
            }
        }
    }
}
