//! L3 serving coordinator: a threaded prediction service with dynamic
//! batching, latency/throughput metrics, and a line-delimited JSON TCP
//! protocol.
//!
//! The hierarchical kernel's out-of-sample path (Algorithm 3) is
//! O(r² log(n/r) + dr) per query after an O(nr) precomputation — exactly
//! the shape of workload where a serving layer wants *batching*: the
//! per-query tree walk is cheap, so amortizing queueing and dispatch
//! overhead across a batch dominates tail latency. The batcher collects
//! requests until `max_batch` or `max_wait` elapses — the standard
//! dynamic-batching policy of model servers (vLLM-style), scaled to this
//! paper's predictor.
//!
//! In sharded mode the batcher stays in front and a
//! [`crate::shard::ShardedPredictor`] fans each flushed batch out across
//! per-shard worker queues; per-shard counters surface through
//! [`MetricsSnapshot::shards`].

pub mod metrics;
pub mod protocol;
pub mod service;

pub use metrics::{Metrics, MetricsSnapshot, ShardSnapshot};
pub use protocol::serve_tcp;
pub use service::{BatchPolicy, PredictionService, Predictor, QueryReply};
