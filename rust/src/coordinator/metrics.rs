//! Serving metrics: request counters, latency histogram, batch sizes.

use crate::util::json::Json;
use std::sync::Mutex;
use std::time::Instant;

/// Log-scale latency histogram (microseconds) + aggregate counters.
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

struct Inner {
    requests: u64,
    batches: u64,
    batch_size_sum: u64,
    /// Histogram buckets: [1µs, 2µs, 4µs, ...] (powers of two), 40 deep.
    latency_us: [u64; 40],
    latencies_sorted_cache: Vec<f64>,
    /// Raw latencies (µs), bounded ring for percentile reporting.
    raw: Vec<f64>,
    /// Ring write cursor once `raw` reaches `RAW_CAP`: the next slot to
    /// overwrite, so percentiles always reflect the most recent
    /// `RAW_CAP` requests instead of freezing on the first ones.
    raw_next: usize,
}

const RAW_CAP: usize = 65536;

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            inner: Mutex::new(Inner {
                requests: 0,
                batches: 0,
                batch_size_sum: 0,
                latency_us: [0; 40],
                latencies_sorted_cache: Vec::new(),
                raw: Vec::new(),
                raw_next: 0,
            }),
            started: Instant::now(),
        }
    }

    /// Record one served batch: per-request latencies in seconds.
    pub fn record_batch(&self, latencies_secs: &[f64]) {
        // A recorder that panicked mid-update must not make the metrics
        // mutex permanently unusable for serving threads: the counters
        // are plain integers, so take the data through the poison.
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        g.batches += 1;
        g.batch_size_sum += latencies_secs.len() as u64;
        for &s in latencies_secs {
            g.requests += 1;
            let us = (s * 1e6).max(0.0);
            let bucket = (us.max(1.0).log2().floor() as usize).min(39);
            g.latency_us[bucket] += 1;
            if g.raw.len() < RAW_CAP {
                g.raw.push(us);
            } else {
                let i = g.raw_next;
                g.raw[i] = us;
                g.raw_next = (i + 1) % RAW_CAP;
            }
        }
        g.latencies_sorted_cache.clear();
    }

    /// Snapshot of the current counters.
    pub fn snapshot(&self) -> MetricsSnapshot {
        // Same poison recovery as record_batch: a snapshot must always
        // be observable even after a panicking recorder.
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if g.latencies_sorted_cache.is_empty() && !g.raw.is_empty() {
            let mut v = g.raw.clone();
            // total_cmp: latencies are never NaN, but a panicking sort
            // comparator has no place on the serving path.
            v.sort_by(|a, b| a.total_cmp(b));
            g.latencies_sorted_cache = v;
        }
        let pct = |p: f64| -> f64 {
            crate::util::bench::percentile_sorted(&g.latencies_sorted_cache, p)
        };
        let elapsed = self.started.elapsed().as_secs_f64();
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            mean_batch_size: if g.batches > 0 {
                g.batch_size_sum as f64 / g.batches as f64
            } else {
                0.0
            },
            throughput_rps: if elapsed > 0.0 { g.requests as f64 / elapsed } else { 0.0 },
            p50_us: pct(50.0),
            p95_us: pct(95.0),
            p99_us: pct(99.0),
            elapsed_secs: elapsed,
            shards: Vec::new(),
            workers: Vec::new(),
        }
    }
}

/// Point-in-time view of one **remote** shard worker as seen from the
/// router (`hck serve --workers`): reachability, reconnect count, and
/// the worker's own per-shard counters polled over the `stats` wire
/// command. Attached to [`MetricsSnapshot::workers`] by
/// [`super::service::PredictionService::snapshot`].
#[derive(Debug, Clone)]
pub struct WorkerSnapshot {
    /// The worker's address (`host:port`) — the `worker` label in the
    /// Prometheus exposition.
    pub worker: String,
    /// How many times the router re-established this worker's
    /// connection after a failure.
    pub reconnects: u64,
    /// Whether the worker answered the stats poll behind this snapshot.
    pub reachable: bool,
    /// Replica lifecycle state: `active`, `draining`, or `retired`.
    pub state: String,
    /// How many times this worker's circuit breaker opened (consecutive
    /// predict failures reached the threshold).
    pub breaker_opens: u64,
    /// How many times this worker was asked to drain.
    pub drains: u64,
    /// Straggling sub-batches re-issued from this worker to a sibling
    /// replica (the worker was the slow side of a hedge).
    pub hedges: u64,
    /// The worker's per-shard counters (empty when unreachable).
    pub shards: Vec<ShardSnapshot>,
}

impl WorkerSnapshot {
    /// JSON encoding (one row of the snapshot's "workers" array).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("worker", Json::Str(self.worker.clone())),
            ("reconnects", Json::Num(self.reconnects as f64)),
            ("reachable", Json::Bool(self.reachable)),
            ("state", Json::Str(self.state.clone())),
            ("breaker_opens", Json::Num(self.breaker_opens as f64)),
            ("drains", Json::Num(self.drains as f64)),
            ("hedges", Json::Num(self.hedges as f64)),
            ("shards", Json::Arr(self.shards.iter().map(|s| s.to_json()).collect())),
        ])
    }
}

/// Point-in-time counters of one shard worker (sharded serving mode);
/// attached to [`MetricsSnapshot::shards`] by
/// [`super::service::PredictionService::snapshot`].
#[derive(Debug, Clone)]
pub struct ShardSnapshot {
    /// Shard index.
    pub shard: usize,
    /// Global tree-order row range the shard owns.
    pub rows_lo: usize,
    /// End of the owned range (exclusive).
    pub rows_hi: usize,
    /// Sub-batches submitted but not yet finished.
    pub queue_depth: usize,
    /// Sub-batches served.
    pub batches: u64,
    /// Queries served.
    pub requests: u64,
    /// Mean sub-batch size.
    pub mean_batch_size: f64,
    /// Mean evaluation time per query, in ns (queueing excluded).
    pub ns_per_query: f64,
    /// Mean time a sub-batch waited in the worker's queue before
    /// evaluation started, in ns.
    pub queue_wait_ns: f64,
    /// Fraction of the worker's lifetime spent evaluating (0..=1) —
    /// the utilization signal the ROADMAP's shard-replication story
    /// keys on.
    pub busy_frac: f64,
    /// Queries whose worker-side evaluation failed — a panic contained
    /// to one sub-batch, a failed variance factorization, or a dead
    /// worker thread. The affected requests receive typed
    /// `PredictError::Shard`/`Internal` replies, so a non-zero count
    /// here signals worker-level faults. Requests rejected *before*
    /// reaching a worker (bad dimensions, unsupported capabilities) are
    /// not counted here — they never enter a shard queue.
    pub dropped: u64,
}

impl ShardSnapshot {
    /// JSON encoding (one row of the snapshot's "shards" array).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard", Json::Num(self.shard as f64)),
            ("rows_lo", Json::Num(self.rows_lo as f64)),
            ("rows_hi", Json::Num(self.rows_hi as f64)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("mean_batch_size", Json::Num(self.mean_batch_size)),
            ("ns_per_query", Json::Num(self.ns_per_query)),
            ("queue_wait_ns", Json::Num(self.queue_wait_ns)),
            ("busy_frac", Json::Num(self.busy_frac)),
            ("dropped", Json::Num(self.dropped as f64)),
        ])
    }
}

/// Point-in-time metrics view.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub throughput_rps: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub elapsed_secs: f64,
    /// Per-shard counters when the model behind the service is sharded
    /// (empty for single-replica predictors).
    pub shards: Vec<ShardSnapshot>,
    /// Per-remote-worker counters when the service fronts remote shard
    /// workers (`hck serve --workers`); empty otherwise.
    pub workers: Vec<WorkerSnapshot>,
}

impl MetricsSnapshot {
    /// JSON encoding for the wire protocol / bench logs.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("requests", Json::Num(self.requests as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("mean_batch_size", Json::Num(self.mean_batch_size)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("p50_us", Json::Num(self.p50_us)),
            ("p95_us", Json::Num(self.p95_us)),
            ("p99_us", Json::Num(self.p99_us)),
            ("elapsed_secs", Json::Num(self.elapsed_secs)),
        ];
        if !self.shards.is_empty() {
            pairs.push((
                "shards",
                Json::Arr(self.shards.iter().map(|s| s.to_json()).collect()),
            ));
        }
        if !self.workers.is_empty() {
            pairs.push((
                "workers",
                Json::Arr(self.workers.iter().map(|w| w.to_json()).collect()),
            ));
        }
        Json::obj(pairs)
    }
}

/// Render a snapshot (plus worker-pool utilization) in the Prometheus
/// text exposition format — `# TYPE` headers followed by
/// `name{label="v"} value` samples — so any scraper can consume the
/// `metrics_text` TCP command or the `hck serve --metrics` dump.
/// Percentiles with no data render as `NaN`, which the format allows.
pub fn render_prometheus(
    snap: &MetricsSnapshot,
    pool: &crate::util::parallel::PoolStats,
) -> String {
    use std::fmt::Write as _;
    fn num(x: f64) -> String {
        if x.is_nan() {
            "NaN".to_string()
        } else {
            format!("{x}")
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "# TYPE hck_requests_total counter");
    let _ = writeln!(out, "hck_requests_total {}", snap.requests);
    let _ = writeln!(out, "# TYPE hck_batches_total counter");
    let _ = writeln!(out, "hck_batches_total {}", snap.batches);
    let _ = writeln!(out, "# TYPE hck_batch_size_mean gauge");
    let _ = writeln!(out, "hck_batch_size_mean {}", num(snap.mean_batch_size));
    let _ = writeln!(out, "# TYPE hck_throughput_rps gauge");
    let _ = writeln!(out, "hck_throughput_rps {}", num(snap.throughput_rps));
    let _ = writeln!(out, "# TYPE hck_uptime_seconds gauge");
    let _ = writeln!(out, "hck_uptime_seconds {}", num(snap.elapsed_secs));
    let _ = writeln!(out, "# TYPE hck_latency_us summary");
    for (q, v) in [("0.5", snap.p50_us), ("0.95", snap.p95_us), ("0.99", snap.p99_us)] {
        let _ = writeln!(out, "hck_latency_us{{quantile=\"{q}\"}} {}", num(v));
    }
    let _ = writeln!(out, "# TYPE hck_pool_workers gauge");
    let _ = writeln!(out, "hck_pool_workers {}", pool.workers);
    let _ = writeln!(out, "# TYPE hck_pool_tasks_total counter");
    let _ = writeln!(out, "hck_pool_tasks_total {}", pool.tasks);
    let _ = writeln!(out, "# TYPE hck_pool_busy_frac gauge");
    let _ = writeln!(out, "hck_pool_busy_frac {}", num(pool.busy_frac()));
    if !snap.shards.is_empty() {
        let _ = writeln!(out, "# TYPE hck_shard_requests_total counter");
        for s in &snap.shards {
            let _ =
                writeln!(out, "hck_shard_requests_total{{shard=\"{}\"}} {}", s.shard, s.requests);
        }
        let _ = writeln!(out, "# TYPE hck_shard_queue_depth gauge");
        for s in &snap.shards {
            let _ =
                writeln!(out, "hck_shard_queue_depth{{shard=\"{}\"}} {}", s.shard, s.queue_depth);
        }
        let _ = writeln!(out, "# TYPE hck_shard_queue_wait_ns gauge");
        for s in &snap.shards {
            let _ = writeln!(
                out,
                "hck_shard_queue_wait_ns{{shard=\"{}\"}} {}",
                s.shard,
                num(s.queue_wait_ns)
            );
        }
        let _ = writeln!(out, "# TYPE hck_shard_busy_frac gauge");
        for s in &snap.shards {
            let _ =
                writeln!(out, "hck_shard_busy_frac{{shard=\"{}\"}} {}", s.shard, num(s.busy_frac));
        }
        let _ = writeln!(out, "# TYPE hck_shard_ns_per_query gauge");
        for s in &snap.shards {
            let _ = writeln!(
                out,
                "hck_shard_ns_per_query{{shard=\"{}\"}} {}",
                s.shard,
                num(s.ns_per_query)
            );
        }
        let _ = writeln!(out, "# TYPE hck_shard_dropped_total counter");
        for s in &snap.shards {
            let _ = writeln!(out, "hck_shard_dropped_total{{shard=\"{}\"}} {}", s.shard, s.dropped);
        }
    }
    if !snap.workers.is_empty() {
        let _ = writeln!(out, "# TYPE hck_worker_up gauge");
        for w in &snap.workers {
            let _ = writeln!(
                out,
                "hck_worker_up{{worker=\"{}\"}} {}",
                w.worker,
                u8::from(w.reachable)
            );
        }
        let _ = writeln!(out, "# TYPE hck_worker_reconnects_total counter");
        for w in &snap.workers {
            let _ = writeln!(
                out,
                "hck_worker_reconnects_total{{worker=\"{}\"}} {}",
                w.worker, w.reconnects
            );
        }
        // Lifecycle as a one-hot state-set gauge (the Prometheus idiom
        // for enums): exactly one series per worker carries a 1.
        let _ = writeln!(out, "# TYPE hck_worker_state gauge");
        for w in &snap.workers {
            for state in ["active", "draining", "retired"] {
                let _ = writeln!(
                    out,
                    "hck_worker_state{{worker=\"{}\",state=\"{state}\"}} {}",
                    w.worker,
                    u8::from(w.state == state)
                );
            }
        }
        let _ = writeln!(out, "# TYPE hck_worker_breaker_open_total counter");
        for w in &snap.workers {
            let _ = writeln!(
                out,
                "hck_worker_breaker_open_total{{worker=\"{}\"}} {}",
                w.worker, w.breaker_opens
            );
        }
        let _ = writeln!(out, "# TYPE hck_worker_drains_total counter");
        for w in &snap.workers {
            let _ = writeln!(
                out,
                "hck_worker_drains_total{{worker=\"{}\"}} {}",
                w.worker, w.drains
            );
        }
        // Hedges are counted against the straggling worker; the total
        // is the fleet-wide number of re-issued sub-batches.
        let _ = writeln!(
            out,
            "# TYPE hck_hedges_total counter\nhck_hedges_total {}",
            snap.workers.iter().map(|w| w.hedges).sum::<u64>()
        );
        // The same per-shard series as the local block above, but with a
        // `worker` label: replicated shards appear once per replica.
        let _ = writeln!(out, "# TYPE hck_shard_queue_wait_ns gauge");
        for w in &snap.workers {
            for s in &w.shards {
                let _ = writeln!(
                    out,
                    "hck_shard_queue_wait_ns{{worker=\"{}\",shard=\"{}\"}} {}",
                    w.worker,
                    s.shard,
                    num(s.queue_wait_ns)
                );
            }
        }
        let _ = writeln!(out, "# TYPE hck_shard_busy_frac gauge");
        for w in &snap.workers {
            for s in &w.shards {
                let _ = writeln!(
                    out,
                    "hck_shard_busy_frac{{worker=\"{}\",shard=\"{}\"}} {}",
                    w.worker,
                    s.shard,
                    num(s.busy_frac)
                );
            }
        }
        let _ = writeln!(out, "# TYPE hck_shard_queue_depth gauge");
        for w in &snap.workers {
            for s in &w.shards {
                let _ = writeln!(
                    out,
                    "hck_shard_queue_depth{{worker=\"{}\",shard=\"{}\"}} {}",
                    w.worker, s.shard, s.queue_depth
                );
            }
        }
        let _ = writeln!(out, "# TYPE hck_shard_requests_total counter");
        for w in &snap.workers {
            for s in &w.shards {
                let _ = writeln!(
                    out,
                    "hck_shard_requests_total{{worker=\"{}\",shard=\"{}\"}} {}",
                    w.worker, s.shard, s.requests
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(&[1e-3, 2e-3, 4e-3]);
        m.record_batch(&[8e-3]);
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-12);
        assert!(s.p50_us >= 1000.0 && s.p50_us <= 4000.0, "{}", s.p50_us);
        assert!(s.p99_us >= s.p50_us);
        assert!(s.throughput_rps > 0.0);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert!(s.p50_us.is_nan());
    }

    #[test]
    fn json_roundtrip() {
        let m = Metrics::new();
        m.record_batch(&[1e-3]);
        let enc = m.snapshot().to_json().encode();
        let parsed = Json::parse(&enc).unwrap();
        assert_eq!(parsed.get("requests").unwrap().as_usize(), Some(1));
        // No shards → no shards key.
        assert!(parsed.get("shards").is_none());
    }

    #[test]
    fn shard_rows_serialize() {
        let m = Metrics::new();
        m.record_batch(&[1e-3]);
        let mut snap = m.snapshot();
        snap.shards.push(ShardSnapshot {
            shard: 1,
            rows_lo: 64,
            rows_hi: 128,
            queue_depth: 0,
            batches: 3,
            requests: 12,
            mean_batch_size: 4.0,
            ns_per_query: 1500.0,
            queue_wait_ns: 250.0,
            busy_frac: 0.5,
            dropped: 0,
        });
        let parsed = Json::parse(&snap.to_json().encode()).unwrap();
        let shards = parsed.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].get("requests").unwrap().as_usize(), Some(12));
        assert_eq!(shards[0].get("rows_hi").unwrap().as_usize(), Some(128));
        assert_eq!(shards[0].get("queue_wait_ns").unwrap().as_f64(), Some(250.0));
        assert_eq!(shards[0].get("busy_frac").unwrap().as_f64(), Some(0.5));
    }

    #[test]
    fn raw_ring_wraps_instead_of_freezing() {
        let m = Metrics::new();
        // Fill the ring with 1ms latencies, then overwrite it entirely
        // with 9ms ones: percentiles must track the *recent* window.
        m.record_batch(&vec![1e-3; RAW_CAP]);
        let before = m.snapshot();
        assert!((before.p50_us - 1000.0).abs() < 1.0, "{}", before.p50_us);
        m.record_batch(&vec![9e-3; RAW_CAP]);
        let after = m.snapshot();
        assert_eq!(after.requests, 2 * RAW_CAP as u64);
        assert!((after.p50_us - 9000.0).abs() < 1.0, "p50 froze: {}", after.p50_us);
        assert!((after.p99_us - 9000.0).abs() < 1.0, "p99 froze: {}", after.p99_us);
        // Partial overwrite keeps the ring at capacity and mixes the
        // window rather than growing or resetting it.
        m.record_batch(&vec![1e-3; RAW_CAP / 2]);
        let mixed = m.snapshot();
        assert!((mixed.p99_us - 9000.0).abs() < 1.0, "{}", mixed.p99_us);
        assert!((mixed.p50_us - 1000.0).abs() < 1.0, "{}", mixed.p50_us);
    }

    #[test]
    fn prometheus_exposition_renders() {
        let m = Metrics::new();
        m.record_batch(&[1e-3, 2e-3]);
        let mut snap = m.snapshot();
        snap.shards.push(ShardSnapshot {
            shard: 0,
            rows_lo: 0,
            rows_hi: 64,
            queue_depth: 1,
            batches: 2,
            requests: 8,
            mean_batch_size: 4.0,
            ns_per_query: 1200.0,
            queue_wait_ns: 300.0,
            busy_frac: 0.25,
            dropped: 0,
        });
        let pool = crate::util::parallel::pool_stats();
        let text = render_prometheus(&snap, &pool);
        for needle in [
            "# TYPE hck_requests_total counter",
            "hck_requests_total 2",
            "hck_latency_us{quantile=\"0.5\"}",
            "hck_latency_us{quantile=\"0.99\"}",
            "# TYPE hck_pool_busy_frac gauge",
            "hck_shard_queue_wait_ns{shard=\"0\"} 300",
            "hck_shard_busy_frac{shard=\"0\"} 0.25",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Every sample line is `name[{labels}] value` with a parseable value.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (_, value) = line.rsplit_once(' ').expect("sample line has a value");
            assert!(value.parse::<f64>().is_ok() || value == "NaN", "bad value in {line:?}");
        }
        // An empty snapshot renders NaN percentiles, not invalid JSON-isms.
        let empty = render_prometheus(&Metrics::new().snapshot(), &pool);
        assert!(empty.contains("hck_latency_us{quantile=\"0.5\"} NaN"), "{empty}");
    }

    #[test]
    fn worker_rows_serialize_and_render() {
        let m = Metrics::new();
        m.record_batch(&[1e-3]);
        let mut snap = m.snapshot();
        snap.workers.push(WorkerSnapshot {
            worker: "127.0.0.1:7981".into(),
            reconnects: 2,
            reachable: true,
            state: "active".into(),
            breaker_opens: 0,
            drains: 0,
            hedges: 3,
            shards: vec![ShardSnapshot {
                shard: 1,
                rows_lo: 64,
                rows_hi: 128,
                queue_depth: 3,
                batches: 5,
                requests: 20,
                mean_batch_size: 4.0,
                ns_per_query: 900.0,
                queue_wait_ns: 120.0,
                busy_frac: 0.75,
                dropped: 0,
            }],
        });
        snap.workers.push(WorkerSnapshot {
            worker: "127.0.0.1:7982".into(),
            reconnects: 0,
            reachable: false,
            state: "draining".into(),
            breaker_opens: 1,
            drains: 1,
            hedges: 2,
            shards: Vec::new(),
        });
        let parsed = Json::parse(&snap.to_json().encode()).unwrap();
        let workers = parsed.get("workers").unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        assert_eq!(workers[0].get("reconnects").unwrap().as_usize(), Some(2));
        assert_eq!(workers[1].get("reachable").unwrap().as_bool(), Some(false));
        let pool = crate::util::parallel::pool_stats();
        let text = render_prometheus(&snap, &pool);
        for needle in [
            "hck_worker_up{worker=\"127.0.0.1:7981\"} 1",
            "hck_worker_up{worker=\"127.0.0.1:7982\"} 0",
            "hck_worker_reconnects_total{worker=\"127.0.0.1:7981\"} 2",
            "hck_shard_queue_wait_ns{worker=\"127.0.0.1:7981\",shard=\"1\"} 120",
            "hck_shard_busy_frac{worker=\"127.0.0.1:7981\",shard=\"1\"} 0.75",
            "hck_shard_queue_depth{worker=\"127.0.0.1:7981\",shard=\"1\"} 3",
            "hck_worker_state{worker=\"127.0.0.1:7981\",state=\"active\"} 1",
            "hck_worker_state{worker=\"127.0.0.1:7981\",state=\"draining\"} 0",
            "hck_worker_state{worker=\"127.0.0.1:7982\",state=\"draining\"} 1",
            "hck_worker_breaker_open_total{worker=\"127.0.0.1:7982\"} 1",
            "hck_worker_drains_total{worker=\"127.0.0.1:7982\"} 1",
            "hck_hedges_total 5",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (_, value) = line.rsplit_once(' ').expect("sample line has a value");
            assert!(value.parse::<f64>().is_ok() || value == "NaN", "bad value in {line:?}");
        }
    }
}
