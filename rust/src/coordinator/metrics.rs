//! Serving metrics: request counters, latency histogram, batch sizes.

use crate::util::json::Json;
use std::sync::Mutex;
use std::time::Instant;

/// Log-scale latency histogram (microseconds) + aggregate counters.
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

struct Inner {
    requests: u64,
    batches: u64,
    batch_size_sum: u64,
    /// Histogram buckets: [1µs, 2µs, 4µs, ...] (powers of two), 40 deep.
    latency_us: [u64; 40],
    latencies_sorted_cache: Vec<f64>,
    /// Raw latencies (µs), bounded ring for percentile reporting.
    raw: Vec<f64>,
}

const RAW_CAP: usize = 65536;

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            inner: Mutex::new(Inner {
                requests: 0,
                batches: 0,
                batch_size_sum: 0,
                latency_us: [0; 40],
                latencies_sorted_cache: Vec::new(),
                raw: Vec::new(),
            }),
            started: Instant::now(),
        }
    }

    /// Record one served batch: per-request latencies in seconds.
    pub fn record_batch(&self, latencies_secs: &[f64]) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_size_sum += latencies_secs.len() as u64;
        for &s in latencies_secs {
            g.requests += 1;
            let us = (s * 1e6).max(0.0);
            let bucket = (us.max(1.0).log2().floor() as usize).min(39);
            g.latency_us[bucket] += 1;
            if g.raw.len() < RAW_CAP {
                g.raw.push(us);
            }
        }
        g.latencies_sorted_cache.clear();
    }

    /// Snapshot of the current counters.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut g = self.inner.lock().unwrap();
        if g.latencies_sorted_cache.is_empty() && !g.raw.is_empty() {
            let mut v = g.raw.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            g.latencies_sorted_cache = v;
        }
        let pct = |p: f64| -> f64 {
            crate::util::bench::percentile_sorted(&g.latencies_sorted_cache, p)
        };
        let elapsed = self.started.elapsed().as_secs_f64();
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            mean_batch_size: if g.batches > 0 {
                g.batch_size_sum as f64 / g.batches as f64
            } else {
                0.0
            },
            throughput_rps: if elapsed > 0.0 { g.requests as f64 / elapsed } else { 0.0 },
            p50_us: pct(50.0),
            p95_us: pct(95.0),
            p99_us: pct(99.0),
            elapsed_secs: elapsed,
            shards: Vec::new(),
        }
    }
}

/// Point-in-time counters of one shard worker (sharded serving mode);
/// attached to [`MetricsSnapshot::shards`] by
/// [`super::service::PredictionService::snapshot`].
#[derive(Debug, Clone)]
pub struct ShardSnapshot {
    /// Shard index.
    pub shard: usize,
    /// Global tree-order row range the shard owns.
    pub rows_lo: usize,
    /// End of the owned range (exclusive).
    pub rows_hi: usize,
    /// Sub-batches submitted but not yet finished.
    pub queue_depth: usize,
    /// Sub-batches served.
    pub batches: u64,
    /// Queries served.
    pub requests: u64,
    /// Mean sub-batch size.
    pub mean_batch_size: f64,
    /// Mean evaluation time per query, in ns (queueing excluded).
    pub ns_per_query: f64,
    /// Queries whose worker-side evaluation failed — a panic contained
    /// to one sub-batch, a failed variance factorization, or a dead
    /// worker thread. The affected requests receive typed
    /// `PredictError::Shard`/`Internal` replies, so a non-zero count
    /// here signals worker-level faults. Requests rejected *before*
    /// reaching a worker (bad dimensions, unsupported capabilities) are
    /// not counted here — they never enter a shard queue.
    pub dropped: u64,
}

impl ShardSnapshot {
    /// JSON encoding (one row of the snapshot's "shards" array).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard", Json::Num(self.shard as f64)),
            ("rows_lo", Json::Num(self.rows_lo as f64)),
            ("rows_hi", Json::Num(self.rows_hi as f64)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("mean_batch_size", Json::Num(self.mean_batch_size)),
            ("ns_per_query", Json::Num(self.ns_per_query)),
            ("dropped", Json::Num(self.dropped as f64)),
        ])
    }
}

/// Point-in-time metrics view.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub throughput_rps: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub elapsed_secs: f64,
    /// Per-shard counters when the model behind the service is sharded
    /// (empty for single-replica predictors).
    pub shards: Vec<ShardSnapshot>,
}

impl MetricsSnapshot {
    /// JSON encoding for the wire protocol / bench logs.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("requests", Json::Num(self.requests as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("mean_batch_size", Json::Num(self.mean_batch_size)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("p50_us", Json::Num(self.p50_us)),
            ("p95_us", Json::Num(self.p95_us)),
            ("p99_us", Json::Num(self.p99_us)),
            ("elapsed_secs", Json::Num(self.elapsed_secs)),
        ];
        if !self.shards.is_empty() {
            pairs.push((
                "shards",
                Json::Arr(self.shards.iter().map(|s| s.to_json()).collect()),
            ));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(&[1e-3, 2e-3, 4e-3]);
        m.record_batch(&[8e-3]);
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-12);
        assert!(s.p50_us >= 1000.0 && s.p50_us <= 4000.0, "{}", s.p50_us);
        assert!(s.p99_us >= s.p50_us);
        assert!(s.throughput_rps > 0.0);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert!(s.p50_us.is_nan());
    }

    #[test]
    fn json_roundtrip() {
        let m = Metrics::new();
        m.record_batch(&[1e-3]);
        let enc = m.snapshot().to_json().encode();
        let parsed = Json::parse(&enc).unwrap();
        assert_eq!(parsed.get("requests").unwrap().as_usize(), Some(1));
        // No shards → no shards key.
        assert!(parsed.get("shards").is_none());
    }

    #[test]
    fn shard_rows_serialize() {
        let m = Metrics::new();
        m.record_batch(&[1e-3]);
        let mut snap = m.snapshot();
        snap.shards.push(ShardSnapshot {
            shard: 1,
            rows_lo: 64,
            rows_hi: 128,
            queue_depth: 0,
            batches: 3,
            requests: 12,
            mean_batch_size: 4.0,
            ns_per_query: 1500.0,
            dropped: 0,
        });
        let parsed = Json::parse(&snap.to_json().encode()).unwrap();
        let shards = parsed.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].get("requests").unwrap().as_usize(), Some(12));
        assert_eq!(shards[0].get("rows_hi").unwrap().as_usize(), Some(128));
    }
}
