//! TCP wire protocol: newline-delimited JSON.
//!
//! Requests:
//!   {"features": [f, ...]}            → {"prediction": [...], "latency_ms": x}
//!   {"cmd": "metrics"}                → metrics snapshot object
//!   {"cmd": "ping"}                   → {"ok": true}
//!   {"cmd": "shutdown"}               → {"ok": true} and the server stops
//! Malformed input → {"error": "..."}.

use super::service::PredictionService;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Serve the prediction service over a TCP listener. Blocks until a
/// `shutdown` command arrives. Returns the number of connections served.
pub fn serve_tcp(listener: TcpListener, svc: Arc<PredictionService>) -> std::io::Result<usize> {
    let stop = Arc::new(AtomicBool::new(false));
    listener.set_nonblocking(true)?;
    let mut conns = 0usize;
    let mut handles = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                conns += 1;
                let svc = svc.clone();
                let stop = stop.clone();
                handles.push(std::thread::spawn(move || handle_conn(stream, svc, stop)));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(conns)
}

fn handle_conn(stream: TcpStream, svc: Arc<PredictionService>, stop: Arc<AtomicBool>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = handle_line(&line, &svc, &stop);
        let mut text = reply.encode();
        text.push('\n');
        if writer.write_all(text.as_bytes()).is_err() {
            break;
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
    let _ = peer;
}

/// Process one protocol line (exposed for unit testing without sockets).
pub fn handle_line(line: &str, svc: &PredictionService, stop: &AtomicBool) -> Json {
    let parsed = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return Json::obj(vec![("error", Json::Str(format!("bad json: {e}")))]),
    };
    if let Some(cmd) = parsed.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "metrics" => svc.snapshot().to_json(),
            "ping" => Json::obj(vec![("ok", Json::Bool(true))]),
            "shutdown" => {
                stop.store(true, Ordering::SeqCst);
                Json::obj(vec![("ok", Json::Bool(true))])
            }
            other => Json::obj(vec![("error", Json::Str(format!("unknown cmd '{other}'")))]),
        };
    }
    let Some(features) = parsed.get("features").and_then(|f| f.to_f64s()) else {
        return Json::obj(vec![("error", Json::Str("missing 'features'".into()))]);
    };
    if svc.dim() > 0 && features.len() != svc.dim() {
        return Json::obj(vec![(
            "error",
            Json::Str(format!("expected {} features, got {}", svc.dim(), features.len())),
        )]);
    }
    let t = std::time::Instant::now();
    match svc.predict(features) {
        Ok(pred) => Json::obj(vec![
            ("prediction", Json::from_f64s(&pred)),
            ("latency_ms", Json::Num(t.elapsed().as_secs_f64() * 1e3)),
        ]),
        Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{BatchPolicy, Predictor};
    use crate::linalg::Mat;

    struct Echo;
    impl Predictor for Echo {
        fn predict_batch(&self, q: &Mat) -> Mat {
            Mat::from_fn(q.rows(), 1, |i, _| q.row(i)[0] * 2.0)
        }
        fn dim(&self) -> usize {
            2
        }
        fn outputs(&self) -> usize {
            1
        }
    }

    fn svc() -> PredictionService {
        PredictionService::start(std::sync::Arc::new(Echo), BatchPolicy::default())
    }

    #[test]
    fn predict_line() {
        let s = svc();
        let stop = AtomicBool::new(false);
        let out = handle_line(r#"{"features": [3.0, 1.0]}"#, &s, &stop);
        assert_eq!(out.get("prediction").unwrap().to_f64s().unwrap(), vec![6.0]);
        assert!(out.get("latency_ms").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn command_lines() {
        let s = svc();
        let stop = AtomicBool::new(false);
        assert_eq!(
            handle_line(r#"{"cmd": "ping"}"#, &s, &stop).get("ok"),
            Some(&Json::Bool(true))
        );
        let m = handle_line(r#"{"cmd": "metrics"}"#, &s, &stop);
        assert!(m.get("requests").is_some());
        assert!(!stop.load(Ordering::SeqCst));
        handle_line(r#"{"cmd": "shutdown"}"#, &s, &stop);
        assert!(stop.load(Ordering::SeqCst));
    }

    #[test]
    fn error_lines() {
        let s = svc();
        let stop = AtomicBool::new(false);
        assert!(handle_line("not json", &s, &stop).get("error").is_some());
        assert!(handle_line(r#"{"cmd": "nope"}"#, &s, &stop).get("error").is_some());
        assert!(handle_line(r#"{"features": [1.0]}"#, &s, &stop)
            .get("error")
            .is_some()); // wrong dim
        assert!(handle_line(r#"{"x": 1}"#, &s, &stop).get("error").is_some());
    }

    #[test]
    fn tcp_roundtrip() {
        use std::io::{BufRead, BufReader, Write};
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let service = std::sync::Arc::new(svc());
        let server = std::thread::spawn(move || serve_tcp(listener, service).unwrap());

        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.write_all(b"{\"features\": [2.0, 0.0]}\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("prediction").unwrap().to_f64s().unwrap(), vec![4.0]);
        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let served = server.join().unwrap();
        assert!(served >= 1);
    }
}
