//! TCP wire protocol: newline-delimited JSON, versions 1 and 2.
//!
//! **v1 frames** (unchanged, still accepted — existing clients keep
//! getting correct mean predictions):
//!   {"features": [f, ...]}            → {"prediction": [...], "latency_ms": x}
//!   {"cmd": "metrics"}                → metrics snapshot object
//!   {"cmd": "ping"}                   → {"ok": true}
//!   {"cmd": "shutdown"}               → {"ok": true} and the server stops
//!   malformed input                   → {"error": "..."} (plain string)
//!
//! **v2 frames** (typed, capability-based — [`crate::infer`]):
//!   {"v": 2, "queries": [[...], ...],
//!    "want": {"variance": true, "leaf_route": true}}
//!     → {"v": 2, "mean": [[...], ...], "variance": [...],
//!        "routes": [{"shard": s|null, "rows_lo": l, "rows_hi": h}, ...],
//!        "per_query_ns": x, "latency_ms": y}
//!   {"cmd": "schema"}                 → model schema + capability set
//!   {"cmd": "metrics_text"}           → {"content_type": "text/plain; version=0.0.4",
//!                                        "text": "<Prometheus exposition>"}
//!   errors → {"v": 2, "error": {"kind": "bad_request" | "unsupported" |
//!             "shard_failure" | "internal", "message": "..."}}
//!
//! A v2 frame is recognized by `"v": 2` or a `"queries"`/`"want"` key;
//! `"queries"` may be replaced by a single `"features"` row. All rows of
//! one frame are submitted before any reply is awaited, so a frame forms
//! one dynamic batch. Malformed frames produce typed error replies and
//! never kill the connection or the batcher ("bad frame ≠ dead worker").
//!
//! **Request ids.** Every v2 reply carries `"request_ids"`: the
//! service-minted id of each query row, in row order — the same ids that
//! tag the `coord.queue_wait` / `coord.execute` spans in a trace
//! ([`crate::obs`]), so a slow wire reply can be joined to its exact
//! spans. A client may also put its own `"request_id"` (any JSON value)
//! on a v2 frame; it is echoed verbatim on the reply — success or error
//! — for client-side correlation over pipelined frames.

use super::service::PredictionService;
use crate::infer::{InferResult, PredictError, Want};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Serve the prediction service over a TCP listener. Blocks until a
/// `shutdown` command arrives. Returns the number of connections served.
pub fn serve_tcp(listener: TcpListener, svc: Arc<PredictionService>) -> std::io::Result<usize> {
    let stop = Arc::new(AtomicBool::new(false));
    listener.set_nonblocking(true)?;
    let mut conns = 0usize;
    let mut handles = Vec::new();
    // ORDERING: SeqCst — shutdown control plane; one load per accept
    // iteration, so strength is free and keeps the flag trivially
    // coherent with the store in handle_line.
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                conns += 1;
                let svc = svc.clone();
                let stop = stop.clone();
                handles.push(std::thread::spawn(move || handle_conn(stream, svc, stop)));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(conns)
}

fn handle_conn(stream: TcpStream, svc: Arc<PredictionService>, stop: Arc<AtomicBool>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = handle_line(&line, &svc, &stop);
        let mut text = reply.encode();
        text.push('\n');
        if writer.write_all(text.as_bytes()).is_err() {
            break;
        }
        // ORDERING: SeqCst — shutdown control plane, checked once per
        // request line; matches the store in handle_line.
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
    let _ = peer;
}

/// Process one protocol line (exposed for unit testing without sockets).
pub fn handle_line(line: &str, svc: &PredictionService, stop: &AtomicBool) -> Json {
    let parsed = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return Json::obj(vec![("error", Json::Str(format!("bad json: {e}")))]),
    };
    if let Some(cmd) = parsed.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "metrics" => svc.snapshot().to_json(),
            "metrics_text" => metrics_text_reply(svc),
            "ping" => Json::obj(vec![("ok", Json::Bool(true))]),
            "schema" => schema_reply(svc),
            "worker_add" | "worker_drain" | "workers" => admin_reply(cmd, &parsed, svc),
            "shutdown" => {
                // ORDERING: SeqCst — single shutdown store; pairs with
                // the accept-loop and per-connection loads above.
                stop.store(true, Ordering::SeqCst);
                Json::obj(vec![("ok", Json::Bool(true))])
            }
            other => Json::obj(vec![("error", Json::Str(format!("unknown cmd '{other}'")))]),
        };
    }
    // v2 frames are marked explicitly or carry v2-only keys.
    let is_v2 = parsed.get("v").and_then(|v| v.as_usize()) == Some(2)
        || parsed.get("queries").is_some()
        || parsed.get("want").is_some();
    if is_v2 {
        return match v2_reply(&parsed, svc) {
            Ok(reply) => reply,
            Err(e) => {
                let mut pairs = vec![("v", Json::Num(2.0)), ("error", e.to_json())];
                if let Some(rid) = parsed.get("request_id") {
                    pairs.push(("request_id", rid.clone()));
                }
                Json::obj(pairs)
            }
        };
    }
    // ---- v1 path, byte-compatible with existing clients. ----
    let Some(features) = parsed.get("features").and_then(|f| f.to_f64s()) else {
        return Json::obj(vec![("error", Json::Str("missing 'features'".into()))]);
    };
    if svc.dim() > 0 && features.len() != svc.dim() {
        return Json::obj(vec![(
            "error",
            Json::Str(format!("expected {} features, got {}", svc.dim(), features.len())),
        )]);
    }
    let t = std::time::Instant::now();
    match svc.predict(features) {
        Ok(pred) => Json::obj(vec![
            ("prediction", Json::from_f64s(&pred)),
            ("latency_ms", Json::Num(t.elapsed().as_secs_f64() * 1e3)),
        ]),
        Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
    }
}

/// The `metrics_text` command: the service + pool + shard counters
/// rendered as Prometheus text exposition, wrapped in a JSON envelope so
/// the newline-delimited framing stays intact (newlines are escaped
/// inside the JSON string). Scrapers unwrap `.text` and serve it as
/// `content_type` verbatim.
fn metrics_text_reply(svc: &PredictionService) -> Json {
    let snap = svc.snapshot();
    let pool = crate::util::parallel::pool_stats();
    Json::obj(vec![
        ("content_type", Json::Str("text/plain; version=0.0.4".into())),
        ("text", Json::Str(super::metrics::render_prometheus(&snap, &pool))),
    ])
}

/// The `worker_add` / `worker_drain` / `workers` admin commands:
/// replica-lifecycle control forwarded to the predictor. `worker_add`
/// and `worker_drain` take the target in `addr`; predictors without a
/// dynamic topology answer with a typed `unsupported` error.
fn admin_reply(cmd: &str, parsed: &Json, svc: &PredictionService) -> Json {
    let addr = parsed.get("addr").and_then(|a| a.as_str()).unwrap_or("");
    if addr.is_empty() && cmd != "workers" {
        return Json::obj(vec![(
            "error",
            Json::Str(format!("cmd '{cmd}' needs an 'addr' field")),
        )]);
    }
    match svc.admin(cmd, addr) {
        Ok(reply) => reply,
        Err(e) => Json::obj(vec![("error", e.to_json())]),
    }
}

/// The `schema` command: dimension, outputs, capability set, supported
/// protocol versions, and — when the predictor wraps a self-describing
/// artifact — the full model schema.
fn schema_reply(svc: &PredictionService) -> Json {
    let mut pairs = vec![
        ("dim", Json::Num(svc.dim() as f64)),
        ("capabilities", svc.capabilities().to_json()),
        (
            "protocol_versions",
            Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]),
        ),
    ];
    if let Some(model) = svc.schema_json() {
        pairs.push(("model", model));
    }
    Json::obj(pairs)
}

/// Serve one v2 frame: parse queries + want, submit every row before
/// gathering (one frame = one dynamic batch), assemble the typed reply.
fn v2_reply(parsed: &Json, svc: &PredictionService) -> InferResult<Json> {
    let rows = parse_queries(parsed)?;
    let want = parse_want(parsed.get("want"))?;
    // Validate the whole frame before submitting anything: a frame with
    // one bad row must not enqueue (and evaluate, and count in the
    // metrics) its good rows only to discard their results. `submit`
    // re-runs the same checks per row — deliberate: this loop buys
    // frame atomicity, submit's copy guards direct callers, and both
    // call the same helpers so they cannot drift; the double scan is
    // O(rows·d), noise next to evaluation.
    svc.capabilities().check(want)?;
    for row in &rows {
        crate::infer::validate_features(row, svc.dim())?;
    }
    let t = std::time::Instant::now();
    let mut receivers = Vec::with_capacity(rows.len());
    let mut ids = Vec::with_capacity(receivers.capacity());
    for row in rows {
        let (id, rrx) = svc.submit(row, want)?;
        ids.push(id);
        receivers.push(rrx);
    }
    let mut replies = Vec::with_capacity(receivers.len());
    for rrx in receivers {
        let reply = rrx
            .recv()
            .map_err(|_| PredictError::Internal("service dropped request".into()))??;
        replies.push(reply);
    }
    let mut pairs = vec![
        ("v", Json::Num(2.0)),
        (
            "mean",
            Json::Arr(replies.iter().map(|r| Json::from_f64s(&r.mean)).collect()),
        ),
        (
            "request_ids",
            Json::Arr(ids.iter().map(|&id| Json::Num(id as f64)).collect()),
        ),
    ];
    if want.variance {
        pairs.push((
            "variance",
            Json::Arr(
                replies
                    .iter()
                    .map(|r| match r.variance {
                        Some(v) => Json::Num(v),
                        None => Json::Null,
                    })
                    .collect(),
            ),
        ));
    }
    if want.leaf_route {
        pairs.push((
            "routes",
            Json::Arr(
                replies
                    .iter()
                    .map(|r| match &r.route {
                        Some(route) => route.to_json(),
                        None => Json::Null,
                    })
                    .collect(),
            ),
        ));
    }
    let mean_ns =
        replies.iter().map(|r| r.per_query_ns).sum::<f64>() / replies.len().max(1) as f64;
    pairs.push(("per_query_ns", Json::Num(mean_ns)));
    pairs.push(("latency_ms", Json::Num(t.elapsed().as_secs_f64() * 1e3)));
    // Echo a client-supplied frame-level request_id verbatim (any JSON
    // value — clients correlate pipelined frames with it).
    if let Some(rid) = parsed.get("request_id") {
        pairs.push(("request_id", rid.clone()));
    }
    Ok(Json::obj(pairs))
}

/// Extract the query rows of a v2 frame: `"queries"` (array of feature
/// arrays) or a single `"features"` row.
fn parse_queries(parsed: &Json) -> InferResult<Vec<Vec<f64>>> {
    if let Some(queries) = parsed.get("queries") {
        let arr = queries
            .as_arr()
            .ok_or_else(|| PredictError::BadRequest("'queries' must be an array".into()))?;
        if arr.is_empty() {
            return Err(PredictError::BadRequest("'queries' is empty".into()));
        }
        arr.iter()
            .enumerate()
            .map(|(i, row)| {
                row.to_f64s().ok_or_else(|| {
                    PredictError::BadRequest(format!(
                        "query {i} is not an array of numbers"
                    ))
                })
            })
            .collect()
    } else if let Some(features) = parsed.get("features").and_then(|f| f.to_f64s()) {
        Ok(vec![features])
    } else {
        Err(PredictError::BadRequest(
            "missing 'queries' (or 'features')".into(),
        ))
    }
}

/// Parse the `"want"` flag object (absent = mean only). Unknown keys —
/// and `"mean": false`, which the protocol cannot honor (the mean is
/// always served) — are rejected so client mistakes fail loudly instead
/// of silently serving something else.
fn parse_want(want: Option<&Json>) -> InferResult<Want> {
    let Some(want) = want else {
        return Ok(Want::mean_only());
    };
    let Json::Obj(map) = want else {
        return Err(PredictError::BadRequest("'want' must be an object".into()));
    };
    let mut out = Want::mean_only();
    for (key, val) in map {
        let flag = val.as_bool().ok_or_else(|| {
            PredictError::BadRequest(format!("want.{key} must be a boolean"))
        })?;
        match key.as_str() {
            "mean" => {
                if !flag {
                    return Err(PredictError::BadRequest(
                        "want.mean cannot be false — the mean is always served".into(),
                    ));
                }
            }
            "variance" => out.variance = flag,
            "leaf_route" => out.leaf_route = flag,
            other => {
                return Err(PredictError::BadRequest(format!(
                    "unknown want flag '{other}' (mean | variance | leaf_route)"
                )))
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{BatchPolicy, Predictor};
    use crate::infer::{Capabilities, LeafRoute, PredictRequest, PredictResponse};
    use crate::linalg::Mat;

    struct Echo;
    impl Predictor for Echo {
        fn predict(&self, req: &PredictRequest) -> InferResult<PredictResponse> {
            let q = &req.queries;
            let mean = Mat::from_fn(q.rows(), 1, |i, _| q.row(i)[0] * 2.0);
            let variance = if req.want.variance {
                Some((0..q.rows()).map(|i| q.row(i)[1].abs()).collect())
            } else {
                None
            };
            let routes = if req.want.leaf_route {
                Some(
                    (0..q.rows())
                        .map(|_| LeafRoute { shard: Some(0), rows_lo: 0, rows_hi: 4 })
                        .collect(),
                )
            } else {
                None
            };
            Ok(PredictResponse { mean, variance, routes, per_query_ns: 10.0 })
        }
        fn dim(&self) -> usize {
            2
        }
        fn outputs(&self) -> usize {
            1
        }
        fn capabilities(&self) -> Capabilities {
            Capabilities { mean: true, variance: true, leaf_route: true }
        }
    }

    fn svc() -> PredictionService {
        PredictionService::start(std::sync::Arc::new(Echo), BatchPolicy::default())
    }

    #[test]
    fn predict_line() {
        let s = svc();
        let stop = AtomicBool::new(false);
        let out = handle_line(r#"{"features": [3.0, 1.0]}"#, &s, &stop);
        assert_eq!(out.get("prediction").unwrap().to_f64s().unwrap(), vec![6.0]);
        assert!(out.get("latency_ms").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn admin_commands_answer_typed_errors_without_a_registry() {
        let s = svc();
        let stop = AtomicBool::new(false);
        // Lifecycle verbs need a target address.
        let bad = handle_line(r#"{"cmd": "worker_add"}"#, &s, &stop);
        assert!(bad.get("error").unwrap().as_str().unwrap().contains("addr"));
        // Echo has no dynamic replica topology: typed unsupported error.
        let out =
            handle_line(r#"{"cmd": "worker_drain", "addr": "127.0.0.1:1"}"#, &s, &stop);
        let err = out.get("error").unwrap();
        assert_eq!(err.get("kind").and_then(|k| k.as_str()), Some("unsupported"));
        let ws = handle_line(r#"{"cmd": "workers"}"#, &s, &stop);
        assert_eq!(
            ws.get("error").unwrap().get("kind").and_then(|k| k.as_str()),
            Some("unsupported")
        );
    }

    #[test]
    fn command_lines() {
        let s = svc();
        let stop = AtomicBool::new(false);
        assert_eq!(
            handle_line(r#"{"cmd": "ping"}"#, &s, &stop).get("ok"),
            Some(&Json::Bool(true))
        );
        let m = handle_line(r#"{"cmd": "metrics"}"#, &s, &stop);
        assert!(m.get("requests").is_some());
        let mt = handle_line(r#"{"cmd": "metrics_text"}"#, &s, &stop);
        let text = mt.get("text").unwrap().as_str().unwrap();
        assert!(text.contains("# TYPE hck_requests_total counter"), "{text}");
        assert!(text.contains("hck_pool_workers"), "{text}");
        assert!(mt.get("content_type").unwrap().as_str().unwrap().starts_with("text/plain"));
        let sch = handle_line(r#"{"cmd": "schema"}"#, &s, &stop);
        assert_eq!(sch.get("dim").unwrap().as_usize(), Some(2));
        let caps = sch.get("capabilities").unwrap();
        assert_eq!(caps.get("variance").unwrap().as_bool(), Some(true));
        assert!(!stop.load(Ordering::SeqCst));
        handle_line(r#"{"cmd": "shutdown"}"#, &s, &stop);
        assert!(stop.load(Ordering::SeqCst));
    }

    #[test]
    fn error_lines() {
        let s = svc();
        let stop = AtomicBool::new(false);
        assert!(handle_line("not json", &s, &stop).get("error").is_some());
        assert!(handle_line(r#"{"cmd": "nope"}"#, &s, &stop).get("error").is_some());
        assert!(handle_line(r#"{"features": [1.0]}"#, &s, &stop)
            .get("error")
            .is_some()); // wrong dim
        assert!(handle_line(r#"{"x": 1}"#, &s, &stop).get("error").is_some());
    }

    #[test]
    fn v2_frame_serves_requested_columns() {
        let s = svc();
        let stop = AtomicBool::new(false);
        let out = handle_line(
            r#"{"v": 2, "queries": [[3.0, 1.0], [1.0, -2.0]],
                "want": {"variance": true, "leaf_route": true}}"#,
            &s,
            &stop,
        );
        let mean = out.get("mean").unwrap().as_arr().unwrap();
        assert_eq!(mean.len(), 2);
        assert_eq!(mean[0].to_f64s().unwrap(), vec![6.0]);
        assert_eq!(mean[1].to_f64s().unwrap(), vec![2.0]);
        let var = out.get("variance").unwrap().as_arr().unwrap();
        assert_eq!(var[1].as_f64(), Some(2.0));
        let routes = out.get("routes").unwrap().as_arr().unwrap();
        assert_eq!(routes[0].get("rows_hi").unwrap().as_usize(), Some(4));
        assert!(out.get("per_query_ns").unwrap().as_f64().unwrap() >= 0.0);
        // Every v2 reply names the service-minted id of each row.
        let ids = out.get("request_ids").unwrap().as_arr().unwrap();
        assert_eq!(ids.len(), 2);
        assert!(ids.iter().all(|id| id.as_f64().unwrap() >= 1.0));

        // Mean-only v2 frame: no optional columns in the reply.
        let out = handle_line(r#"{"v": 2, "features": [2.0, 0.0]}"#, &s, &stop);
        assert_eq!(
            out.get("mean").unwrap().as_arr().unwrap()[0].to_f64s().unwrap(),
            vec![4.0]
        );
        assert!(out.get("variance").is_none() && out.get("routes").is_none());
    }

    #[test]
    fn v2_errors_are_typed_and_do_not_kill_the_loop() {
        let s = svc();
        let stop = AtomicBool::new(false);
        // Wrong dimension → typed bad_request.
        let out = handle_line(r#"{"v": 2, "queries": [[1.0]]}"#, &s, &stop);
        let err = out.get("error").unwrap();
        assert_eq!(err.get("kind").unwrap().as_str(), Some("bad_request"));
        // Non-finite feature (JSON null → NaN is unparseable; use a huge
        // exponent that overflows to inf).
        let out = handle_line(r#"{"v": 2, "queries": [[1e999, 0.0]]}"#, &s, &stop);
        assert_eq!(
            out.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("bad_request")
        );
        // Unknown want flag → typed bad_request naming the flag.
        let out = handle_line(
            r#"{"v": 2, "queries": [[1.0, 1.0]], "want": {"varaince": true}}"#,
            &s,
            &stop,
        );
        let msg = out.get("error").unwrap().get("message").unwrap().as_str().unwrap();
        assert!(msg.contains("varaince"), "{msg}");
        // want.mean = false cannot be honored — loud rejection.
        let out = handle_line(
            r#"{"v": 2, "queries": [[1.0, 1.0]], "want": {"mean": false}}"#,
            &s,
            &stop,
        );
        assert_eq!(
            out.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("bad_request")
        );
        // The service survives all of it.
        let out = handle_line(r#"{"v": 2, "features": [1.0, 0.0]}"#, &s, &stop);
        assert!(out.get("error").is_none());
        assert!(!stop.load(Ordering::SeqCst));
    }

    #[test]
    fn tcp_roundtrip() {
        use std::io::{BufRead, BufReader, Write};
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let service = std::sync::Arc::new(svc());
        let server = std::thread::spawn(move || serve_tcp(listener, service).unwrap());

        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.write_all(b"{\"features\": [2.0, 0.0]}\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("prediction").unwrap().to_f64s().unwrap(), vec![4.0]);
        // v2 on the same connection, with a client frame-level request_id:
        // echoed verbatim, alongside the server-minted per-row ids.
        conn.write_all(
            b"{\"v\": 2, \"queries\": [[2.0, 3.0]], \"want\": {\"variance\": true}, \
               \"request_id\": \"client-7\"}\n",
        )
        .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(
            resp.get("variance").unwrap().as_arr().unwrap()[0].as_f64(),
            Some(3.0)
        );
        assert_eq!(resp.get("request_id").unwrap().as_str(), Some("client-7"));
        let ids = resp.get("request_ids").unwrap().as_arr().unwrap();
        assert_eq!(ids.len(), 1);
        assert!(ids[0].as_f64().unwrap() >= 1.0);
        // A typed v2 error still echoes the client id.
        conn.write_all(b"{\"v\": 2, \"queries\": [[1.0]], \"request_id\": 42}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert!(resp.get("error").is_some());
        assert_eq!(resp.get("request_id").unwrap().as_f64(), Some(42.0));
        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let served = server.join().unwrap();
        assert!(served >= 1);
    }
}
