//! Unified kernel ridge regression front-end over all five engines
//! compared in the paper (Section 5): hierarchical (the contribution),
//! Nyström, random Fourier features, cross-domain independent, and the
//! exact dense reference. Classification is one-vs-all regression on ±1
//! targets (the setup the paper uses for its binary/multiclass sets).

use crate::approx::{ExactKrr, FourierKrr, IndependentKrr, NystromKrr};
use crate::data::Dataset;
use crate::error::Result;
use crate::hkernel::{HConfig, HFactors, HPredictor, HSolver};
use crate::kernels::KernelKind;
use crate::linalg::Mat;
use crate::partition::SplitRule;
use crate::util::rng::Rng;
use crate::util::timer::Phases;

/// Which engine to train.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EngineSpec {
    /// The paper's hierarchically compositional kernel with level rank r.
    Hierarchical { rank: usize },
    /// Nyström low-rank kernel with r landmarks.
    Nystrom { rank: usize },
    /// Random Fourier features with r frequencies.
    Fourier { rank: usize },
    /// Cross-domain independent kernel with leaf size n0 (comparable r).
    Independent { n0: usize },
    /// Exact dense kernel (reference; O(n³)).
    Exact,
}

impl EngineSpec {
    /// Short name for reports (matches the paper's legends).
    pub fn name(&self) -> &'static str {
        match self {
            EngineSpec::Hierarchical { .. } => "hierarchical",
            EngineSpec::Nystrom { .. } => "nystrom",
            EngineSpec::Fourier { .. } => "fourier",
            EngineSpec::Independent { .. } => "independent",
            EngineSpec::Exact => "exact",
        }
    }

    /// The comparable size parameter r (Section 5.1: "the quantity r is
    /// comparable across kernels").
    pub fn r(&self) -> usize {
        match self {
            EngineSpec::Hierarchical { rank }
            | EngineSpec::Nystrom { rank }
            | EngineSpec::Fourier { rank } => *rank,
            EngineSpec::Independent { n0 } => *n0,
            EngineSpec::Exact => 0,
        }
    }
}

/// Full training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Base kernel family + bandwidth σ.
    pub kind: KernelKind,
    /// Ridge regularization λ.
    pub lambda: f64,
    /// Engine selection.
    pub engine: EngineSpec,
    /// Partitioning rule for tree-based engines.
    pub rule: SplitRule,
    /// Random seed (landmarks, partitioning, frequencies).
    pub seed: u64,
    /// λ′ base-kernel stabilizer for the hierarchical engine (§4.3).
    pub lambda_prime: f64,
}

impl TrainConfig {
    /// Defaults: λ = 0.01 (the paper's reasonable default), RP splits.
    pub fn new(kind: KernelKind, engine: EngineSpec) -> TrainConfig {
        TrainConfig {
            kind,
            lambda: 0.01,
            engine,
            rule: SplitRule::RandomProjection,
            seed: 0,
            lambda_prime: 1e-8,
        }
    }

    /// Builder-style overrides.
    pub fn with_lambda(mut self, lambda: f64) -> Self {
        self.lambda = lambda;
        self
    }
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    pub fn with_rule(mut self, rule: SplitRule) -> Self {
        self.rule = rule;
        self
    }
    pub fn with_sigma(mut self, sigma: f64) -> Self {
        self.kind = self.kind.with_sigma(sigma);
        self
    }
}

/// The engine-specific fitted state. Crate-visible so the [`crate::model`]
/// persistence layer can serialize each variant's parts and rebuild a
/// [`KrrModel`] from an artifact without refitting.
pub(crate) enum FittedEngine {
    Hierarchical {
        factors: std::sync::Arc<HFactors>,
        w: Mat,
        /// Long-lived Algorithm-3 predictor (precomputed once at fit).
        predictor: HPredictor,
    },
    Nystrom(NystromKrr),
    Fourier(FourierKrr),
    Independent(IndependentKrr),
    Exact(ExactKrr),
}

/// A fitted KRR model (any engine), with training phase timings and the
/// Section 5 memory estimate attached.
pub struct KrrModel {
    engine: FittedEngine,
    /// Phase timing breakdown of `fit`.
    pub phases: Phases,
    /// Estimated memory footprint in f64 words (the paper's §5 model:
    /// ≈ 4nr hierarchical, ≈ nr for the others).
    pub memory_words: usize,
    /// Feature dimension d, recorded at fit time so every engine can
    /// report it (the serving layer validates request lengths with it).
    dim: usize,
    /// Output columns m, recorded at fit time.
    n_outputs: usize,
    cfg: TrainConfig,
}

impl KrrModel {
    /// Train on features `x` (n x d) and target matrix `y` (n x m).
    pub fn fit(cfg: &TrainConfig, x: &Mat, y: &Mat) -> Result<KrrModel> {
        let mut phases = Phases::new();
        let mut rng = Rng::new(cfg.seed);
        let n = x.rows();
        let (engine, memory_words) = match cfg.engine {
            EngineSpec::Hierarchical { rank } => {
                let mut hcfg = HConfig::new(cfg.kind, rank)
                    .with_seed(cfg.seed)
                    .with_rule(cfg.rule);
                hcfg.n0 = rank.max(1);
                hcfg.lambda_prime = cfg.lambda_prime.min(cfg.lambda * 0.5);
                let factors = phases.scope("instantiate", || HFactors::build(x, hcfg))?;
                let lambda_eff = (cfg.lambda - factors.config.lambda_prime).max(1e-12);
                let w = {
                    let solver =
                        phases.scope("factor", || HSolver::factor(&factors, lambda_eff))?;
                    phases.scope("solve", || solver.solve_mat_original(y))
                };
                let mem = factors.memory_words();
                let factors = std::sync::Arc::new(factors);
                let predictor =
                    phases.scope("predictor", || HPredictor::new(factors.clone(), &w));
                (FittedEngine::Hierarchical { factors, w, predictor }, mem)
            }
            EngineSpec::Nystrom { rank } => {
                let m = phases.scope("train", || {
                    NystromKrr::fit(cfg.kind, x, y, rank, cfg.lambda, &mut rng)
                })?;
                let mem = m.memory_words(n);
                (FittedEngine::Nystrom(m), mem)
            }
            EngineSpec::Fourier { rank } => {
                let m = phases.scope("train", || {
                    FourierKrr::fit(cfg.kind, x, y, rank, cfg.lambda, &mut rng)
                })?;
                let mem = m.memory_words(n);
                (FittedEngine::Fourier(m), mem)
            }
            EngineSpec::Independent { n0 } => {
                let m = phases.scope("train", || {
                    IndependentKrr::fit(cfg.kind, x, y, n0, cfg.rule, cfg.lambda, &mut rng)
                })?;
                // §5 memory model: r per point (leaf blocks are n0 x n0
                // but stored once; the paper normalizes to r = n0/point).
                let mem = n * n0;
                (FittedEngine::Independent(m), mem)
            }
            EngineSpec::Exact => {
                let m = phases.scope("train", || ExactKrr::fit(cfg.kind, x, y, cfg.lambda))?;
                (FittedEngine::Exact(m), n * n)
            }
        };
        Ok(KrrModel {
            engine,
            phases,
            memory_words,
            dim: x.cols(),
            n_outputs: y.cols(),
            cfg: cfg.clone(),
        })
    }

    /// Convenience: train on a [`Dataset`] (encodes targets per task).
    pub fn fit_dataset(cfg: &TrainConfig, ds: &Dataset) -> Result<KrrModel> {
        Self::fit(cfg, &ds.x, &ds.target_matrix())
    }

    /// Raw predictions (q x m).
    pub fn predict(&self, q: &Mat) -> Mat {
        match &self.engine {
            FittedEngine::Hierarchical { predictor, .. } => predictor.predict_batch(q),
            FittedEngine::Nystrom(m) => m.predict(q),
            FittedEngine::Fourier(m) => m.predict(q),
            FittedEngine::Independent(m) => m.predict(q),
            FittedEngine::Exact(m) => m.predict(q),
        }
    }

    /// Evaluate on a test set, returning the task metric
    /// (relative error for regression — lower better; accuracy for
    /// classification — higher better) per [`super::metrics::score`].
    pub fn evaluate(&self, test: &Dataset) -> f64 {
        let pred = self.predict(&test.x);
        super::metrics::score(test, &pred).0
    }

    /// Training configuration used.
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Feature dimension d the model was trained on (any engine).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of output columns m (any engine).
    pub fn outputs(&self) -> usize {
        self.n_outputs
    }

    /// Borrow the hierarchical factors, if this is the hierarchical engine
    /// (used by the coordinator for the low-latency serving path).
    pub fn hierarchical_parts(&self) -> Option<(&HFactors, &Mat)> {
        match &self.engine {
            FittedEngine::Hierarchical { factors, w, .. } => Some((factors, w)),
            _ => None,
        }
    }

    /// Borrow the long-lived Algorithm-3 predictor, if this is the
    /// hierarchical engine (the input to
    /// [`crate::shard::split_predictor`]).
    pub fn hierarchical_predictor(&self) -> Option<&HPredictor> {
        match &self.engine {
            FittedEngine::Hierarchical { predictor, .. } => Some(predictor),
            _ => None,
        }
    }

    /// Internal view of the fitted engine state, for [`crate::model`]
    /// artifact serialization.
    pub(crate) fn engine(&self) -> &FittedEngine {
        &self.engine
    }

    /// Reassemble a model from artifact parts without refitting. Phase
    /// timings are empty (nothing was trained); `memory_words` is the
    /// value recorded at fit time and carried by the artifact.
    pub(crate) fn from_engine(
        engine: FittedEngine,
        cfg: TrainConfig,
        dim: usize,
        n_outputs: usize,
        memory_words: usize,
    ) -> KrrModel {
        KrrModel { engine, phases: Phases::new(), memory_words, dim, n_outputs, cfg }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{spec_by_name, synthetic};
    use crate::kernels::Gaussian;

    fn small_regression() -> (Dataset, Dataset) {
        let spec = spec_by_name("cadata").unwrap();
        synthetic::generate(spec, 600, 150, 42)
    }

    #[test]
    fn all_engines_learn_regression() {
        let (train, test) = small_regression();
        let specs = [
            EngineSpec::Hierarchical { rank: 75 },
            EngineSpec::Nystrom { rank: 75 },
            EngineSpec::Fourier { rank: 75 },
            EngineSpec::Independent { n0: 75 },
            EngineSpec::Exact,
        ];
        for spec in specs {
            let cfg = TrainConfig::new(Gaussian::new(0.5), spec).with_seed(1);
            let model = KrrModel::fit_dataset(&cfg, &train).unwrap();
            let err = model.evaluate(&test);
            assert!(
                err < 0.8,
                "{}: rel err {err} — should beat the trivial predictor",
                spec.name()
            );
            assert!(model.memory_words > 0 || matches!(spec, EngineSpec::Exact));
        }
    }

    #[test]
    fn hierarchical_beats_nystrom_on_clustery_data() {
        // The covtype-like generator has slow eigendecay; at small r the
        // full-rank local kernels should win (the paper's headline gap).
        let spec = spec_by_name("covtype.binary").unwrap();
        let (train, test) = synthetic::generate(spec, 900, 250, 7);
        let r = 48;
        let sigma = 0.35;
        let hier = KrrModel::fit_dataset(
            &TrainConfig::new(Gaussian::new(sigma), EngineSpec::Hierarchical { rank: r })
                .with_seed(3),
            &train,
        )
        .unwrap()
        .evaluate(&test);
        let nys = KrrModel::fit_dataset(
            &TrainConfig::new(Gaussian::new(sigma), EngineSpec::Nystrom { rank: r })
                .with_seed(3),
            &train,
        )
        .unwrap()
        .evaluate(&test);
        assert!(
            hier >= nys - 0.02,
            "hierarchical acc {hier} should be >= nystrom acc {nys} - eps"
        );
    }

    #[test]
    fn multiclass_one_vs_all() {
        let spec = spec_by_name("acoustic").unwrap();
        let (train, test) = synthetic::generate(spec, 500, 120, 11);
        let cfg = TrainConfig::new(Gaussian::new(0.6), EngineSpec::Hierarchical { rank: 60 })
            .with_seed(5)
            .with_lambda(0.05);
        let model = KrrModel::fit_dataset(&cfg, &train).unwrap();
        let acc = model.evaluate(&test);
        // 3 classes: far above chance.
        assert!(acc > 0.55, "multiclass acc {acc}");
    }

    #[test]
    fn hierarchical_approaches_exact_at_full_rank() {
        let (train, test) = small_regression();
        let sigma = 0.6;
        let exact = KrrModel::fit_dataset(
            &TrainConfig::new(Gaussian::new(sigma), EngineSpec::Exact),
            &train,
        )
        .unwrap()
        .evaluate(&test);
        let hier = KrrModel::fit_dataset(
            &TrainConfig::new(
                Gaussian::new(sigma),
                EngineSpec::Hierarchical { rank: 600 },
            ),
            &train,
        )
        .unwrap()
        .evaluate(&test);
        assert!(
            (hier - exact).abs() < 0.02,
            "full-rank hierarchical {hier} vs exact {exact}"
        );
    }

    /// The serving layer rejects every request when `dim() == 0`
    /// (ISSUE 2 satellite): the dimension must be recorded at fit time
    /// for *every* engine, not inferred from hierarchical internals.
    #[test]
    fn dim_and_outputs_recorded_for_all_engines() {
        let (train, _) = small_regression();
        for spec in [
            EngineSpec::Hierarchical { rank: 40 },
            EngineSpec::Nystrom { rank: 40 },
            EngineSpec::Fourier { rank: 40 },
            EngineSpec::Independent { n0: 40 },
            EngineSpec::Exact,
        ] {
            let cfg = TrainConfig::new(Gaussian::new(0.5), spec).with_seed(2);
            let model = KrrModel::fit_dataset(&cfg, &train).unwrap();
            assert_eq!(model.dim(), train.d(), "{}", spec.name());
            assert_eq!(model.outputs(), 1, "{}", spec.name());
        }
    }

    #[test]
    fn phases_recorded() {
        let (train, _) = small_regression();
        let cfg = TrainConfig::new(Gaussian::new(0.5), EngineSpec::Hierarchical { rank: 50 });
        let model = KrrModel::fit_dataset(&cfg, &train).unwrap();
        assert!(model.phases.get("instantiate") > 0.0);
        assert!(model.phases.get("factor") > 0.0);
    }
}
