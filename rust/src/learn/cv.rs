//! Grid-search model selection over (σ, λ) — the tuning protocol of
//! Section 5.3 ("a grid search of the optimal parameters σ and λ").

use crate::data::Dataset;
use crate::error::Result;
use crate::learn::krr::{KrrModel, TrainConfig};

/// Outcome of a grid search.
#[derive(Debug, Clone)]
pub struct GridResult {
    /// Best bandwidth found.
    pub sigma: f64,
    /// Best regularization found.
    pub lambda: f64,
    /// Validation metric at the optimum (rel. error or accuracy).
    pub metric: f64,
    /// Whether higher metric is better (classification) or lower
    /// (regression).
    pub higher_is_better: bool,
    /// All evaluated grid points: (σ, λ, metric).
    pub grid: Vec<(f64, f64, f64)>,
}

/// Exhaustive grid search: trains one model per (σ, λ) pair on `train`,
/// scores on `val`, returns the winner. The same seed is used for every
/// grid point so randomness does not confound the sweep (the protocol of
/// Section 5.1: "the seed always stays the same every time the range of σ
/// is swept").
pub fn grid_search(
    base: &TrainConfig,
    sigmas: &[f64],
    lambdas: &[f64],
    train: &Dataset,
    val: &Dataset,
) -> Result<GridResult> {
    assert!(!sigmas.is_empty() && !lambdas.is_empty());
    let mut grid = Vec::with_capacity(sigmas.len() * lambdas.len());
    let mut best: Option<(f64, f64, f64)> = None;
    let mut higher_is_better = false;
    for &s in sigmas {
        for &l in lambdas {
            let cfg = base.clone().with_sigma(s).with_lambda(l);
            let model = KrrModel::fit_dataset(&cfg, train)?;
            let pred = model.predict(&val.x);
            let (metric, hib) = super::metrics::score(val, &pred);
            higher_is_better = hib;
            grid.push((s, l, metric));
            let better = match &best {
                None => true,
                Some((_, _, m)) => {
                    if hib {
                        metric > *m
                    } else {
                        metric < *m
                    }
                }
            };
            if better {
                best = Some((s, l, metric));
            }
        }
    }
    let (sigma, lambda, metric) = best.unwrap();
    Ok(GridResult { sigma, lambda, metric, higher_is_better, grid })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{spec_by_name, synthetic};
    use crate::kernels::Gaussian;
    use crate::learn::krr::EngineSpec;

    #[test]
    fn finds_interior_optimum() {
        let spec = spec_by_name("cadata").unwrap();
        let (train, val) = synthetic::generate(spec, 400, 100, 21);
        let base = TrainConfig::new(Gaussian::new(1.0), EngineSpec::Nystrom { rank: 60 })
            .with_seed(2);
        let res = grid_search(&base, &[0.05, 0.3, 2.0], &[1e-4, 1e-2], &train, &val).unwrap();
        assert_eq!(res.grid.len(), 6);
        assert!(!res.higher_is_better);
        // Best metric is the min of the grid.
        let min = res.grid.iter().map(|g| g.2).fold(f64::INFINITY, f64::min);
        assert_eq!(res.metric, min);
        assert!(res.sigma > 0.0 && res.lambda > 0.0);
    }

    #[test]
    fn classification_maximizes() {
        let spec = spec_by_name("ijcnn1").unwrap();
        let (train, val) = synthetic::generate(spec, 300, 80, 5);
        let base = TrainConfig::new(Gaussian::new(1.0), EngineSpec::Independent { n0: 50 })
            .with_seed(3);
        let res = grid_search(&base, &[0.2, 1.0], &[1e-2], &train, &val).unwrap();
        assert!(res.higher_is_better);
        let max = res.grid.iter().map(|g| g.2).fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(res.metric, max);
    }
}
