//! Kernel principal component analysis (paper Section 5.6, Figure 8).
//!
//! The embedding of the training points is U = V_m diag(√λ_m) from the
//! eigendecomposition of the *centered* kernel matrix K̃ = H K H,
//! H = I − 11ᵀ/n. For explicit-feature kernels (Nyström, Fourier) the same
//! embedding comes from PCA of the centered feature matrix. For the
//! hierarchical kernel we never densify: Lanczos runs on the centered
//! matvec, whose inner K·v is the paper's Algorithm 1 at O(nr).
//!
//! Figure 8 compares embeddings across kernels by the alignment
//! difference min_M ‖U − Ũ M‖_F / ‖U‖_F (a least-squares solve).

use crate::error::{Error, Result};
use crate::hkernel::{hmatvec, HFactors, HPredictor};
use crate::kernels::KernelKind;
use crate::linalg::{lanczos_topk, lstsq, matmul, sym_eig, Mat, Trans};
use crate::util::rng::Rng;

/// Center a square kernel matrix in place: K ← H K H.
pub fn center_kernel_matrix(k: &mut Mat) {
    let n = k.rows();
    assert_eq!(k.cols(), n);
    let nf = n as f64;
    let row_means: Vec<f64> = (0..n).map(|i| k.row(i).iter().sum::<f64>() / nf).collect();
    let total_mean = row_means.iter().sum::<f64>() / nf;
    for i in 0..n {
        for j in 0..n {
            k[(i, j)] += total_mean - row_means[i] - row_means[j];
        }
    }
}

/// Embedding from a dense kernel matrix: top-`dim` eigenpairs of the
/// centered matrix, scaled by √λ. Rows follow the matrix's row order.
///
/// Small matrices (n ≤ 256) use the dense Jacobi eigensolver; larger ones
/// use Lanczos with a dense matvec — only the leading `dim` pairs are
/// needed, so full O(n³) diagonalization would be wasted work.
pub fn embed_from_kernel_matrix(k: &Mat, dim: usize) -> Result<Mat> {
    let mut kc = k.clone();
    center_kernel_matrix(&mut kc);
    kc.symmetrize();
    let n = kc.rows();
    if n <= 256 {
        let (w, v) = sym_eig(&kc)?;
        return Ok(scale_embedding(&w, &v, dim));
    }
    let mut rng = Rng::new(0x5eed_cafe);
    let (w, v) = lanczos_topk(n, dim, dim + 40, &mut rng, |b| {
        let mut y = vec![0.0; n];
        crate::linalg::gemv(1.0, &kc, Trans::No, b, 0.0, &mut y);
        y
    })?;
    Ok(scale_embedding(&w, &v, dim))
}

/// Exact-kernel embedding of the rows of `x` (dense path). The n×n
/// block is evaluated across the worker pool.
pub fn kpca_embed_dense(kind: KernelKind, x: &Mat, dim: usize) -> Result<Mat> {
    let k = crate::kernels::par_kernel_block(kind, x);
    embed_from_kernel_matrix(&k, dim)
}

/// Embedding from an explicit feature map (Nyström / Fourier): PCA of the
/// centered features. Returns an n x dim matrix; equals the kernel-matrix
/// path because ⟨φ_c(x_i), φ_c(x_j)⟩ = K̃_ij.
pub fn kpca_embed_features(phi: &Mat, dim: usize) -> Result<Mat> {
    let (n, r) = phi.shape();
    // Center features.
    let mut mean = vec![0.0; r];
    for i in 0..n {
        for (m, v) in mean.iter_mut().zip(phi.row(i).iter()) {
            *m += v;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut pc = phi.clone();
    for i in 0..n {
        for (v, m) in pc.row_mut(i).iter_mut().zip(mean.iter()) {
            *v -= m;
        }
    }
    // Eig of the r x r covariance; project.
    let mut cov = Mat::zeros(r, r);
    crate::linalg::gemm(1.0, &pc, Trans::Yes, &pc, Trans::No, 0.0, &mut cov);
    cov.symmetrize();
    let (w, v) = sym_eig(&cov)?;
    let dim = dim.min(r);
    // Projection onto unit principal directions: U = Φc V_dim. The
    // kernel-matrix convention scales eigenvectors of K̃ by √λ, which is
    // exactly Φc times the unit right singular vectors — identical.
    let mut vdim = Mat::zeros(r, dim);
    for c in 0..dim {
        if w[c] <= 1e-12 {
            continue;
        }
        for i in 0..r {
            vdim[(i, c)] = v[(i, c)];
        }
    }
    Ok(matmul(&pc, Trans::No, &vdim, Trans::No))
}

/// Hierarchical-kernel embedding via Lanczos on the centered O(nr) matvec.
/// Returns rows in **original order**.
pub fn kpca_embed_hierarchical(
    f: &HFactors,
    dim: usize,
    iters: usize,
    rng: &mut Rng,
) -> Result<Mat> {
    let n = f.n();
    let center = |v: &[f64]| -> Vec<f64> {
        let mean = v.iter().sum::<f64>() / n as f64;
        v.iter().map(|x| x - mean).collect()
    };
    let (w, v) = lanczos_topk(n, dim, iters.max(dim + 20), rng, |b| {
        let kb = hmatvec(f, &center(b));
        center(&kb)
    })?;
    let emb_tree = scale_embedding(&w, &v, dim);
    Ok(f.rows_from_tree_order(&emb_tree))
}

/// A fitted, persistable kernel-PCA transform on the hierarchical
/// kernel: the training eigenbasis of the centered kernel matrix plus
/// the centering statistics needed to embed **new** points (the
/// Nyström-style out-of-sample extension u(x) = Λ^{-1/2} Vᵀ k̃(X, x)),
/// evaluated at O(n) per query through the fast column materialization
/// of [`HPredictor::column_with_agg`] — no densification anywhere.
///
/// This is the [`crate::model::Model`] face of Section 5.6: it fits,
/// transforms batches, and round-trips through the `HCKM` artifact
/// format like the supervised models.
pub struct KpcaTransformer {
    factors: std::sync::Arc<HFactors>,
    /// V Λ^{-1/2} (n x dim, tree order): maps a doubly centered kernel
    /// column onto the embedding coordinates.
    proj: Mat,
    /// Per-row means of the training kernel matrix (tree order).
    row_means: Vec<f64>,
    /// Grand mean of the training kernel matrix.
    grand_mean: f64,
    /// Training embedding U = V Λ^{1/2} (n x dim, **original order**).
    train_embedding: Mat,
    /// Aggregate bases for column materialization (derived state —
    /// recomputed deterministically on artifact load).
    agg: Vec<Option<Mat>>,
}

impl KpcaTransformer {
    /// Fit the transform: Lanczos on the centered O(nr) matvec for the
    /// top `dim` eigenpairs, plus the centering statistics (one extra
    /// matvec). `iters = 0` picks the default `dim + 40` budget.
    pub fn fit(
        factors: std::sync::Arc<HFactors>,
        dim: usize,
        iters: usize,
        rng: &mut Rng,
    ) -> Result<KpcaTransformer> {
        let f = factors.as_ref();
        let n = f.n();
        let dim = dim.max(1).min(n);
        let iters = if iters == 0 { dim + 40 } else { iters };
        let center = |v: &[f64]| -> Vec<f64> {
            let mean = v.iter().sum::<f64>() / n as f64;
            v.iter().map(|x| x - mean).collect()
        };
        let (wv, v) = lanczos_topk(n, dim, iters.max(dim + 2), rng, |b| {
            let kb = hmatvec(f, &center(b));
            center(&kb)
        })?;
        let dim = dim.min(wv.len());
        let mut proj = Mat::zeros(n, dim);
        for c in 0..dim {
            let lam = wv[c].max(0.0);
            if lam <= 1e-12 {
                continue; // numerically null direction: embed to 0
            }
            let s = 1.0 / lam.sqrt();
            for i in 0..n {
                proj[(i, c)] = s * v[(i, c)];
            }
        }
        let train_tree = scale_embedding(&wv, &v, dim);
        let train_embedding = f.rows_from_tree_order(&train_tree);
        // Centering statistics: row means of K from one matvec K·1.
        let k1 = hmatvec(f, &vec![1.0; n]);
        let row_means: Vec<f64> = k1.iter().map(|s| s / n as f64).collect();
        let grand_mean = row_means.iter().sum::<f64>() / n as f64;
        let agg = crate::hkernel::densify::aggregate_bases(f);
        Ok(KpcaTransformer { factors, proj, row_means, grand_mean, train_embedding, agg })
    }

    /// Embedding dimension.
    pub fn dim(&self) -> usize {
        self.proj.cols()
    }

    /// The underlying hierarchical factors.
    pub fn factors(&self) -> &std::sync::Arc<HFactors> {
        &self.factors
    }

    /// Embedding of the training points (original row order), identical
    /// in convention to [`kpca_embed_hierarchical`].
    pub fn train_embedding(&self) -> &Mat {
        &self.train_embedding
    }

    /// Embed query rows: u(x) = Λ^{-1/2} Vᵀ k̃(X, x), where k̃ applies
    /// the training centering to the kernel column of x. At a training
    /// point this reproduces that row of [`Self::train_embedding`]
    /// (exactly, up to Lanczos convergence).
    pub fn transform(&self, q: &Mat) -> Mat {
        let f = self.factors.as_ref();
        let n = f.n();
        let dim = self.dim();
        let mut out = Mat::zeros(q.rows(), dim);
        for i in 0..q.rows() {
            let col = HPredictor::column_with_agg(f, &self.agg, q.row(i));
            let cmean = col.iter().sum::<f64>() / n as f64;
            let ct: Vec<f64> = (0..n)
                .map(|j| col[j] - cmean - self.row_means[j] + self.grand_mean)
                .collect();
            for c in 0..dim {
                let mut acc = 0.0;
                for (j, &v) in ct.iter().enumerate() {
                    acc += self.proj[(j, c)] * v;
                }
                out[(i, c)] = acc;
            }
        }
        out
    }

    /// Internal view for [`crate::model`] persistence:
    /// (factors, proj, row means, grand mean, training embedding).
    pub(crate) fn parts(&self) -> (&std::sync::Arc<HFactors>, &Mat, &[f64], f64, &Mat) {
        (&self.factors, &self.proj, &self.row_means, self.grand_mean, &self.train_embedding)
    }

    /// Reassemble from persisted parts; the aggregate bases are derived
    /// state and recomputed deterministically.
    pub(crate) fn from_parts(
        factors: std::sync::Arc<HFactors>,
        proj: Mat,
        row_means: Vec<f64>,
        grand_mean: f64,
        train_embedding: Mat,
    ) -> Result<KpcaTransformer> {
        let n = factors.n();
        if proj.rows() != n
            || row_means.len() != n
            || train_embedding.rows() != n
            || train_embedding.cols() != proj.cols()
        {
            return Err(Error::data("kpca artifact: inconsistent shapes"));
        }
        let agg = crate::hkernel::densify::aggregate_bases(&factors);
        Ok(KpcaTransformer { factors, proj, row_means, grand_mean, train_embedding, agg })
    }
}

fn scale_embedding(w: &[f64], v: &Mat, dim: usize) -> Mat {
    let n = v.rows();
    let dim = dim.min(w.len());
    let mut u = Mat::zeros(n, dim);
    for c in 0..dim {
        let s = w[c].max(0.0).sqrt();
        for i in 0..n {
            u[(i, c)] = s * v[(i, c)];
        }
    }
    u
}

/// Alignment difference ‖U − Ũ M‖_F / ‖U‖_F with M the least-squares
/// minimizer (Figure 8's metric, after Zhang et al. 2008).
pub fn alignment_difference(u: &Mat, u_tilde: &Mat) -> Result<f64> {
    assert_eq!(u.rows(), u_tilde.rows());
    let m = lstsq(u_tilde, u)?;
    let mut res = matmul(u_tilde, Trans::No, &m, Trans::No);
    res.axpy(-1.0, u);
    Ok(res.fro_norm() / u.fro_norm())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::NystromFeatures;
    use crate::hkernel::HConfig;
    use crate::kernels::Gaussian;

    fn cloud(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, d, |_, _| rng.uniform(0.0, 1.0))
    }

    #[test]
    fn centering_zeroes_row_sums() {
        let x = cloud(15, 3, 1);
        let mut k = crate::kernels::kernel_block(Gaussian::new(0.5), &x);
        center_kernel_matrix(&mut k);
        for i in 0..15 {
            let s: f64 = k.row(i).iter().sum();
            assert!(s.abs() < 1e-9, "row {i} sums to {s}");
        }
    }

    #[test]
    fn feature_embedding_matches_kernel_embedding() {
        // Full-rank Nyström features reproduce the exact kernel, so both
        // embedding paths must agree up to per-column sign.
        let x = cloud(25, 3, 2);
        let kind = Gaussian::new(0.6);
        let mut rng = Rng::new(3);
        let feat = NystromFeatures::fit(kind, &x, 25, &mut rng).unwrap();
        let phi = feat.transform(&x);
        let ue = kpca_embed_dense(kind, &x, 3).unwrap();
        let uf = kpca_embed_features(&phi, 3).unwrap();
        for c in 0..3 {
            let dot: f64 = (0..25).map(|i| ue[(i, c)] * uf[(i, c)]).sum();
            let sign = if dot >= 0.0 { 1.0 } else { -1.0 };
            for i in 0..25 {
                assert!(
                    (ue[(i, c)] - sign * uf[(i, c)]).abs() < 1e-6,
                    "col {c} row {i}"
                );
            }
        }
    }

    #[test]
    fn hierarchical_embedding_matches_densified() {
        let x = cloud(60, 3, 4);
        let mut cfg = HConfig::new(Gaussian::new(0.5), 8).with_seed(5);
        cfg.n0 = 8;
        let f = HFactors::build(&x, cfg).unwrap();
        let kdense = crate::hkernel::densify::densify_original_order(&f);
        let u_dense = embed_from_kernel_matrix(&kdense, 3).unwrap();
        let mut rng = Rng::new(6);
        let u_lanczos = kpca_embed_hierarchical(&f, 3, 60, &mut rng).unwrap();
        let diff = alignment_difference(&u_dense, &u_lanczos).unwrap();
        assert!(diff < 1e-6, "alignment diff {diff}");
    }

    /// The out-of-sample extension evaluated *at a training point* must
    /// reproduce that row of the training embedding: with iters = n the
    /// Lanczos eigenpairs are exact, so u(x_i) = Λ^{-1/2} Vᵀ K̃ e_i
    /// = Λ^{1/2} V_{i,·} identically.
    #[test]
    fn transformer_oos_matches_train_embedding_at_training_points() {
        let x = cloud(50, 3, 11);
        let mut cfg = HConfig::new(Gaussian::new(0.5), 7).with_seed(12);
        cfg.n0 = 7;
        let f = std::sync::Arc::new(crate::hkernel::HFactors::build(&x, cfg).unwrap());
        let mut rng = Rng::new(13);
        let t = KpcaTransformer::fit(f, 3, 50, &mut rng).unwrap();
        assert_eq!(t.dim(), 3);
        let u = t.transform(&x);
        let want = t.train_embedding();
        for i in 0..50 {
            for c in 0..3 {
                assert!(
                    (u[(i, c)] - want[(i, c)]).abs() < 1e-5 * (1.0 + want[(i, c)].abs()),
                    "({i},{c}): {} vs {}",
                    u[(i, c)],
                    want[(i, c)]
                );
            }
        }
    }

    #[test]
    fn alignment_zero_for_rotations() {
        let u = cloud(20, 3, 7);
        // Rotate columns by an orthogonal-ish mix: alignment must be ~0.
        let m = Mat::from_vec(
            3,
            3,
            vec![0.0, 1.0, 0.0, -1.0, 0.0, 0.0, 0.0, 0.0, 1.0],
        );
        let ut = matmul(&u, Trans::No, &m, Trans::No);
        let d = alignment_difference(&u, &ut).unwrap();
        assert!(d < 1e-10);
    }

    #[test]
    fn alignment_positive_for_unrelated() {
        let u = cloud(30, 3, 8);
        let v = cloud(30, 3, 9);
        let d = alignment_difference(&u, &v).unwrap();
        assert!(d > 0.3, "unrelated embeddings should misalign: {d}");
    }
}
