//! Kernel principal component analysis (paper Section 5.6, Figure 8).
//!
//! The embedding of the training points is U = V_m diag(√λ_m) from the
//! eigendecomposition of the *centered* kernel matrix K̃ = H K H,
//! H = I − 11ᵀ/n. For explicit-feature kernels (Nyström, Fourier) the same
//! embedding comes from PCA of the centered feature matrix. For the
//! hierarchical kernel we never densify: Lanczos runs on the centered
//! matvec, whose inner K·v is the paper's Algorithm 1 at O(nr).
//!
//! Figure 8 compares embeddings across kernels by the alignment
//! difference min_M ‖U − Ũ M‖_F / ‖U‖_F (a least-squares solve).

use crate::error::Result;
use crate::hkernel::{hmatvec, HFactors};
use crate::kernels::{kernel_block, KernelKind};
use crate::linalg::{lanczos_topk, lstsq, matmul, sym_eig, Mat, Trans};
use crate::util::rng::Rng;

/// Center a square kernel matrix in place: K ← H K H.
pub fn center_kernel_matrix(k: &mut Mat) {
    let n = k.rows();
    assert_eq!(k.cols(), n);
    let nf = n as f64;
    let row_means: Vec<f64> = (0..n).map(|i| k.row(i).iter().sum::<f64>() / nf).collect();
    let total_mean = row_means.iter().sum::<f64>() / nf;
    for i in 0..n {
        for j in 0..n {
            k[(i, j)] += total_mean - row_means[i] - row_means[j];
        }
    }
}

/// Embedding from a dense kernel matrix: top-`dim` eigenpairs of the
/// centered matrix, scaled by √λ. Rows follow the matrix's row order.
///
/// Small matrices (n ≤ 256) use the dense Jacobi eigensolver; larger ones
/// use Lanczos with a dense matvec — only the leading `dim` pairs are
/// needed, so full O(n³) diagonalization would be wasted work.
pub fn embed_from_kernel_matrix(k: &Mat, dim: usize) -> Result<Mat> {
    let mut kc = k.clone();
    center_kernel_matrix(&mut kc);
    kc.symmetrize();
    let n = kc.rows();
    if n <= 256 {
        let (w, v) = sym_eig(&kc)?;
        return Ok(scale_embedding(&w, &v, dim));
    }
    let mut rng = Rng::new(0x5eed_cafe);
    let (w, v) = lanczos_topk(n, dim, dim + 40, &mut rng, |b| {
        let mut y = vec![0.0; n];
        crate::linalg::gemv(1.0, &kc, Trans::No, b, 0.0, &mut y);
        y
    })?;
    Ok(scale_embedding(&w, &v, dim))
}

/// Exact-kernel embedding of the rows of `x` (dense path).
pub fn kpca_embed_dense(kind: KernelKind, x: &Mat, dim: usize) -> Result<Mat> {
    let k = kernel_block(kind, x);
    embed_from_kernel_matrix(&k, dim)
}

/// Embedding from an explicit feature map (Nyström / Fourier): PCA of the
/// centered features. Returns an n x dim matrix; equals the kernel-matrix
/// path because ⟨φ_c(x_i), φ_c(x_j)⟩ = K̃_ij.
pub fn kpca_embed_features(phi: &Mat, dim: usize) -> Result<Mat> {
    let (n, r) = phi.shape();
    // Center features.
    let mut mean = vec![0.0; r];
    for i in 0..n {
        for (m, v) in mean.iter_mut().zip(phi.row(i).iter()) {
            *m += v;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut pc = phi.clone();
    for i in 0..n {
        for (v, m) in pc.row_mut(i).iter_mut().zip(mean.iter()) {
            *v -= m;
        }
    }
    // Eig of the r x r covariance; project.
    let mut cov = Mat::zeros(r, r);
    crate::linalg::gemm(1.0, &pc, Trans::Yes, &pc, Trans::No, 0.0, &mut cov);
    cov.symmetrize();
    let (w, v) = sym_eig(&cov)?;
    let dim = dim.min(r);
    // Projection onto unit principal directions: U = Φc V_dim. The
    // kernel-matrix convention scales eigenvectors of K̃ by √λ, which is
    // exactly Φc times the unit right singular vectors — identical.
    let mut vdim = Mat::zeros(r, dim);
    for c in 0..dim {
        if w[c] <= 1e-12 {
            continue;
        }
        for i in 0..r {
            vdim[(i, c)] = v[(i, c)];
        }
    }
    Ok(matmul(&pc, Trans::No, &vdim, Trans::No))
}

/// Hierarchical-kernel embedding via Lanczos on the centered O(nr) matvec.
/// Returns rows in **original order**.
pub fn kpca_embed_hierarchical(
    f: &HFactors,
    dim: usize,
    iters: usize,
    rng: &mut Rng,
) -> Result<Mat> {
    let n = f.n();
    let center = |v: &[f64]| -> Vec<f64> {
        let mean = v.iter().sum::<f64>() / n as f64;
        v.iter().map(|x| x - mean).collect()
    };
    let (w, v) = lanczos_topk(n, dim, iters.max(dim + 20), rng, |b| {
        let kb = hmatvec(f, &center(b));
        center(&kb)
    })?;
    let emb_tree = scale_embedding(&w, &v, dim);
    Ok(f.rows_from_tree_order(&emb_tree))
}

fn scale_embedding(w: &[f64], v: &Mat, dim: usize) -> Mat {
    let n = v.rows();
    let dim = dim.min(w.len());
    let mut u = Mat::zeros(n, dim);
    for c in 0..dim {
        let s = w[c].max(0.0).sqrt();
        for i in 0..n {
            u[(i, c)] = s * v[(i, c)];
        }
    }
    u
}

/// Alignment difference ‖U − Ũ M‖_F / ‖U‖_F with M the least-squares
/// minimizer (Figure 8's metric, after Zhang et al. 2008).
pub fn alignment_difference(u: &Mat, u_tilde: &Mat) -> Result<f64> {
    assert_eq!(u.rows(), u_tilde.rows());
    let m = lstsq(u_tilde, u)?;
    let mut res = matmul(u_tilde, Trans::No, &m, Trans::No);
    res.axpy(-1.0, u);
    Ok(res.fro_norm() / u.fro_norm())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::NystromFeatures;
    use crate::hkernel::HConfig;
    use crate::kernels::Gaussian;

    fn cloud(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, d, |_, _| rng.uniform(0.0, 1.0))
    }

    #[test]
    fn centering_zeroes_row_sums() {
        let x = cloud(15, 3, 1);
        let mut k = kernel_block(Gaussian::new(0.5), &x);
        center_kernel_matrix(&mut k);
        for i in 0..15 {
            let s: f64 = k.row(i).iter().sum();
            assert!(s.abs() < 1e-9, "row {i} sums to {s}");
        }
    }

    #[test]
    fn feature_embedding_matches_kernel_embedding() {
        // Full-rank Nyström features reproduce the exact kernel, so both
        // embedding paths must agree up to per-column sign.
        let x = cloud(25, 3, 2);
        let kind = Gaussian::new(0.6);
        let mut rng = Rng::new(3);
        let feat = NystromFeatures::fit(kind, &x, 25, &mut rng).unwrap();
        let phi = feat.transform(&x);
        let ue = kpca_embed_dense(kind, &x, 3).unwrap();
        let uf = kpca_embed_features(&phi, 3).unwrap();
        for c in 0..3 {
            let dot: f64 = (0..25).map(|i| ue[(i, c)] * uf[(i, c)]).sum();
            let sign = if dot >= 0.0 { 1.0 } else { -1.0 };
            for i in 0..25 {
                assert!(
                    (ue[(i, c)] - sign * uf[(i, c)]).abs() < 1e-6,
                    "col {c} row {i}"
                );
            }
        }
    }

    #[test]
    fn hierarchical_embedding_matches_densified() {
        let x = cloud(60, 3, 4);
        let mut cfg = HConfig::new(Gaussian::new(0.5), 8).with_seed(5);
        cfg.n0 = 8;
        let f = HFactors::build(&x, cfg).unwrap();
        let kdense = crate::hkernel::densify::densify_original_order(&f);
        let u_dense = embed_from_kernel_matrix(&kdense, 3).unwrap();
        let mut rng = Rng::new(6);
        let u_lanczos = kpca_embed_hierarchical(&f, 3, 60, &mut rng).unwrap();
        let diff = alignment_difference(&u_dense, &u_lanczos).unwrap();
        assert!(diff < 1e-6, "alignment diff {diff}");
    }

    #[test]
    fn alignment_zero_for_rotations() {
        let u = cloud(20, 3, 7);
        // Rotate columns by an orthogonal-ish mix: alignment must be ~0.
        let m = Mat::from_vec(
            3,
            3,
            vec![0.0, 1.0, 0.0, -1.0, 0.0, 0.0, 0.0, 0.0, 1.0],
        );
        let ut = matmul(&u, Trans::No, &m, Trans::No);
        let d = alignment_difference(&u, &ut).unwrap();
        assert!(d < 1e-10);
    }

    #[test]
    fn alignment_positive_for_unrelated() {
        let u = cloud(30, 3, 8);
        let v = cloud(30, 3, 9);
        let d = alignment_difference(&u, &v).unwrap();
        assert!(d > 0.3, "unrelated embeddings should misalign: {d}");
    }
}
