//! Evaluation metrics used by the paper's experiments: relative error for
//! regression, accuracy for classification.

use crate::data::{Dataset, Task};
use crate::linalg::Mat;

/// Relative testing error ‖pred − y‖₂ / ‖y‖₂ (regression plots, Fig. 3–7).
pub fn relative_error(pred: &[f64], y: &[f64]) -> f64 {
    assert_eq!(pred.len(), y.len());
    let num: f64 = pred.iter().zip(y).map(|(p, t)| (p - t) * (p - t)).sum();
    let den: f64 = y.iter().map(|t| t * t).sum();
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], y: &[f64]) -> f64 {
    assert_eq!(pred.len(), y.len());
    let n = pred.len().max(1) as f64;
    (pred.iter().zip(y).map(|(p, t)| (p - t) * (p - t)).sum::<f64>() / n).sqrt()
}

/// Classification accuracy in [0, 1].
pub fn accuracy(pred_labels: &[f64], y: &[f64]) -> f64 {
    assert_eq!(pred_labels.len(), y.len());
    if y.is_empty() {
        return 0.0;
    }
    let hits = pred_labels.iter().zip(y).filter(|(p, t)| p == t).count();
    hits as f64 / y.len() as f64
}

/// Task-appropriate score for a prediction matrix against a data set.
/// Returns (metric value, higher_is_better).
pub fn score(ds: &Dataset, raw_pred: &Mat) -> (f64, bool) {
    let decoded = ds.decode_predictions(raw_pred);
    match ds.task {
        Task::Regression => (relative_error(&decoded, &ds.y), false),
        Task::Binary | Task::Multiclass(_) => (accuracy(&decoded, &ds.y), true),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_error_basics() {
        assert_eq!(relative_error(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((relative_error(&[0.0, 0.0], &[3.0, 4.0]) - 1.0).abs() < 1e-12);
        assert_eq!(relative_error(&[0.0], &[0.0]), 0.0);
        assert!(relative_error(&[1.0], &[0.0]).is_infinite());
    }

    #[test]
    fn rmse_basics() {
        assert!((rmse(&[1.0, 3.0], &[0.0, 0.0]) - 5.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn accuracy_basics() {
        assert_eq!(accuracy(&[1.0, -1.0, 1.0], &[1.0, 1.0, 1.0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn score_dispatches_on_task() {
        use crate::data::Task;
        let x = Mat::zeros(2, 1);
        let reg = Dataset::new("r", x.clone(), vec![1.0, 2.0], Task::Regression).unwrap();
        let (v, hib) = score(&reg, &Mat::from_vec(2, 1, vec![1.0, 2.0]));
        assert_eq!((v, hib), (0.0, false));
        let cls = Dataset::new("c", x, vec![1.0, -1.0], Task::Binary).unwrap();
        let (v, hib) = score(&cls, &Mat::from_vec(2, 1, vec![0.5, 0.5]));
        assert_eq!((v, hib), (0.5, true));
    }
}
