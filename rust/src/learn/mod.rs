//! Learning tasks on top of the kernel engines: a unified KRR front-end
//! over all five kernels compared in Section 5 (hierarchical, Nyström,
//! Fourier, independent, exact), classification wrappers, kernel PCA
//! (Section 5.6), grid-search model selection, and metrics.

pub mod cv;
pub mod kpca;
pub mod krr;
pub mod metrics;

pub use cv::{grid_search, GridResult};
pub use kpca::{
    alignment_difference, kpca_embed_dense, kpca_embed_features, kpca_embed_hierarchical,
    KpcaTransformer,
};
pub use krr::{EngineSpec, KrrModel, TrainConfig};
pub use metrics::{accuracy, relative_error, rmse};
