//! The PJRT engine: manifest discovery, lazy compilation, tiled execution.
//!
//! Two builds of this module exist:
//!
//! - With the off-by-default `pjrt` cargo feature, the real engine wraps
//!   the `xla` crate's PJRT CPU client and executes the AOT HLO artifacts
//!   emitted by `python/compile/aot.py`. That crate is **not** in the
//!   offline vendored set, so enabling the feature requires vendoring it
//!   first; the code is kept compilable-in-principle behind the gate.
//! - The default build ships an API-compatible stub: [`PjrtEngine::load`]
//!   reports the runtime as unavailable, and [`PjrtBlockEvaluator`] falls
//!   back to the native evaluator with identical semantics. Every caller
//!   (`hck info`, the end-to-end example, the integration tests) already
//!   treats "no runtime" as the graceful degradation path, so the stub
//!   keeps the whole crate buildable and testable offline.

use crate::error::{Error, Result};
use crate::kernels::{BlockEvaluator, KernelKind, NativeEvaluator};
use crate::linalg::Mat;
use std::path::Path;
use std::sync::Mutex;

/// One artifact from manifest.json.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub op: String,
    pub family: String,
    pub tile_m: usize,
    pub tile_n: usize,
    pub d: usize,
}

/// Counters for reporting/benches.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub tiles_executed: usize,
    pub compiles: usize,
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::*;

    /// Stub PJRT engine (crate built without the `pjrt` feature).
    ///
    /// Construction always fails with a descriptive [`Error::Runtime`];
    /// the methods exist so call sites compile unchanged and keep their
    /// fallback logic exercised.
    pub struct PjrtEngine {
        artifacts: Vec<ArtifactInfo>,
        /// Execution statistics (always zero in the stub).
        pub stats: Mutex<EngineStats>,
    }

    impl PjrtEngine {
        /// Always fails: the XLA/PJRT backend is not compiled in.
        pub fn load(dir: impl AsRef<Path>) -> Result<PjrtEngine> {
            Err(Error::runtime(format!(
                "PJRT runtime not compiled in (build with --features pjrt and a \
                 vendored `xla` crate to load {})",
                dir.as_ref().display()
            )))
        }

        /// Load from the conventional `artifacts/` directory if present.
        pub fn load_default() -> Result<PjrtEngine> {
            Self::load("artifacts")
        }

        /// Artifact inventory (empty in the stub).
        pub fn artifacts(&self) -> &[ArtifactInfo] {
            &self.artifacts
        }

        /// PJRT platform string.
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Whether a kernel-block request can be served (never, in the stub).
        pub fn supports(&self, _kind: KernelKind, _d: usize) -> bool {
            false
        }

        /// Evaluate K(X, Y); unreachable in practice because [`Self::load`]
        /// never succeeds, but kept for API parity.
        pub fn kernel_block(&self, _kind: KernelKind, _x: &Mat, _y: &Mat) -> Result<Mat> {
            Err(Error::runtime("PJRT runtime not compiled in"))
        }
    }
}

#[cfg(feature = "pjrt")]
mod imp {
    use super::*;
    use crate::util::json::Json;
    use std::collections::HashMap;
    use std::path::PathBuf;

    /// PJRT CPU client + compiled-executable cache over an artifact
    /// directory.
    pub struct PjrtEngine {
        client: xla::PjRtClient,
        dir: PathBuf,
        artifacts: Vec<ArtifactInfo>,
        /// name -> compiled executable (compiled on first use).
        cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
        /// Execution statistics (tiles executed, executables compiled).
        pub stats: Mutex<EngineStats>,
    }

    impl PjrtEngine {
        /// Load the manifest from an artifact directory and start a CPU
        /// client. Fails if the directory or manifest is missing.
        pub fn load(dir: impl AsRef<Path>) -> Result<PjrtEngine> {
            let dir = dir.as_ref().to_path_buf();
            let mpath = dir.join("manifest.json");
            let text = std::fs::read_to_string(&mpath).map_err(|e| {
                Error::runtime(format!("cannot read {}: {e}", mpath.display()))
            })?;
            let json = Json::parse(&text)
                .map_err(|e| Error::runtime(format!("manifest parse error: {e}")))?;
            let mut artifacts = Vec::new();
            for a in json
                .get("artifacts")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| Error::runtime("manifest missing artifacts"))?
            {
                let gets = |k: &str| a.get(k).and_then(|v| v.as_str()).unwrap_or("").to_string();
                let getn = |k: &str| a.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
                artifacts.push(ArtifactInfo {
                    name: gets("name"),
                    file: gets("file"),
                    op: gets("op"),
                    family: gets("family"),
                    tile_m: getn("tile_m"),
                    tile_n: getn("tile_n"),
                    d: getn("d"),
                });
            }
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::runtime(format!("PJRT cpu client: {e}")))?;
            Ok(PjrtEngine {
                client,
                dir,
                artifacts,
                cache: Mutex::new(HashMap::new()),
                stats: Mutex::new(EngineStats::default()),
            })
        }

        /// Load from the conventional `artifacts/` directory if present.
        pub fn load_default() -> Result<PjrtEngine> {
            Self::load("artifacts")
        }

        /// Artifact inventory.
        pub fn artifacts(&self) -> &[ArtifactInfo] {
            &self.artifacts
        }

        /// PJRT platform string (e.g. "cpu").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            {
                let cache = self.cache.lock().unwrap();
                if let Some(exe) = cache.get(name) {
                    return Ok(exe.clone());
                }
            }
            let info = self
                .artifacts
                .iter()
                .find(|a| a.name == name)
                .ok_or_else(|| Error::runtime(format!("no artifact '{name}'")))?;
            let path = self.dir.join(&info.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| Error::runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::runtime(format!("compile {name}: {e}")))?;
            let exe = std::sync::Arc::new(exe);
            self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
            self.stats.lock().unwrap().compiles += 1;
            Ok(exe)
        }

        /// The d-bucket an artifact set offers for a family, smallest >= d.
        fn pick_bucket(&self, family: &str, d: usize) -> Option<&ArtifactInfo> {
            self.artifacts
                .iter()
                .filter(|a| a.op == "kernel_block" && a.family == family && a.d >= d)
                .min_by_key(|a| a.d)
        }

        /// Whether a kernel-block request can be served by the artifacts.
        pub fn supports(&self, kind: KernelKind, d: usize) -> bool {
            self.pick_bucket(kind.family(), d).is_some()
        }

        /// Evaluate K(X, Y) through the AOT XLA executable, tiling and
        /// padding to the artifact's fixed shapes. Exact for all supported
        /// kernels (zero-padding the feature dimension adds zero distance);
        /// f32 precision.
        pub fn kernel_block(&self, kind: KernelKind, x: &Mat, y: &Mat) -> Result<Mat> {
            let d = x.cols();
            if y.cols() != d {
                return Err(Error::dim("kernel_block: dim mismatch"));
            }
            let info = self.pick_bucket(kind.family(), d).ok_or_else(|| {
                Error::runtime(format!(
                    "no kernel_block artifact for family={} d={d}",
                    kind.family()
                ))
            })?;
            let exe = self.executable(&info.name.clone())?;
            let (tm, tn, db) = (info.tile_m, info.tile_n, info.d);
            let (m, n) = (x.rows(), y.rows());
            let mut out = Mat::zeros(m, n);
            let sigma_lit = xla::Literal::scalar(kind.sigma() as f32);

            let mut xbuf = vec![0f32; tm * db];
            let mut ybuf = vec![0f32; tn * db];
            for i0 in (0..m.max(1)).step_by(tm.max(1)) {
                if i0 >= m {
                    break;
                }
                let ih = (i0 + tm).min(m);
                fill_padded(&mut xbuf, x, i0, ih, db);
                for j0 in (0..n.max(1)).step_by(tn.max(1)) {
                    if j0 >= n {
                        break;
                    }
                    let jh = (j0 + tn).min(n);
                    fill_padded(&mut ybuf, y, j0, jh, db);
                    let xlit = xla::Literal::vec1(&xbuf)
                        .reshape(&[tm as i64, db as i64])
                        .map_err(wrap)?;
                    let ylit = xla::Literal::vec1(&ybuf)
                        .reshape(&[tn as i64, db as i64])
                        .map_err(wrap)?;
                    let result = exe
                        .execute::<xla::Literal>(&[xlit, ylit, sigma_lit.clone()])
                        .map_err(wrap)?[0][0]
                        .to_literal_sync()
                        .map_err(wrap)?;
                    let tile = result.to_tuple1().map_err(wrap)?;
                    let vals: Vec<f32> = tile.to_vec().map_err(wrap)?;
                    for (bi, row) in (i0..ih).enumerate() {
                        let src = &vals[bi * tn..bi * tn + (jh - j0)];
                        let dst = &mut out.row_mut(row)[j0..jh];
                        for (dv, sv) in dst.iter_mut().zip(src.iter()) {
                            *dv = *sv as f64;
                        }
                    }
                    self.stats.lock().unwrap().tiles_executed += 1;
                }
            }
            Ok(out)
        }
    }

    /// Copy rows [lo, hi) of `m` into a (tile x db) f32 buffer, zero-padding
    /// both the row tail and the feature tail.
    fn fill_padded(buf: &mut [f32], m: &Mat, lo: usize, hi: usize, db: usize) {
        buf.fill(0.0);
        let d = m.cols();
        for (bi, row) in (lo..hi).enumerate() {
            let src = m.row(row);
            let dst = &mut buf[bi * db..bi * db + d];
            for (dv, sv) in dst.iter_mut().zip(src.iter()) {
                *dv = *sv as f32;
            }
        }
    }

    fn wrap(e: xla::Error) -> Error {
        Error::runtime(format!("xla: {e}"))
    }
}

pub use imp::PjrtEngine;

/// A [`BlockEvaluator`] that runs supported kernel blocks through the
/// PJRT executables and falls back to the native evaluator otherwise
/// (in the stub build: always the native evaluator).
pub struct PjrtBlockEvaluator {
    engine: std::sync::Arc<PjrtEngine>,
    fallback: NativeEvaluator,
}

impl PjrtBlockEvaluator {
    pub fn new(engine: std::sync::Arc<PjrtEngine>) -> PjrtBlockEvaluator {
        PjrtBlockEvaluator { engine, fallback: NativeEvaluator }
    }
}

impl BlockEvaluator for PjrtBlockEvaluator {
    fn eval_block(&self, kind: KernelKind, x: &Mat, y: &Mat, out: &mut Mat) {
        if self.engine.supports(kind, x.cols()) {
            if let Ok(res) = self.engine.kernel_block(kind, x, y) {
                out.as_mut_slice().copy_from_slice(res.as_slice());
                return;
            }
        }
        self.fallback.eval_block(kind, x, y, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_reports_unavailable() {
        let err = PjrtEngine::load("does-not-matter").unwrap_err();
        assert!(err.to_string().contains("PJRT runtime not compiled in"));
        assert!(PjrtEngine::load_default().is_err());
    }
}
