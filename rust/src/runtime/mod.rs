//! PJRT runtime: loads the AOT-compiled HLO artifacts emitted by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs here — the interchange is HLO *text* (see
//! aot.py's module docs for why text and not serialized protos), compiled
//! once per artifact by the PJRT CPU client and cached. The
//! [`PjrtBlockEvaluator`] plugs into [`crate::kernels::BlockEvaluator`],
//! so the hierarchical factor construction can run its kernel-block
//! evaluations through XLA; anything the artifact set cannot serve
//! (unsupported family, d beyond the largest bucket) falls back to the
//! native Rust path with identical semantics.

pub mod engine;

pub use engine::{PjrtBlockEvaluator, PjrtEngine};
