//! RAII span guards over the tracer.
//!
//! `span("name", "cat")` opens a span that records itself on drop.
//! When tracing is disabled the constructors return an inert guard
//! without reading the clock or allocating — the span probes stay in
//! release builds at effectively zero cost.

use super::trace;

/// An open span; records one [`trace::Event`](super::Event) on drop.
pub struct Span {
    name: &'static str,
    cat: &'static str,
    start_ns: u64,
    request_id: u64,
    args: Option<String>,
    armed: bool,
}

/// Open a span inheriting the thread-local request id.
#[inline]
pub fn span(name: &'static str, cat: &'static str) -> Span {
    if !trace::is_enabled() {
        return Span::inert(name, cat);
    }
    Span {
        name,
        cat,
        start_ns: trace::now_ns(),
        request_id: trace::current_request_id(),
        args: None,
        armed: true,
    }
}

/// Open a span with an explicit request id.
#[inline]
pub fn span_req(name: &'static str, cat: &'static str, request_id: u64) -> Span {
    if !trace::is_enabled() {
        return Span::inert(name, cat);
    }
    Span { name, cat, start_ns: trace::now_ns(), request_id, args: None, armed: true }
}

/// Open a span with lazily-built args: `args` must return a pre-encoded
/// JSON object (e.g. `{"m":512,"backend":"avx2"}`) and is only invoked
/// when tracing is enabled, so shape formatting costs nothing on the
/// disabled path.
#[inline]
pub fn span_with<F: FnOnce() -> String>(name: &'static str, cat: &'static str, args: F) -> Span {
    if !trace::is_enabled() {
        return Span::inert(name, cat);
    }
    Span {
        name,
        cat,
        start_ns: trace::now_ns(),
        request_id: trace::current_request_id(),
        args: Some(args()),
        armed: true,
    }
}

impl Span {
    #[inline]
    fn inert(name: &'static str, cat: &'static str) -> Span {
        Span { name, cat, start_ns: 0, request_id: 0, args: None, armed: false }
    }

    /// Override the request id this span will record with.
    pub fn with_request_id(mut self, id: u64) -> Span {
        if self.armed {
            self.request_id = id;
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let end = trace::now_ns();
        trace::record(
            self.name,
            self.cat,
            self.start_ns,
            end.saturating_sub(self.start_ns),
            self.request_id,
            self.args.take(),
        );
    }
}
