//! Tracer state: the global enable flag, the time epoch, per-thread
//! event rings, and the thread-local request-id context.
//!
//! Layout: every thread that records a span lazily registers one
//! `ThreadBuf` (an `Arc` shared with a global registry) holding a
//! bounded ring of events. Recording locks only the calling thread's
//! own ring mutex — uncontended except while a flush is draining — so
//! tracing never serializes pool workers against each other. The
//! disabled path is a single relaxed atomic load with no time read and
//! no allocation.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Per-thread ring capacity. 64Ki events ≈ a few MB per active thread,
/// bounded regardless of server lifetime; oldest events are overwritten.
const RING_CAP: usize = 1 << 16;

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static OUT_PATH: Mutex<Option<String>> = Mutex::new(None);
static REGISTRY: Mutex<Vec<Arc<ThreadBuf>>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// One completed span, in nanoseconds since the process trace epoch.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub name: &'static str,
    pub cat: &'static str,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Tracer-local thread id (registration order, 1-based).
    pub tid: u64,
    /// Serving request id, 0 when the span is not request-scoped.
    pub request_id: u64,
    /// Pre-encoded JSON object of span-specific args, if any.
    pub args: Option<String>,
}

struct Ring {
    events: Vec<Event>,
    /// Write cursor once the ring is full.
    next: usize,
    dropped: u64,
}

struct ThreadBuf {
    tid: u64,
    name: String,
    ring: Mutex<Ring>,
}

impl ThreadBuf {
    fn push(&self, ev: Event) {
        let mut r = self.ring.lock().unwrap();
        if r.events.len() < RING_CAP {
            r.events.push(ev);
        } else {
            let i = r.next;
            r.events[i] = ev;
            r.next = (i + 1) % RING_CAP;
            r.dropped += 1;
        }
    }
}

thread_local! {
    static LOCAL: Arc<ThreadBuf> = register_thread();
    static CURRENT_REQUEST: Cell<u64> = const { Cell::new(0) };
}

fn register_thread() -> Arc<ThreadBuf> {
    // ORDERING: Relaxed — the fetch_add's atomicity alone guarantees
    // unique ids; registration order is published by the REGISTRY
    // mutex, not by this counter.
    let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    let name = std::thread::current().name().unwrap_or("thread").to_string();
    let buf = Arc::new(ThreadBuf {
        tid,
        name,
        ring: Mutex::new(Ring { events: Vec::new(), next: 0, dropped: 0 }),
    });
    REGISTRY.lock().unwrap().push(Arc::clone(&buf));
    buf
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Whether tracing is currently recording. One relaxed load.
#[inline]
pub fn is_enabled() -> bool {
    // ORDERING: Relaxed — a stale read only means a span near the
    // enable/disable edge is skipped or recorded; event data itself is
    // published by the per-thread ring mutexes, never by this flag.
    ENABLED.load(Ordering::Relaxed)
}

/// Nanoseconds since the trace epoch.
pub(crate) fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Convert an `Instant` captured elsewhere (e.g. a request's enqueue
/// time) to nanoseconds since the trace epoch. Instants that predate
/// the epoch clamp to 0.
pub(crate) fn ns_of(t: Instant) -> u64 {
    t.checked_duration_since(epoch()).map(|d| d.as_nanos() as u64).unwrap_or(0)
}

/// Enable tracing and remember `path` as the Chrome-trace destination
/// for [`flush`].
pub fn enable(path: &str) {
    let _ = epoch();
    *OUT_PATH.lock().unwrap() = Some(path.to_string());
    // ORDERING: SeqCst store — a rare control-plane edge; keeps the
    // epoch/OUT_PATH writes above globally visible before any thread
    // can observe tracing as on.
    ENABLED.store(true, Ordering::SeqCst);
}

/// Enable tracing for in-process capture (no output file); pair with
/// [`drain_events`]. Used by benches and tests.
pub fn enable_capture() {
    let _ = epoch();
    *OUT_PATH.lock().unwrap() = None;
    // ORDERING: SeqCst store — same control-plane edge as [`enable`].
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop recording. Already-buffered events stay drainable.
pub fn disable() {
    // ORDERING: SeqCst store — rare control-plane edge, symmetric with
    // [`enable`]; spans already mid-record drain normally.
    ENABLED.store(false, Ordering::SeqCst);
}

/// Enable tracing when `HCK_TRACE=path.json` is set in the environment.
/// Called once at CLI startup; a later `--trace` flag overrides the path.
pub fn init_from_env() {
    if let Ok(path) = std::env::var("HCK_TRACE") {
        if !path.is_empty() {
            enable(&path);
        }
    }
}

/// Record one completed span into the calling thread's ring.
#[inline]
pub(crate) fn record(
    name: &'static str,
    cat: &'static str,
    start_ns: u64,
    dur_ns: u64,
    request_id: u64,
    args: Option<String>,
) {
    if !is_enabled() {
        return;
    }
    LOCAL.with(|b| {
        b.push(Event { name, cat, start_ns, dur_ns, tid: b.tid, request_id, args })
    });
}

/// Record a span whose bounds were measured with `Instant`s (e.g. the
/// coordinator's queue-wait window, which starts on the submitting
/// thread and ends on the batcher thread).
pub fn record_span_between(
    name: &'static str,
    cat: &'static str,
    start: Instant,
    end: Instant,
    request_id: u64,
) {
    if !is_enabled() {
        return;
    }
    let s = ns_of(start);
    let e = ns_of(end);
    record(name, cat, s, e.saturating_sub(s), request_id, None);
}

/// The request id attached to spans opened on this thread (0 = none).
pub fn current_request_id() -> u64 {
    CURRENT_REQUEST.with(|c| c.get())
}

/// Scope guard restoring the previous thread-local request id on drop.
pub struct RequestIdGuard {
    prev: u64,
}

/// Set the thread-local request id for the duration of the returned
/// guard; spans opened while it lives inherit the id.
pub fn with_request_id(id: u64) -> RequestIdGuard {
    let prev = CURRENT_REQUEST.with(|c| c.replace(id));
    RequestIdGuard { prev }
}

impl Drop for RequestIdGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        CURRENT_REQUEST.with(|c| c.set(prev));
    }
}

/// Drain every thread's ring, returning all buffered events sorted by
/// start time. Rings are left empty (and their overwrite cursors reset).
pub fn drain_events() -> Vec<Event> {
    let reg = REGISTRY.lock().unwrap();
    let mut out = Vec::new();
    for buf in reg.iter() {
        let mut r = buf.ring.lock().unwrap();
        out.append(&mut r.events);
        r.next = 0;
        r.dropped = 0;
    }
    drop(reg);
    out.sort_by(|a, b| (a.start_ns, a.tid).cmp(&(b.start_ns, b.tid)));
    out
}

/// Total events overwritten by ring wraparound since the last drain.
pub fn dropped_events() -> u64 {
    REGISTRY.lock().unwrap().iter().map(|b| b.ring.lock().unwrap().dropped).sum()
}

/// `(tid, thread name)` for every registered thread, for the trace
/// metadata header.
pub(crate) fn thread_names() -> Vec<(u64, String)> {
    REGISTRY.lock().unwrap().iter().map(|b| (b.tid, b.name.clone())).collect()
}

/// Drain all events and write the Chrome-trace file recorded by
/// [`enable`]. Returns the path written, or `None` when tracing was
/// enabled for in-process capture only.
pub fn flush() -> std::io::Result<Option<String>> {
    let path = OUT_PATH.lock().unwrap().clone();
    let Some(path) = path else {
        return Ok(None);
    };
    let threads = thread_names();
    let events = drain_events();
    super::export::write_chrome_trace(&path, &events, &threads)?;
    Ok(Some(path))
}
