//! Chrome-trace (Perfetto JSON array) exporter.
//!
//! Emits the classic `chrome://tracing` format: a JSON array of
//! `ph:"M"` thread-name metadata events followed by `ph:"X"` complete
//! events with microsecond `ts`/`dur`. The output loads directly in
//! <https://ui.perfetto.dev> and parses with `util::json` (the
//! validity test and `scripts/check_trace.py` both rely on that).

use super::trace::Event;
use crate::util::json::Json;
use std::fmt::Write as _;

/// Render events (already sorted by `drain_events`) as one Chrome-trace
/// JSON array string.
pub fn chrome_trace_json(events: &[Event], threads: &[(u64, String)]) -> String {
    let mut out = String::with_capacity(64 + events.len() * 96);
    out.push('[');
    let mut first = true;
    for (tid, name) in threads {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\"args\":{{\"name\":{}}}}}",
            Json::Str(name.clone()).encode()
        );
    }
    for ev in events {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"name\":{},\"cat\":{},\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":1,\"tid\":{}",
            Json::Str(ev.name.to_string()).encode(),
            Json::Str(ev.cat.to_string()).encode(),
            ev.start_ns as f64 / 1e3,
            ev.dur_ns as f64 / 1e3,
            ev.tid,
        );
        match event_args(ev) {
            Some(args) => {
                out.push_str(",\"args\":");
                out.push_str(&args);
                out.push('}');
            }
            None => out.push('}'),
        }
    }
    out.push(']');
    out
}

/// Merge the span's pre-encoded args object with its request id (when
/// request-scoped) into one JSON object string.
fn event_args(ev: &Event) -> Option<String> {
    match (&ev.args, ev.request_id) {
        (None, 0) => None,
        (None, rid) => Some(format!("{{\"request_id\":{rid}}}")),
        (Some(a), 0) => Some(a.clone()),
        (Some(a), rid) => {
            let inner = a.trim();
            let body = inner.strip_prefix('{').and_then(|s| s.strip_suffix('}')).unwrap_or("");
            if body.trim().is_empty() {
                Some(format!("{{\"request_id\":{rid}}}"))
            } else {
                Some(format!("{{{body},\"request_id\":{rid}}}"))
            }
        }
    }
}

/// Write the Chrome-trace file.
pub fn write_chrome_trace(
    path: &str,
    events: &[Event],
    threads: &[(u64, String)],
) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace_json(events, threads))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &'static str, start: u64, rid: u64, args: Option<&str>) -> Event {
        Event {
            name,
            cat: "test",
            start_ns: start,
            dur_ns: 500,
            tid: 1,
            request_id: rid,
            args: args.map(|s| s.to_string()),
        }
    }

    #[test]
    fn export_parses_and_carries_fields() {
        let events = vec![
            ev("a", 1_000, 0, None),
            ev("b", 2_000, 7, None),
            ev("c", 3_000, 9, Some("{\"m\":4}")),
        ];
        let threads = vec![(1, "main \"q\"".to_string())];
        let text = chrome_trace_json(&events, &threads);
        let doc = Json::parse(&text).expect("chrome trace must be valid JSON");
        let arr = doc.as_arr().unwrap();
        assert_eq!(arr.len(), 4);
        assert_eq!(arr[0].get("ph").unwrap().as_str(), Some("M"));
        assert_eq!(
            arr[0].get("args").unwrap().get("name").unwrap().as_str(),
            Some("main \"q\"")
        );
        assert_eq!(arr[1].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(arr[1].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(arr[1].get("ts").unwrap().as_f64(), Some(1.0));
        assert!(arr[1].get("args").is_none());
        assert_eq!(
            arr[2].get("args").unwrap().get("request_id").unwrap().as_usize(),
            Some(7)
        );
        let c = arr[3].get("args").unwrap();
        assert_eq!(c.get("m").unwrap().as_usize(), Some(4));
        assert_eq!(c.get("request_id").unwrap().as_usize(), Some(9));
    }

    #[test]
    fn empty_args_object_merges_request_id() {
        let text = chrome_trace_json(&[ev("z", 0, 3, Some("{}"))], &[]);
        let doc = Json::parse(&text).unwrap();
        let args = doc.as_arr().unwrap()[0].get("args").unwrap();
        assert_eq!(args.get("request_id").unwrap().as_usize(), Some(3));
    }
}
