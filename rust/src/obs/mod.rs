//! Observability: a zero-dependency, process-global span tracer with
//! Chrome-trace export.
//!
//! The tracer is off by default and costs one relaxed atomic load per
//! probe when disabled — cheap enough to leave permanently wired into
//! the BLAS-3 core, the hierarchical factor/solve phases, the
//! coordinator, and the shard workers. Enable it with
//! `HCK_TRACE=out.json` (any CLI entry point) or `--trace out.json`
//! (`hck serve` / `hck train`), or in-process via
//! [`trace::enable_capture`] + [`trace::drain_events`] for benches and
//! tests that want the raw events instead of a file.
//!
//! Events land in per-thread bounded rings (oldest overwritten), so a
//! long-lived server can trace indefinitely with fixed memory. At
//! [`trace::flush`] the rings are drained, merged, sorted by start
//! time, and written as a Chrome-trace JSON array (the
//! `chrome://tracing` / [Perfetto](https://ui.perfetto.dev) format):
//! one `ph:"X"` complete event per span, carrying the category, the
//! owning thread, the serving `request_id` (when the span belongs to a
//! request), and any span-specific args (matrix shapes, batch sizes,
//! tree levels).
//!
//! Span names are stable identifiers — `scripts/check_trace.py` and
//! the bench harness both key on them:
//!
//! | span | category | layer |
//! | --- | --- | --- |
//! | `train.partition` / `train.sample_landmarks` / `train.sigma_factor` / `train.node_factors` | `train` | `hkernel::build` |
//! | `factor.leaves` / `factor.level` (args `{"level":d}`) | `train` | `hkernel::solve` |
//! | `blas.par_gemm` / `blas.par_syrk` (args shape+backend) | `blas` | `linalg::blas` |
//! | `coord.queue_wait` / `coord.execute` / `coord.batch` / `coord.member_eval` | `coord` | coordinator |
//! | `shard.queue_wait` / `shard.eval` (args `{"shard":i}`) | `shard` | shard workers |

pub mod export;
pub mod span;
pub mod trace;

pub use span::{span, span_req, span_with, Span};
pub use trace::{
    current_request_id, disable, drain_events, enable, enable_capture, flush, init_from_env,
    is_enabled, record_span_between, with_request_id, Event, RequestIdGuard,
};
