//! Observability: a zero-dependency, process-global span tracer with
//! Chrome-trace export.
//!
//! The tracer is off by default and costs one relaxed atomic load per
//! probe when disabled — cheap enough to leave permanently wired into
//! the BLAS-3 core, the hierarchical factor/solve phases, the
//! coordinator, and the shard workers. Enable it with
//! `HCK_TRACE=out.json` (any CLI entry point) or `--trace out.json`
//! (`hck serve` / `hck train`), or in-process via
//! [`trace::enable_capture`] + [`trace::drain_events`] for benches and
//! tests that want the raw events instead of a file.
//!
//! Events land in per-thread bounded rings (oldest overwritten), so a
//! long-lived server can trace indefinitely with fixed memory. At
//! [`trace::flush`] the rings are drained, merged, sorted by start
//! time, and written as a Chrome-trace JSON array (the
//! `chrome://tracing` / [Perfetto](https://ui.perfetto.dev) format):
//! one `ph:"X"` complete event per span, carrying the category, the
//! owning thread, the serving `request_id` (when the span belongs to a
//! request), and any span-specific args (matrix shapes, batch sizes,
//! tree levels).
//!
//! Span names are stable identifiers — `scripts/check_trace.py` and
//! the bench harness both key on them. The single source of truth is
//! the [`registry::SPANS`] const table in `obs/registry.rs`: every
//! in-crate call site must use a registered name and every registered
//! name must have a call site (enforced by `hck-lint`, rule
//! `span-registry`). See that table for the full name/category/layer
//! listing; new spans are added there first.

pub mod export;
pub mod registry;
pub mod span;
pub mod trace;

pub use span::{span, span_req, span_with, Span};
pub use trace::{
    current_request_id, disable, drain_events, enable, enable_capture, flush, init_from_env,
    is_enabled, record_span_between, with_request_id, Event, RequestIdGuard,
};
