//! The span-name registry: the single source of truth for every span
//! name this crate records.
//!
//! Every `obs::span` / `span_req` / `span_with` / `record_span_between`
//! call site in `rust/src` must use a name listed in [`SPANS`], and
//! every entry in [`SPANS`] must have at least one call site — both
//! directions are enforced statically by `hck-lint` (rule
//! `span-registry`), so the table cannot drift from the code. CI
//! additionally exports this table via `hck-lint --emit-spans` and
//! hands it to `scripts/check_trace.py --known-spans`, which pins the
//! required-span list and rejects trace files containing unregistered
//! names.
//!
//! Integration tests and benches outside `rust/src` may record ad-hoc
//! span names through the public API; the registry governs the
//! library's own instrumentation points only.
//!
//! Keep the table sorted by name, one `("name", "category")` tuple per
//! line — the lint parses it textually.

/// `(name, category)` of every span the library records, sorted by name.
pub const SPANS: &[(&str, &str)] = &[
    ("balance.scale", "balance"),
    ("blas.par_gemm", "blas"),
    ("blas.par_syrk", "blas"),
    ("coord.batch", "coord"),
    ("coord.execute", "coord"),
    ("coord.member_eval", "coord"),
    ("coord.queue_wait", "coord"),
    ("factor.leaves", "train"),
    ("factor.level", "train"),
    ("remote.drain", "remote"),
    ("remote.hedge", "remote"),
    ("remote.retry", "remote"),
    ("remote.send", "remote"),
    ("remote.wait", "remote"),
    ("shard.eval", "shard"),
    ("shard.queue_wait", "shard"),
    ("solve.downward", "solve"),
    ("solve.leaf_finish", "solve"),
    ("solve.upward", "solve"),
    ("train.node_factors", "train"),
    ("train.partition", "train"),
    ("train.sample_landmarks", "train"),
    ("train.sigma_factor", "train"),
];

/// Whether `name` is a registered span name.
pub fn is_registered(name: &str) -> bool {
    SPANS.iter().any(|(n, _)| *n == name)
}

/// All registered span names, in table (sorted) order.
pub fn names() -> impl Iterator<Item = &'static str> {
    SPANS.iter().map(|(n, _)| *n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_is_sorted_and_unique() {
        for w in SPANS.windows(2) {
            assert!(
                w[0].0 < w[1].0,
                "SPANS must stay sorted/unique: {:?} before {:?}",
                w[0].0,
                w[1].0
            );
        }
    }

    #[test]
    fn names_are_wellformed() {
        for (name, cat) in SPANS {
            assert!(!name.is_empty() && !cat.is_empty());
            assert!(
                name.chars().all(|c| c.is_ascii_lowercase() || c == '.' || c == '_'),
                "span name {name:?} must be lowercase dotted"
            );
            assert!(name.contains('.'), "span name {name:?} must be <layer>.<what>");
        }
    }

    #[test]
    fn lookup() {
        assert!(is_registered("coord.batch"));
        assert!(!is_registered("coord.bogus"));
        assert_eq!(names().count(), SPANS.len());
    }
}
