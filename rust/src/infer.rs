//! Typed inference protocol v2: capability-based prediction requests.
//!
//! Every inference surface in the crate — [`crate::model::Model`], the
//! coordinator's [`crate::coordinator::Predictor`], the shard workers and
//! the TCP wire protocol — speaks one request/response pair instead of a
//! bare matrix-in/matrix-out call:
//!
//! - [`PredictRequest`]: a batch of query rows plus a [`Want`] flag set
//!   (mean / posterior variance / leaf route) and [`PredictOpts`].
//! - [`PredictResponse`]: the mean block, plus the optional variance and
//!   route columns that were requested, and a per-query timing diagnostic.
//! - [`PredictError`]: a typed, clonable error (bad request, unsupported
//!   capability, shard failure, internal) that crosses thread and wire
//!   boundaries instead of panicking inside serving threads.
//! - [`Capabilities`]: what a model can serve, so callers (CLI, service,
//!   router) negotiate instead of guessing — see
//!   [`crate::model::ModelSchema::capabilities`].
//!
//! The mean-only path is unchanged math: a request with
//! [`Want::mean_only`] reproduces the pre-protocol outputs bitwise.

use crate::linalg::Mat;
use crate::util::json::Json;

/// Which response columns a request asks for. The mean is always
/// computed and returned (it is the model's output and every consumer
/// needs it); `variance` and `leaf_route` are optional capabilities that
/// must be present in the model's [`Capabilities`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Want {
    /// Posterior mean (always served; the flag exists for wire symmetry
    /// with [`Capabilities`]).
    pub mean: bool,
    /// Posterior variance σ²(x) per query (GP models).
    pub variance: bool,
    /// The partition-tree leaf each query routed to (hierarchical-factor
    /// models), as a [`LeafRoute`] per query.
    pub leaf_route: bool,
}

impl Default for Want {
    fn default() -> Self {
        Want::mean_only()
    }
}

impl Want {
    /// Mean only — the v1 behavior.
    pub fn mean_only() -> Want {
        Want { mean: true, variance: false, leaf_route: false }
    }

    /// Request the posterior variance column as well.
    pub fn with_variance(mut self) -> Want {
        self.variance = true;
        self
    }

    /// Request the per-query leaf routes as well.
    pub fn with_leaf_route(mut self) -> Want {
        self.leaf_route = true;
        self
    }

    /// Field-wise OR of two flag sets.
    pub fn union(self, other: Want) -> Want {
        Want {
            mean: self.mean || other.mean,
            variance: self.variance || other.variance,
            leaf_route: self.leaf_route || other.leaf_route,
        }
    }
}

/// Per-request evaluation options.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PredictOpts {
    /// The queries are already preprocessed into the model's feature
    /// space: skip the artifact's recorded normalization. Serving paths
    /// leave this `false` (raw features on the wire); in-process callers
    /// that normalized explicitly set it to keep the math identical.
    pub pre_normalized: bool,
}

/// A typed prediction request: query rows + wanted columns + options.
#[derive(Clone)]
pub struct PredictRequest {
    /// Query points, one per row (rows x d).
    pub queries: Mat,
    /// Which response columns to serve.
    pub want: Want,
    /// Evaluation options.
    pub opts: PredictOpts,
}

impl PredictRequest {
    /// A request for the given columns with default options.
    pub fn new(queries: Mat, want: Want) -> PredictRequest {
        PredictRequest { queries, want, opts: PredictOpts::default() }
    }

    /// Mean-only request on raw (serving-side) features.
    pub fn mean_of(queries: &Mat) -> PredictRequest {
        PredictRequest::new(queries.clone(), Want::mean_only())
    }

    /// Mean-only request on already-normalized features — the exact
    /// pre-protocol `predict_batch` semantics.
    pub fn raw_mean(queries: &Mat) -> PredictRequest {
        PredictRequest {
            queries: queries.clone(),
            want: Want::mean_only(),
            opts: PredictOpts { pre_normalized: true },
        }
    }
}

/// Where a query landed in the partition tree: the routed leaf's
/// training-row range in **global tree order** (identical for sharded
/// and in-process serving), plus the shard that served it, when one did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LeafRoute {
    /// Shard id that served the query (`None` on unsharded paths).
    pub shard: Option<usize>,
    /// First global tree-order training row of the routed leaf.
    pub rows_lo: usize,
    /// One past the last global tree-order training row of the leaf.
    pub rows_hi: usize,
}

impl LeafRoute {
    /// Wire encoding: `{"shard": n|null, "rows_lo": l, "rows_hi": h}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "shard",
                match self.shard {
                    Some(s) => Json::Num(s as f64),
                    None => Json::Null,
                },
            ),
            ("rows_lo", Json::Num(self.rows_lo as f64)),
            ("rows_hi", Json::Num(self.rows_hi as f64)),
        ])
    }
}

/// A typed prediction response. `variance` and `routes` are present iff
/// they were requested (and the model has the capability); both are
/// indexed per query row of the request.
#[derive(Clone)]
pub struct PredictResponse {
    /// Predicted mean block (rows x outputs).
    pub mean: Mat,
    /// Posterior variance σ²(x) per query, when requested.
    pub variance: Option<Vec<f64>>,
    /// Routed leaf per query, when requested.
    pub routes: Option<Vec<LeafRoute>>,
    /// Wall-clock spent evaluating this request, divided by its query
    /// count (ns) — the per-query latency diagnostic.
    pub per_query_ns: f64,
}

impl PredictResponse {
    /// A mean-only response (no optional columns, no timing).
    pub fn of_mean(mean: Mat) -> PredictResponse {
        PredictResponse { mean, variance: None, routes: None, per_query_ns: 0.0 }
    }
}

/// Typed inference failure. Clonable so the batcher can fan one model
/// error out to every request of a dynamic batch, and so it crosses the
/// shard worker reply channels.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PredictError {
    /// The request itself is malformed (wrong dimension, zero rows,
    /// non-finite features). Never kills a serving thread.
    BadRequest(String),
    /// The request asks for a column the model cannot serve — negotiate
    /// with [`Capabilities`] first.
    Unsupported(String),
    /// A shard worker failed; the request-order scatter/gather aborts
    /// with the failing shard attached.
    Shard {
        /// Which shard failed.
        shard: usize,
        /// What happened.
        message: String,
    },
    /// The transport to a **remote** shard worker failed (connect, send,
    /// receive, or frame decode), attributed to the worker address. The
    /// remote router fails over to another replica on this variant; it
    /// only reaches a client when every replica of a shard is
    /// unreachable (then wrapped as [`PredictError::Shard`]).
    Transport {
        /// The worker address (host:port) the failure is attributed to.
        worker: String,
        /// What happened.
        message: String,
    },
    /// The worker is draining: it finishes in-flight batches but
    /// accepts no new ones (the `drain` wire command). The remote
    /// router treats this like a transport failure for routing — the
    /// next replica absorbs the sub-batch — while the distinct kind
    /// lets operators tell a planned handoff from a real outage.
    Draining {
        /// The draining worker's address (host:port).
        worker: String,
    },
    /// Anything else (factorization failure, dead service).
    Internal(String),
}

impl PredictError {
    /// Stable machine-readable tag (the wire protocol's `error.kind`).
    pub fn kind(&self) -> &'static str {
        match self {
            PredictError::BadRequest(_) => "bad_request",
            PredictError::Unsupported(_) => "unsupported",
            PredictError::Shard { .. } => "shard_failure",
            PredictError::Transport { .. } => "transport",
            PredictError::Draining { .. } => "draining",
            PredictError::Internal(_) => "internal",
        }
    }

    /// Wire encoding: `{"kind": "...", "message": "..."}`.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("kind", Json::Str(self.kind().into())),
            ("message", Json::Str(self.message())),
        ];
        if let PredictError::Shard { shard, .. } = self {
            pairs.push(("shard", Json::Num(*shard as f64)));
        }
        if let PredictError::Transport { worker, .. } = self {
            pairs.push(("worker", Json::Str(worker.clone())));
        }
        if let PredictError::Draining { worker } = self {
            pairs.push(("worker", Json::Str(worker.clone())));
        }
        Json::obj(pairs)
    }

    /// The human-readable message without the kind tag.
    pub fn message(&self) -> String {
        match self {
            PredictError::BadRequest(m)
            | PredictError::Unsupported(m)
            | PredictError::Internal(m) => m.clone(),
            PredictError::Shard { shard, message } => {
                format!("shard {shard}: {message}")
            }
            PredictError::Transport { worker, message } => {
                format!("worker {worker}: {message}")
            }
            PredictError::Draining { worker } => {
                format!("worker {worker}: draining (not accepting new batches)")
            }
        }
    }
}

impl std::fmt::Display for PredictError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.kind(), self.message())
    }
}

impl std::error::Error for PredictError {}

impl From<PredictError> for crate::error::Error {
    fn from(e: PredictError) -> Self {
        crate::error::Error::Serve(e.to_string())
    }
}

/// Result alias for the typed inference surface.
pub type InferResult<T> = std::result::Result<T, PredictError>;

/// What a model (or serving front) can put in a [`PredictResponse`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Capabilities {
    /// Serves the predicted mean (every model).
    pub mean: bool,
    /// Serves the posterior variance.
    pub variance: bool,
    /// Serves per-query leaf routes.
    pub leaf_route: bool,
}

impl Capabilities {
    /// Mean only — the floor every model provides.
    pub fn mean_only() -> Capabilities {
        Capabilities { mean: true, variance: false, leaf_route: false }
    }

    /// Whether every column in `want` is available.
    pub fn supports(&self, want: Want) -> bool {
        (!want.variance || self.variance) && (!want.leaf_route || self.leaf_route)
    }

    /// Reject a request asking for unavailable columns with a typed
    /// [`PredictError::Unsupported`] naming what is missing.
    pub fn check(&self, want: Want) -> InferResult<()> {
        let mut missing = Vec::new();
        if want.variance && !self.variance {
            missing.push("variance");
        }
        if want.leaf_route && !self.leaf_route {
            missing.push("leaf_route");
        }
        if missing.is_empty() {
            Ok(())
        } else {
            Err(PredictError::Unsupported(format!(
                "model does not serve: {} (capabilities: {})",
                missing.join(", "),
                self
            )))
        }
    }

    /// Wire encoding: `{"mean": true, "variance": ..., "leaf_route": ...}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mean", Json::Bool(self.mean)),
            ("variance", Json::Bool(self.variance)),
            ("leaf_route", Json::Bool(self.leaf_route)),
        ])
    }
}

impl std::fmt::Display for Capabilities {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut parts = Vec::new();
        if self.mean {
            parts.push("mean");
        }
        if self.variance {
            parts.push("variance");
        }
        if self.leaf_route {
            parts.push("leaf_route");
        }
        write!(f, "{}", parts.join(","))
    }
}

/// Validate a query batch against a model dimension (`dim == 0` skips
/// the dimension check for predictors that do not know theirs). Zero
/// rows, a wrong feature count, or any non-finite feature is a
/// [`PredictError::BadRequest`] — malformed input must never reach (or
/// panic inside) an evaluation thread.
pub fn validate_queries(q: &Mat, dim: usize) -> InferResult<()> {
    if q.rows() == 0 {
        return Err(PredictError::BadRequest("empty query batch".into()));
    }
    if dim > 0 && q.cols() != dim {
        return Err(PredictError::BadRequest(format!(
            "expected {dim} features, got {}",
            q.cols()
        )));
    }
    for i in 0..q.rows() {
        if q.row(i).iter().any(|v| !v.is_finite()) {
            return Err(PredictError::BadRequest(format!(
                "query row {i} contains a non-finite feature"
            )));
        }
    }
    Ok(())
}

/// Apply recorded per-column (min, max) feature normalization to a
/// request's queries, honoring [`PredictOpts::pre_normalized`]. Returns
/// `Some(normalized copy)` when normalization applies, `None` when the
/// request's own queries can be used as-is — the one normalization
/// decision shared by the in-process model pipeline and the sharded
/// serving front, so the two paths cannot drift.
pub fn normalized_queries(
    req: &PredictRequest,
    ranges: Option<&[(f64, f64)]>,
) -> Option<Mat> {
    if req.opts.pre_normalized {
        return None;
    }
    let ranges = ranges?;
    let mut m = req.queries.clone();
    crate::data::preprocess::apply_normalization(&mut m, ranges);
    Some(m)
}

/// [`validate_queries`] for a single feature vector (the service's
/// per-request enqueue path).
pub fn validate_features(features: &[f64], dim: usize) -> InferResult<()> {
    if features.is_empty() {
        return Err(PredictError::BadRequest("empty feature vector".into()));
    }
    if dim > 0 && features.len() != dim {
        return Err(PredictError::BadRequest(format!(
            "expected {dim} features, got {}",
            features.len()
        )));
    }
    if features.iter().any(|v| !v.is_finite()) {
        return Err(PredictError::BadRequest(
            "features contain a non-finite value".into(),
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn want_union_and_defaults() {
        let w = Want::default();
        assert!(w.mean && !w.variance && !w.leaf_route);
        let u = w.union(Want::mean_only().with_variance());
        assert!(u.variance && !u.leaf_route);
        let u2 = u.union(Want::mean_only().with_leaf_route());
        assert!(u2.variance && u2.leaf_route);
    }

    #[test]
    fn capabilities_check_names_missing_columns() {
        let caps = Capabilities::mean_only();
        assert!(caps.check(Want::mean_only()).is_ok());
        let err = caps.check(Want::mean_only().with_variance().with_leaf_route()).unwrap_err();
        assert_eq!(err.kind(), "unsupported");
        let msg = err.to_string();
        assert!(msg.contains("variance") && msg.contains("leaf_route"), "{msg}");
        let full = Capabilities { mean: true, variance: true, leaf_route: true };
        assert!(full.check(Want::mean_only().with_variance().with_leaf_route()).is_ok());
        assert!(full.supports(Want::mean_only()));
    }

    #[test]
    fn validation_rejects_malformed_batches() {
        assert_eq!(
            validate_queries(&Mat::zeros(0, 3), 3).unwrap_err().kind(),
            "bad_request"
        );
        assert!(validate_queries(&Mat::zeros(2, 3), 3).is_ok());
        assert!(validate_queries(&Mat::zeros(2, 2), 3).is_err());
        let mut q = Mat::zeros(2, 3);
        q.row_mut(1)[0] = f64::NAN;
        assert!(validate_queries(&q, 3).is_err());
        q.row_mut(1)[0] = f64::INFINITY;
        assert!(validate_queries(&q, 3).is_err());
        // dim 0 skips only the dimension check.
        assert!(validate_queries(&Mat::zeros(2, 7), 0).is_ok());
        assert!(validate_features(&[1.0, 2.0], 2).is_ok());
        assert!(validate_features(&[1.0], 2).is_err());
        assert!(validate_features(&[], 0).is_err());
        assert!(validate_features(&[f64::NAN], 1).is_err());
    }

    #[test]
    fn error_wire_encoding_carries_kind_and_shard() {
        let e = PredictError::Shard { shard: 3, message: "boom".into() };
        let j = e.to_json();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("shard_failure"));
        assert_eq!(j.get("shard").unwrap().as_usize(), Some(3));
        assert!(j.get("message").unwrap().as_str().unwrap().contains("boom"));
        let b = PredictError::BadRequest("nope".into());
        assert!(b.to_json().get("shard").is_none());
        assert_eq!(b.to_string(), "bad_request: nope");
    }

    #[test]
    fn route_json_encodes_optional_shard() {
        let r = LeafRoute { shard: None, rows_lo: 4, rows_hi: 9 };
        let j = r.to_json();
        assert_eq!(j.get("shard"), Some(&Json::Null));
        assert_eq!(j.get("rows_hi").unwrap().as_usize(), Some(9));
        let r2 = LeafRoute { shard: Some(1), rows_lo: 0, rows_hi: 2 };
        assert_eq!(r2.to_json().get("shard").unwrap().as_usize(), Some(1));
    }
}
