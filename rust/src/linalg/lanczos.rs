//! Iterative spectral methods over implicit operators.
//!
//! [`lanczos_topk`] computes leading eigenpairs of a symmetric operator
//! given only a matvec closure — this is how kernel PCA runs on the
//! hierarchical kernel matrix, whose matvec is the paper's Algorithm 1 at
//! O(nr) cost, avoiding any O(n^2) densification.
//!
//! [`power_iteration`] computes the dominant singular vector of a (shifted)
//! data matrix — the PCA partitioning rule of Section 4.1.

use super::eig::sym_eig;
use super::matrix::{dot, Mat};
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Top-k eigenpairs (descending by eigenvalue) of a symmetric operator of
/// dimension `n`, available only through `matvec`.
///
/// Runs Lanczos with full reorthogonalization for `iters` steps
/// (iters >= k; a few k + 20 is plenty for kernel matrices whose spectrum
/// decays), then solves the small tridiagonal problem densely.
/// Returns (eigenvalues, eigenvectors as columns of an n x k matrix).
pub fn lanczos_topk(
    n: usize,
    k: usize,
    iters: usize,
    rng: &mut Rng,
    mut matvec: impl FnMut(&[f64]) -> Vec<f64>,
) -> Result<(Vec<f64>, Mat)> {
    if k == 0 || n == 0 {
        return Ok((vec![], Mat::zeros(n, 0)));
    }
    let m = iters.max(k + 2).min(n);
    let mut qs: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut alphas: Vec<f64> = Vec::with_capacity(m);
    let mut betas: Vec<f64> = Vec::with_capacity(m);

    // Random start vector.
    let mut q = vec![0.0; n];
    rng.fill_normal(&mut q);
    normalize(&mut q)?;
    qs.push(q);

    for j in 0..m {
        let mut w = matvec(&qs[j]);
        if w.len() != n {
            return Err(Error::dim("lanczos: matvec returned wrong length"));
        }
        let alpha = dot(&w, &qs[j]);
        alphas.push(alpha);
        // w -= alpha q_j + beta_{j-1} q_{j-1}
        for (wi, qi) in w.iter_mut().zip(qs[j].iter()) {
            *wi -= alpha * qi;
        }
        if j > 0 {
            let beta_prev = betas[j - 1];
            let qprev = &qs[j - 1];
            for (wi, qi) in w.iter_mut().zip(qprev.iter()) {
                *wi -= beta_prev * qi;
            }
        }
        // Full reorthogonalization (twice is enough).
        for _ in 0..2 {
            for qv in &qs {
                let c = dot(&w, qv);
                if c != 0.0 {
                    for (wi, qi) in w.iter_mut().zip(qv.iter()) {
                        *wi -= c * qi;
                    }
                }
            }
        }
        let beta = norm(&w);
        if j + 1 == m || beta < 1e-12 {
            betas.push(beta);
            break;
        }
        betas.push(beta);
        for x in w.iter_mut() {
            *x /= beta;
        }
        qs.push(w);
    }

    // Solve the tridiagonal eigenproblem densely (small).
    let steps = qs.len();
    let mut t = Mat::zeros(steps, steps);
    for i in 0..steps {
        t[(i, i)] = alphas[i];
        if i + 1 < steps {
            t[(i, i + 1)] = betas[i];
            t[(i + 1, i)] = betas[i];
        }
    }
    let (w, s) = sym_eig(&t)?;
    let k_eff = k.min(steps);
    // Ritz vectors: V = Q S[:, :k]
    let mut v = Mat::zeros(n, k_eff);
    for col in 0..k_eff {
        for (jrow, qv) in qs.iter().enumerate() {
            let c = s[(jrow, col)];
            if c == 0.0 {
                continue;
            }
            for i in 0..n {
                v[(i, col)] += c * qv[i];
            }
        }
    }
    Ok((w[..k_eff].to_vec(), v))
}

fn norm(v: &[f64]) -> f64 {
    dot(v, v).sqrt()
}

fn normalize(v: &mut [f64]) -> Result<()> {
    let n = norm(v);
    if n < 1e-300 {
        return Err(Error::linalg("cannot normalize zero vector"));
    }
    for x in v.iter_mut() {
        *x /= n;
    }
    Ok(())
}

/// Dominant right singular vector of the row-centered data matrix
/// `X - mean` (i.e. the first principal axis), via power iteration on
/// Cov = (X-m)ᵀ(X-m) without forming it. Returns (direction, iterations).
///
/// This is the split rule of the PCA partitioning baseline (Section 4.1);
/// Table 2 measures its overhead relative to random projection.
pub fn power_iteration(x: &Mat, rows: &[usize], iters: usize, rng: &mut Rng) -> Vec<f64> {
    let d = x.cols();
    let nr = rows.len();
    if nr == 0 || d == 0 {
        return vec![0.0; d];
    }
    // Column means over the selected rows.
    let mut mean = vec![0.0; d];
    for &i in rows {
        for (mj, xj) in mean.iter_mut().zip(x.row(i).iter()) {
            *mj += xj;
        }
    }
    for mj in mean.iter_mut() {
        *mj /= nr as f64;
    }

    let mut v = rng.unit_vector(d);
    let mut xv = vec![0.0; nr];
    for _ in 0..iters {
        // xv = (X - m) v
        for (k, &i) in rows.iter().enumerate() {
            xv[k] = dot(x.row(i), &v) - dot(&mean, &v);
        }
        // v = (X - m)ᵀ xv
        for vj in v.iter_mut() {
            *vj = 0.0;
        }
        let mut xv_sum = 0.0;
        for (k, &i) in rows.iter().enumerate() {
            let c = xv[k];
            xv_sum += c;
            for (vj, xj) in v.iter_mut().zip(x.row(i).iter()) {
                *vj += c * xj;
            }
        }
        for (vj, mj) in v.iter_mut().zip(mean.iter()) {
            *vj -= xv_sum * mj;
        }
        let nv = norm(&v);
        if nv < 1e-300 {
            // Degenerate data (all points identical): any direction works.
            return rng.unit_vector(d);
        }
        for x in v.iter_mut() {
            *x /= nv;
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{matmul, Trans};

    #[test]
    fn lanczos_matches_dense_eig() {
        let mut rng = Rng::new(1);
        let n = 40;
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = matmul(&g, Trans::No, &g, Trans::Yes);
        a.symmetrize();
        let (w_dense, _) = sym_eig(&a).unwrap();
        let (w, v) = lanczos_topk(n, 5, 40, &mut rng, |x| {
            let mut y = vec![0.0; n];
            crate::linalg::blas::gemv(1.0, &a, Trans::No, x, 0.0, &mut y);
            y
        })
        .unwrap();
        for i in 0..5 {
            assert!(
                (w[i] - w_dense[i]).abs() / w_dense[0] < 1e-8,
                "eig {i}: {} vs {}",
                w[i],
                w_dense[i]
            );
        }
        // Ritz vectors orthonormal.
        let vtv = matmul(&v, Trans::Yes, &v, Trans::No);
        let mut d = vtv;
        d.axpy(-1.0, &Mat::eye(5));
        assert!(d.fro_norm() < 1e-8);
    }

    #[test]
    fn lanczos_k_zero() {
        let mut rng = Rng::new(2);
        let (w, v) = lanczos_topk(10, 0, 5, &mut rng, |x| x.to_vec()).unwrap();
        assert!(w.is_empty());
        assert_eq!(v.cols(), 0);
    }

    #[test]
    fn lanczos_on_identity_terminates_early() {
        let mut rng = Rng::new(3);
        // Identity: Krylov space is 1-dimensional; beta hits ~0 at step 1.
        let (w, _) = lanczos_topk(20, 3, 20, &mut rng, |x| x.to_vec()).unwrap();
        assert!((w[0] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn power_iteration_finds_principal_axis() {
        let mut rng = Rng::new(4);
        // Points stretched along (1, 1)/sqrt(2), offset by a constant mean.
        let n = 300;
        let x = Mat::from_fn(n, 2, |_, j| {
            // filled below
            let _ = j;
            0.0
        });
        let mut x = x;
        for i in 0..n {
            let t = rng.normal() * 5.0;
            let e = rng.normal() * 0.3;
            x[(i, 0)] = 10.0 + (t + e) / std::f64::consts::SQRT_2;
            x[(i, 1)] = -3.0 + (t - e) / std::f64::consts::SQRT_2;
        }
        let rows: Vec<usize> = (0..n).collect();
        let v = power_iteration(&x, &rows, 30, &mut rng);
        let target = std::f64::consts::FRAC_1_SQRT_2;
        let align = (v[0] * target + v[1] * target).abs();
        assert!(align > 0.99, "alignment {align}, v={v:?}");
    }

    #[test]
    fn power_iteration_degenerate_data() {
        let mut rng = Rng::new(5);
        let x = Mat::zeros(5, 3);
        let rows: Vec<usize> = (0..5).collect();
        let v = power_iteration(&x, &rows, 10, &mut rng);
        let nv: f64 = v.iter().map(|a| a * a).sum::<f64>().sqrt();
        assert!((nv - 1.0).abs() < 1e-10);
    }
}
