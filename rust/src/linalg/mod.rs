//! Dense linear algebra substrate.
//!
//! The offline crate set has no BLAS/LAPACK bindings and no `nalgebra`, so
//! the library carries its own: a row-major [`Mat`] type, cache-blocked
//! matrix multiplication, Cholesky / LU / QR factorizations, a symmetric
//! eigensolver (cyclic Jacobi), Lanczos iteration over an implicit operator
//! (used for kernel PCA on the hierarchical matrix, whose matvec is the
//! paper's Algorithm 1), and power iteration for dominant singular vectors
//! (used by the PCA partitioning baseline of Section 4.1).
//!
//! The factorizations are `r x r` or `n0 x n0` (a few hundred at most)
//! and are written for correctness and reasonable single-core throughput;
//! the genuinely hot routines are the BLAS-3 kernels in [`blas`], which
//! run packed and cache-blocked with optional row-panel parallelism
//! (`par_gemm`/`par_syrk`) over the persistent worker pool — bitwise
//! identical to the sequential path for every thread count (see
//! `rust/benches/hotpath.rs` for the GFLOP/s trajectory). Under the
//! packing layer, the register microkernel is runtime-SIMD-dispatched
//! ([`simd`]): AVX2+FMA on x86_64, NEON on aarch64, scalar fallback
//! anywhere, overridable via `HCK_SIMD=scalar|avx2|neon`.

pub mod blas;
pub mod chol;
pub mod eig;
pub mod lanczos;
pub mod lu;
pub mod matrix;
pub mod qr;
pub mod simd;

pub use blas::{
    gemm, gemm_epilogue, gemv, matmul, par_gemm, par_gemm_epilogue, par_gemm_with,
    par_matmul, par_syrk, par_syrk_with, syrk, Epilogue, Trans,
};
pub use chol::Cholesky;
pub use eig::sym_eig;
pub use lanczos::{lanczos_topk, power_iteration};
pub use lu::Lu;
pub use matrix::Mat;
pub use qr::{lstsq, Qr};
pub use simd::{backend_name as simd_backend_name, Backend as SimdBackend};
