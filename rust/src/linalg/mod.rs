//! Dense linear algebra substrate.
//!
//! The offline crate set has no BLAS/LAPACK bindings and no `nalgebra`, so
//! the library carries its own: a row-major [`Mat`] type, cache-blocked
//! matrix multiplication, Cholesky / LU / QR factorizations, a symmetric
//! eigensolver (cyclic Jacobi), Lanczos iteration over an implicit operator
//! (used for kernel PCA on the hierarchical matrix, whose matvec is the
//! paper's Algorithm 1), and power iteration for dominant singular vectors
//! (used by the PCA partitioning baseline of Section 4.1).
//!
//! All factor sizes in the hierarchical kernel are `r x r` or `n0 x n0`
//! (a few hundred at most), so these routines are written for correctness
//! and reasonable single-core throughput rather than peak LINPACK numbers;
//! the `gemm` microkernel is the one genuinely hot routine and is blocked
//! and unrolled accordingly (see `rust/benches/hotpath.rs`).

pub mod blas;
pub mod chol;
pub mod eig;
pub mod lanczos;
pub mod lu;
pub mod matrix;
pub mod qr;

pub use blas::{gemm, gemv, matmul, syrk, Trans};
pub use chol::Cholesky;
pub use eig::sym_eig;
pub use lanczos::{lanczos_topk, power_iteration};
pub use lu::Lu;
pub use matrix::Mat;
pub use qr::{lstsq, Qr};
