//! BLAS-3 core: packed, cache-blocked GEMM / SYRK plus GEMV.
//!
//! `gemm` is the hottest native routine in the library — kernel-block
//! evaluation uses the |x−y|² = |x|² + |y|² − 2⟨x,y⟩ expansion, the
//! hierarchical factor construction multiplies U/W/Σ factors constantly,
//! and the leaf Schur updates are rank-r GEMMs — so it is implemented as
//! a BLIS-style blocked kernel rather than a plain loop nest.
//!
//! # Blocking scheme
//!
//! The driver tiles `C = α·op(A)·op(B) + β·C` with three cache blocks and
//! a register-blocked microkernel:
//!
//! - **KC = 256** (depth) × **NC = 1024** (columns): a panel of op(B) is
//!   packed once per (kc, nc) block into contiguous NR-wide column
//!   panels (~2 MB worst case, L3-resident; the common r-sized blocks
//!   stay far smaller).
//! - **MC = 64** (rows): a panel of op(A) is packed into MR-wide row
//!   panels (≤ 128 KB, L2-resident); each packed pair feeds the
//!   macro-kernel while hot.
//! - **MR×NR = 4×8** microkernel: a register tile updated
//!   `acc[i][j] += a[p·MR+i] · b[p·NR+j]` over the packed panels — pure
//!   contiguous streams. The tile itself is **runtime-SIMD-dispatched**
//!   through [`super::simd`]: an explicit AVX2+FMA 4×8 kernel on x86_64,
//!   NEON 4×4 half-tiles on aarch64, or the scalar 32-accumulator
//!   fallback, selected once per process (`HCK_SIMD` overrides). Edge
//!   tiles are zero-padded inside the packed buffers so the microkernel
//!   never branches on shape; only the valid `mr×nr` region is written
//!   back.
//!
//! Packing reads each transpose case directly from the source matrix
//! (`Trans::Yes/Yes` included — no materialized `b.t()` anywhere), and
//! problems too small to amortize packing (`m·k·n` below
//! `PACK_MIN_VOLUME`, or fewer rows/cols than one micro-tile) fall back
//! to unpacked per-row loops.
//!
//! # Parallel layer and determinism
//!
//! [`par_gemm`] / [`par_syrk`] split C into **disjoint row panels** and
//! dispatch them through the persistent worker pool in
//! [`crate::util::parallel`]. Each worker owns its output rows and runs
//! exactly the same per-row computation as the sequential code: the
//! accumulation order over `k` is fixed by the (plan, KC) blocking alone
//! and never by the row/column tiling, so the result is **bitwise
//! identical** to single-threaded `gemm` for every thread count — the
//! repo-wide determinism invariant (`HCK_THREADS=1` is a fallback, not a
//! different numerical mode). The invariant holds under each SIMD
//! backend separately: the microkernel is selected once per process and
//! every backend accumulates over `k` in the same order (only FMA
//! contraction differs across backends — see [`super::simd`]). Inside
//! an enclosing parallel region (a pool worker, or the caller's own bin
//! of a `run_parallel`) the `par_*` entry points degrade to the
//! sequential path, so routing them through mid-chain code cannot
//! oversubscribe the pool.
//!
//! See `rust/benches/hotpath.rs` for GFLOP/s measurements and the
//! thread-scaling sweep recorded in `BENCH_hotpath.json`.

use super::matrix::Mat;
use super::simd::{self, MR, NR};
use crate::util::parallel::{default_threads, disjoint_slices, run_parallel};

/// Transpose marker for [`gemm`].
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Trans {
    /// Use the operand as stored.
    No,
    /// Use the transpose of the operand.
    Yes,
}

/// Row cache block: one packed op(A) panel is MC×KC (≤ 128 KB).
const MC: usize = 64;
/// Depth cache block.
const KC: usize = 256;
/// Column cache block: one packed op(B) panel is KC×NC (≤ 2 MB).
const NC: usize = 1024;

/// Below this `m·k·n` volume the unpacked per-row loops win — packing
/// traffic (`m·k + k·n` writes) stops being negligible against `2·m·k·n`
/// flops, and the r×m solves with a handful of right-hand sides live
/// here.
const PACK_MIN_VOLUME: usize = 32 * 32 * 32;

/// Minimum `m·k·n` volume before the `par_*` entry points engage the
/// worker pool; below it dispatch latency eats the speedup. Shared with
/// the kernel-block evaluator's direct (L1) path, which has the same
/// row-panel dispatch economics.
pub(crate) const PAR_MIN_VOLUME: usize = 64 * 64 * 64;

/// Per-row epilogue for [`gemm_epilogue`] / [`par_gemm_epilogue`]:
/// called as `epi(i, j0, seg)` where `seg` is the freshly accumulated
/// segment `C[i][j0 .. j0 + seg.len()]`, invoked exactly once per (row,
/// column-strip) while the strip is still cache-hot. Kernel-block
/// evaluation fuses the squared-norm expansion and the kernel profile
/// here instead of re-sweeping the full output matrix.
pub type Epilogue<'a> = &'a (dyn Fn(usize, usize, &mut [f64]) + Sync);

/// Which inner implementation a problem shape routes to. Chosen once per
/// call from the **full** problem shape, so a row-panel split inside
/// [`par_gemm`] executes the same code path as the sequential call —
/// part of the bitwise-determinism argument.
#[derive(Copy, Clone, PartialEq, Eq)]
enum Plan {
    /// Unpacked per-row loops (small problems).
    Small,
    /// Packed panels + microkernel.
    Packed,
}

fn plan_for(m: usize, k: usize, n: usize) -> Plan {
    if m >= MR && n >= NR && m * k * n >= PACK_MIN_VOLUME {
        Plan::Packed
    } else {
        Plan::Small
    }
}

/// Whether a `gemm` of shape (m, k, n) routes through the packed panels
/// and the SIMD microkernel (`true`) or the unpacked per-row loops
/// (`false`). Exposed for the cross-backend property tests: the small
/// plan never touches the microkernel, so every SIMD backend is bitwise
/// identical to scalar on it, while packed results may differ by FMA
/// contraction (see [`super::simd`]).
pub fn uses_packed_plan(m: usize, k: usize, n: usize) -> bool {
    plan_for(m, k, n) == Plan::Packed
}

/// One gemm problem: operands, scaling, inner dimension and the chosen
/// plan — shared by every row/column sub-range the drivers carve out of
/// C.
struct GemmOp<'a> {
    alpha: f64,
    a: &'a Mat,
    ta: Trans,
    b: &'a Mat,
    tb: Trans,
    k: usize,
    plan: Plan,
}

/// General matrix multiply: `C = alpha * op_a(A) * op_b(B) + beta * C`.
///
/// Panics on dimension mismatch (programming error, not data error).
pub fn gemm(alpha: f64, a: &Mat, ta: Trans, b: &Mat, tb: Trans, beta: f64, c: &mut Mat) {
    gemm_driver(1, alpha, a, ta, b, tb, beta, c, None);
}

/// [`gemm`] with a fused per-strip epilogue (see [`Epilogue`]).
#[allow(clippy::too_many_arguments)]
pub fn gemm_epilogue(
    alpha: f64,
    a: &Mat,
    ta: Trans,
    b: &Mat,
    tb: Trans,
    beta: f64,
    c: &mut Mat,
    epi: Epilogue,
) {
    gemm_driver(1, alpha, a, ta, b, tb, beta, c, Some(epi));
}

/// Parallel [`gemm`] over the persistent worker pool with the
/// process-default thread count. Bitwise identical to [`gemm`] for every
/// thread count; degrades to the sequential path for small problems or
/// inside an enclosing parallel region.
pub fn par_gemm(alpha: f64, a: &Mat, ta: Trans, b: &Mat, tb: Trans, beta: f64, c: &mut Mat) {
    let threads = default_threads();
    let _sp = crate::obs::span_with("blas.par_gemm", "blas", || {
        let (m, n) = c.shape();
        let k = match ta {
            Trans::No => a.cols(),
            Trans::Yes => a.rows(),
        };
        format!(
            "{{\"m\":{m},\"n\":{n},\"k\":{k},\"threads\":{threads},\"backend\":\"{}\"}}",
            simd::backend_name()
        )
    });
    gemm_driver(threads, alpha, a, ta, b, tb, beta, c, None);
}

/// [`par_gemm`] with an explicit thread count (testing / benchmarks).
#[allow(clippy::too_many_arguments)]
pub fn par_gemm_with(
    threads: usize,
    alpha: f64,
    a: &Mat,
    ta: Trans,
    b: &Mat,
    tb: Trans,
    beta: f64,
    c: &mut Mat,
) {
    gemm_driver(threads, alpha, a, ta, b, tb, beta, c, None);
}

/// Parallel [`gemm_epilogue`] with an explicit thread count (`1` =
/// sequential). The epilogue runs on the worker that owns the row, once
/// per completed column strip.
#[allow(clippy::too_many_arguments)]
pub fn par_gemm_epilogue(
    threads: usize,
    alpha: f64,
    a: &Mat,
    ta: Trans,
    b: &Mat,
    tb: Trans,
    beta: f64,
    c: &mut Mat,
    epi: Epilogue,
) {
    gemm_driver(threads, alpha, a, ta, b, tb, beta, c, Some(epi));
}

#[allow(clippy::too_many_arguments)]
fn gemm_driver(
    threads: usize,
    alpha: f64,
    a: &Mat,
    ta: Trans,
    b: &Mat,
    tb: Trans,
    beta: f64,
    c: &mut Mat,
    epi: Option<Epilogue>,
) {
    let (am, ak) = match ta {
        Trans::No => a.shape(),
        Trans::Yes => (a.cols(), a.rows()),
    };
    let (bk, bn) = match tb {
        Trans::No => b.shape(),
        Trans::Yes => (b.cols(), b.rows()),
    };
    assert_eq!(ak, bk, "gemm inner dims: {ak} vs {bk}");
    assert_eq!(c.shape(), (am, bn), "gemm output shape");

    if alpha == 0.0 || am == 0 || bn == 0 || ak == 0 {
        // The epilogue contract is "runs over the final accumulated C",
        // which on these degenerate shapes is just beta * C.
        apply_beta(c.as_mut_slice(), beta);
        if let Some(epi) = epi {
            for i in 0..am {
                epi(i, 0, c.row_mut(i));
            }
        }
        return;
    }

    let op = GemmOp { alpha, a, ta, b, tb, k: ak, plan: plan_for(am, ak, bn) };
    let par_ok = am * ak * bn >= PAR_MIN_VOLUME;
    let threads = if par_ok { threads.max(1) } else { 1 };
    if threads <= 1 {
        apply_beta(c.as_mut_slice(), beta);
        gemm_rows(&op, (0, am), (0, bn), c.as_mut_slice(), bn, epi);
        return;
    }

    // Row-panel split: contiguous chunks, one per worker. Every row's
    // value depends only on the (plan, KC) schedule — never on which
    // panel it landed in — so the result is bitwise identical to the
    // sequential sweep.
    let chunk = am.div_ceil(threads);
    let ranges: Vec<(usize, usize)> = (0..threads)
        .map(|t| (t * chunk, ((t + 1) * chunk).min(am)))
        .filter(|&(lo, hi)| lo < hi)
        .collect();
    let elems: Vec<(usize, usize)> =
        ranges.iter().map(|&(lo, hi)| (lo * bn, hi * bn)).collect();
    let slices = disjoint_slices(c.as_mut_slice(), &elems);
    let items: Vec<((usize, usize), &mut [f64])> =
        ranges.into_iter().zip(slices).collect();
    let opref = &op;
    run_parallel(threads, items, move |(rows, slice)| {
        // Each worker scales its own rows by beta before accumulating —
        // no serial full-matrix sweep ahead of the dispatch, and the
        // elementwise scale is bitwise identical however it is split.
        apply_beta(slice, beta);
        gemm_rows(opref, rows, (0, bn), slice, bn, epi);
    });
}

/// C ← beta · C over a raw slice (0 clears, 1 is a no-op).
fn apply_beta(c: &mut [f64], beta: f64) {
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for v in c.iter_mut() {
            *v *= beta;
        }
    }
}

/// Accumulate `C[rows, cols] += alpha · op(A)[rows, :] · op(B)[:, cols]`
/// into `c`, a row-major slice covering exactly rows `rows.0..rows.1` of
/// the full C (leading dimension `ldc`, beta already applied). The
/// k-accumulation order is fixed by `op.plan` and the KC blocking alone.
fn gemm_rows(
    op: &GemmOp,
    rows: (usize, usize),
    cols: (usize, usize),
    c: &mut [f64],
    ldc: usize,
    epi: Option<Epilogue>,
) {
    match op.plan {
        Plan::Small => {
            debug_assert!(cols == (0, ldc), "small plan computes full rows");
            small_rows(op, rows, c, ldc);
            if let Some(epi) = epi {
                for i in rows.0..rows.1 {
                    let off = (i - rows.0) * ldc;
                    epi(i, 0, &mut c[off..off + ldc]);
                }
            }
        }
        Plan::Packed => packed_rows(op, rows, cols, c, ldc, epi),
    }
}

// ---------------------------------------------------------------------
// Small plan: unpacked per-row loops. Each output row accumulates in the
// same order whatever row range it is computed under.
// ---------------------------------------------------------------------

fn small_rows(op: &GemmOp, rows: (usize, usize), c: &mut [f64], ldc: usize) {
    let (row_lo, row_hi) = rows;
    let alpha = op.alpha;
    let (a, b, k) = (op.a, op.b, op.k);
    let n = ldc;
    match (op.ta, op.tb) {
        (Trans::No, Trans::No) => {
            // i-k-j with 4-way register blocking over k: each pass over
            // the C row consumes four B rows, quartering C-row traffic.
            let bd = b.as_slice();
            let k4 = k / 4 * 4;
            for i in row_lo..row_hi {
                let arow = a.row(i);
                let off = (i - row_lo) * ldc;
                let crow = &mut c[off..off + n];
                let mut p = 0;
                while p < k4 {
                    let a0 = alpha * arow[p];
                    let a1 = alpha * arow[p + 1];
                    let a2 = alpha * arow[p + 2];
                    let a3 = alpha * arow[p + 3];
                    let b0 = &bd[p * n..(p + 1) * n];
                    let b1 = &bd[(p + 1) * n..(p + 2) * n];
                    let b2 = &bd[(p + 2) * n..(p + 3) * n];
                    let b3 = &bd[(p + 3) * n..(p + 4) * n];
                    for j in 0..n {
                        crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                    p += 4;
                }
                while p < k {
                    let aip = alpha * arow[p];
                    if aip != 0.0 {
                        axpy_row(aip, &bd[p * n..(p + 1) * n], crow);
                    }
                    p += 1;
                }
            }
        }
        (Trans::Yes, Trans::No) => {
            // Outer products over k; all operands row-contiguous. The
            // per-row accumulation order over p is unchanged by the row
            // range.
            for p in 0..k {
                let arow = a.row(p);
                let brow = b.row(p);
                for i in row_lo..row_hi {
                    let aip = alpha * arow[i];
                    if aip == 0.0 {
                        continue;
                    }
                    let off = (i - row_lo) * ldc;
                    axpy_row(aip, brow, &mut c[off..off + n]);
                }
            }
        }
        (Trans::No, Trans::Yes) => {
            // Every C entry is a dot of two stored rows.
            for i in row_lo..row_hi {
                let arow = a.row(i);
                let off = (i - row_lo) * ldc;
                let crow = &mut c[off..off + n];
                for (j, cj) in crow.iter_mut().enumerate() {
                    *cj += alpha * super::matrix::dot(arow, b.row(j));
                }
            }
        }
        (Trans::Yes, Trans::Yes) => {
            // C[i][j] = Σ_p A[p][i] · B[j][p]: gather the strided A
            // column once per output row, then dot against stored B
            // rows — no materialized transpose.
            let mut acol = vec![0.0; k];
            for i in row_lo..row_hi {
                for (p, v) in acol.iter_mut().enumerate() {
                    *v = a[(p, i)];
                }
                let off = (i - row_lo) * ldc;
                let crow = &mut c[off..off + n];
                for (j, cj) in crow.iter_mut().enumerate() {
                    *cj += alpha * super::matrix::dot(&acol, b.row(j));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Packed plan: BLIS-style loop nest jc (NC) → pc (KC) → ic (MC) with
// zero-padded MR/NR panels and the register microkernel.
// ---------------------------------------------------------------------

fn packed_rows(
    op: &GemmOp,
    rows: (usize, usize),
    cols: (usize, usize),
    c: &mut [f64],
    ldc: usize,
    epi: Option<Epilogue>,
) {
    let (row_lo, row_hi) = rows;
    let (col_lo, col_hi) = cols;
    let k = op.k;
    let kc_max = k.min(KC);
    let mc_max = (row_hi - row_lo).min(MC);
    let nc_max = (col_hi - col_lo).min(NC);
    let mut apack = vec![0.0; mc_max.div_ceil(MR) * MR * kc_max];
    let mut bpack = vec![0.0; nc_max.div_ceil(NR) * NR * kc_max];

    let mut jc = col_lo;
    while jc < col_hi {
        let nc = nc_max.min(col_hi - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b(op.b, op.tb, pc, kc, jc, nc, &mut bpack);
            let mut ic = row_lo;
            while ic < row_hi {
                let mc = MC.min(row_hi - ic);
                pack_a(op.a, op.ta, ic, mc, pc, kc, &mut apack);
                let coff = (ic - row_lo) * ldc + jc;
                macro_kernel(op.alpha, &apack, &bpack, kc, mc, nc, &mut c[coff..], ldc);
                ic += mc;
            }
            pc += kc;
        }
        if let Some(epi) = epi {
            for i in row_lo..row_hi {
                let off = (i - row_lo) * ldc + jc;
                epi(i, jc, &mut c[off..off + nc]);
            }
        }
        jc += nc;
    }
}

/// Pack op(A)[row_lo .. row_lo+mc, p0 .. p0+kc] into MR-row panels:
/// `buf[panel][p * MR + i]`, zero-padding partial panels so the
/// microkernel always sees a full MR lane set.
fn pack_a(a: &Mat, ta: Trans, row_lo: usize, mc: usize, p0: usize, kc: usize, buf: &mut [f64]) {
    let panels = mc.div_ceil(MR);
    match ta {
        Trans::No => {
            for ip in 0..panels {
                let i0 = ip * MR;
                let live = MR.min(mc - i0);
                let dst = &mut buf[ip * kc * MR..(ip + 1) * kc * MR];
                if live < MR {
                    dst.fill(0.0);
                }
                for i in 0..live {
                    let arow = &a.row(row_lo + i0 + i)[p0..p0 + kc];
                    for (p, &v) in arow.iter().enumerate() {
                        dst[p * MR + i] = v;
                    }
                }
            }
        }
        Trans::Yes => {
            // op(A)[i][p] = a[p][i]; row p of the stored (k x m) matrix
            // is contiguous over i.
            for ip in 0..panels {
                let i0 = ip * MR;
                let live = MR.min(mc - i0);
                let dst = &mut buf[ip * kc * MR..(ip + 1) * kc * MR];
                if live < MR {
                    dst.fill(0.0);
                }
                for p in 0..kc {
                    let arow = a.row(p0 + p);
                    let src = &arow[row_lo + i0..row_lo + i0 + live];
                    dst[p * MR..p * MR + live].copy_from_slice(src);
                }
            }
        }
    }
}

/// Pack op(B)[p0 .. p0+kc, j0 .. j0+nc] into NR-column panels:
/// `buf[panel][p * NR + j]`, zero-padded like [`pack_a`].
fn pack_b(b: &Mat, tb: Trans, p0: usize, kc: usize, j0: usize, nc: usize, buf: &mut [f64]) {
    let panels = nc.div_ceil(NR);
    match tb {
        Trans::No => {
            for jp in 0..panels {
                let jj = j0 + jp * NR;
                let live = NR.min(nc - jp * NR);
                let dst = &mut buf[jp * kc * NR..(jp + 1) * kc * NR];
                if live < NR {
                    dst.fill(0.0);
                }
                for p in 0..kc {
                    let brow = b.row(p0 + p);
                    let src = &brow[jj..jj + live];
                    dst[p * NR..p * NR + live].copy_from_slice(src);
                }
            }
        }
        Trans::Yes => {
            // op(B)[p][j] = b[j][p]; row j of the stored (n x k) matrix
            // is contiguous over p.
            for jp in 0..panels {
                let jj = j0 + jp * NR;
                let live = NR.min(nc - jp * NR);
                let dst = &mut buf[jp * kc * NR..(jp + 1) * kc * NR];
                if live < NR {
                    dst.fill(0.0);
                }
                for j in 0..live {
                    let brow = &b.row(jj + j)[p0..p0 + kc];
                    for (p, &v) in brow.iter().enumerate() {
                        dst[p * NR + j] = v;
                    }
                }
            }
        }
    }
}

/// Sweep the packed panels with the register microkernel. `c` starts at
/// the (row, column) origin of this macro block inside the caller's
/// panel; only the valid `mr×nr` region of each tile is written back.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    alpha: f64,
    apack: &[f64],
    bpack: &[f64],
    kc: usize,
    mc: usize,
    nc: usize,
    c: &mut [f64],
    ldc: usize,
) {
    let mpanels = mc.div_ceil(MR);
    let npanels = nc.div_ceil(NR);
    for jp in 0..npanels {
        let j0 = jp * NR;
        let nr = NR.min(nc - j0);
        let bpanel = &bpack[jp * kc * NR..(jp + 1) * kc * NR];
        for ip in 0..mpanels {
            let i0 = ip * MR;
            let mr = MR.min(mc - i0);
            let apanel = &apack[ip * kc * MR..(ip + 1) * kc * MR];
            let mut acc = [[0.0f64; NR]; MR];
            simd::microkernel(kc, apanel, bpanel, &mut acc);
            for i in 0..mr {
                let base = (i0 + i) * ldc + j0;
                let crow = &mut c[base..base + nr];
                for (j, cj) in crow.iter_mut().enumerate() {
                    *cj += alpha * acc[i][j];
                }
            }
        }
    }
}

/// y[j] += a * x[j] over a row — unrolled 8-way.
#[inline]
fn axpy_row(a: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len().min(y.len());
    let chunks = n / 8;
    for cidx in 0..chunks {
        let i = cidx * 8;
        y[i] += a * x[i];
        y[i + 1] += a * x[i + 1];
        y[i + 2] += a * x[i + 2];
        y[i + 3] += a * x[i + 3];
        y[i + 4] += a * x[i + 4];
        y[i + 5] += a * x[i + 5];
        y[i + 6] += a * x[i + 6];
        y[i + 7] += a * x[i + 7];
    }
    for i in chunks * 8..n {
        y[i] += a * x[i];
    }
}

/// Matrix-vector product: `y = alpha * op(A) x + beta * y`.
pub fn gemv(alpha: f64, a: &Mat, ta: Trans, x: &[f64], beta: f64, y: &mut [f64]) {
    let (m, n) = match ta {
        Trans::No => a.shape(),
        Trans::Yes => (a.cols(), a.rows()),
    };
    assert_eq!(x.len(), n, "gemv x len");
    assert_eq!(y.len(), m, "gemv y len");
    if beta == 0.0 {
        y.fill(0.0);
    } else if beta != 1.0 {
        for v in y.iter_mut() {
            *v *= beta;
        }
    }
    match ta {
        Trans::No => {
            for i in 0..m {
                y[i] += alpha * super::matrix::dot(a.row(i), x);
            }
        }
        Trans::Yes => {
            for p in 0..a.rows() {
                let ax = alpha * x[p];
                if ax == 0.0 {
                    continue;
                }
                axpy_row(ax, a.row(p), y);
            }
        }
    }
}

/// Convenience: allocate and return op_a(A) * op_b(B).
pub fn matmul(a: &Mat, ta: Trans, b: &Mat, tb: Trans) -> Mat {
    let (m, n) = matmul_shape(a, ta, b, tb);
    let mut c = Mat::zeros(m, n);
    gemm(1.0, a, ta, b, tb, 0.0, &mut c);
    c
}

/// [`matmul`] through [`par_gemm`]: same result bitwise, pool-parallel
/// when called at the top of the chain on a big enough product.
pub fn par_matmul(a: &Mat, ta: Trans, b: &Mat, tb: Trans) -> Mat {
    let (m, n) = matmul_shape(a, ta, b, tb);
    let mut c = Mat::zeros(m, n);
    par_gemm(1.0, a, ta, b, tb, 0.0, &mut c);
    c
}

fn matmul_shape(a: &Mat, ta: Trans, b: &Mat, tb: Trans) -> (usize, usize) {
    let m = match ta {
        Trans::No => a.rows(),
        Trans::Yes => a.cols(),
    };
    let n = match tb {
        Trans::No => b.cols(),
        Trans::Yes => b.rows(),
    };
    (m, n)
}

/// Symmetric rank-k update over full storage:
/// `C = alpha * op(A) op(A)ᵀ + beta * C` — `ta = No` gives A·Aᵀ
/// (`m = a.rows()`), `ta = Yes` gives Aᵀ·A (`m = a.cols()`, the Gram
/// matrix of a feature block). Only the upper triangle is computed
/// (through the same packed core as [`gemm`]); the lower triangle is
/// mirrored from it, so the result is exactly symmetric.
pub fn syrk(alpha: f64, a: &Mat, ta: Trans, beta: f64, c: &mut Mat) {
    syrk_driver(1, alpha, a, ta, beta, c);
}

/// Parallel [`syrk`] over the persistent worker pool (process-default
/// thread count); bitwise identical to [`syrk`] for every thread count.
pub fn par_syrk(alpha: f64, a: &Mat, ta: Trans, beta: f64, c: &mut Mat) {
    let threads = default_threads();
    let _sp = crate::obs::span_with("blas.par_syrk", "blas", || {
        let (m, k) = match ta {
            Trans::No => a.shape(),
            Trans::Yes => (a.cols(), a.rows()),
        };
        format!(
            "{{\"m\":{m},\"k\":{k},\"threads\":{threads},\"backend\":\"{}\"}}",
            simd::backend_name()
        )
    });
    syrk_driver(threads, alpha, a, ta, beta, c);
}

/// [`par_syrk`] with an explicit thread count (testing / benchmarks).
pub fn par_syrk_with(threads: usize, alpha: f64, a: &Mat, ta: Trans, beta: f64, c: &mut Mat) {
    syrk_driver(threads, alpha, a, ta, beta, c);
}

fn syrk_driver(threads: usize, alpha: f64, a: &Mat, ta: Trans, beta: f64, c: &mut Mat) {
    let (m, k) = match ta {
        Trans::No => a.shape(),
        Trans::Yes => (a.cols(), a.rows()),
    };
    assert_eq!(c.shape(), (m, m), "syrk output shape");
    if m == 0 {
        return;
    }
    if alpha == 0.0 || k == 0 {
        for i in 0..m {
            for j in i..m {
                let prev = if beta == 0.0 { 0.0 } else { beta * c[(i, j)] };
                c[(i, j)] = prev;
            }
        }
        mirror_lower(c);
        return;
    }
    // op(A) · op(A)ᵀ is a gemm against the flipped transpose of the same
    // operand, restricted to the upper triangle.
    let tb = match ta {
        Trans::No => Trans::Yes,
        Trans::Yes => Trans::No,
    };
    let plan = plan_for(m, k, m);
    if plan == Plan::Small {
        // Dot loop over the upper triangle; materialize opᵀ(A) only in
        // the strided case (small by definition of the plan).
        let att;
        let opa: &Mat = match ta {
            Trans::No => a,
            Trans::Yes => {
                att = a.t();
                &att
            }
        };
        for i in 0..m {
            let ri = opa.row(i);
            for j in i..m {
                let v = alpha * super::matrix::dot(ri, opa.row(j));
                let prev = if beta == 0.0 { 0.0 } else { beta * c[(i, j)] };
                c[(i, j)] = prev + v;
            }
        }
        mirror_lower(c);
        return;
    }

    // Packed path: MC-row panels, panel starting at row `lo` computes
    // columns [lo, m) — the upper wedge plus the sub-diagonal corner of
    // its own diagonal block (whose entries are the same values by
    // symmetry of the accumulation; the final mirror overwrites them
    // with bitwise-equal numbers). Panels are dealt round-robin so the
    // shrinking wedges balance across workers; every panel owns disjoint
    // C rows, so thread count cannot change a bit of the result.
    let op = GemmOp { alpha, a, ta, b: a, tb, k, plan };
    let par_ok = m * k * m >= PAR_MIN_VOLUME;
    let threads = if par_ok { threads.max(1) } else { 1 };
    let ranges: Vec<(usize, usize)> =
        (0..m.div_ceil(MC)).map(|p| (p * MC, ((p + 1) * MC).min(m))).collect();
    let elems: Vec<(usize, usize)> = ranges.iter().map(|&(lo, hi)| (lo * m, hi * m)).collect();
    let slices = disjoint_slices(c.as_mut_slice(), &elems);
    let items: Vec<((usize, usize), &mut [f64])> = ranges.into_iter().zip(slices).collect();
    let opref = &op;
    run_parallel(threads, items, move |((lo, hi), slice)| {
        // beta on this panel's [lo, m) wedge, then accumulate.
        for i in lo..hi {
            let off = (i - lo) * m;
            let seg = &mut slice[off + lo..off + m];
            if beta == 0.0 {
                seg.fill(0.0);
            } else if beta != 1.0 {
                for v in seg.iter_mut() {
                    *v *= beta;
                }
            }
        }
        gemm_rows(opref, (lo, hi), (lo, m), slice, m, None);
    });
    mirror_lower(c);
}

/// Overwrite the strict lower triangle with the upper one.
fn mirror_lower(c: &mut Mat) {
    let m = c.rows();
    for i in 1..m {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(r: &mut Rng, m: usize, n: usize) -> Mat {
        Mat::from_fn(m, n, |_, _| r.normal())
    }

    fn naive_mm(a: &Mat, b: &Mat) -> Mat {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Mat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f64) {
        assert_eq!(a.shape(), b.shape());
        let mut diff = a.clone();
        diff.axpy(-1.0, b);
        let rel = diff.fro_norm() / (1.0 + b.fro_norm());
        assert!(rel < tol, "relative diff {rel}");
    }

    #[test]
    fn gemm_matches_naive_all_transposes() {
        let mut r = Rng::new(1);
        // Both plans: a small-path shape and a packed-path shape with
        // edges off every block multiple.
        for (m, k, n) in [(13usize, 9usize, 17usize), (67, 35, 70)] {
            let a = randmat(&mut r, m, k);
            let b = randmat(&mut r, k, n);
            let at = a.t();
            let bt = b.t();
            let want = naive_mm(&a, &b);
            assert_close(&matmul(&a, Trans::No, &b, Trans::No), &want, 1e-12);
            assert_close(&matmul(&at, Trans::Yes, &b, Trans::No), &want, 1e-12);
            assert_close(&matmul(&a, Trans::No, &bt, Trans::Yes), &want, 1e-12);
            assert_close(&matmul(&at, Trans::Yes, &bt, Trans::Yes), &want, 1e-12);
        }
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut r = Rng::new(2);
        let a = randmat(&mut r, 4, 5);
        let b = randmat(&mut r, 5, 3);
        let c0 = randmat(&mut r, 4, 3);
        let mut c = c0.clone();
        gemm(2.0, &a, Trans::No, &b, Trans::No, 0.5, &mut c);
        let mut want = naive_mm(&a, &b);
        want.scale(2.0);
        want.axpy(0.5, &c0);
        assert_close(&c, &want, 1e-12);
    }

    #[test]
    fn gemv_matches_gemm() {
        let mut r = Rng::new(3);
        let a = randmat(&mut r, 6, 4);
        let x: Vec<f64> = (0..4).map(|_| r.normal()).collect();
        let mut y = vec![0.0; 6];
        gemv(1.0, &a, Trans::No, &x, 0.0, &mut y);
        let want = naive_mm(&a, &Mat::col_vec(&x));
        for i in 0..6 {
            assert!((y[i] - want[(i, 0)]).abs() < 1e-12);
        }
        // transposed
        let mut yt = vec![1.0; 4];
        gemv(1.0, &a, Trans::Yes, &y, 2.0, &mut yt);
        let want_t = naive_mm(&a.t(), &Mat::col_vec(&y));
        for j in 0..4 {
            assert!((yt[j] - (want_t[(j, 0)] + 2.0)).abs() < 1e-10);
        }
    }

    #[test]
    fn syrk_matches_gemm_both_transposes() {
        let mut r = Rng::new(4);
        for (m, k) in [(7usize, 3usize), (70, 40)] {
            let a = randmat(&mut r, m, k);
            // ta = No: A Aᵀ
            let mut c = Mat::zeros(m, m);
            syrk(1.5, &a, Trans::No, 0.0, &mut c);
            let mut want = matmul(&a, Trans::No, &a, Trans::Yes);
            want.scale(1.5);
            assert_close(&c, &want, 1e-12);
            assert!(c.is_symmetric(0.0));
            // ta = Yes: Aᵀ A (the Gram matrix of a feature block)
            let mut g = Mat::zeros(k, k);
            syrk(0.5, &a, Trans::Yes, 0.0, &mut g);
            let mut wantg = matmul(&a, Trans::Yes, &a, Trans::No);
            wantg.scale(0.5);
            assert_close(&g, &wantg, 1e-12);
            assert!(g.is_symmetric(0.0));
        }
    }

    #[test]
    fn gemm_epilogue_runs_per_strip() {
        let mut r = Rng::new(5);
        let (m, k, n) = (37, 33, 41);
        let a = randmat(&mut r, m, k);
        let b = randmat(&mut r, k, n);
        let mut c = Mat::zeros(m, n);
        let epi = |i: usize, j0: usize, seg: &mut [f64]| {
            for (off, v) in seg.iter_mut().enumerate() {
                *v += (i * 1000 + j0 + off) as f64;
            }
        };
        gemm_epilogue(1.0, &a, Trans::No, &b, Trans::No, 0.0, &mut c, &epi);
        let plain = naive_mm(&a, &b);
        for i in 0..m {
            for j in 0..n {
                let want = plain[(i, j)] + (i * 1000 + j) as f64;
                assert!((c[(i, j)] - want).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn par_gemm_bitwise_equals_gemm() {
        let mut r = Rng::new(6);
        let (m, k, n) = (130, 70, 90);
        let a = randmat(&mut r, m, k);
        let b = randmat(&mut r, k, n);
        let mut want = Mat::zeros(m, n);
        gemm(1.0, &a, Trans::No, &b, Trans::No, 0.0, &mut want);
        for threads in [1usize, 2, 3, 8] {
            let mut c = Mat::zeros(m, n);
            par_gemm_with(threads, 1.0, &a, Trans::No, &b, Trans::No, 0.0, &mut c);
            assert_eq!(c.as_slice(), want.as_slice(), "threads={threads}");
        }
    }

    #[test]
    fn empty_dims_ok() {
        let a = Mat::zeros(0, 3);
        let b = Mat::zeros(3, 2);
        let c = matmul(&a, Trans::No, &b, Trans::No);
        assert_eq!(c.shape(), (0, 2));
        let a2 = Mat::zeros(2, 0);
        let b2 = Mat::zeros(0, 2);
        let c2 = matmul(&a2, Trans::No, &b2, Trans::No);
        assert_eq!(c2.fro_norm(), 0.0);
    }
}
