//! BLAS-like kernels: blocked GEMM, GEMV, SYRK.
//!
//! `gemm` is the hottest native routine in the library (kernel-block
//! evaluation uses the |x-y|^2 = |x|^2 + |y|^2 - 2<x,y> expansion, the
//! hierarchical factor construction multiplies U/W/Σ factors constantly).
//! The implementation packs nothing but uses an i-k-j loop order with 4-way
//! j-unrolling, which keeps the B row in cache and lets LLVM autovectorize;
//! on the benchmark machine it reaches a few GFLOP/s single-core, which is
//! within ~2-3x of an optimized microkernel and far from the O(n^3) naive
//! j-inner order. See rust/benches/hotpath.rs for measurements.

use super::matrix::Mat;

/// Transpose marker for [`gemm`].
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Trans {
    /// Use the operand as stored.
    No,
    /// Use the transpose of the operand.
    Yes,
}

/// General matrix multiply: `C = alpha * op_a(A) * op_b(B) + beta * C`.
///
/// Panics on dimension mismatch (programming error, not data error).
pub fn gemm(alpha: f64, a: &Mat, ta: Trans, b: &Mat, tb: Trans, beta: f64, c: &mut Mat) {
    let (am, ak) = match ta {
        Trans::No => a.shape(),
        Trans::Yes => (a.cols(), a.rows()),
    };
    let (bk, bn) = match tb {
        Trans::No => b.shape(),
        Trans::Yes => (b.cols(), b.rows()),
    };
    assert_eq!(ak, bk, "gemm inner dims: {ak} vs {bk}");
    assert_eq!(c.shape(), (am, bn), "gemm output shape");

    if beta == 0.0 {
        c.as_mut_slice().fill(0.0);
    } else if beta != 1.0 {
        c.scale(beta);
    }
    if alpha == 0.0 || am == 0 || bn == 0 || ak == 0 {
        return;
    }

    match (ta, tb) {
        (Trans::No, Trans::No) => gemm_nn(alpha, a, b, c),
        (Trans::Yes, Trans::No) => gemm_tn(alpha, a, b, c),
        (Trans::No, Trans::Yes) => gemm_nt(alpha, a, b, c),
        (Trans::Yes, Trans::Yes) => {
            // Rare; fall back to materializing Bᵀ (small matrices here).
            let bt = b.t();
            gemm_tn(alpha, a, &bt, c);
        }
    }
}

/// C += alpha * A * B, row-major, i-k-j order with 4-way register
/// blocking over k: each pass over the C row consumes four B rows, which
/// quarters the C-row load/store traffic (the bottleneck the flat profile
/// shows — see EXPERIMENTS.md §Perf iteration 4).
fn gemm_nn(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) {
    let (m, k) = a.shape();
    let n = b.cols();
    let bd = b.as_slice();
    let k4 = k / 4 * 4;
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        let mut p = 0;
        while p < k4 {
            let a0 = alpha * arow[p];
            let a1 = alpha * arow[p + 1];
            let a2 = alpha * arow[p + 2];
            let a3 = alpha * arow[p + 3];
            let b0 = &bd[p * n..(p + 1) * n];
            let b1 = &bd[(p + 1) * n..(p + 2) * n];
            let b2 = &bd[(p + 2) * n..(p + 3) * n];
            let b3 = &bd[(p + 3) * n..(p + 4) * n];
            for j in 0..n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            p += 4;
        }
        while p < k {
            let aip = alpha * arow[p];
            if aip != 0.0 {
                axpy_row(aip, &bd[p * n..(p + 1) * n], crow);
            }
            p += 1;
        }
    }
}

/// C += alpha * Aᵀ * B where A is (k x m): loop over k accumulating outer
/// products; accesses all operands row-contiguously.
fn gemm_tn(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) {
    let (k, m) = a.shape();
    let n = b.cols();
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..m {
            let aip = alpha * arow[i];
            if aip == 0.0 {
                continue;
            }
            axpy_row(aip, brow, &mut c.row_mut(i)[..n]);
        }
    }
}

/// C += alpha * A * Bᵀ: every C entry is a dot of two rows.
fn gemm_nt(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) {
    let m = a.rows();
    let n = b.rows();
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..n {
            crow[j] += alpha * super::matrix::dot(arow, b.row(j));
        }
    }
}

/// y[j] += a * x[j] over a row — unrolled 8-way.
#[inline]
fn axpy_row(a: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len().min(y.len());
    let chunks = n / 8;
    for cidx in 0..chunks {
        let i = cidx * 8;
        y[i] += a * x[i];
        y[i + 1] += a * x[i + 1];
        y[i + 2] += a * x[i + 2];
        y[i + 3] += a * x[i + 3];
        y[i + 4] += a * x[i + 4];
        y[i + 5] += a * x[i + 5];
        y[i + 6] += a * x[i + 6];
        y[i + 7] += a * x[i + 7];
    }
    for i in chunks * 8..n {
        y[i] += a * x[i];
    }
}

/// Matrix-vector product: `y = alpha * op(A) x + beta * y`.
pub fn gemv(alpha: f64, a: &Mat, ta: Trans, x: &[f64], beta: f64, y: &mut [f64]) {
    let (m, n) = match ta {
        Trans::No => a.shape(),
        Trans::Yes => (a.cols(), a.rows()),
    };
    assert_eq!(x.len(), n, "gemv x len");
    assert_eq!(y.len(), m, "gemv y len");
    if beta == 0.0 {
        y.fill(0.0);
    } else if beta != 1.0 {
        for v in y.iter_mut() {
            *v *= beta;
        }
    }
    match ta {
        Trans::No => {
            for i in 0..m {
                y[i] += alpha * super::matrix::dot(a.row(i), x);
            }
        }
        Trans::Yes => {
            for p in 0..a.rows() {
                let ax = alpha * x[p];
                if ax == 0.0 {
                    continue;
                }
                axpy_row(ax, a.row(p), y);
            }
        }
    }
}

/// Convenience: allocate and return op_a(A) * op_b(B).
pub fn matmul(a: &Mat, ta: Trans, b: &Mat, tb: Trans) -> Mat {
    let m = match ta {
        Trans::No => a.rows(),
        Trans::Yes => a.cols(),
    };
    let n = match tb {
        Trans::No => b.cols(),
        Trans::Yes => b.rows(),
    };
    let mut c = Mat::zeros(m, n);
    gemm(1.0, a, ta, b, tb, 0.0, &mut c);
    c
}

/// Symmetric rank-k update: C = alpha * A Aᵀ + beta * C (full storage,
/// exploits symmetry by computing the upper triangle and mirroring).
pub fn syrk(alpha: f64, a: &Mat, beta: f64, c: &mut Mat) {
    let m = a.rows();
    assert_eq!(c.shape(), (m, m));
    for i in 0..m {
        let arow_i = a.row(i);
        for j in i..m {
            let v = alpha * super::matrix::dot(arow_i, a.row(j));
            let prev = if beta == 0.0 { 0.0 } else { beta * c[(i, j)] };
            c[(i, j)] = prev + v;
        }
    }
    for i in 0..m {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(r: &mut Rng, m: usize, n: usize) -> Mat {
        Mat::from_fn(m, n, |_, _| r.normal())
    }

    fn naive_mm(a: &Mat, b: &Mat) -> Mat {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Mat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f64) {
        assert_eq!(a.shape(), b.shape());
        let mut diff = a.clone();
        diff.axpy(-1.0, b);
        let rel = diff.fro_norm() / (1.0 + b.fro_norm());
        assert!(rel < tol, "relative diff {rel}");
    }

    #[test]
    fn gemm_matches_naive_all_transposes() {
        let mut r = Rng::new(1);
        let (m, k, n) = (13, 9, 17);
        let a = randmat(&mut r, m, k);
        let b = randmat(&mut r, k, n);
        let at = a.t();
        let bt = b.t();
        let want = naive_mm(&a, &b);
        assert_close(&matmul(&a, Trans::No, &b, Trans::No), &want, 1e-12);
        assert_close(&matmul(&at, Trans::Yes, &b, Trans::No), &want, 1e-12);
        assert_close(&matmul(&a, Trans::No, &bt, Trans::Yes), &want, 1e-12);
        assert_close(&matmul(&at, Trans::Yes, &bt, Trans::Yes), &want, 1e-12);
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut r = Rng::new(2);
        let a = randmat(&mut r, 4, 5);
        let b = randmat(&mut r, 5, 3);
        let c0 = randmat(&mut r, 4, 3);
        let mut c = c0.clone();
        gemm(2.0, &a, Trans::No, &b, Trans::No, 0.5, &mut c);
        let mut want = naive_mm(&a, &b);
        want.scale(2.0);
        want.axpy(0.5, &c0);
        assert_close(&c, &want, 1e-12);
    }

    #[test]
    fn gemv_matches_gemm() {
        let mut r = Rng::new(3);
        let a = randmat(&mut r, 6, 4);
        let x: Vec<f64> = (0..4).map(|_| r.normal()).collect();
        let mut y = vec![0.0; 6];
        gemv(1.0, &a, Trans::No, &x, 0.0, &mut y);
        let want = naive_mm(&a, &Mat::col_vec(&x));
        for i in 0..6 {
            assert!((y[i] - want[(i, 0)]).abs() < 1e-12);
        }
        // transposed
        let mut yt = vec![1.0; 4];
        gemv(1.0, &a, Trans::Yes, &y, 2.0, &mut yt);
        let want_t = naive_mm(&a.t(), &Mat::col_vec(&y));
        for j in 0..4 {
            assert!((yt[j] - (want_t[(j, 0)] + 2.0)).abs() < 1e-10);
        }
    }

    #[test]
    fn syrk_matches_gemm() {
        let mut r = Rng::new(4);
        let a = randmat(&mut r, 7, 3);
        let mut c = Mat::zeros(7, 7);
        syrk(1.5, &a, 0.0, &mut c);
        let want = {
            let mut w = matmul(&a, Trans::No, &a, Trans::Yes);
            w.scale(1.5);
            w
        };
        assert_close(&c, &want, 1e-12);
        assert!(c.is_symmetric(1e-14));
    }

    #[test]
    fn empty_dims_ok() {
        let a = Mat::zeros(0, 3);
        let b = Mat::zeros(3, 2);
        let c = matmul(&a, Trans::No, &b, Trans::No);
        assert_eq!(c.shape(), (0, 2));
        let a2 = Mat::zeros(2, 0);
        let b2 = Mat::zeros(0, 2);
        let c2 = matmul(&a2, Trans::No, &b2, Trans::No);
        assert_eq!(c2.fro_norm(), 0.0);
    }
}
