//! Row-major dense matrix type.

use crate::error::{Error, Result};
use std::ops::{Index, IndexMut};

/// A dense, row-major, `f64` matrix.
///
/// This is the single matrix currency of the library: kernel blocks, the
/// hierarchical factors `U_i`, `Σ_p`, `W_p`, data matrices and feature maps
/// are all `Mat`s.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let rmax = self.rows.min(8);
        let cmax = self.cols.min(8);
        for i in 0..rmax {
            write!(f, "  ")?;
            for j in 0..cmax {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > cmax { "..." } else { "" })?;
        }
        if self.rows > rmax {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    /// Zero matrix of shape (rows, cols).
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of order n.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a closure f(i, j).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Build a column vector from a slice.
    pub fn col_vec(v: &[f64]) -> Mat {
        Mat::from_vec(v.len(), 1, v.to_vec())
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Underlying row-major slice.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Underlying mutable row-major slice.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Row i as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row i as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column j.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Set column j from a slice.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Transposed copy.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on larger matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out[(j, i)] = self[(i, j)];
                    }
                }
            }
        }
        out
    }

    /// Extract the sub-matrix of the given rows (in order).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Contiguous row range [lo, hi) as a new matrix.
    pub fn row_range(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat::from_vec(hi - lo, self.cols, self.data[lo * self.cols..hi * self.cols].to_vec())
    }

    /// Vertically stack two matrices.
    pub fn vstack(&self, other: &Mat) -> Result<Mat> {
        if self.cols != other.cols {
            return Err(Error::dim(format!(
                "vstack: {}x{} vs {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Ok(Mat::from_vec(self.rows + other.rows, self.cols, data))
    }

    /// In-place scale by alpha.
    pub fn scale(&mut self, alpha: f64) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// self += alpha * other (same shape).
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// Add `lambda` to the diagonal (regularization).
    pub fn add_diag(&mut self, lambda: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += lambda;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Spectral norm (2-norm) estimated by power iteration on AᵀA.
    /// Exact enough for the norm-comparison experiments (Theorem 4).
    pub fn norm2_est(&self, iters: usize) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        let mut v = vec![1.0 / (self.cols as f64).sqrt(); self.cols];
        let mut av = vec![0.0; self.rows];
        let mut s = 0.0;
        for _ in 0..iters {
            // av = A v
            for i in 0..self.rows {
                av[i] = dot(self.row(i), &v);
            }
            // v = Aᵀ av
            for x in v.iter_mut() {
                *x = 0.0;
            }
            for i in 0..self.rows {
                let r = self.row(i);
                let a = av[i];
                for (vj, rj) in v.iter_mut().zip(r.iter()) {
                    *vj += a * rj;
                }
            }
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm < 1e-300 {
                return 0.0;
            }
            for x in v.iter_mut() {
                *x /= norm;
            }
            s = norm;
        }
        s.sqrt()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, x| m.max(x.abs()))
    }

    /// Is this matrix symmetric to within `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Symmetrize in place: A <- (A + Aᵀ)/2. Used after floating-point
    /// accumulation of Gram/kernel matrices to restore exact symmetry.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }
}

/// Dot product of two slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: lets the compiler vectorize and reduces
    // dependency chains. This shows up in every kernel evaluation.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = 4 * c;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// Squared Euclidean distance of two slices.
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// L1 (Manhattan) distance of two slices.
#[inline]
pub fn l1dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).sum()
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.col(2), vec![2.0, 5.0]);
    }

    #[test]
    fn eye_and_zeros() {
        let i = Mat::eye(3);
        assert_eq!(i[(0, 0)], 1.0);
        assert_eq!(i[(0, 1)], 0.0);
        assert_eq!(Mat::zeros(2, 2).fro_norm(), 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(5, 7, |i, j| (i as f64) - 2.0 * (j as f64));
        let t = m.t();
        assert_eq!(t.shape(), (7, 5));
        assert_eq!(m, t.t());
        assert_eq!(m[(3, 6)], t[(6, 3)]);
    }

    #[test]
    fn select_and_range() {
        let m = Mat::from_fn(4, 2, |i, _| i as f64);
        let s = m.select_rows(&[3, 1]);
        assert_eq!(s.row(0), &[3.0, 3.0]);
        assert_eq!(s.row(1), &[1.0, 1.0]);
        let r = m.row_range(1, 3);
        assert_eq!(r.rows(), 2);
        assert_eq!(r.row(0), &[1.0, 1.0]);
    }

    #[test]
    fn vstack_checks_cols() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(1, 3);
        assert_eq!(a.vstack(&b).unwrap().shape(), (3, 3));
        assert!(a.vstack(&Mat::zeros(1, 2)).is_err());
    }

    #[test]
    fn arithmetic_helpers() {
        let mut m = Mat::eye(2);
        m.scale(3.0);
        assert_eq!(m[(0, 0)], 3.0);
        m.axpy(2.0, &Mat::eye(2));
        assert_eq!(m[(1, 1)], 5.0);
        m.add_diag(0.5);
        assert_eq!(m[(0, 0)], 5.5);
    }

    #[test]
    fn dot_and_dists() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
        assert_eq!(sqdist(&a, &b), 16.0 + 4.0 + 0.0 + 4.0 + 16.0);
        assert_eq!(l1dist(&a, &b), 4.0 + 2.0 + 0.0 + 2.0 + 4.0);
    }

    #[test]
    fn norm2_est_on_diag() {
        let mut m = Mat::zeros(3, 3);
        m[(0, 0)] = 2.0;
        m[(1, 1)] = -7.0;
        m[(2, 2)] = 1.0;
        let n = m.norm2_est(50);
        assert!((n - 7.0).abs() < 1e-6, "norm {n}");
    }

    #[test]
    fn symmetry_helpers() {
        let mut m = Mat::from_fn(3, 3, |i, j| (i + 2 * j) as f64);
        assert!(!m.is_symmetric(1e-12));
        m.symmetrize();
        assert!(m.is_symmetric(1e-12));
    }

    #[test]
    fn fro_norm() {
        let m = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
    }
}
