//! Householder QR factorization and least-squares solves.
//!
//! Used by the kernel-PCA alignment experiment (Figure 8: M minimizing
//! ||U - Ũ M||_F is a least-squares solve) and by Lanczos
//! reorthogonalization.

use super::matrix::Mat;
use crate::error::{Error, Result};

/// Thin QR of an m x n matrix with m >= n: A = Q R, Q m x n with
/// orthonormal columns, R n x n upper triangular.
#[derive(Debug, Clone)]
pub struct Qr {
    q: Mat,
    r: Mat,
}

impl Qr {
    /// Factor `a` (requires rows >= cols).
    pub fn new(a: &Mat) -> Result<Qr> {
        let (m, n) = a.shape();
        if m < n {
            return Err(Error::dim(format!("thin QR needs rows>=cols, got {m}x{n}")));
        }
        // Householder on a working copy; accumulate Q by applying the
        // reflectors to the identity afterwards.
        let mut work = a.clone();
        let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
        for k in 0..n {
            // Build the reflector for column k below the diagonal.
            let mut norm = 0.0;
            for i in k..m {
                norm += work[(i, k)] * work[(i, k)];
            }
            let norm = norm.sqrt();
            let mut v = vec![0.0; m - k];
            if norm < 1e-300 {
                vs.push(v);
                continue;
            }
            let alpha = if work[(k, k)] >= 0.0 { -norm } else { norm };
            for i in k..m {
                v[i - k] = work[(i, k)];
            }
            v[0] -= alpha;
            let vnorm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if vnorm > 1e-300 {
                for x in v.iter_mut() {
                    *x /= vnorm;
                }
                // Apply H = I - 2 v vᵀ to the trailing submatrix.
                for j in k..n {
                    let mut s = 0.0;
                    for i in k..m {
                        s += v[i - k] * work[(i, j)];
                    }
                    let s2 = 2.0 * s;
                    for i in k..m {
                        work[(i, j)] -= s2 * v[i - k];
                    }
                }
            }
            vs.push(v);
        }
        // R = top n x n of work.
        let mut r = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = work[(i, j)];
            }
        }
        // Q = H_0 H_1 ... H_{n-1} * [I_n; 0].
        let mut q = Mat::zeros(m, n);
        for j in 0..n {
            q[(j, j)] = 1.0;
        }
        for k in (0..n).rev() {
            let v = &vs[k];
            if v.iter().all(|&x| x == 0.0) {
                continue;
            }
            for j in 0..n {
                let mut s = 0.0;
                for i in k..m {
                    s += v[i - k] * q[(i, j)];
                }
                let s2 = 2.0 * s;
                for i in k..m {
                    q[(i, j)] -= s2 * v[i - k];
                }
            }
        }
        Ok(Qr { q, r })
    }

    /// Orthonormal factor (m x n).
    pub fn q(&self) -> &Mat {
        &self.q
    }

    /// Upper-triangular factor (n x n).
    pub fn r(&self) -> &Mat {
        &self.r
    }

    /// Solve the least-squares problem min ||A x - b||_2 via R x = Qᵀ b.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        let (m, n) = self.q.shape();
        if b.len() != m {
            return Err(Error::dim("qr solve rhs length"));
        }
        // qtb = Qᵀ b
        let mut qtb = vec![0.0; n];
        super::blas::gemv(1.0, &self.q, super::blas::Trans::Yes, b, 0.0, &mut qtb);
        // Back substitution R x = qtb.
        let mut x = qtb;
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.r[(i, k)] * x[k];
            }
            let d = self.r[(i, i)];
            if d.abs() < 1e-300 {
                return Err(Error::linalg("qr: rank deficient"));
            }
            x[i] = s / d;
        }
        Ok(x)
    }
}

/// Least squares min_X ||A X - B||_F, column by column.
pub fn lstsq(a: &Mat, b: &Mat) -> Result<Mat> {
    let qr = Qr::new(a)?;
    let n = a.cols();
    let mut x = Mat::zeros(n, b.cols());
    for j in 0..b.cols() {
        let col = qr.solve(&b.col(j))?;
        x.set_col(j, &col);
    }
    Ok(x)
}

/// Orthonormalize the columns of `a` in place (modified Gram-Schmidt,
/// two passes). Returns the numerical rank found.
pub fn orthonormalize_cols(a: &mut Mat) -> usize {
    let (m, n) = a.shape();
    let mut rank = 0;
    for j in 0..n {
        let mut col = a.col(j);
        for _pass in 0..2 {
            for k in 0..rank {
                let qk = a.col(k);
                let proj = super::matrix::dot(&col, &qk);
                for i in 0..m {
                    col[i] -= proj * qk[i];
                }
            }
        }
        let norm = col.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > 1e-12 {
            for x in col.iter_mut() {
                *x /= norm;
            }
            // Move into position `rank`.
            a.set_col(rank, &col);
            rank += 1;
        }
    }
    // Zero out the trailing columns.
    for j in rank..n {
        let zero = vec![0.0; m];
        a.set_col(j, &zero);
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{matmul, Trans};
    use crate::util::rng::Rng;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(1);
        let a = Mat::from_fn(9, 4, |_, _| rng.normal());
        let qr = Qr::new(&a).unwrap();
        let rec = matmul(qr.q(), Trans::No, qr.r(), Trans::No);
        let mut diff = rec;
        diff.axpy(-1.0, &a);
        assert!(diff.fro_norm() / a.fro_norm() < 1e-12);
        // Q orthonormal columns.
        let qtq = matmul(qr.q(), Trans::Yes, qr.q(), Trans::No);
        let mut d = qtq;
        d.axpy(-1.0, &Mat::eye(4));
        assert!(d.fro_norm() < 1e-12);
    }

    #[test]
    fn lstsq_exact_when_consistent() {
        let mut rng = Rng::new(2);
        let a = Mat::from_fn(10, 3, |_, _| rng.normal());
        let xstar = Mat::from_fn(3, 2, |_, _| rng.normal());
        let b = matmul(&a, Trans::No, &xstar, Trans::No);
        let x = lstsq(&a, &b).unwrap();
        let mut diff = x;
        diff.axpy(-1.0, &xstar);
        assert!(diff.fro_norm() < 1e-9);
    }

    #[test]
    fn lstsq_minimizes_residual() {
        let mut rng = Rng::new(3);
        let a = Mat::from_fn(20, 4, |_, _| rng.normal());
        let b = Mat::from_fn(20, 1, |_, _| rng.normal());
        let x = lstsq(&a, &b).unwrap();
        // At the optimum the residual is orthogonal to the column space.
        let mut res = matmul(&a, Trans::No, &x, Trans::No);
        res.axpy(-1.0, &b);
        let atr = matmul(&a, Trans::Yes, &res, Trans::No);
        assert!(atr.fro_norm() < 1e-9);
    }

    #[test]
    fn orthonormalize_detects_rank() {
        let mut rng = Rng::new(4);
        // 3 independent columns, then a dependent one.
        let base = Mat::from_fn(8, 3, |_, _| rng.normal());
        let mut a = Mat::zeros(8, 4);
        for j in 0..3 {
            a.set_col(j, &base.col(j));
        }
        let dep: Vec<f64> =
            (0..8).map(|i| base[(i, 0)] + 2.0 * base[(i, 1)]).collect();
        a.set_col(3, &dep);
        let rank = orthonormalize_cols(&mut a);
        assert_eq!(rank, 3);
        let qtq = matmul(&a, Trans::Yes, &a, Trans::No);
        for i in 0..3 {
            assert!((qtq[(i, i)] - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn rejects_wide() {
        assert!(Qr::new(&Mat::zeros(2, 5)).is_err());
    }
}
