//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Jacobi is slow asymptotically but extremely robust and accurate for the
//! modest orders this library needs (factor sizes r and leaf sizes n0, a
//! few hundred at most; the dense kernel-PCA path caps n in the low
//! thousands). Larger spectral problems go through [`super::lanczos`]
//! on top of the O(nr) hierarchical matvec instead.

use super::matrix::Mat;
use crate::error::{Error, Result};

/// Eigendecomposition A = V diag(w) Vᵀ of a symmetric matrix.
/// Eigenvalues are returned in *descending* order, V's columns matching.
pub fn sym_eig(a: &Mat) -> Result<(Vec<f64>, Mat)> {
    let n = a.rows();
    if a.cols() != n {
        return Err(Error::dim(format!("sym_eig of {}x{}", a.rows(), a.cols())));
    }
    if !a.is_symmetric(1e-8 * (1.0 + a.max_abs())) {
        return Err(Error::linalg("sym_eig: matrix is not symmetric".to_string()));
    }
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Mat::eye(n);

    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= 1e-14 * (1.0 + m.max_abs()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Jacobi rotation angle.
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Apply rotation to rows/cols p and q of m.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let mut w: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    // Sort descending, permuting eigenvector columns along.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| w[j].partial_cmp(&w[i]).unwrap());
    let wsorted: Vec<f64> = order.iter().map(|&i| w[i]).collect();
    let mut vsorted = Mat::zeros(n, n);
    for (newj, &oldj) in order.iter().enumerate() {
        for i in 0..n {
            vsorted[(i, newj)] = v[(i, oldj)];
        }
    }
    w = wsorted;
    Ok((w, vsorted))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{matmul, Trans};
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_matrix() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = 5.0;
        a[(2, 2)] = 3.0;
        let (w, _) = sym_eig(&a).unwrap();
        assert!((w[0] - 5.0).abs() < 1e-12);
        assert!((w[1] - 3.0).abs() < 1e-12);
        assert!((w[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reconstructs_random_symmetric() {
        let mut r = Rng::new(1);
        let n = 15;
        let g = Mat::from_fn(n, n, |_, _| r.normal());
        let mut a = matmul(&g, Trans::No, &g, Trans::Yes);
        a.symmetrize();
        let (w, v) = sym_eig(&a).unwrap();
        // A ≈ V diag(w) Vᵀ
        let mut vd = v.clone();
        for i in 0..n {
            for j in 0..n {
                vd[(i, j)] *= w[j];
            }
        }
        let rec = matmul(&vd, Trans::No, &v, Trans::Yes);
        let mut diff = rec;
        diff.axpy(-1.0, &a);
        assert!(diff.fro_norm() / a.fro_norm() < 1e-10);
        // Eigenvalues descending.
        for k in 1..n {
            assert!(w[k - 1] >= w[k] - 1e-12);
        }
        // V orthogonal.
        let vtv = matmul(&v, Trans::Yes, &v, Trans::No);
        let mut d = vtv;
        d.axpy(-1.0, &Mat::eye(n));
        assert!(d.fro_norm() < 1e-10);
    }

    #[test]
    fn psd_matrix_has_nonneg_eigs() {
        let mut r = Rng::new(2);
        let g = Mat::from_fn(8, 3, |_, _| r.normal());
        let a = matmul(&g, Trans::No, &g, Trans::Yes); // rank 3 PSD
        let (w, _) = sym_eig(&a).unwrap();
        for &x in &w {
            assert!(x > -1e-10);
        }
        // Rank should be 3: eigenvalues 4..8 near zero.
        assert!(w[2] > 1e-6);
        assert!(w[3].abs() < 1e-8);
    }

    #[test]
    fn rejects_nonsymmetric() {
        let a = Mat::from_vec(2, 2, vec![1.0, 5.0, 0.0, 1.0]);
        assert!(sym_eig(&a).is_err());
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigs 3, 1.
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (w, v) = sym_eig(&a).unwrap();
        assert!((w[0] - 3.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
        // Eigenvector for 3 is (1,1)/sqrt(2) up to sign.
        assert!((v[(0, 0)].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-10);
    }
}
