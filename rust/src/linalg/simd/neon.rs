//! NEON microkernel: 4×4 f64 tiles over 128-bit vectors.
//!
//! NEON's 128-bit lanes hold two f64, so the natural register tile is
//! 4×4 (eight `float64x2_t` accumulators). The packed-B panels are
//! NR = 8 wide, so one call sweeps the panel as **two interleaved 4×4
//! tiles** sharing each depth step's A broadcasts — sixteen
//! accumulators, four B loads and four duplicated A lanes per step,
//! 21 of the 32 NEON registers live. Per-element accumulation order
//! over `p` matches the scalar fallback exactly; `vfmaq_f64` fuses each
//! multiply-add (the only numerical difference).

use super::{MR, NR};
use core::arch::aarch64::{float64x2_t, vdupq_n_f64, vfmaq_f64, vld1q_f64, vst1q_f64};

/// Fill `acc` (zeroed on entry) with the 4×8 panel product, computed as
/// two fused 4×4 NEON tiles.
///
/// # Safety
///
/// - **Target features**: aarch64-only; NEON (`asimd`) is baseline on
///   every aarch64 target this crate builds for, so the
///   `#[target_feature]` requirement is always met when this module
///   compiles at all.
/// - **Lengths**: every read is a 16-byte `vld1q_f64` at offsets
///   `p·NR + j` (`j ∈ {0, 2, 4, 6}`) into `bpanel` or a scalar
///   broadcast at `p·MR + i` (`i < 4`) into `apanel` with `p < kc`, so
///   the caller must guarantee `apanel.len() >= kc·MR` and
///   `bpanel.len() >= kc·NR` (the blas packing layer zero-pads to
///   exactly these shapes; the dispatcher `debug_assert!`s them).
/// - **Aliasing**: `acc` is written through `&mut`, so it cannot alias
///   either panel; the 16 `vst1q_f64` writes cover exactly the
///   MR×NR = 4×8 tile and nothing else. NEON load/store intrinsics
///   require only `f64` alignment, which the slices guarantee.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn microkernel(
    kc: usize,
    apanel: &[f64],
    bpanel: &[f64],
    acc: &mut [[f64; NR]; MR],
) {
    debug_assert!(apanel.len() >= kc * MR);
    debug_assert!(bpanel.len() >= kc * NR);
    let a = apanel.as_ptr();
    let b = bpanel.as_ptr();
    let zero: float64x2_t = vdupq_n_f64(0.0);
    // Row i of the tile lives in (ci0..ci3): column pairs 0-1, 2-3
    // (left 4×4 tile) and 4-5, 6-7 (right 4×4 tile).
    let mut c00 = zero;
    let mut c01 = zero;
    let mut c02 = zero;
    let mut c03 = zero;
    let mut c10 = zero;
    let mut c11 = zero;
    let mut c12 = zero;
    let mut c13 = zero;
    let mut c20 = zero;
    let mut c21 = zero;
    let mut c22 = zero;
    let mut c23 = zero;
    let mut c30 = zero;
    let mut c31 = zero;
    let mut c32 = zero;
    let mut c33 = zero;
    for p in 0..kc {
        let b0 = vld1q_f64(b.add(p * NR));
        let b1 = vld1q_f64(b.add(p * NR + 2));
        let b2 = vld1q_f64(b.add(p * NR + 4));
        let b3 = vld1q_f64(b.add(p * NR + 6));
        let a0 = vdupq_n_f64(*a.add(p * MR));
        c00 = vfmaq_f64(c00, a0, b0);
        c01 = vfmaq_f64(c01, a0, b1);
        c02 = vfmaq_f64(c02, a0, b2);
        c03 = vfmaq_f64(c03, a0, b3);
        let a1 = vdupq_n_f64(*a.add(p * MR + 1));
        c10 = vfmaq_f64(c10, a1, b0);
        c11 = vfmaq_f64(c11, a1, b1);
        c12 = vfmaq_f64(c12, a1, b2);
        c13 = vfmaq_f64(c13, a1, b3);
        let a2 = vdupq_n_f64(*a.add(p * MR + 2));
        c20 = vfmaq_f64(c20, a2, b0);
        c21 = vfmaq_f64(c21, a2, b1);
        c22 = vfmaq_f64(c22, a2, b2);
        c23 = vfmaq_f64(c23, a2, b3);
        let a3 = vdupq_n_f64(*a.add(p * MR + 3));
        c30 = vfmaq_f64(c30, a3, b0);
        c31 = vfmaq_f64(c31, a3, b1);
        c32 = vfmaq_f64(c32, a3, b2);
        c33 = vfmaq_f64(c33, a3, b3);
    }
    vst1q_f64(acc[0].as_mut_ptr(), c00);
    vst1q_f64(acc[0].as_mut_ptr().add(2), c01);
    vst1q_f64(acc[0].as_mut_ptr().add(4), c02);
    vst1q_f64(acc[0].as_mut_ptr().add(6), c03);
    vst1q_f64(acc[1].as_mut_ptr(), c10);
    vst1q_f64(acc[1].as_mut_ptr().add(2), c11);
    vst1q_f64(acc[1].as_mut_ptr().add(4), c12);
    vst1q_f64(acc[1].as_mut_ptr().add(6), c13);
    vst1q_f64(acc[2].as_mut_ptr(), c20);
    vst1q_f64(acc[2].as_mut_ptr().add(2), c21);
    vst1q_f64(acc[2].as_mut_ptr().add(4), c22);
    vst1q_f64(acc[2].as_mut_ptr().add(6), c23);
    vst1q_f64(acc[3].as_mut_ptr(), c30);
    vst1q_f64(acc[3].as_mut_ptr().add(2), c31);
    vst1q_f64(acc[3].as_mut_ptr().add(4), c32);
    vst1q_f64(acc[3].as_mut_ptr().add(6), c33);
}
