//! Scalar fallback microkernel — the portable reference tile.
//!
//! This is the pre-SIMD packed kernel verbatim: 32 independent
//! accumulators over two contiguous packed streams, written so LLVM can
//! autovectorize it for whatever the build target offers (baseline
//! x86-64 gets SSE2 here — the explicit AVX2/NEON tiles exist because
//! the default target cannot assume more). It is also the semantic
//! oracle for the intrinsic backends: same per-element accumulation
//! order over `p`, differing only in that the intrinsics fuse each
//! multiply-add while this tile rounds twice.

use super::{MR, NR};

/// Fill `acc` (zeroed on entry) with the MR×NR panel product
/// `acc[i][j] = Σ_p apanel[p·MR+i] · bpanel[p·NR+j]`.
#[inline]
pub(crate) fn microkernel(kc: usize, apanel: &[f64], bpanel: &[f64], acc: &mut [[f64; NR]; MR]) {
    for p in 0..kc {
        let ap: &[f64; MR] = apanel[p * MR..p * MR + MR].try_into().unwrap();
        let bp: &[f64; NR] = bpanel[p * NR..p * NR + NR].try_into().unwrap();
        for i in 0..MR {
            let ai = ap[i];
            let row = &mut acc[i];
            for j in 0..NR {
                row[j] += ai * bp[j];
            }
        }
    }
}
