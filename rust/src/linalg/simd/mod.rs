//! SIMD microkernels with one-time runtime dispatch.
//!
//! The packed BLAS-3 core in [`crate::linalg::blas`] funnels every large
//! product through a single MR×NR register microkernel over zero-padded
//! packed panels. This module supplies that microkernel in three
//! flavors and picks one **once per process**:
//!
//! - `avx2.rs` (x86_64): a 4×8 f64 tile on AVX2 + FMA — eight 256-bit
//!   accumulators, two packed-B loads and four broadcast-FMA pairs per
//!   depth step; selected when `is_x86_feature_detected!("avx2")` and
//!   `"fma"` both hold.
//! - `neon.rs` (aarch64): a 4×4 f64 tile on 128-bit NEON, applied to
//!   the two halves of the NR=8 packed panel in one fused sweep
//!   (sixteen `float64x2_t` accumulators); NEON is baseline on aarch64.
//! - `emulate.rs`: the scalar 32-accumulator tile (the pre-SIMD packed
//!   kernel, LLVM-autovectorized) — always available, and the reference
//!   the property tests pin the intrinsics against.
//!
//! # Dispatch
//!
//! [`backend`] resolves lazily on first use from the `HCK_SIMD`
//! environment variable (`scalar` | `avx2` | `neon` | `auto`, default
//! `auto` = best detected) and caches the choice in an atomic. Forcing
//! a backend the CPU cannot run **panics** — CI forces `HCK_SIMD=avx2`
//! on the x86 matrix leg precisely so a runner without AVX2 fails
//! loudly instead of silently testing the scalar path. [`force_backend`]
//! swaps the cached choice at runtime for tests and benchmarks (the
//! scalar-baseline rows in `BENCH_hotpath.json` come from it).
//!
//! # Numerics and determinism
//!
//! Every backend accumulates each C element over the depth index `p` in
//! the **same order**; the SIMD tiles vectorize across columns only. The
//! repo-wide invariant "`par_* == serial` bitwise for every thread
//! count" therefore holds under each backend separately. Across
//! backends, results differ only by FMA contraction (the intrinsics fuse
//! multiply-add; the scalar tile rounds twice): identical bitwise
//! wherever the packed plan is not used, and within a few ULPs per
//! accumulation step otherwise — `rust/tests/blas_property.rs` pins
//! both statements.
//!
//! All `unsafe` in this subtree is confined to the per-arch intrinsic
//! tiles, which read exactly `kc·MR` / `kc·NR` packed elements and
//! write exactly the MR×NR accumulator — CI runs the linalg tests under
//! AddressSanitizer to keep that claim honest (zero-padded tails could
//! otherwise mask an out-of-bounds packed read).

use std::sync::atomic::{AtomicU8, Ordering};

#[cfg(target_arch = "x86_64")]
pub mod avx2;
pub mod emulate;
#[cfg(target_arch = "aarch64")]
pub mod neon;

/// Microkernel rows (register tile height) — the geometry every backend
/// and the packing layer in [`crate::linalg::blas`] agree on.
pub const MR: usize = 4;
/// Microkernel columns (register tile width).
pub const NR: usize = 8;

/// Which microkernel implementation the packed core dispatches to.
#[repr(u8)]
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Scalar 32-accumulator tile (`emulate.rs`); always available.
    Scalar = 1,
    /// AVX2 + FMA 4×8 tile (`avx2.rs`); x86_64 with both features.
    Avx2 = 2,
    /// NEON 4×4 half-tiles over the 4×8 panel (`neon.rs`); aarch64.
    Neon = 3,
}

impl Backend {
    /// Stable lowercase name, matching the `HCK_SIMD` spelling.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    /// Whether this process can actually execute the backend.
    pub fn available(self) -> bool {
        match self {
            Backend::Scalar => true,
            Backend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            // NEON is baseline on every aarch64 target the crate builds
            // for; no finer runtime probe is needed.
            Backend::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

/// Cached selection: 0 = not yet resolved, else a `Backend` discriminant.
static SELECTED: AtomicU8 = AtomicU8::new(0);

/// The process-wide microkernel backend: `HCK_SIMD` if set (panics if
/// the forced backend is unavailable — never a silent fallback),
/// otherwise the best detected. Resolved once; subsequent calls are an
/// atomic load.
#[inline]
pub fn backend() -> Backend {
    // ORDERING: Relaxed — SELECTED is a write-once-then-stable cache of
    // a pure CPU-feature decision; racing resolvers compute the same
    // value, so no ordering with other memory is required.
    match SELECTED.load(Ordering::Relaxed) {
        1 => Backend::Scalar,
        2 => Backend::Avx2,
        3 => Backend::Neon,
        _ => init_backend(),
    }
}

/// The selected backend's name — for banners and telemetry rows.
pub fn backend_name() -> &'static str {
    backend().name()
}

#[cold]
fn init_backend() -> Backend {
    let chosen = match std::env::var("HCK_SIMD") {
        Ok(v) => {
            let v = v.trim().to_ascii_lowercase();
            let req = match v.as_str() {
                "" | "auto" => detect(),
                "scalar" => Backend::Scalar,
                "avx2" => Backend::Avx2,
                "neon" => Backend::Neon,
                other => panic!("HCK_SIMD={other}: expected scalar|avx2|neon|auto"),
            };
            assert!(
                req.available(),
                "HCK_SIMD={} requested but the {} backend is not available on this CPU/arch \
                 (detected: {})",
                req.name(),
                req.name(),
                detect().name()
            );
            req
        }
        Err(_) => detect(),
    };
    // ORDERING: Relaxed — idempotent cache fill; see backend().
    SELECTED.store(chosen as u8, Ordering::Relaxed);
    chosen
}

/// Best backend the current CPU can run, ignoring `HCK_SIMD`.
pub fn detect() -> Backend {
    if Backend::Avx2.available() {
        Backend::Avx2
    } else if Backend::Neon.available() {
        Backend::Neon
    } else {
        Backend::Scalar
    }
}

/// Swap the cached backend at runtime; returns the previous selection so
/// callers can restore it. Errs (changing nothing) if `b` cannot run on
/// this CPU.
///
/// For tests and benchmarks only — the scalar-baseline rows in
/// `BENCH_hotpath.json` and the cross-backend property tests use it.
/// The swap is process-global: tests that combine it with bitwise
/// comparisons must serialize against each other (see the backend lock
/// in `rust/tests/blas_property.rs`).
pub fn force_backend(b: Backend) -> Result<Backend, String> {
    if !b.available() {
        return Err(format!(
            "backend {} is not available on this CPU/arch (detected: {})",
            b.name(),
            detect().name()
        ));
    }
    let prev = backend();
    // ORDERING: Relaxed — test-only override of the same availability-
    // validated cache; concurrent readers see either backend, both sound.
    SELECTED.store(b as u8, Ordering::Relaxed);
    Ok(prev)
}

/// The dispatched MR×NR register tile: on entry `acc` is zeroed; on exit
/// `acc[i][j] = Σ_p apanel[p·MR+i] · bpanel[p·NR+j]` over `p < kc`.
/// Panels are the zero-padded packed buffers from the blas packing
/// layer, so the tile never branches on shape.
#[inline]
pub(crate) fn microkernel(kc: usize, apanel: &[f64], bpanel: &[f64], acc: &mut [[f64; NR]; MR]) {
    debug_assert!(apanel.len() >= kc * MR, "apanel holds kc MR-lanes");
    debug_assert!(bpanel.len() >= kc * NR, "bpanel holds kc NR-lanes");
    match backend() {
        Backend::Scalar => emulate::microkernel(kc, apanel, bpanel, acc),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: target-feature — Avx2 is only ever selected after
        // `available()` confirmed `is_x86_feature_detected!` for
        // avx2 + fma on this CPU; lengths — the debug_asserts above
        // restate the packing-layer guarantee `apanel.len() >= kc*MR`,
        // `bpanel.len() >= kc*NR`, which covers every packed read the
        // kernel performs; `acc` is a uniquely borrowed fixed-size tile.
        Backend::Avx2 => unsafe { avx2::microkernel(kc, apanel, bpanel, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: target-feature — NEON is baseline on aarch64, so the
        // `#[target_feature(enable = "neon")]` fn is always callable
        // here; length/aliasing invariants identical to the AVX2 arm.
        Backend::Neon => unsafe { neon::microkernel(kc, apanel, bpanel, acc) },
        // A backend compiled out on this arch is unselectable (its
        // `available()` is false and selection validates availability).
        #[allow(unreachable_patterns)]
        _ => emulate::microkernel(kc, apanel, bpanel, acc),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_available_and_selected_backend_is_runnable() {
        assert!(Backend::Scalar.available());
        assert!(backend().available());
        assert!(detect().available());
    }

    #[test]
    fn names_round_trip() {
        for b in [Backend::Scalar, Backend::Avx2, Backend::Neon] {
            assert!(!b.name().is_empty());
        }
        assert_eq!(Backend::Scalar.name(), "scalar");
        assert_eq!(Backend::Avx2.name(), "avx2");
        assert_eq!(Backend::Neon.name(), "neon");
    }

    #[test]
    fn forcing_an_unavailable_backend_errs_without_changing_selection() {
        let before = backend();
        let unavailable = if cfg!(target_arch = "x86_64") {
            Backend::Neon
        } else {
            Backend::Avx2
        };
        assert!(force_backend(unavailable).is_err());
        assert_eq!(backend(), before);
    }
}
