//! AVX2 + FMA microkernel: a 4×8 f64 register tile.
//!
//! Eight 256-bit accumulators hold the full MR×NR = 4×8 tile (two ymm
//! per row). Each depth step loads the two packed-B vectors once and
//! issues four broadcast + two-FMA pairs — 8 FMAs against 6 loads, with
//! 11 of the 16 ymm registers live, so nothing spills. The panel
//! streams are the zero-padded packed buffers from the blas packing
//! layer: perfectly contiguous, no shape branches, and the tile's
//! per-element accumulation order over `p` matches the scalar fallback
//! exactly (only FMA contraction differs).
//!
//! An 8×8 tile was considered and rejected: sixteen f64×4 accumulators
//! consume every ymm register before the B loads and A broadcast get
//! one, so it spills on AVX2; 4×8 is the widest tile that stays fully
//! register-resident (the AVX-512 generation is where 8×8 pays off).

use super::{MR, NR};
use core::arch::x86_64::{
    _mm256_fmadd_pd, _mm256_loadu_pd, _mm256_set1_pd, _mm256_setzero_pd, _mm256_storeu_pd,
};

/// Fill `acc` (zeroed on entry) with the 4×8 panel product.
///
/// # Safety
///
/// - **Target features**: the executing CPU must support AVX2 and FMA.
///   The dispatch layer only selects this backend after
///   `is_x86_feature_detected!("avx2")` and `("fma")` both pass, so the
///   `#[target_feature]` instructions below are executable.
/// - **Lengths**: every read is an unaligned 32-byte `_mm256_loadu_pd`
///   or scalar broadcast at offsets `p·MR + i` (`i < 4`) into `apanel`
///   and `p·NR + j` (`j ∈ {0, 4}`) into `bpanel` with `p < kc`, so the
///   caller must guarantee `apanel.len() >= kc·MR` and
///   `bpanel.len() >= kc·NR` (the blas packing layer zero-pads to
///   exactly these shapes; the dispatcher `debug_assert!`s them).
/// - **Aliasing**: `acc` is written through `&mut`, so it cannot alias
///   either panel; the 8 `_mm256_storeu_pd` writes cover exactly the
///   MR×NR = 4×8 tile and nothing else. Unaligned load/store intrinsics
///   are used throughout — no alignment precondition beyond `f64`'s.
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn microkernel(
    kc: usize,
    apanel: &[f64],
    bpanel: &[f64],
    acc: &mut [[f64; NR]; MR],
) {
    debug_assert!(apanel.len() >= kc * MR);
    debug_assert!(bpanel.len() >= kc * NR);
    let a = apanel.as_ptr();
    let b = bpanel.as_ptr();
    // Row i of the tile lives in (ci0, ci1): columns 0..4 and 4..8.
    let mut c00 = _mm256_setzero_pd();
    let mut c01 = _mm256_setzero_pd();
    let mut c10 = _mm256_setzero_pd();
    let mut c11 = _mm256_setzero_pd();
    let mut c20 = _mm256_setzero_pd();
    let mut c21 = _mm256_setzero_pd();
    let mut c30 = _mm256_setzero_pd();
    let mut c31 = _mm256_setzero_pd();
    for p in 0..kc {
        let b0 = _mm256_loadu_pd(b.add(p * NR));
        let b1 = _mm256_loadu_pd(b.add(p * NR + 4));
        let a0 = _mm256_set1_pd(*a.add(p * MR));
        c00 = _mm256_fmadd_pd(a0, b0, c00);
        c01 = _mm256_fmadd_pd(a0, b1, c01);
        let a1 = _mm256_set1_pd(*a.add(p * MR + 1));
        c10 = _mm256_fmadd_pd(a1, b0, c10);
        c11 = _mm256_fmadd_pd(a1, b1, c11);
        let a2 = _mm256_set1_pd(*a.add(p * MR + 2));
        c20 = _mm256_fmadd_pd(a2, b0, c20);
        c21 = _mm256_fmadd_pd(a2, b1, c21);
        let a3 = _mm256_set1_pd(*a.add(p * MR + 3));
        c30 = _mm256_fmadd_pd(a3, b0, c30);
        c31 = _mm256_fmadd_pd(a3, b1, c31);
    }
    _mm256_storeu_pd(acc[0].as_mut_ptr(), c00);
    _mm256_storeu_pd(acc[0].as_mut_ptr().add(4), c01);
    _mm256_storeu_pd(acc[1].as_mut_ptr(), c10);
    _mm256_storeu_pd(acc[1].as_mut_ptr().add(4), c11);
    _mm256_storeu_pd(acc[2].as_mut_ptr(), c20);
    _mm256_storeu_pd(acc[2].as_mut_ptr().add(4), c21);
    _mm256_storeu_pd(acc[3].as_mut_ptr(), c30);
    _mm256_storeu_pd(acc[3].as_mut_ptr().add(4), c31);
}
