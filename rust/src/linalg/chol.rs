//! Cholesky factorization of symmetric positive-definite matrices.
//!
//! Used everywhere the paper inverts a landmark Gram matrix K(X_i, X_i):
//! the Nyström factors U_i = K(X_i, X_p) K(X_p, X_p)^{-1}, the change-of-
//! basis W_p, the leaf blocks of the fast solver, the baselines' primal
//! systems. Supports jitter retry: kernel matrices are notoriously
//! ill-conditioned (Section 4.3), so on breakdown we add a small multiple
//! of the mean diagonal and retry, mirroring the paper's λ' stabilization.

use super::matrix::Mat;
use crate::error::{Error, Result};

/// Lower-triangular Cholesky factor L with A = L Lᵀ.
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Mat,
    /// Jitter that was added to the diagonal to make the factorization
    /// succeed (0.0 if none was needed).
    pub jitter: f64,
}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix. Fails if `a` is not
    /// (numerically) positive-definite.
    pub fn new(a: &Mat) -> Result<Cholesky> {
        Self::factor_with_jitter(a, 0.0).map(|l| Cholesky { l, jitter: 0.0 })
    }

    /// Factor with automatic jitter retry: if the plain factorization
    /// breaks down, retry with diag += jitter, growing 10x per attempt
    /// starting from `1e-12 * mean(diag)`, up to `max_tries` attempts.
    pub fn new_jittered(a: &Mat, max_tries: usize) -> Result<Cholesky> {
        match Self::factor_with_jitter(a, 0.0) {
            Ok(l) => return Ok(Cholesky { l, jitter: 0.0 }),
            Err(_) => {}
        }
        let n = a.rows();
        let mean_diag =
            (0..n).map(|i| a[(i, i)].abs()).sum::<f64>() / n.max(1) as f64;
        let mut jitter = (mean_diag * 1e-12).max(1e-300);
        for _ in 0..max_tries {
            if let Ok(l) = Self::factor_with_jitter(a, jitter) {
                return Ok(Cholesky { l, jitter });
            }
            jitter *= 10.0;
        }
        Err(Error::linalg(format!(
            "cholesky breakdown (n={n}), jitter up to {jitter:.1e} did not help"
        )))
    }

    fn factor_with_jitter(a: &Mat, jitter: f64) -> Result<Mat> {
        let n = a.rows();
        if a.cols() != n {
            return Err(Error::dim(format!("cholesky of {}x{}", a.rows(), a.cols())));
        }
        let mut l = Mat::zeros(n, n);
        for j in 0..n {
            // L[j][j]
            let mut d = a[(j, j)] + jitter;
            let lrow_j_owned: Vec<f64> = l.row(j)[..j].to_vec();
            for v in &lrow_j_owned {
                d -= v * v;
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(Error::linalg(format!(
                    "cholesky breakdown at pivot {j} (d={d:.3e})"
                )));
            }
            let djj = d.sqrt();
            l[(j, j)] = djj;
            // Column below the pivot.
            for i in (j + 1)..n {
                let s = super::matrix::dot(&l.row(i)[..j], &lrow_j_owned);
                l[(i, j)] = (a[(i, j)] - s) / djj;
            }
        }
        Ok(l)
    }

    /// The lower factor L.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Order of the factored matrix.
    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// Solve A x = b in place (b becomes x). Forward then back substitution.
    pub fn solve_in_place(&self, b: &mut [f64]) {
        let n = self.n();
        assert_eq!(b.len(), n);
        // L y = b
        for i in 0..n {
            let s = super::matrix::dot(&self.l.row(i)[..i], &b[..i]);
            b[i] = (b[i] - s) / self.l[(i, i)];
        }
        // Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = b[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * b[k];
            }
            b[i] = s / self.l[(i, i)];
        }
    }

    /// Solve A x = b, returning x.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// Solve A X = B for a block of right-hand sides (B is n x m).
    ///
    /// Row-wise substitution vectorized across the m RHS columns: every
    /// inner update is a contiguous row axpy, no transposes, no strided
    /// accesses (EXPERIMENTS.md §Perf iteration 5).
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let m = b.cols();
        let mut y = b.clone();
        let yd = y.as_mut_slice();
        // Forward: L Y = B.
        for i in 0..n {
            let (done, rest) = yd.split_at_mut(i * m);
            let yrow = &mut rest[..m];
            let lrow = &self.l.row(i)[..i];
            for (k, &lik) in lrow.iter().enumerate() {
                if lik != 0.0 {
                    let yk = &done[k * m..(k + 1) * m];
                    for (a, b) in yrow.iter_mut().zip(yk.iter()) {
                        *a -= lik * b;
                    }
                }
            }
            let inv = 1.0 / self.l[(i, i)];
            for a in yrow.iter_mut() {
                *a *= inv;
            }
        }
        // Backward: Lᵀ X = Y.
        for i in (0..n).rev() {
            let (head, tail) = yd.split_at_mut((i + 1) * m);
            let yrow = &mut head[i * m..];
            for k in (i + 1)..n {
                let lki = self.l[(k, i)];
                if lki != 0.0 {
                    let yk = &tail[(k - i - 1) * m..(k - i) * m];
                    for (a, b) in yrow.iter_mut().zip(yk.iter()) {
                        *a -= lki * b;
                    }
                }
            }
            let inv = 1.0 / self.l[(i, i)];
            for a in yrow.iter_mut() {
                *a *= inv;
            }
        }
        y
    }

    /// Solve Xᵀ A = Bᵀ i.e. return B A^{-1} for row-major B (m x n).
    /// Because A is symmetric this is (A^{-1} Bᵀ)ᵀ.
    pub fn solve_right(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.cols(), n);
        let mut out = b.clone();
        for r in 0..out.rows() {
            self.solve_in_place(out.row_mut(r));
        }
        out
    }

    /// Forward substitution only: solve L y = b (in place). Used to form
    /// Nyström features Z = K(X, L) L^{-T} etc.
    pub fn forward_solve_in_place(&self, b: &mut [f64]) {
        let n = self.n();
        assert_eq!(b.len(), n);
        for i in 0..n {
            let s = super::matrix::dot(&self.l.row(i)[..i], &b[..i]);
            b[i] = (b[i] - s) / self.l[(i, i)];
        }
    }

    /// Solve rows of B against Lᵀ from the right: return B L^{-T}.
    /// Each row b of B is replaced by the solution y of Lᵀ... specifically
    /// y such that y Lᵀ = b, i.e. L y = b with y as a row.
    pub fn forward_solve_rows(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.cols(), n);
        let mut out = b.clone();
        for r in 0..out.rows() {
            self.forward_solve_in_place(out.row_mut(r));
        }
        out
    }

    /// Explicit inverse A^{-1} (n x n). Only for small factors.
    pub fn inverse(&self) -> Mat {
        let n = self.n();
        let eye = Mat::eye(n);
        self.solve_mat(&eye)
    }

    /// log det(A) = 2 * sum log L[i][i].
    pub fn logdet(&self) -> f64 {
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{matmul, Trans};
    use crate::util::rng::Rng;

    /// A random SPD matrix A = G Gᵀ + n*I.
    fn spd(r: &mut Rng, n: usize) -> Mat {
        let g = Mat::from_fn(n, n, |_, _| r.normal());
        let mut a = matmul(&g, Trans::No, &g, Trans::Yes);
        a.add_diag(n as f64 * 0.1);
        a.symmetrize();
        a
    }

    #[test]
    fn factor_reconstructs() {
        let mut r = Rng::new(1);
        let a = spd(&mut r, 12);
        let ch = Cholesky::new(&a).unwrap();
        let rec = matmul(ch.l(), Trans::No, ch.l(), Trans::Yes);
        let mut diff = rec.clone();
        diff.axpy(-1.0, &a);
        assert!(diff.fro_norm() / a.fro_norm() < 1e-12);
        assert_eq!(ch.jitter, 0.0);
    }

    #[test]
    fn solve_matches_direct() {
        let mut r = Rng::new(2);
        let a = spd(&mut r, 9);
        let ch = Cholesky::new(&a).unwrap();
        let b: Vec<f64> = (0..9).map(|_| r.normal()).collect();
        let x = ch.solve(&b);
        // A x should equal b.
        let mut ax = vec![0.0; 9];
        crate::linalg::blas::gemv(1.0, &a, Trans::No, &x, 0.0, &mut ax);
        for i in 0..9 {
            assert!((ax[i] - b[i]).abs() < 1e-9, "{} vs {}", ax[i], b[i]);
        }
    }

    #[test]
    fn solve_mat_and_inverse() {
        let mut r = Rng::new(3);
        let a = spd(&mut r, 7);
        let ch = Cholesky::new(&a).unwrap();
        let inv = ch.inverse();
        let prod = matmul(&a, Trans::No, &inv, Trans::No);
        let mut diff = prod.clone();
        diff.axpy(-1.0, &Mat::eye(7));
        assert!(diff.fro_norm() < 1e-9);
    }

    #[test]
    fn solve_right_is_b_ainv() {
        let mut r = Rng::new(4);
        let a = spd(&mut r, 6);
        let b = Mat::from_fn(4, 6, |_, _| r.normal());
        let ch = Cholesky::new(&a).unwrap();
        let x = ch.solve_right(&b); // B A^{-1}
        let rec = matmul(&x, Trans::No, &a, Trans::No);
        let mut diff = rec.clone();
        diff.axpy(-1.0, &b);
        assert!(diff.fro_norm() / b.fro_norm() < 1e-10);
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn jitter_rescues_singular() {
        // Rank-1 PSD matrix: plain Cholesky fails, jittered succeeds.
        let v = Mat::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let a = matmul(&v, Trans::No, &v, Trans::Yes);
        assert!(Cholesky::new(&a).is_err());
        let ch = Cholesky::new_jittered(&a, 40).unwrap();
        assert!(ch.jitter > 0.0);
    }

    #[test]
    fn logdet_matches_known() {
        // diag(2, 3, 4): logdet = ln 24.
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 2.0;
        a[(1, 1)] = 3.0;
        a[(2, 2)] = 4.0;
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.logdet() - 24.0f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn forward_solve_rows_matches() {
        // Z = B L^{-T} should satisfy Z Lᵀ = B.
        let mut r = Rng::new(5);
        let a = spd(&mut r, 5);
        let ch = Cholesky::new(&a).unwrap();
        let b = Mat::from_fn(3, 5, |_, _| r.normal());
        let z = ch.forward_solve_rows(&b);
        let rec = matmul(&z, Trans::No, &ch.l().t(), Trans::No);
        let mut diff = rec.clone();
        diff.axpy(-1.0, &b);
        assert!(diff.fro_norm() / b.fro_norm() < 1e-10);
    }
}
