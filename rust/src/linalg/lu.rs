//! LU factorization with partial pivoting, for general (non-symmetric)
//! small systems — e.g. the `F_i = G_i^{-1} + Ŝ_i` capacitance matrices of
//! the fast solver when they are near-symmetric but not numerically SPD,
//! and the kernel-PCA alignment solve.

use super::matrix::Mat;
use crate::error::{Error, Result};

/// P A = L U factorization (packed in one matrix + pivot vector).
#[derive(Debug, Clone)]
pub struct Lu {
    lu: Mat,
    piv: Vec<usize>,
    /// Number of row swaps (for the determinant sign).
    swaps: usize,
}

impl Lu {
    /// Factor a square matrix. Fails on (numerical) singularity.
    pub fn new(a: &Mat) -> Result<Lu> {
        let n = a.rows();
        if a.cols() != n {
            return Err(Error::dim(format!("lu of {}x{}", a.rows(), a.cols())));
        }
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut swaps = 0;
        for k in 0..n {
            // Partial pivot.
            let mut pmax = k;
            let mut vmax = lu[(k, k)].abs();
            for i in (k + 1)..n {
                let v = lu[(i, k)].abs();
                if v > vmax {
                    vmax = v;
                    pmax = i;
                }
            }
            if vmax < 1e-300 || !vmax.is_finite() {
                return Err(Error::linalg(format!("lu: singular at pivot {k}")));
            }
            if pmax != k {
                piv.swap(k, pmax);
                swaps += 1;
                // swap rows in lu
                for j in 0..n {
                    let t = lu[(k, j)];
                    lu[(k, j)] = lu[(pmax, j)];
                    lu[(pmax, j)] = t;
                }
            }
            let pivot = lu[(k, k)];
            for i in (k + 1)..n {
                let m = lu[(i, k)] / pivot;
                lu[(i, k)] = m;
                if m != 0.0 {
                    for j in (k + 1)..n {
                        let v = lu[(k, j)];
                        lu[(i, j)] -= m * v;
                    }
                }
            }
        }
        Ok(Lu { lu, piv, swaps })
    }

    /// Order of the system.
    pub fn n(&self) -> usize {
        self.lu.rows()
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        // Apply permutation.
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        // Forward: L y = Pb (unit lower).
        for i in 1..n {
            let s = super::matrix::dot(&self.lu.row(i)[..i], &x[..i]);
            x[i] -= s;
        }
        // Back: U x = y.
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.lu[(i, k)] * x[k];
            }
            x[i] = s / self.lu[(i, i)];
        }
        x
    }

    /// Solve A X = B for a matrix of right-hand sides.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let mut out = Mat::zeros(n, b.cols());
        for j in 0..b.cols() {
            let col = self.solve(&b.col(j));
            out.set_col(j, &col);
        }
        out
    }

    /// Explicit inverse (small systems only).
    pub fn inverse(&self) -> Mat {
        self.solve_mat(&Mat::eye(self.n()))
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        let mut d = if self.swaps % 2 == 0 { 1.0 } else { -1.0 };
        for i in 0..self.n() {
            d *= self.lu[(i, i)];
        }
        d
    }

    /// log |det(A)| (stable against overflow).
    pub fn logabsdet(&self) -> f64 {
        (0..self.n()).map(|i| self.lu[(i, i)].abs().ln()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{gemv, matmul, Trans};
    use crate::util::rng::Rng;

    #[test]
    fn solve_random_system() {
        let mut r = Rng::new(1);
        let n = 11;
        let a = Mat::from_fn(n, n, |_, _| r.normal());
        let xstar: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mut b = vec![0.0; n];
        gemv(1.0, &a, Trans::No, &xstar, 0.0, &mut b);
        let lu = Lu::new(&a).unwrap();
        let x = lu.solve(&b);
        for i in 0..n {
            assert!((x[i] - xstar[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_roundtrip() {
        let mut r = Rng::new(2);
        let a = Mat::from_fn(6, 6, |_, _| r.normal());
        let inv = Lu::new(&a).unwrap().inverse();
        let prod = matmul(&a, Trans::No, &inv, Trans::No);
        let mut diff = prod;
        diff.axpy(-1.0, &Mat::eye(6));
        assert!(diff.fro_norm() < 1e-9);
    }

    #[test]
    fn det_of_known_matrix() {
        // [[2, 0], [1, 3]] -> det 6.
        let a = Mat::from_vec(2, 2, vec![2.0, 0.0, 1.0, 3.0]);
        let lu = Lu::new(&a).unwrap();
        assert!((lu.det() - 6.0).abs() < 1e-12);
        assert!((lu.logabsdet() - 6.0f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn permutation_sign() {
        // Swapping two rows of the identity gives det -1.
        let a = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let lu = Lu::new(&a).unwrap();
        assert!((lu.det() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_singular() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(Lu::new(&a).is_err());
    }

    #[test]
    fn needs_pivoting() {
        // Zero on the leading diagonal forces a pivot.
        let a = Mat::from_vec(2, 2, vec![0.0, 1.0, 2.0, 1.0]);
        let lu = Lu::new(&a).unwrap();
        let x = lu.solve(&[3.0, 5.0]);
        // 0*x0 + 1*x1 = 3; 2*x0 + x1 = 5 -> x1 = 3, x0 = 1.
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }
}
