//! Random Fourier features (Rahimi–Recht), eq. (7) of the paper.
//!
//! φ_i(x) = sqrt(2/r) cos(ω_iᵀ x + b_i), b ~ U(0, 2π) and ω sampled from
//! the kernel's normalized spectral density:
//! - Gaussian exp(−|δ|²/(2σ²)) → ω ~ N(0, σ^{-2} I);
//! - Laplace exp(−|δ|₁/σ) → ω_j ~ Cauchy(0, 1/σ) independently.
//!
//! The paper notes RFF applies only to stationary kernels with a known
//! spectral density — the inverse multiquadric has none tabulated, so
//! Figures 11–12 omit the Fourier column; we return an error likewise.

use crate::error::{Error, Result};
use crate::kernels::KernelKind;
use crate::linalg::{gemm, matmul, Mat, Trans};
use crate::util::rng::Rng;

/// Sampled random Fourier feature map.
pub struct FourierFeatures {
    /// Frequencies (r x d).
    pub omega: Mat,
    /// Phases (r).
    pub b: Vec<f64>,
}

impl FourierFeatures {
    /// Sample r frequencies for the given kernel.
    pub fn sample(kind: KernelKind, d: usize, r: usize, rng: &mut Rng) -> Result<FourierFeatures> {
        let r = r.max(1);
        let omega = match kind {
            KernelKind::Gaussian { sigma } => {
                Mat::from_fn(r, d, |_, _| rng.normal() / sigma)
            }
            KernelKind::Laplace { sigma } => {
                Mat::from_fn(r, d, |_, _| rng.cauchy() / sigma)
            }
            other => {
                return Err(Error::config(format!(
                    "random Fourier features need a stationary kernel with known \
                     spectral density; {:?} is not supported (cf. paper §5.4)",
                    other.family()
                )))
            }
        };
        let b: Vec<f64> = (0..r).map(|_| rng.uniform(0.0, 2.0 * std::f64::consts::PI)).collect();
        Ok(FourierFeatures { omega, b })
    }

    /// Feature dimension r.
    pub fn dim(&self) -> usize {
        self.omega.rows()
    }

    /// φ(Q): (q x r) matrix of sqrt(2/r) cos(Q ωᵀ + b).
    pub fn transform(&self, q: &Mat) -> Mat {
        let r = self.dim();
        let mut proj = Mat::zeros(q.rows(), r);
        gemm(1.0, q, Trans::No, &self.omega, Trans::Yes, 0.0, &mut proj);
        let scale = (2.0 / r as f64).sqrt();
        for i in 0..q.rows() {
            let row = proj.row_mut(i);
            for (v, &bb) in row.iter_mut().zip(self.b.iter()) {
                *v = scale * (*v + bb).cos();
            }
        }
        proj
    }
}

/// Ridge regression on random Fourier features.
pub struct FourierKrr {
    features: FourierFeatures,
    w: Mat,
}

impl FourierKrr {
    /// Fit on features `x` and targets `y` (n x m).
    pub fn fit(
        kind: KernelKind,
        x: &Mat,
        y: &Mat,
        r: usize,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<FourierKrr> {
        let features = FourierFeatures::sample(kind, x.cols(), r, rng)?;
        let phi = features.transform(x);
        let w = super::nystrom::primal_ridge(&phi, y, lambda)?;
        Ok(FourierKrr { features, w })
    }

    /// Predict for query rows.
    pub fn predict(&self, q: &Mat) -> Mat {
        matmul(&self.features.transform(q), Trans::No, &self.w, Trans::No)
    }

    /// Estimated memory in f64 words (r per training point, §5).
    pub fn memory_words(&self, n_train: usize) -> usize {
        n_train * self.features.dim()
    }

    /// Internal view for [`crate::model`] persistence: (ω, b, w).
    pub(crate) fn parts(&self) -> (&Mat, &[f64], &Mat) {
        (&self.features.omega, &self.features.b, &self.w)
    }

    /// Rebuild from persisted parts — the sampled frequencies and phases
    /// are stored verbatim, so the reloaded feature map is bit-identical.
    pub(crate) fn from_parts(omega: Mat, b: Vec<f64>, w: Mat) -> Result<FourierKrr> {
        if b.len() != omega.rows() || w.rows() != omega.rows() {
            return Err(Error::data("fourier artifact: inconsistent feature shapes"));
        }
        Ok(FourierKrr { features: FourierFeatures { omega, b }, w })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Gaussian, Imq, Laplace};
    use crate::linalg::matrix::sqdist;

    #[test]
    fn gaussian_kernel_approximated() {
        let mut rng = Rng::new(1);
        let kind = Gaussian::new(0.8);
        let d = 3;
        let feat = FourierFeatures::sample(kind, d, 4096, &mut rng).unwrap();
        let x = Mat::from_fn(8, d, |_, _| rng.uniform(0.0, 1.0));
        let phi = feat.transform(&x);
        let approx = matmul(&phi, Trans::No, &phi, Trans::Yes);
        for i in 0..8 {
            for j in 0..8 {
                let true_k = kind.eval(x.row(i), x.row(j));
                assert!(
                    (approx[(i, j)] - true_k).abs() < 0.08,
                    "({i},{j}): {} vs {}",
                    approx[(i, j)],
                    true_k
                );
            }
        }
    }

    #[test]
    fn laplace_kernel_approximated() {
        let mut rng = Rng::new(2);
        let kind = Laplace::new(1.2);
        let feat = FourierFeatures::sample(kind, 2, 8192, &mut rng).unwrap();
        let x = Mat::from_fn(6, 2, |_, _| rng.uniform(0.0, 1.0));
        let phi = feat.transform(&x);
        let approx = matmul(&phi, Trans::No, &phi, Trans::Yes);
        for i in 0..6 {
            for j in 0..6 {
                let true_k = kind.eval(x.row(i), x.row(j));
                assert!(
                    (approx[(i, j)] - true_k).abs() < 0.1,
                    "({i},{j}): {} vs {}",
                    approx[(i, j)],
                    true_k
                );
            }
        }
    }

    #[test]
    fn imq_rejected() {
        let mut rng = Rng::new(3);
        assert!(FourierFeatures::sample(Imq::new(1.0), 2, 16, &mut rng).is_err());
    }

    #[test]
    fn krr_learns_smooth_target() {
        let mut rng = Rng::new(4);
        let n = 400;
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(n, 1, |i, _| {
            (4.0 * x[(i, 0)]).sin() * (2.0 * x[(i, 1)]).cos()
        });
        let model = FourierKrr::fit(Gaussian::new(0.3), &x, &y, 300, 1e-4, &mut rng).unwrap();
        let pred = model.predict(&x);
        let mut diff = pred;
        diff.axpy(-1.0, &y);
        let rel = diff.fro_norm() / y.fro_norm();
        assert!(rel < 0.1, "train rel err {rel}");
    }

    #[test]
    fn shift_invariance_sanity() {
        // k(x, y) depends only on x − y: feature inner products for
        // shifted pairs should agree in expectation. Weak check at high r.
        let mut rng = Rng::new(5);
        let kind = Gaussian::new(1.0);
        let feat = FourierFeatures::sample(kind, 2, 4096, &mut rng).unwrap();
        let a = Mat::from_vec(2, 2, vec![0.1, 0.2, 0.4, 0.6]);
        let b = Mat::from_vec(2, 2, vec![0.5, 0.5, 0.8, 0.9]);
        assert!((sqdist(a.row(0), a.row(1)) - sqdist(b.row(0), b.row(1))).abs() < 1e-12);
        let pa = feat.transform(&a);
        let pb = feat.transform(&b);
        let ka = crate::linalg::matrix::dot(pa.row(0), pa.row(1));
        let kb = crate::linalg::matrix::dot(pb.row(0), pb.row(1));
        assert!((ka - kb).abs() < 0.1, "{ka} vs {kb}");
    }
}
