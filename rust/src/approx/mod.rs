//! The approximate kernels the paper compares against (Section 1.2 / 5):
//! Nyström, random Fourier features, the cross-domain independent kernel,
//! and the exact (non-approximate) dense kernel as reference.

pub mod exact;
pub mod fourier;
pub mod independent;
pub mod nystrom;

pub use exact::ExactKrr;
pub use fourier::{FourierFeatures, FourierKrr};
pub use independent::IndependentKrr;
pub use nystrom::{NystromFeatures, NystromKrr};
