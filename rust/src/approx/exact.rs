//! Exact (non-approximate) dense kernel ridge regression — eq. (2).
//!
//! O(n²) memory, O(n³) time: the reference the paper's Figure 7 compares
//! against (there computed on an EC2 cluster; here at reduced n).

use crate::error::Result;
use crate::kernels::{par_kernel_block, par_kernel_cross, KernelKind};
use crate::linalg::{par_matmul, Cholesky, Mat, Trans};

/// Fitted dense KRR.
pub struct ExactKrr {
    kind: KernelKind,
    x: Mat,
    /// Dual coefficients (n x m).
    alpha: Mat,
}

impl ExactKrr {
    /// Fit: α = (K + λI)^{-1} y. The n×n kernel block is evaluated
    /// across the worker pool (top of the fit chain).
    pub fn fit(kind: KernelKind, x: &Mat, y: &Mat, lambda: f64) -> Result<ExactKrr> {
        let mut k = par_kernel_block(kind, x);
        k.add_diag(lambda);
        let chol = Cholesky::new_jittered(&k, 30)?;
        Ok(ExactKrr { kind, x: x.clone(), alpha: chol.solve_mat(y) })
    }

    /// Predict: K(Q, X) α (pool-parallel kernel block + product).
    pub fn predict(&self, q: &Mat) -> Mat {
        par_matmul(&par_kernel_cross(self.kind, q, &self.x), Trans::No, &self.alpha, Trans::No)
    }

    /// Dual coefficients.
    pub fn alpha(&self) -> &Mat {
        &self.alpha
    }

    /// Internal view for [`crate::model`] persistence: (x, α).
    pub(crate) fn parts(&self) -> (&Mat, &Mat) {
        (&self.x, &self.alpha)
    }

    /// Rebuild from persisted parts (x and α stored verbatim —
    /// predictions are bit-identical).
    pub(crate) fn from_parts(kind: KernelKind, x: Mat, alpha: Mat) -> Result<ExactKrr> {
        if alpha.rows() != x.rows() {
            return Err(crate::error::Error::data(
                "exact artifact: coefficient rows do not match training size",
            ));
        }
        Ok(ExactKrr { kind, x, alpha })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Gaussian;
    use crate::util::rng::Rng;

    #[test]
    fn interpolates_at_tiny_lambda() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(20, 2, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(20, 1, |i, _| (x[(i, 0)] * 5.0).sin());
        let model = ExactKrr::fit(Gaussian::new(0.4), &x, &y, 1e-10).unwrap();
        let pred = model.predict(&x);
        let mut diff = pred;
        diff.axpy(-1.0, &y);
        assert!(diff.max_abs() < 1e-5, "{}", diff.max_abs());
    }

    #[test]
    fn regularization_shrinks_predictions() {
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(30, 2, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(30, 1, |_, _| rng.normal());
        let loose = ExactKrr::fit(Gaussian::new(0.4), &x, &y, 1e-8).unwrap();
        let tight = ExactKrr::fit(Gaussian::new(0.4), &x, &y, 100.0).unwrap();
        assert!(tight.predict(&x).fro_norm() < 0.1 * loose.predict(&x).fro_norm());
    }
}
