//! Cross-domain independent kernel (eq. 8): keep only the block-diagonal
//! of the kernel matrix over a flat partitioning of the domain.
//!
//! Training decouples into one exact KRR per leaf domain; prediction
//! routes the query to its domain and uses that leaf's coefficients (the
//! covariance to every other domain is zero). The partitioning reuses the
//! same tree machinery as the hierarchical kernel with the hierarchy
//! flattened — exactly the comparison setup of Section 5.1.

use crate::error::Result;
use crate::kernels::{kernel_block, kernel_cross, KernelKind};
use crate::linalg::{matmul, Cholesky, Mat, Trans};
use crate::partition::{PartitionTree, SplitRule};
use crate::util::rng::Rng;

/// Fitted independent-kernel KRR.
pub struct IndependentKrr {
    kind: KernelKind,
    tree: PartitionTree,
    /// Training features (original order).
    x: Mat,
    /// Per-leaf dual coefficients α = (K_leaf + λI)^{-1} y_leaf (n_leaf x m),
    /// indexed by node id.
    alpha: Vec<Option<Mat>>,
}

impl IndependentKrr {
    /// Fit with a fresh partitioning (leaf size n0, given split rule).
    pub fn fit(
        kind: KernelKind,
        x: &Mat,
        y: &Mat,
        n0: usize,
        rule: SplitRule,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<IndependentKrr> {
        let tree = PartitionTree::build(x, n0.max(1), rule, rng);
        Self::fit_on_tree(kind, x, y, tree, lambda)
    }

    /// Fit on an existing tree (its hierarchy is ignored; only leaves
    /// matter).
    pub fn fit_on_tree(
        kind: KernelKind,
        x: &Mat,
        y: &Mat,
        tree: PartitionTree,
        lambda: f64,
    ) -> Result<IndependentKrr> {
        let mut alpha: Vec<Option<Mat>> = (0..tree.nodes.len()).map(|_| None).collect();
        for &leaf in &tree.leaves() {
            let rows = tree.node_points(leaf);
            let xl = x.select_rows(rows);
            let yl = y.select_rows(rows);
            let mut k = kernel_block(kind, &xl);
            k.add_diag(lambda);
            let chol = Cholesky::new_jittered(&k, 30)?;
            alpha[leaf] = Some(chol.solve_mat(&yl));
        }
        Ok(IndependentKrr { kind, tree, x: x.clone(), alpha })
    }

    /// Predict for query rows: route each to its leaf, evaluate against
    /// that leaf's points only.
    pub fn predict(&self, q: &Mat) -> Mat {
        let m = self
            .alpha
            .iter()
            .flatten()
            .next()
            .map(|a| a.cols())
            .unwrap_or(1);
        let mut out = Mat::zeros(q.rows(), m);
        for i in 0..q.rows() {
            let leaf = self.tree.route_leaf(q.row(i));
            let rows = self.tree.node_points(leaf);
            let xl = self.x.select_rows(rows);
            let kq = kernel_cross(self.kind, &q.row_range(i, i + 1), &xl);
            let pred = matmul(&kq, Trans::No, self.alpha[leaf].as_ref().unwrap(), Trans::No);
            out.row_mut(i).copy_from_slice(pred.row(0));
        }
        out
    }

    /// Memory model of Section 5: r (= n0) words per training point.
    pub fn memory_words(&self) -> usize {
        self.tree
            .leaves()
            .iter()
            .map(|&l| {
                let n_l = self.tree.nodes[l].len();
                n_l * n_l
            })
            .sum()
    }

    /// The underlying partitioning tree.
    pub fn tree(&self) -> &PartitionTree {
        &self.tree
    }

    /// Internal view for [`crate::model`] persistence:
    /// (tree, x, per-node α).
    pub(crate) fn parts(&self) -> (&PartitionTree, &Mat, &[Option<Mat>]) {
        (&self.tree, &self.x, &self.alpha)
    }

    /// Rebuild from persisted parts (the per-leaf dual coefficients are
    /// stored verbatim, so predictions are bit-identical).
    pub(crate) fn from_parts(
        kind: KernelKind,
        tree: PartitionTree,
        x: Mat,
        alpha: Vec<Option<Mat>>,
    ) -> Result<IndependentKrr> {
        if alpha.len() != tree.nodes.len() || tree.perm.len() != x.rows() {
            return Err(crate::error::Error::data(
                "independent artifact: tree/coefficient shapes disagree",
            ));
        }
        for &leaf in &tree.leaves() {
            let Some(a) = &alpha[leaf] else {
                return Err(crate::error::Error::data(
                    "independent artifact: leaf without coefficients",
                ));
            };
            if a.rows() != tree.nodes[leaf].len() {
                return Err(crate::error::Error::data(
                    "independent artifact: coefficient rows do not match leaf size",
                ));
            }
        }
        Ok(IndependentKrr { kind, tree, x, alpha })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Gaussian;

    #[test]
    fn single_leaf_equals_exact_krr() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(25, 2, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(25, 1, |i, _| x[(i, 0)] * x[(i, 1)]);
        let kind = Gaussian::new(0.5);
        let model =
            IndependentKrr::fit(kind, &x, &y, 100, SplitRule::RandomProjection, 0.05, &mut rng)
                .unwrap();
        // Exact KRR.
        let mut k = kernel_block(kind, &x);
        k.add_diag(0.05);
        let alpha = Cholesky::new_jittered(&k, 5).unwrap().solve_mat(&y);
        let q = Mat::from_fn(6, 2, |_, _| rng.uniform(0.0, 1.0));
        let want = matmul(&kernel_cross(kind, &q, &x), Trans::No, &alpha, Trans::No);
        let got = model.predict(&q);
        let mut diff = got;
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-10);
    }

    #[test]
    fn prediction_uses_only_local_leaf() {
        // Two well-separated blobs: predicting inside blob A must be
        // unaffected by blob B's targets.
        let mut rng = Rng::new(2);
        let n = 40;
        let x = Mat::from_fn(n, 2, |i, _| {
            if i < 20 {
                rng.uniform(0.0, 0.2)
            } else {
                rng.uniform(0.8, 1.0)
            }
        });
        let kind = Gaussian::new(0.1);
        let y1 = Mat::from_fn(n, 1, |i, _| if i < 20 { 1.0 } else { 5.0 });
        let y2 = Mat::from_fn(n, 1, |i, _| if i < 20 { 1.0 } else { -77.0 });
        let m1 = IndependentKrr::fit(kind, &x, &y1, 20, SplitRule::KdTree, 0.01, &mut Rng::new(9))
            .unwrap();
        let m2 = IndependentKrr::fit(kind, &x, &y2, 20, SplitRule::KdTree, 0.01, &mut Rng::new(9))
            .unwrap();
        let q = Mat::from_vec(1, 2, vec![0.1, 0.1]);
        let p1 = m1.predict(&q)[(0, 0)];
        let p2 = m2.predict(&q)[(0, 0)];
        assert!((p1 - p2).abs() < 1e-9, "leakage across domains: {p1} vs {p2}");
    }

    #[test]
    fn fits_local_structure() {
        let mut rng = Rng::new(3);
        let n = 300;
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(n, 1, |i, _| (6.0 * x[(i, 0)]).sin());
        let model = IndependentKrr::fit(
            Gaussian::new(0.3),
            &x,
            &y,
            50,
            SplitRule::RandomProjection,
            1e-4,
            &mut rng,
        )
        .unwrap();
        let pred = model.predict(&x);
        let mut diff = pred;
        diff.axpy(-1.0, &y);
        assert!(diff.fro_norm() / y.fro_norm() < 0.1);
    }

    #[test]
    fn multi_output() {
        let mut rng = Rng::new(4);
        let x = Mat::from_fn(60, 2, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(60, 3, |i, c| x[(i, 0)] * (c as f64 + 1.0));
        let model = IndependentKrr::fit(
            Gaussian::new(0.4),
            &x,
            &y,
            15,
            SplitRule::RandomProjection,
            1e-3,
            &mut rng,
        )
        .unwrap();
        let pred = model.predict(&x);
        assert_eq!(pred.shape(), (60, 3));
    }
}
