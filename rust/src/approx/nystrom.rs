//! Nyström approximation (eq. 6) and its KRR.
//!
//! Landmarks X̲ are uniformly sampled training points; the explicit
//! feature map is φ(x) = L^{-1} k(X̲, x) with K(X̲, X̲) = L Lᵀ, so that
//! ⟨φ(x), φ(x′)⟩ = k_Nyström(x, x′). Ridge regression is solved in the
//! primal: w = (ΦᵀΦ + λ I_r)^{-1} Φᵀ y, at O(nr²).

use crate::error::Result;
use crate::kernels::{kernel_cross, par_kernel_cross, KernelKind};
use crate::linalg::{matmul, par_syrk, Cholesky, Mat, Trans};
use crate::util::rng::Rng;

/// The Nyström feature map.
pub struct NystromFeatures {
    kind: KernelKind,
    /// Landmark coordinates (r x d).
    pub landmarks: Mat,
    /// Cholesky of K(X̲, X̲) (+ tiny jitter if needed).
    chol: Cholesky,
}

impl NystromFeatures {
    /// Sample r landmarks from the rows of `x` and factor their Gram.
    pub fn fit(kind: KernelKind, x: &Mat, r: usize, rng: &mut Rng) -> Result<NystromFeatures> {
        let r = r.min(x.rows()).max(1);
        let idx = rng.sample_indices(x.rows(), r);
        let landmarks = x.select_rows(&idx);
        let mut kll = kernel_cross(kind, &landmarks, &landmarks);
        kll.symmetrize();
        let chol = Cholesky::new_jittered(&kll, 30)?;
        Ok(NystromFeatures { kind, landmarks, chol })
    }

    /// Feature dimension r.
    pub fn dim(&self) -> usize {
        self.landmarks.rows()
    }

    /// Rebuild from persisted landmark coordinates (the Gram Cholesky is
    /// recomputed deterministically, so the feature map is bit-identical
    /// to the saved one). Used by [`crate::model`] artifact loading.
    pub(crate) fn from_landmarks(kind: KernelKind, landmarks: Mat) -> Result<NystromFeatures> {
        let mut kll = kernel_cross(kind, &landmarks, &landmarks);
        kll.symmetrize();
        let chol = Cholesky::new_jittered(&kll, 30)?;
        Ok(NystromFeatures { kind, landmarks, chol })
    }

    /// φ(Q) for a block of points: rows are L^{-1} k(X̲, q), i.e. we solve
    /// Lᵀ-systems against rows of K(Q, X̲). The n×r kernel block — the
    /// dominant cost of the Nyström fit — is evaluated across the worker
    /// pool.
    pub fn transform(&self, q: &Mat) -> Mat {
        let kql = par_kernel_cross(self.kind, q, &self.landmarks);
        // Row y of output solves L y = k(X̲, q) → y = L^{-1} k.
        self.chol.forward_solve_rows(&kql)
    }
}

/// Kernel ridge regression with the Nyström kernel.
pub struct NystromKrr {
    features: NystromFeatures,
    /// Primal weights (r x m).
    w: Mat,
}

impl NystromKrr {
    /// Fit on features `x` and (possibly multi-column) targets `y`.
    pub fn fit(
        kind: KernelKind,
        x: &Mat,
        y: &Mat,
        r: usize,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<NystromKrr> {
        let features = NystromFeatures::fit(kind, x, r, rng)?;
        let w = primal_ridge(&features.transform(x), y, lambda)?;
        Ok(NystromKrr { features, w })
    }

    /// Predict for query rows.
    pub fn predict(&self, q: &Mat) -> Mat {
        matmul(&self.features.transform(q), Trans::No, &self.w, Trans::No)
    }

    /// Estimated memory footprint in f64 words (≈ n·r features — the
    /// paper's Section 5 memory model counts r words per training point).
    pub fn memory_words(&self, n_train: usize) -> usize {
        n_train * self.features.dim()
    }

    /// Internal view for [`crate::model`] persistence: (landmarks, w).
    pub(crate) fn parts(&self) -> (&Mat, &Mat) {
        (&self.features.landmarks, &self.w)
    }

    /// Rebuild from persisted parts (see [`NystromFeatures::from_landmarks`]).
    pub(crate) fn from_parts(kind: KernelKind, landmarks: Mat, w: Mat) -> Result<NystromKrr> {
        if w.rows() != landmarks.rows() {
            return Err(crate::error::Error::data(
                "nystrom artifact: weight rows do not match landmark count",
            ));
        }
        Ok(NystromKrr { features: NystromFeatures::from_landmarks(kind, landmarks)?, w })
    }
}

/// Solve the primal ridge system w = (ΦᵀΦ + λ n? I)^{-1} Φᵀ y.
///
/// We follow the paper's convention (eq. 1-2): regularizer λ‖f‖², which in
/// the primal equals λ‖w‖² — no n scaling.
pub fn primal_ridge(phi: &Mat, y: &Mat, lambda: f64) -> Result<Mat> {
    let r = phi.cols();
    let mut gram = Mat::zeros(r, r);
    // ΦᵀΦ as a blocked rank-k update: syrk computes the upper triangle
    // through the packed core and mirrors it, so the Gram matrix comes
    // back exactly symmetric — no symmetrize pass needed.
    par_syrk(1.0, phi, Trans::Yes, 0.0, &mut gram);
    gram.add_diag(lambda.max(1e-12));
    let rhs = matmul(phi, Trans::Yes, y, Trans::No);
    let chol = Cholesky::new_jittered(&gram, 30)?;
    Ok(chol.solve_mat(&rhs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Gaussian;

    fn toy(n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform(0.0, 1.0));
        let y = Mat::from_fn(n, 1, |i, _| (3.0 * x[(i, 0)]).sin() + x[(i, 1)]);
        (x, y)
    }

    #[test]
    fn full_rank_nystrom_equals_exact_krr() {
        // r = n with distinct landmarks == exact kernel ridge regression.
        let (x, y) = toy(30, 1);
        let kind = Gaussian::new(0.5);
        let lambda = 0.1;
        let mut rng = Rng::new(2);
        let model = NystromKrr::fit(kind, &x, &y, 30, lambda, &mut rng).unwrap();
        // Exact KRR.
        let mut k = crate::kernels::kernel_block(kind, &x);
        k.add_diag(lambda);
        let alpha = Cholesky::new_jittered(&k, 10).unwrap().solve_mat(&y);
        let q = Mat::from_fn(7, 2, |i, j| 0.1 * (i + j) as f64);
        let kq = kernel_cross(kind, &q, &x);
        let want = matmul(&kq, Trans::No, &alpha, Trans::No);
        let got = model.predict(&q);
        let mut diff = got.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-6, "{}", diff.max_abs());
    }

    #[test]
    fn transform_gram_is_nystrom_kernel() {
        let (x, _) = toy(20, 3);
        let kind = Gaussian::new(0.7);
        let mut rng = Rng::new(4);
        let feat = NystromFeatures::fit(kind, &x, 6, &mut rng).unwrap();
        let phi = feat.transform(&x);
        let gram = matmul(&phi, Trans::No, &phi, Trans::Yes);
        // Against direct k_nys = K_XL K_LL^{-1} K_LX.
        let kxl = kernel_cross(kind, &x, &feat.landmarks);
        let sol = feat.chol.solve_right(&kxl); // K_XL K_LL^{-1}
        let want = matmul(&sol, Trans::No, &kxl, Trans::Yes);
        let mut diff = gram.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-9);
    }

    #[test]
    fn fits_smooth_function() {
        let (x, y) = toy(300, 5);
        let mut rng = Rng::new(6);
        let model =
            NystromKrr::fit(Gaussian::new(0.4), &x, &y, 40, 1e-3, &mut rng).unwrap();
        let pred = model.predict(&x);
        let mut diff = pred.clone();
        diff.axpy(-1.0, &y);
        let rel = diff.fro_norm() / y.fro_norm();
        assert!(rel < 0.05, "train rel err {rel}");
    }

    #[test]
    fn r_capped_at_n() {
        let (x, y) = toy(5, 7);
        let mut rng = Rng::new(8);
        let model = NystromKrr::fit(Gaussian::new(0.5), &x, &y, 100, 0.1, &mut rng).unwrap();
        assert_eq!(model.features.dim(), 5);
    }
}
