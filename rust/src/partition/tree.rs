//! The partitioning tree T (paper Figure 1) and out-of-sample routing.

use super::kmeans::kmeans_lloyd;
use crate::linalg::lanczos::power_iteration;
use crate::linalg::matrix::{dot, sqdist, Mat};
use crate::util::rng::Rng;

/// How a nonleaf node splits its domain.
#[derive(Debug, Clone)]
pub enum Split {
    /// Project on `dir`; `<= threshold` goes to children[0], else [1].
    /// Used by random projection and PCA rules.
    Hyperplane { dir: Vec<f64>, threshold: f64 },
    /// Compare coordinate `axis` against `threshold` (k-d rule).
    Axis { axis: usize, threshold: f64 },
    /// Route to the nearest center (k-means rule); centers.rows() ==
    /// children.len().
    Centers { centers: Mat },
}

/// One node of the partitioning tree.
#[derive(Debug, Clone)]
pub struct Node {
    /// Parent id (None for the root).
    pub parent: Option<usize>,
    /// Child ids (empty for leaves). Never exactly one (paper §2.2).
    pub children: Vec<usize>,
    /// The node owns permuted positions [lo, hi).
    pub lo: usize,
    /// End of the owned range (exclusive).
    pub hi: usize,
    /// Split rule (None for leaves).
    pub split: Option<Split>,
    /// Depth (root = 0).
    pub depth: usize,
}

impl Node {
    /// Number of training points in this node's domain.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }
    /// Whether this node is a leaf.
    pub fn is_leaf(&self) -> bool {
        self.children.is_empty()
    }
}

/// Which split rule to use when building the tree (Section 4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitRule {
    /// Random unit direction, median split (the paper's recommendation).
    RandomProjection,
    /// Dominant principal axis via `iters` power iterations, median split.
    Pca { iters: usize },
    /// Widest-spread axis, median split.
    KdTree,
    /// k-means with the given arity.
    KMeans { k: usize, iters: usize },
}

/// A built partitioning tree over a training set.
///
/// Training points are re-indexed by `perm`: node i owns original points
/// `perm[node.lo..node.hi]`. Children of a node partition its range, so
/// every subtree is contiguous — which is what gives the kernel matrix its
/// block structure (paper Figure 2).
#[derive(Debug, Clone)]
pub struct PartitionTree {
    /// Nodes; index 0 is the root. Children always follow parents.
    pub nodes: Vec<Node>,
    /// perm[position] = original training index.
    pub perm: Vec<usize>,
    /// Leaf capacity used at build time.
    pub n0: usize,
}

impl PartitionTree {
    /// Build a tree over the rows of `x`, splitting any node with more
    /// than `n0` points. `n0 >= 1`.
    pub fn build(x: &Mat, n0: usize, rule: SplitRule, rng: &mut Rng) -> PartitionTree {
        assert!(n0 >= 1, "n0 must be >= 1");
        let n = x.rows();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut nodes = vec![Node {
            parent: None,
            children: vec![],
            lo: 0,
            hi: n,
            split: None,
            depth: 0,
        }];
        // Iterative expansion (stack of node ids to consider).
        let mut stack = vec![0usize];
        while let Some(id) = stack.pop() {
            let (lo, hi, depth) = {
                let nd = &nodes[id];
                (nd.lo, nd.hi, nd.depth)
            };
            let len = hi - lo;
            if len <= n0 || len < 2 {
                continue;
            }
            let split = make_split(x, &mut perm[lo..hi], rule, rng);
            let Some((split, child_offsets)) = split else {
                continue; // degenerate (all points identical): stay a leaf
            };
            let mut children = Vec::with_capacity(child_offsets.len() - 1);
            for w in child_offsets.windows(2) {
                let cid = nodes.len();
                children.push(cid);
                nodes.push(Node {
                    parent: Some(id),
                    children: vec![],
                    lo: lo + w[0],
                    hi: lo + w[1],
                    split: None,
                    depth: depth + 1,
                });
                stack.push(cid);
            }
            nodes[id].children = children;
            nodes[id].split = Some(split);
        }
        PartitionTree { nodes, perm, n0 }
    }

    /// Ids of all leaf nodes (ascending by range start).
    pub fn leaves(&self) -> Vec<usize> {
        let mut ls: Vec<usize> =
            (0..self.nodes.len()).filter(|&i| self.nodes[i].is_leaf()).collect();
        ls.sort_by_key(|&i| self.nodes[i].lo);
        ls
    }

    /// Ids of all nonleaf nodes.
    pub fn nonleaves(&self) -> Vec<usize> {
        (0..self.nodes.len()).filter(|&i| !self.nodes[i].is_leaf()).collect()
    }

    /// Route an out-of-sample point to its leaf, returning the node path
    /// root → leaf. O(depth · d).
    pub fn route(&self, x: &[f64]) -> Vec<usize> {
        let mut path = vec![0usize];
        let mut id = 0usize;
        while let Some(split) = &self.nodes[id].split {
            let next = follow_split(split, &self.nodes[id].children, x);
            path.push(next);
            id = next;
        }
        path
    }

    /// Leaf id containing an out-of-sample point.
    pub fn route_leaf(&self, x: &[f64]) -> usize {
        *self.route(x).last().unwrap()
    }

    /// Post-order traversal of node ids (children before parents).
    pub fn postorder(&self) -> Vec<usize> {
        let mut order = Vec::with_capacity(self.nodes.len());
        let mut stack = vec![(0usize, false)];
        while let Some((id, expanded)) = stack.pop() {
            if expanded || self.nodes[id].is_leaf() {
                order.push(id);
            } else {
                stack.push((id, true));
                for &c in self.nodes[id].children.iter().rev() {
                    stack.push((c, false));
                }
            }
        }
        order
    }

    /// Maximum depth over all nodes.
    pub fn depth(&self) -> usize {
        self.nodes.iter().map(|n| n.depth).max().unwrap_or(0)
    }

    /// Original training indices owned by node `id`.
    pub fn node_points(&self, id: usize) -> &[usize] {
        let nd = &self.nodes[id];
        &self.perm[nd.lo..nd.hi]
    }

    /// A "flattened" copy: root with the leaves of `self` as its direct
    /// children. This is the partitioning used by the cross-domain
    /// independent baseline (same leaf domains, no hierarchy) and realizes
    /// the paper's remark that k_compositional is k_hierarchical with a
    /// two-level tree.
    pub fn flatten(&self) -> PartitionTree {
        let leaves = self.leaves();
        if leaves.len() <= 1 {
            return self.clone();
        }
        let n = self.perm.len();
        let mut nodes = vec![Node {
            parent: None,
            children: vec![],
            lo: 0,
            hi: n,
            split: None,
            depth: 0,
        }];
        for &l in &leaves {
            let old = &self.nodes[l];
            let id = nodes.len();
            nodes.push(Node {
                parent: Some(0),
                children: vec![],
                lo: old.lo,
                hi: old.hi,
                split: None,
                depth: 1,
            });
            nodes[0].children.push(id);
        }
        // Routing for the flat tree: delegate to the original tree by
        // storing it as a Centers split over leaf centroids would change
        // assignments; instead we keep the original splits by storing the
        // full hierarchy walk. Simplest correct approach: reuse the deep
        // tree for routing via `FlatRouter` below. Here we encode the flat
        // tree's split as None and let callers route with the deep tree.
        PartitionTree { nodes, perm: self.perm.clone(), n0: self.n0 }
    }
}

/// Apply one split decision: which child of a node owns `x`. Shared by
/// the in-tree routing above and the shard router, which walks a prefix
/// of the tree (`crate::shard::ShardRouter`) or a detached subtree
/// (`crate::shard::Shard`) with the same semantics.
pub fn follow_split(split: &Split, children: &[usize], x: &[f64]) -> usize {
    match split {
        Split::Hyperplane { dir, threshold } => {
            if dot(x, dir) <= *threshold {
                children[0]
            } else {
                children[1]
            }
        }
        Split::Axis { axis, threshold } => {
            if x[*axis] <= *threshold {
                children[0]
            } else {
                children[1]
            }
        }
        Split::Centers { centers } => {
            let mut best = 0usize;
            let mut bestd = f64::INFINITY;
            for c in 0..centers.rows() {
                let d2 = sqdist(x, centers.row(c));
                if d2 < bestd {
                    bestd = d2;
                    best = c;
                }
            }
            children[best]
        }
    }
}

/// Compute a split for the points `perm_slice` (a view of the permutation
/// owned by one node): reorders the slice so children own contiguous
/// sub-ranges, and returns (split, offsets) where `offsets` are the child
/// boundaries relative to the slice start (first = 0, last = len).
/// Returns None when the node cannot be split (degenerate data).
fn make_split(
    x: &Mat,
    perm_slice: &mut [usize],
    rule: SplitRule,
    rng: &mut Rng,
) -> Option<(Split, Vec<usize>)> {
    let len = perm_slice.len();
    match rule {
        SplitRule::RandomProjection => {
            let dir = rng.unit_vector(x.cols());
            median_split(x, perm_slice, &dir).map(|thr| {
                (Split::Hyperplane { dir, threshold: thr }, vec![0, len / 2, len])
            })
        }
        SplitRule::Pca { iters } => {
            let dir = power_iteration(x, perm_slice, iters, rng);
            median_split(x, perm_slice, &dir).map(|thr| {
                (Split::Hyperplane { dir, threshold: thr }, vec![0, len / 2, len])
            })
        }
        SplitRule::KdTree => {
            // Widest-spread axis.
            let d = x.cols();
            let mut best_axis = 0;
            let mut best_span = -1.0;
            for j in 0..d {
                let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
                for &i in perm_slice.iter() {
                    let v = x[(i, j)];
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                if hi - lo > best_span {
                    best_span = hi - lo;
                    best_axis = j;
                }
            }
            if best_span <= 0.0 {
                return None;
            }
            let mut dir = vec![0.0; d];
            dir[best_axis] = 1.0;
            median_split(x, perm_slice, &dir).map(|thr| {
                (Split::Axis { axis: best_axis, threshold: thr }, vec![0, len / 2, len])
            })
        }
        SplitRule::KMeans { k, iters } => {
            let k = k.max(2).min(len);
            let res = kmeans_lloyd(x, perm_slice, k, iters, rng);
            // Group the slice by cluster, preserving stability.
            let mut grouped: Vec<usize> = Vec::with_capacity(len);
            let mut offsets = vec![0usize];
            for c in 0..k {
                for (j, &orig) in perm_slice.iter().enumerate() {
                    if res.assign[j] == c {
                        grouped.push(orig);
                    }
                }
                offsets.push(grouped.len());
            }
            // Drop empty children (k-means re-seeding should prevent this,
            // but the tree invariant "no single-child nodes" must hold).
            let mut clean_offsets = vec![0usize];
            let mut centers_rows: Vec<usize> = Vec::new();
            for c in 0..k {
                if offsets[c + 1] > offsets[c] {
                    clean_offsets.push(offsets[c + 1]);
                    centers_rows.push(c);
                }
            }
            if clean_offsets.len() < 3 {
                return None; // fewer than 2 non-empty children
            }
            perm_slice.copy_from_slice(&grouped);
            let centers = res.centers.select_rows(&centers_rows);
            Some((Split::Centers { centers }, clean_offsets))
        }
    }
}

/// Sort `perm_slice` by projection onto `dir` and return the threshold
/// between the two halves; None if all projections are equal.
fn median_split(x: &Mat, perm_slice: &mut [usize], dir: &[f64]) -> Option<f64> {
    let mut keyed: Vec<(f64, usize)> =
        perm_slice.iter().map(|&i| (dot(x.row(i), dir), i)).collect();
    keyed.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let len = keyed.len();
    let mid = len / 2;
    if keyed[0].0 == keyed[len - 1].0 {
        return None;
    }
    for (slot, (_, i)) in perm_slice.iter_mut().zip(keyed.iter()) {
        *slot = *i;
    }
    Some(0.5 * (keyed[mid - 1].0 + keyed[mid].0))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cloud(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, d, |_, _| rng.uniform(0.0, 1.0))
    }

    fn check_invariants(t: &PartitionTree, n: usize) {
        // perm is a permutation.
        let mut p = t.perm.clone();
        p.sort_unstable();
        assert_eq!(p, (0..n).collect::<Vec<_>>());
        // Children partition the parent's range; no single children.
        for (id, nd) in t.nodes.iter().enumerate() {
            if !nd.is_leaf() {
                assert!(nd.children.len() >= 2, "node {id} has 1 child");
                let mut pos = nd.lo;
                for &c in &nd.children {
                    assert_eq!(t.nodes[c].lo, pos);
                    assert!(t.nodes[c].hi > t.nodes[c].lo);
                    assert_eq!(t.nodes[c].parent, Some(id));
                    assert_eq!(t.nodes[c].depth, nd.depth + 1);
                    pos = t.nodes[c].hi;
                }
                assert_eq!(pos, nd.hi);
            } else {
                assert!(nd.len() >= 1);
            }
        }
    }

    #[test]
    fn builds_balanced_rp_tree() {
        let x = cloud(64, 5, 1);
        let mut rng = Rng::new(2);
        let t = PartitionTree::build(&x, 8, SplitRule::RandomProjection, &mut rng);
        check_invariants(&t, 64);
        let leaves = t.leaves();
        assert_eq!(leaves.len(), 8);
        for &l in &leaves {
            assert_eq!(t.nodes[l].len(), 8);
        }
        assert_eq!(t.depth(), 3);
    }

    #[test]
    fn n0_larger_than_n_gives_single_leaf() {
        let x = cloud(10, 3, 3);
        let mut rng = Rng::new(4);
        let t = PartitionTree::build(&x, 100, SplitRule::RandomProjection, &mut rng);
        assert_eq!(t.nodes.len(), 1);
        assert_eq!(t.leaves(), vec![0]);
    }

    #[test]
    fn routing_matches_training_assignment_hyperplane() {
        let x = cloud(64, 4, 5);
        let mut rng = Rng::new(6);
        for rule in [SplitRule::RandomProjection, SplitRule::Pca { iters: 8 }, SplitRule::KdTree] {
            let t = PartitionTree::build(&x, 8, rule, &mut rng);
            check_invariants(&t, 64);
            // Route each *training* point: must land in the leaf owning it
            // (up to ties at thresholds, which this data avoids w.h.p.).
            let mut agree = 0;
            for pos in 0..64 {
                let orig = t.perm[pos];
                let leaf = t.route_leaf(x.row(orig));
                let nd = &t.nodes[leaf];
                if (nd.lo..nd.hi).contains(&pos) {
                    agree += 1;
                }
            }
            assert!(agree >= 62, "rule {rule:?}: only {agree}/64 routed home");
        }
    }

    #[test]
    fn kmeans_tree_invariants_and_routing() {
        let x = cloud(90, 3, 7);
        let mut rng = Rng::new(8);
        let t = PartitionTree::build(&x, 12, SplitRule::KMeans { k: 3, iters: 15 }, &mut rng);
        check_invariants(&t, 90);
        // Routing a training point lands in its own leaf for the vast
        // majority (Voronoi boundaries can reassign a few).
        let mut agree = 0;
        for pos in 0..90 {
            let orig = t.perm[pos];
            let leaf = t.route_leaf(x.row(orig));
            let nd = &t.nodes[leaf];
            if (nd.lo..nd.hi).contains(&pos) {
                agree += 1;
            }
        }
        assert!(agree > 80, "only {agree}/90 routed home");
    }

    #[test]
    fn degenerate_identical_points_stay_leaf() {
        let x = Mat::zeros(16, 3);
        let mut rng = Rng::new(9);
        let t = PartitionTree::build(&x, 4, SplitRule::RandomProjection, &mut rng);
        assert_eq!(t.nodes.len(), 1, "identical points cannot be split");
        let t2 = PartitionTree::build(&x, 4, SplitRule::KdTree, &mut rng);
        assert_eq!(t2.nodes.len(), 1);
    }

    #[test]
    fn postorder_children_before_parents() {
        let x = cloud(32, 3, 10);
        let mut rng = Rng::new(11);
        let t = PartitionTree::build(&x, 4, SplitRule::RandomProjection, &mut rng);
        let order = t.postorder();
        assert_eq!(order.len(), t.nodes.len());
        let position: std::collections::HashMap<usize, usize> =
            order.iter().enumerate().map(|(p, &id)| (id, p)).collect();
        for (id, nd) in t.nodes.iter().enumerate() {
            for &c in &nd.children {
                assert!(position[&c] < position[&id]);
            }
        }
        assert_eq!(*order.last().unwrap(), 0);
    }

    #[test]
    fn flatten_keeps_leaf_ranges() {
        let x = cloud(64, 3, 12);
        let mut rng = Rng::new(13);
        let t = PartitionTree::build(&x, 8, SplitRule::RandomProjection, &mut rng);
        let f = t.flatten();
        assert_eq!(f.nodes[0].children.len(), t.leaves().len());
        assert_eq!(f.depth(), 1);
        assert_eq!(f.perm, t.perm);
        // Leaf ranges match.
        let t_ranges: Vec<(usize, usize)> =
            t.leaves().iter().map(|&l| (t.nodes[l].lo, t.nodes[l].hi)).collect();
        let f_ranges: Vec<(usize, usize)> =
            f.leaves().iter().map(|&l| (f.nodes[l].lo, f.nodes[l].hi)).collect();
        assert_eq!(t_ranges, f_ranges);
    }

    #[test]
    fn odd_sizes_split_floor_half() {
        let x = cloud(21, 2, 14);
        let mut rng = Rng::new(15);
        let t = PartitionTree::build(&x, 5, SplitRule::RandomProjection, &mut rng);
        check_invariants(&t, 21);
        for &l in &t.leaves() {
            assert!(t.nodes[l].len() <= 5 + 1); // ceil division remainder
        }
    }
}
