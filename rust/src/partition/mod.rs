//! Hierarchical partitioning of the data domain (paper Section 4.1).
//!
//! The partitioning tree T drives everything: the hierarchical kernel's
//! structure, the cross-domain independent baseline (a flattened T), and
//! out-of-sample routing. Four split rules are implemented:
//!
//! - **random projection** (recommended by the paper): project on a random
//!   unit direction, split at the median — O(nz(X)) per level;
//! - **PCA**: split along the dominant principal axis (power iteration),
//!   at the median so partitions stay balanced — the paper's Table 2
//!   measures its overhead;
//! - **k-d**: split the widest-spread axis at the median;
//! - **k-means** (with k-means++ seeding): Voronoi partitioning; the rule
//!   the paper recommends for general metric spaces (§6). May produce
//!   arity > 2.
//!
//! All median-split rules produce perfectly balanced binary trees, which
//! is what the size rule (eq. 22: n0 = ceil(n / 2^j), r = floor(n / 2^j))
//! assumes.

pub mod kmeans;
pub mod tree;

pub use kmeans::kmeans_lloyd;
pub use tree::{follow_split, Node, PartitionTree, Split, SplitRule};
