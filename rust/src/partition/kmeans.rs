//! Lloyd's k-means with k-means++ seeding, over a subset of rows.

use crate::linalg::matrix::{sqdist, Mat};
use crate::util::rng::Rng;

/// Result of a k-means run.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    /// k x d cluster centers.
    pub centers: Mat,
    /// Assignment of each input row (into 0..k).
    pub assign: Vec<usize>,
    /// Final within-cluster sum of squares.
    pub inertia: f64,
}

/// Run k-means on `x.select_rows(rows)`.
///
/// k-means++ seeding, `iters` Lloyd iterations (early exit on no
/// reassignment). Empty clusters are re-seeded from the farthest point of
/// the largest cluster, so the result always has k non-empty clusters when
/// `rows.len() >= k` and the points are not all identical.
pub fn kmeans_lloyd(
    x: &Mat,
    rows: &[usize],
    k: usize,
    iters: usize,
    rng: &mut Rng,
) -> KmeansResult {
    let n = rows.len();
    let d = x.cols();
    assert!(k >= 1 && n >= k, "kmeans: n={n} < k={k}");

    // --- k-means++ seeding ---
    let mut centers = Mat::zeros(k, d);
    let first = rows[rng.below(n)];
    centers.row_mut(0).copy_from_slice(x.row(first));
    let mut dist2: Vec<f64> = rows.iter().map(|&i| sqdist(x.row(i), centers.row(0))).collect();
    for c in 1..k {
        let total: f64 = dist2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.f64() * total;
            let mut idx = n - 1;
            for (j, &d2) in dist2.iter().enumerate() {
                if target < d2 {
                    idx = j;
                    break;
                }
                target -= d2;
            }
            idx
        };
        centers.row_mut(c).copy_from_slice(x.row(rows[pick]));
        for (j, &i) in rows.iter().enumerate() {
            dist2[j] = dist2[j].min(sqdist(x.row(i), centers.row(c)));
        }
    }

    // --- Lloyd iterations ---
    let mut assign = vec![0usize; n];
    let mut counts = vec![0usize; k];
    for _it in 0..iters.max(1) {
        let mut changed = 0usize;
        for (j, &i) in rows.iter().enumerate() {
            let xi = x.row(i);
            let mut best = 0usize;
            let mut bestd = f64::INFINITY;
            for c in 0..k {
                let d2 = sqdist(xi, centers.row(c));
                if d2 < bestd {
                    bestd = d2;
                    best = c;
                }
            }
            if assign[j] != best {
                changed += 1;
            }
            assign[j] = best;
        }
        // Recompute centers.
        counts.fill(0);
        let mut sums = Mat::zeros(k, d);
        for (j, &i) in rows.iter().enumerate() {
            let c = assign[j];
            counts[c] += 1;
            let srow = sums.row_mut(c);
            for (s, v) in srow.iter_mut().zip(x.row(i).iter()) {
                *s += v;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed from the farthest point of the largest cluster.
                let big = (0..k).max_by_key(|&cc| counts[cc]).unwrap();
                let far = rows
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| assign[*j] == big)
                    .max_by(|(_, &a), (_, &b)| {
                        sqdist(x.row(a), centers.row(big))
                            .partial_cmp(&sqdist(x.row(b), centers.row(big)))
                            .unwrap()
                    })
                    .map(|(j, _)| j);
                if let Some(j) = far {
                    centers.row_mut(c).copy_from_slice(x.row(rows[j]));
                    assign[j] = c;
                }
                continue;
            }
            let inv = 1.0 / counts[c] as f64;
            let srow = sums.row(c).to_vec();
            for (cc, s) in centers.row_mut(c).iter_mut().zip(srow.iter()) {
                *cc = s * inv;
            }
        }
        if changed == 0 {
            break;
        }
    }

    let inertia = rows
        .iter()
        .enumerate()
        .map(|(j, &i)| sqdist(x.row(i), centers.row(assign[j])))
        .sum();
    KmeansResult { centers, assign, inertia }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated blobs in 2D.
    fn blobs(rng: &mut Rng) -> Mat {
        let centers = [[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]];
        Mat::from_fn(90, 2, |i, j| centers[i / 30][j] + rng.normal() * 0.3)
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::new(1);
        let x = blobs(&mut rng);
        let rows: Vec<usize> = (0..90).collect();
        let res = kmeans_lloyd(&x, &rows, 3, 30, &mut rng);
        // Each blob maps to one cluster.
        for blob in 0..3 {
            let first = res.assign[blob * 30];
            for j in 0..30 {
                assert_eq!(res.assign[blob * 30 + j], first, "blob {blob} split");
            }
        }
        assert!(res.inertia < 90.0 * 0.5);
    }

    #[test]
    fn all_clusters_nonempty() {
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(50, 3, |_, _| rng.uniform(0.0, 1.0));
        let rows: Vec<usize> = (0..50).collect();
        let res = kmeans_lloyd(&x, &rows, 5, 20, &mut rng);
        let mut counts = vec![0usize; 5];
        for &a in &res.assign {
            counts[a] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn k_equals_n() {
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(4, 2, |i, j| (i * 2 + j) as f64);
        let rows: Vec<usize> = (0..4).collect();
        let res = kmeans_lloyd(&x, &rows, 4, 10, &mut rng);
        let mut a = res.assign.clone();
        a.sort_unstable();
        a.dedup();
        assert_eq!(a.len(), 4);
        assert!(res.inertia < 1e-12);
    }

    #[test]
    fn subset_rows_only() {
        let mut rng = Rng::new(4);
        let x = blobs(&mut rng);
        let rows: Vec<usize> = (0..30).collect(); // only the first blob
        let res = kmeans_lloyd(&x, &rows, 2, 20, &mut rng);
        assert_eq!(res.assign.len(), 30);
    }
}
