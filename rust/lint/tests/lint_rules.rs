//! Rule-by-rule fixture tests: every rule has a good fixture that stays
//! silent and a bad fixture that fires with an exact `file:line` and
//! rule id — the diagnostics contract CI (and humans chasing a lint
//! failure) depend on.

use hck_lint::{lint_paths, registry_names, Report, RULES};
use std::path::{Path, PathBuf};

fn fixtures(tree: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(tree)
}

fn lint(tree: &str) -> Report {
    lint_paths(&[fixtures(tree)]).expect("fixture tree scans")
}

/// `(suffix, line, rule)` triple of a finding, for order-insensitive
/// path matching (the reported path is root-joined and OS-dependent).
fn key(f: &hck_lint::Finding) -> (String, usize, &'static str) {
    (f.file.replace('\\', "/"), f.line, f.rule)
}

#[test]
fn good_tree_is_clean() {
    let report = lint("good");
    assert_eq!(report.files, 6, "good fixture tree grew or shrank");
    assert!(
        report.findings.is_empty(),
        "good tree must lint clean, got:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn bad_tree_fires_every_rule_with_exact_locations() {
    let report = lint("bad");
    assert_eq!(report.files, 7);
    let expected: &[(&str, usize, &str)] = &[
        ("coordinator/allow_bad.rs", 4, "bad-allow"),
        ("coordinator/allow_bad.rs", 5, "serving-no-panic"),
        ("coordinator/panic_bad.rs", 4, "serving-no-panic"),
        ("coordinator/panic_bad.rs", 8, "serving-no-panic"),
        ("coordinator/panic_bad.rs", 12, "serving-no-panic"),
        ("obs/registry.rs", 4, "span-registry"),
        ("ordering_bad.rs", 8, "ordering-comment"),
        ("safety_bad.rs", 4, "safety-comment"),
        ("spans_bad.rs", 5, "span-registry"),
        ("spawn_bad.rs", 4, "thread-spawn"),
    ];
    assert_eq!(
        report.findings.len(),
        expected.len(),
        "finding count drifted:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
    for (want, got) in expected.iter().zip(&report.findings) {
        let (file, line, rule) = key(got);
        assert!(
            file.ends_with(want.0),
            "expected a finding in {}, got {file}",
            want.0
        );
        assert_eq!((line, rule), (want.1, want.2), "at {file}");
    }
    // Every rule id in a finding is a documented rule.
    for f in &report.findings {
        assert!(RULES.iter().any(|(id, _)| *id == f.rule), "undocumented rule {}", f.rule);
    }
}

#[test]
fn allow_without_reason_is_flagged_and_does_not_suppress() {
    let report = lint("bad");
    let in_allow_bad: Vec<_> = report
        .findings
        .iter()
        .filter(|f| f.file.ends_with("allow_bad.rs"))
        .collect();
    // The reasonless directive earns its own finding AND the violation
    // it tried to cover still fires.
    assert_eq!(in_allow_bad.len(), 2);
    assert_eq!(in_allow_bad[0].rule, "bad-allow");
    assert!(in_allow_bad[0].message.contains("requires a reason"));
    assert_eq!(in_allow_bad[1].rule, "serving-no-panic");
}

#[test]
fn rogue_and_orphaned_spans_are_both_reported() {
    let report = lint("bad");
    let spans: Vec<_> =
        report.findings.iter().filter(|f| f.rule == "span-registry").collect();
    assert_eq!(spans.len(), 2);
    let unused = spans.iter().find(|f| f.file.ends_with("obs/registry.rs")).unwrap();
    assert!(
        unused.message.contains("fixture.unused"),
        "orphaned entry named: {}",
        unused.message
    );
    let rogue = spans.iter().find(|f| f.file.ends_with("spans_bad.rs")).unwrap();
    assert!(
        rogue.message.contains("fixture.rogue"),
        "rogue name named: {}",
        rogue.message
    );
}

#[test]
fn registry_names_reads_the_fixture_table() {
    let names = registry_names(&[fixtures("good")]).expect("good tree has a registry");
    assert_eq!(names, vec!["fixture.inner".to_string(), "fixture.outer".to_string()]);
}

/// The gate CI enforces: the real crate sources lint clean. Running it
/// as a unit test means `cargo test` catches violations even before the
/// dedicated CI step does.
#[test]
fn repo_sources_lint_clean() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let roots = [manifest.join("../src"), manifest.join("src")];
    let report = lint_paths(&roots).expect("repo sources scan");
    assert!(
        report.files > 60,
        "expected the full rust/src tree, scanned only {} files",
        report.files
    );
    assert!(
        report.findings.is_empty(),
        "rust/src + rust/lint/src must lint clean, got:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
