//! Fixture: serving path with an allowed spawn and a justified escape.

pub fn start() -> std::thread::JoinHandle<()> {
    std::thread::spawn(|| {})
}

pub fn assembled(v: Option<u32>) -> u32 {
    // hck-lint: allow(serving-no-panic): fixture — value materialized at
    // assembly time, before any request is accepted.
    v.unwrap()
}
