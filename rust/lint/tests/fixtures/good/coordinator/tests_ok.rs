//! Fixture: #[cfg(test)] code is exempt from the serving-path rules.

pub fn add(a: u32, b: u32) -> u32 {
    a + b
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(super::add(1, 2).checked_add(0).unwrap(), 3);
    }
}
