//! Fixture: atomics with ORDERING justifications pass.

use std::sync::atomic::{AtomicUsize, Ordering};

pub static COUNT: AtomicUsize = AtomicUsize::new(0);

pub fn bump() -> usize {
    // ORDERING: Relaxed — statistics counter, no cross-memory ordering.
    COUNT.fetch_add(1, Ordering::Relaxed)
}
