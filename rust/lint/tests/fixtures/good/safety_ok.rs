//! Fixture: unsafe with a SAFETY justification passes.

pub fn first(v: &[u8]) -> u8 {
    // SAFETY: fixture contract — callers pass a non-empty slice, so
    // index 0 is in bounds.
    unsafe { *v.get_unchecked(0) }
}
