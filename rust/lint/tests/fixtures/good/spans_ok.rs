//! Fixture: span call sites using registered names only.

pub fn traced() {
    let _outer = obs::span("fixture.outer");
    let _inner = obs::span("fixture.inner");
}
