//! Fixture registry: every entry has a call site in spans_ok.rs.

pub const SPANS: &[(&str, &str)] = &[
    ("fixture.inner", "fixture"),
    ("fixture.outer", "fixture"),
];
