//! Fixture: raw spawn outside the sanctioned modules.

pub fn go() -> std::thread::JoinHandle<()> {
    std::thread::spawn(|| {})
}
