//! Fixture: panic idioms on the serving path.

pub fn q(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn r(v: Option<u32>) -> u32 {
    v.expect("present")
}

pub fn s() -> u32 {
    panic!("boom")
}
