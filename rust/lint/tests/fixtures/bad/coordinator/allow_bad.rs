//! Fixture: reasonless escape suppresses nothing and is itself flagged.

pub fn q(v: Option<u32>) -> u32 {
    // hck-lint: allow(serving-no-panic)
    v.unwrap()
}
