//! Fixture: a rogue span name beside a registered one.

pub fn traced() {
    let _ok = obs::span("fixture.used");
    let _rogue = obs::span("fixture.rogue");
}
