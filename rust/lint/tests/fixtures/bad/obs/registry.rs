//! Fixture registry with an orphaned entry.

pub const SPANS: &[(&str, &str)] = &[
    ("fixture.unused", "fixture"),
    ("fixture.used", "fixture"),
];
