//! Fixture: unsafe without justification.

pub fn first(v: &[u8]) -> u8 {
    unsafe { *v.get_unchecked(0) }
}
