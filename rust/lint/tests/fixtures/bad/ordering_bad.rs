//! Fixture: bare atomic ordering.

use std::sync::atomic::{AtomicUsize, Ordering};

pub static COUNT: AtomicUsize = AtomicUsize::new(0);

pub fn bump() -> usize {
    COUNT.fetch_add(1, Ordering::Relaxed)
}
